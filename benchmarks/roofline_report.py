"""Roofline report: results/dryrun/*.json -> EXPERIMENTS.md tables.

Per (arch x shape x mesh): the three roofline terms (compute / memory /
collective, seconds per step on TPU v5e), the dominant term, MODEL_FLOPS =
6*N(active)*D, the useful-FLOPs ratio, and a one-line "what would move the
dominant term".  Also ranks cells to pick the hillclimb targets.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

from repro.analysis.roofline import V5E, roofline_from_stats

__all__ = ["load_cells", "make_table", "hillclimb_targets"]

HBM_PER_CHIP = 16e9  # v5e


def load_cells(result_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def terms_for(rec: dict):
    chips = rec["chips"]
    return roofline_from_stats(
        flops_per_device=rec["flops_global"] / chips,
        bytes_per_device=rec["bytes_global"] / chips,
        coll_bytes_per_device=rec["coll_bytes_per_device"],
        chips=chips,
        model_flops=rec.get("model_flops"),
    )


def _advice(rec: dict, t) -> str:
    dom = t.dominant
    if rec["kind"] == "solver":
        return "pack scalar reductions into the lam psum; fuse gather+project (done: fused_kernel)"
    if dom == "collective":
        if rec["arch"].startswith(("deepseek", "kimi")):
            return "group-local MoE dispatch (per-shard routing) removes global sort/scatter all-to-alls"
        return "overlap TP collectives with compute (latency-hiding) or widen per-device shard"
    if dom == "memory":
        if rec["kind"] == "decode":
            return "shrink cache reads: quantized KV (int8) or MLA-style latent cache"
        return "re-use gathered weights across microbatches; bf16 master copies"
    return "cut redundant FLOPs: causal-block-skipping attention halves the S^2 term"


def make_table(cells: list[dict], mesh: Optional[str] = None) -> str:
    lines = [
        "| cell | chips | compute (s) | memory (s) | collective (s) | dominant "
        "| MODEL_FLOPs | useful ratio | HBM/chip | fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in cells:
        if rec["status"] == "skip":
            if mesh is None or rec["cell"].endswith(mesh):
                lines.append(
                    f"| {rec['cell']} | — | — | — | — | skip | — | — | — | {rec['reason']} |"
                )
            continue
        if mesh is not None and rec["mesh"] != mesh:
            continue
        t = terms_for(rec)
        mem_gb = rec["memory"]["peak_estimate_bytes"] / 1e9
        ratio = t.useful_flops_ratio
        lines.append(
            f"| {rec['cell']} | {rec['chips']} | {t.compute_s:.3e} | {t.memory_s:.3e} "
            f"| {t.collective_s:.3e} | **{t.dominant}** | {rec.get('model_flops', 0):.2e} "
            f"| {ratio:.2f} | {mem_gb:.1f} GB | {_advice(rec, t)} |"
        )
    return "\n".join(lines)


def hillclimb_targets(cells: list[dict]) -> dict:
    """worst useful-FLOPs fraction, most collective-bound, paper-representative."""
    ok = [
        (r, terms_for(r)) for r in cells
        if r["status"] == "ok" and r["mesh"] == "single_pod" and r["kind"] != "solver"
    ]
    worst_frac = min(
        (x for x in ok if x[1].useful_flops_ratio), key=lambda x: x[1].useful_flops_ratio
    )
    coll_bound = max(ok, key=lambda x: x[1].collective_s / max(x[1].bound_s, 1e-30))
    solver = [r for r in cells if r["status"] == "ok" and r["kind"] == "solver" and r["mesh"] == "single_pod"]
    return {
        "worst_fraction": worst_frac[0]["cell"],
        "most_collective_bound": coll_bound[0]["cell"],
        "paper_representative": solver[0]["cell"] if solver else None,
    }


def run() -> None:
    from benchmarks.common import emit

    cells = load_cells()
    if not cells:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    ok = [c for c in cells if c["status"] == "ok"]
    emit("roofline/cells", 0.0, f"ok={len(ok)};skip={len(cells) - len(ok)}")
    tg = hillclimb_targets(cells)
    for k, v in tg.items():
        emit(f"roofline/target_{k}", 0.0, str(v))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("## Single pod (16x16 = 256 chips)\n\n")
        f.write(make_table(cells, "single_pod"))
        f.write("\n\n## Multi-pod (2x16x16 = 512 chips)\n\n")
        f.write(make_table(cells, "multi_pod"))
        f.write("\n")
    emit("roofline/report", 0.0, "results/roofline.md")
