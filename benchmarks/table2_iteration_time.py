"""Table 2 analog: average time per AGD iteration across problem sizes.

The paper compares Scala/Spark vs the PyTorch-GPU system at 25M-100M sources;
the CPU analog here sweeps source count and compares the multi-op eager
objective ("Scala-like" unfused role) against the jit'd solver iteration, plus
the per-iteration cost model at production scale from the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import cpu_instance, emit, time_fn
from repro.core import MatchingObjective
from repro.core.maximizer import _stage_scan


def run() -> None:
    for sources in (10_000, 50_000, 200_000):
        inst, packed, scaled = cpu_instance(sources)
        obj = MatchingObjective(scaled)
        lam0 = jnp.zeros((obj.dual_dim,), jnp.float32)

        # eager (dispatch-per-op) single iteration
        def eager_iter(lam):
            with jax.disable_jit():
                ev = obj.calculate(lam, jnp.float32(1.0))
                return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        # jit'd iteration (one fused XLA program; paper's per-iteration unit)
        @jax.jit
        def jit_iter(lam):
            ev = obj.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        t_eager = time_fn(eager_iter, lam0, warmup=1, iters=3)
        t_jit = time_fn(jit_iter, lam0)
        emit(
            f"table2/iter_s{sources}_eager", t_eager,
            f"sources={sources}",
        )
        emit(
            f"table2/iter_s{sources}_jit", t_jit,
            f"speedup_vs_eager={t_eager / max(t_jit, 1e-9):.1f}x",
        )
