"""Table 2 analog: average time per AGD iteration across problem sizes.

The paper compares Scala/Spark vs the PyTorch-GPU system at 25M-100M sources;
the CPU analog here sweeps source count and compares four oracle variants per
AGD iteration:

  eager         dispatch-per-op unfused oracle (the paper's "Scala-like" role)
  jit_legacy    the CURRENT (pre-this-PR) jit'd iteration: gradient half
                built from a [m, n, L] index broadcast + per-family vmap'd
                `.at[].add` scatters, plus separate c'x / ||x||^2 reduction
                passes — the baseline the fused oracle is measured against
  jit           the unfused jit'd iteration after the segment-sum rewrite of
                `_segment_sum_ax` (one flat family-offset segment_sum)
  fused_oracle  the one-pass fused dual oracle (`MatchingObjective(
                fused_oracle=True)`): x, A x and the objective scalars from a
                single slab pass

On this CPU host the fused oracle and the rewritten unfused jit iteration
lower to near-identical XLA programs (XLA fuses the reference's passes), so
their times tie to noise; the fused row's wall-clock win is against the
pre-PR iteration (~15-25x at 200k sources, where the legacy batched scatter
falls off a cliff), and its *slab-traffic* win (~2x analytic HBM bytes/iter)
is what the Mosaic kernel banks on TPU.

Alongside wall time each row reports the *analytic* per-iteration HBM slab
traffic the variant implies on the TPU target (the quantity §4.3 is about):
the unfused oracle reads every slab ~3x per iteration (primal pass, gradient
segment-sum pass, scalar reduction passes), the fused oracle exactly once
plus an O(grid*m*J) partial-histogram tree-sum.

`RESULTS` is consumed by benchmarks/run.py to persist BENCH_oracle.json —
the perf-trajectory record for this hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import cpu_instance, emit, time_fn
from repro.core import MatchingObjective

# sources -> row dict (times in us/iter + analytic bytes); see run.py
RESULTS: dict[int, dict] = {}


def _sweep_cfg():
    """Short continuation solve used for the sweep's quality-drift metric."""
    from repro.core import MaximizerConfig

    return MaximizerConfig(
        gammas=(0.1, 0.01),
        iters_per_stage=25 if common.QUICK else 75,
    )


def _legacy_segment_sum_ax(bucket, x, J):
    """The pre-PR gradient half: broadcast index tensor + vmap'd scatter-add."""
    contrib = bucket.coeff * (x * bucket.mask)[None]  # [m, n, L]
    m = bucket.coeff.shape[0]
    flat_idx = jnp.broadcast_to(bucket.idx[None], contrib.shape).reshape(m, -1)
    return jax.vmap(
        lambda data, seg: jnp.zeros((J,), data.dtype).at[seg].add(data)
    )(contrib.reshape(m, -1), flat_idx)


def _legacy_calculate(obj: MatchingObjective, lam, gamma):
    """The iteration this PR replaces (bit-equal math, legacy lowering)."""
    inst = obj.instance
    x_slabs = obj.primal_candidate(lam, gamma)
    ax = jnp.zeros((inst.num_families, inst.num_destinations), jnp.float32)
    for b, x in zip(inst.buckets, x_slabs):
        ax = ax + _legacy_segment_sum_ax(b, x, inst.num_destinations)
    ax = ax.reshape(-1)
    lin = sum(jnp.vdot(b.cost, x) for b, x in zip(inst.buckets, x_slabs))
    ridge = 0.5 * gamma * sum(jnp.vdot(x, x) for x in x_slabs)
    grad = ax - inst.rhs
    g = lin + ridge + jnp.vdot(lam, grad)
    return g, grad


def _slab_slots(inst) -> int:
    return sum(b.cost.size for b in inst.buckets)


def _analytic_bytes(inst, *, fused: bool, slab_dtype: str = "float32") -> int:
    """Per-iteration HBM slab bytes on the TPU target (see dryrun)."""
    from repro.kernels.ops import (
        oracle_hist_partial_bytes, oracle_slab_slot_bytes,
    )

    m, J = inst.num_families, inst.num_destinations
    slots = _slab_slots(inst)
    it = jnp.dtype(
        jnp.bfloat16 if slab_dtype == "bfloat16" else slab_dtype
    ).itemsize
    # shared primal pass at the storage width: idx(4) + coeff(m*it) +
    # cost(it) + mask(it) reads + the x write (storage width for float
    # slabs, fp32 for int8) — oracle_slab_slot_bytes, the shared model
    per_slot = oracle_slab_slot_bytes(m, slab_dtype)
    if not fused:
        # gradient half re-reads idx + coeff + x; scalar passes re-read
        # cost + x (x at the primal-out width, approximated as storage)
        per_slot += 4 + it * m + it + it + it
    total = per_slot * slots
    if fused:
        # partial histograms: one [m, J] fp32 write + read per grid step
        # (tree-sum) regardless of storage dtype; shared with launch.dryrun
        for b in inst.buckets:
            n, L = b.cost.shape
            total += oracle_hist_partial_bytes(n, L, m, J)
    return total


def _cost_analysis_bytes(compiled) -> float:
    """XLA-measured bytes accessed of one compiled iteration (0 if absent)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", 0.0))


def _dtype_sweep(sources: int, res_ref) -> dict:
    """Mixed-precision slab sweep at one problem size.

    Per storage dtype: the fused-oracle iteration wall time, the analytic
    TPU slab bytes (`oracle_slab_slot_bytes` model), the XLA-measured bytes
    accessed of the compiled iteration on THIS host, and the quality drift
    of a short continuation solve vs the fp32 reference (duals rel-L2 +
    normalized objective gap — the same gap definition as table4_quality).
    """
    from repro.core import Maximizer

    sweep: dict[str, dict] = {}
    for dt in common.SLAB_DTYPES:
        _, _, scaled_dt = cpu_instance(sources, dtype=dt)
        obj_dt = MatchingObjective(scaled_dt, fused_oracle=True)
        lam0 = jnp.zeros((obj_dt.dual_dim,), jnp.float32)

        @jax.jit
        def dt_iter(lam, _obj=obj_dt):
            ev = _obj.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        t_us = time_fn(dt_iter, lam0)
        measured = _cost_analysis_bytes(dt_iter.lower(lam0).compile())
        analytic = _analytic_bytes(scaled_dt, fused=True, slab_dtype=dt)
        res_dt = Maximizer(MatchingObjective(scaled_dt), _sweep_cfg()).solve()
        lam_ref = res_ref.lam
        drift = float(
            jnp.linalg.norm(res_dt.lam - lam_ref)
            / jnp.maximum(jnp.linalg.norm(lam_ref), 1e-12)
        )
        gap = abs(float(res_dt.g) - float(res_ref.g)) / (
            1.0 + abs(float(res_ref.g))
        )
        sweep[dt] = {
            "fused_iter_us": t_us,
            "hbm_bytes_per_iter_analytic": analytic,
            "bytes_accessed_measured": measured,
            "dual_rel_l2_vs_f32": drift,
            "objective_gap_vs_f32": gap,
        }
    base = sweep["float32"]
    for dt, row in sweep.items():
        row["traffic_reduction_vs_f32_analytic"] = base[
            "hbm_bytes_per_iter_analytic"
        ] / max(row["hbm_bytes_per_iter_analytic"], 1)
        row["traffic_reduction_vs_f32_measured"] = base[
            "bytes_accessed_measured"
        ] / max(row["bytes_accessed_measured"], 1.0)
        emit(
            f"table2/iter_s{sources}_slab_{dt}",
            row["fused_iter_us"],
            f"hbm_bytes~{row['hbm_bytes_per_iter_analytic']};"
            f"measured_bytes~{row['bytes_accessed_measured']:.0f};"
            f"traffic_reduction="
            f"{row['traffic_reduction_vs_f32_analytic']:.2f}x;"
            f"dual_drift={row['dual_rel_l2_vs_f32']:.2e}",
        )
    return sweep


def run() -> None:
    sizes = (10_000,) if common.QUICK else (10_000, 50_000, 200_000)
    for sources in sizes:
        inst, packed, scaled = cpu_instance(sources)
        obj = MatchingObjective(scaled)
        obj_fused = MatchingObjective(scaled, fused_oracle=True)
        lam0 = jnp.zeros((obj.dual_dim,), jnp.float32)

        # eager (dispatch-per-op) single iteration
        def eager_iter(lam):
            with jax.disable_jit():
                ev = obj.calculate(lam, jnp.float32(1.0))
                return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        # jit'd iteration (one fused XLA program; paper's per-iteration unit)
        @jax.jit
        def jit_iter(lam):
            ev = obj.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        # the pre-PR jit'd iteration (broadcast + vmap'd scatter gradient)
        @jax.jit
        def legacy_iter(lam):
            _, grad = _legacy_calculate(obj, lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * grad, 0.0)

        # one-pass fused dual oracle iteration
        @jax.jit
        def fused_iter(lam):
            ev = obj_fused.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        t_eager = time_fn(eager_iter, lam0, warmup=1, iters=3)
        t_legacy = time_fn(legacy_iter, lam0)
        t_jit = time_fn(jit_iter, lam0)
        t_fused = time_fn(fused_iter, lam0)
        bytes_unfused = _analytic_bytes(scaled, fused=False)
        bytes_fused = _analytic_bytes(scaled, fused=True)
        emit(f"table2/iter_s{sources}_eager", t_eager, f"sources={sources}")
        emit(
            f"table2/iter_s{sources}_jit_legacy", t_legacy,
            f"hbm_bytes~{bytes_unfused}",
        )
        emit(
            f"table2/iter_s{sources}_jit", t_jit,
            f"hbm_bytes~{bytes_unfused};"
            f"speedup_vs_eager={t_eager / max(t_jit, 1e-9):.1f}x",
        )
        emit(
            f"table2/iter_s{sources}_fused_oracle", t_fused,
            f"hbm_bytes~{bytes_fused};"
            f"speedup_vs_current={t_legacy / max(t_fused, 1e-9):.2f}x;"
            f"speedup_vs_rewritten={t_jit / max(t_fused, 1e-9):.2f}x;"
            f"traffic_reduction={bytes_unfused / max(bytes_fused, 1):.2f}x",
        )
        from repro.core import Maximizer

        res_ref = Maximizer(MatchingObjective(scaled), _sweep_cfg()).solve()
        sweep = _dtype_sweep(sources, res_ref)
        RESULTS[sources] = {
            "eager_us": t_eager,
            "jit_legacy_us": t_legacy,
            "jit_us": t_jit,
            "fused_oracle_us": t_fused,
            # 'current' = the pre-PR jit'd iteration (jit_legacy row)
            "fused_speedup_vs_current": t_legacy / max(t_fused, 1e-9),
            "fused_faster_than_current": bool(t_fused < t_legacy),
            "fused_speedup_vs_rewritten_unfused": t_jit / max(t_fused, 1e-9),
            "hbm_bytes_per_iter_unfused": bytes_unfused,
            "hbm_bytes_per_iter_fused": bytes_fused,
            "hbm_traffic_reduction": bytes_unfused / max(bytes_fused, 1),
            # mixed-precision slab storage sweep (fused oracle, per dtype):
            # wall time, analytic + XLA-measured bytes, quality drift vs fp32
            "slab_dtype_sweep": sweep,
        }
