"""Table 2 analog: average time per AGD iteration across problem sizes.

The paper compares Scala/Spark vs the PyTorch-GPU system at 25M-100M sources;
the CPU analog here sweeps source count and compares four oracle variants per
AGD iteration:

  eager         dispatch-per-op unfused oracle (the paper's "Scala-like" role)
  jit_legacy    the CURRENT (pre-this-PR) jit'd iteration: gradient half
                built from a [m, n, L] index broadcast + per-family vmap'd
                `.at[].add` scatters, plus separate c'x / ||x||^2 reduction
                passes — the baseline the fused oracle is measured against
  jit           the unfused jit'd iteration after the segment-sum rewrite of
                `_segment_sum_ax` (one flat family-offset segment_sum)
  fused_oracle  the one-pass fused dual oracle (`MatchingObjective(
                fused_oracle=True)`): x, A x and the objective scalars from a
                single slab pass

On this CPU host the fused oracle and the rewritten unfused jit iteration
lower to near-identical XLA programs (XLA fuses the reference's passes), so
their times tie to noise; the fused row's wall-clock win is against the
pre-PR iteration (~15-25x at 200k sources, where the legacy batched scatter
falls off a cliff), and its *slab-traffic* win (~2x analytic HBM bytes/iter)
is what the Mosaic kernel banks on TPU.

Alongside wall time each row reports the *analytic* per-iteration HBM slab
traffic the variant implies on the TPU target (the quantity §4.3 is about):
the unfused oracle reads every slab ~3x per iteration (primal pass, gradient
segment-sum pass, scalar reduction passes), the fused oracle exactly once
plus an O(grid*m*J) partial-histogram tree-sum.

`RESULTS` is consumed by benchmarks/run.py to persist BENCH_oracle.json —
the perf-trajectory record for this hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import cpu_instance, emit, time_fn
from repro.core import MatchingObjective

# sources -> row dict (times in us/iter + analytic bytes); see run.py
RESULTS: dict[int, dict] = {}


def _legacy_segment_sum_ax(bucket, x, J):
    """The pre-PR gradient half: broadcast index tensor + vmap'd scatter-add."""
    contrib = bucket.coeff * (x * bucket.mask)[None]  # [m, n, L]
    m = bucket.coeff.shape[0]
    flat_idx = jnp.broadcast_to(bucket.idx[None], contrib.shape).reshape(m, -1)
    return jax.vmap(
        lambda data, seg: jnp.zeros((J,), data.dtype).at[seg].add(data)
    )(contrib.reshape(m, -1), flat_idx)


def _legacy_calculate(obj: MatchingObjective, lam, gamma):
    """The iteration this PR replaces (bit-equal math, legacy lowering)."""
    inst = obj.instance
    x_slabs = obj.primal_candidate(lam, gamma)
    ax = jnp.zeros((inst.num_families, inst.num_destinations), jnp.float32)
    for b, x in zip(inst.buckets, x_slabs):
        ax = ax + _legacy_segment_sum_ax(b, x, inst.num_destinations)
    ax = ax.reshape(-1)
    lin = sum(jnp.vdot(b.cost, x) for b, x in zip(inst.buckets, x_slabs))
    ridge = 0.5 * gamma * sum(jnp.vdot(x, x) for x in x_slabs)
    grad = ax - inst.rhs
    g = lin + ridge + jnp.vdot(lam, grad)
    return g, grad


def _slab_slots(inst) -> int:
    return sum(b.cost.size for b in inst.buckets)


def _analytic_bytes(inst, *, fused: bool) -> int:
    """Per-iteration HBM slab bytes on the TPU target (fp32, see dryrun)."""
    m, J = inst.num_families, inst.num_destinations
    slots = _slab_slots(inst)
    # shared primal pass: idx(4) + coeff(4m) + cost(4) + mask(4) reads + x(4) write
    per_slot = 4 + 4 * m + 4 + 4 + 4
    if not fused:
        # gradient half re-reads idx + coeff + x; scalar passes re-read cost + x
        per_slot += 4 + 4 * m + 4 + 4 + 4
    total = per_slot * slots
    if fused:
        # partial histograms: one [m, J] write + read per grid step
        # (tree-sum); shared model with launch.dryrun
        from repro.kernels.ops import oracle_hist_partial_bytes

        for b in inst.buckets:
            n, L = b.cost.shape
            total += oracle_hist_partial_bytes(n, L, m, J)
    return total


def run() -> None:
    sizes = (10_000,) if common.QUICK else (10_000, 50_000, 200_000)
    for sources in sizes:
        inst, packed, scaled = cpu_instance(sources)
        obj = MatchingObjective(scaled)
        obj_fused = MatchingObjective(scaled, fused_oracle=True)
        lam0 = jnp.zeros((obj.dual_dim,), jnp.float32)

        # eager (dispatch-per-op) single iteration
        def eager_iter(lam):
            with jax.disable_jit():
                ev = obj.calculate(lam, jnp.float32(1.0))
                return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        # jit'd iteration (one fused XLA program; paper's per-iteration unit)
        @jax.jit
        def jit_iter(lam):
            ev = obj.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        # the pre-PR jit'd iteration (broadcast + vmap'd scatter gradient)
        @jax.jit
        def legacy_iter(lam):
            _, grad = _legacy_calculate(obj, lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * grad, 0.0)

        # one-pass fused dual oracle iteration
        @jax.jit
        def fused_iter(lam):
            ev = obj_fused.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        t_eager = time_fn(eager_iter, lam0, warmup=1, iters=3)
        t_legacy = time_fn(legacy_iter, lam0)
        t_jit = time_fn(jit_iter, lam0)
        t_fused = time_fn(fused_iter, lam0)
        bytes_unfused = _analytic_bytes(scaled, fused=False)
        bytes_fused = _analytic_bytes(scaled, fused=True)
        emit(f"table2/iter_s{sources}_eager", t_eager, f"sources={sources}")
        emit(
            f"table2/iter_s{sources}_jit_legacy", t_legacy,
            f"hbm_bytes~{bytes_unfused}",
        )
        emit(
            f"table2/iter_s{sources}_jit", t_jit,
            f"hbm_bytes~{bytes_unfused};"
            f"speedup_vs_eager={t_eager / max(t_jit, 1e-9):.1f}x",
        )
        emit(
            f"table2/iter_s{sources}_fused_oracle", t_fused,
            f"hbm_bytes~{bytes_fused};"
            f"speedup_vs_current={t_legacy / max(t_fused, 1e-9):.2f}x;"
            f"speedup_vs_rewritten={t_jit / max(t_fused, 1e-9):.2f}x;"
            f"traffic_reduction={bytes_unfused / max(bytes_fused, 1):.2f}x",
        )
        RESULTS[sources] = {
            "eager_us": t_eager,
            "jit_legacy_us": t_legacy,
            "jit_us": t_jit,
            "fused_oracle_us": t_fused,
            # 'current' = the pre-PR jit'd iteration (jit_legacy row)
            "fused_speedup_vs_current": t_legacy / max(t_fused, 1e-9),
            "fused_faster_than_current": bool(t_fused < t_legacy),
            "fused_speedup_vs_rewritten_unfused": t_jit / max(t_fused, 1e-9),
            "hbm_bytes_per_iter_unfused": bytes_unfused,
            "hbm_bytes_per_iter_fused": bytes_fused,
            "hbm_traffic_reduction": bytes_unfused / max(bytes_fused, 1),
        }
