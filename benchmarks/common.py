"""Shared benchmark utilities: timing, CSV emission, standard instances."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.core import MatchingObjective, MaximizerConfig, normalize_rows
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)

ROWS: list[tuple] = []

# CI smoke mode (benchmarks/run.py --quick): suites shrink their sweeps so
# the whole harness finishes in a couple of minutes on a shared runner.
QUICK = False

# Slab storage dtypes table2's mixed-precision sweep measures (run.py
# --slab-dtypes).  float32 is always the baseline row of the sweep.
SLAB_DTYPES: tuple[str, ...] = ("float32", "bfloat16", "int8")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def cpu_instance(sources: int, *, destinations: int = 1000, avg_degree: float = 8.0,
                 families: int = 1, seed: int = 0, shard_multiple: int = 1,
                 dtype: str = "float32"):
    """CPU-scaled matching instance (paper uses 25M-100M; we sweep 10k-1M)."""
    spec = MatchingInstanceSpec(
        num_sources=sources,
        num_destinations=destinations,
        avg_degree=avg_degree,
        num_families=families,
        seed=seed,
    )
    inst = generate_matching_instance(spec)
    packed = bucketize(inst, shard_multiple=shard_multiple, dtype=dtype)
    scaled, d = normalize_rows(packed)
    return inst, packed, scaled
