"""Figure 4: effect of Jacobi preconditioning on dual convergence.

Reports log10 |L - L_hat| after fixed iteration budgets with and without row
normalization, on a heterogeneous-scale instance (scale_sigma=1.5 makes row
norms differ by orders of magnitude, the regime the paper targets).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    normalize_rows,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)


def run() -> None:
    spec = MatchingInstanceSpec(
        num_sources=50_000, num_destinations=1000, avg_degree=8.0,
        scale_sigma=1.5, seed=0,
    )
    packed = bucketize(generate_matching_instance(spec))
    scaled, _ = normalize_rows(packed)
    gamma = (0.1,)
    # converged reference on the preconditioned system
    ref = Maximizer(
        MatchingObjective(scaled), MaximizerConfig(gammas=gamma, iters_per_stage=2000)
    ).solve()
    L_hat = float(ref.g)
    for name, inst_ in (("jacobi", scaled), ("raw", packed)):
        res = Maximizer(
            MatchingObjective(inst_), MaximizerConfig(gammas=gamma, iters_per_stage=400)
        ).solve()
        # evaluate the raw run's dual in the preconditioned frame for an
        # apples-to-apples objective: g is invariant to row scaling of (A, b)
        # at the corresponding rescaled duals, so compare primal objectives.
        g = float(
            MatchingObjective(scaled).calculate(
                res.lam if name == "jacobi" else _rescale(res.lam, packed, scaled),
                0.1,
            ).g
        )
        err = abs(g - L_hat) / (1 + abs(L_hat))
        tr = np.asarray(res.stats[0].g)
        emit(
            f"fig4/{name}", 0.0,
            f"log10_err={np.log10(max(err, 1e-16)):.2f};"
            f"g100={tr[min(99, len(tr)-1)]:.4f};g400={tr[-1]:.4f}",
        )


def _rescale(lam, raw, scaled):
    import numpy as np

    n_raw = np.sqrt(raw.row_norms_sq())
    d = np.where(n_raw > 1e-30, 1.0 / n_raw, 1.0)
    # lam_original = D lam_scaled  =>  lam_scaled_frame = lam_raw / d
    return lam / np.asarray(d, lam.dtype)
