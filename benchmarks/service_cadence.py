"""Recurring-solve service benchmarks: delta ingest, warm starts, batching.

Five measurements the serving layer is built around:

  * ``ingest``  — O(delta) in-place slab surgery vs O(nnz) re-bucketize;
  * ``scatter`` — device-resident scatter-plan replay vs full slab re-upload:
                  per-cadence host→device BYTES must scale with |delta|
                  (plan size), not nnz (slab size);
  * ``warm``    — warm-started shortened-schedule solve vs cold full budget
                  (wall time and iterations actually executed);
  * ``pool``    — one vmapped batched solve of B shape-identical tenants vs
                  B sequential solves;
  * ``pipeline``— double-buffered multi-cadence run (host ingest of cadence
                  t+1 overlapped with the device solve of cadence t) vs the
                  same cadences run synchronously.

Rows: ``service_<what>,us_per_call,derived``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import MaximizerConfig
from repro.instances import (
    DeltaIngestor,
    InstanceDelta,
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.service import (
    BatchedSolvePool,
    Scheduler,
    ServiceConfig,
    apply_scatter_plan,
    compiled_solver,
    device_put_instance,
    instance_nbytes,
    to_solve_result,
)


def _delta(edge_list, rng, frac=0.02):
    n_upd = max(1, int(frac * edge_list.nnz))
    upd = rng.permutation(edge_list.nnz)[:n_upd]
    return InstanceDelta(
        update_src=edge_list.src[upd],
        update_dst=edge_list.dst[upd],
        update_values=edge_list.values[upd] * rng.uniform(0.9, 1.1, n_upd),
    )


def run() -> None:
    rng = np.random.default_rng(0)
    spec = MatchingInstanceSpec(
        num_sources=20_000, num_destinations=200, avg_degree=8.0, seed=0
    )
    inst = generate_matching_instance(spec)
    ing = DeltaIngestor(inst, row_headroom=8)
    delta = _delta(inst, rng)

    # -- ingest: O(delta) in place vs O(nnz) re-bucketize --------------------
    t0 = time.perf_counter()
    ing.apply(delta)
    dt_ingest = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    bucketize(inst)
    dt_repack = (time.perf_counter() - t0) * 1e6
    emit("service_ingest_in_place", dt_ingest, f"edits={delta.num_edits}")
    emit(
        "service_ingest_rebucketize", dt_repack,
        f"nnz={inst.nnz};speedup={dt_repack / max(dt_ingest, 1e-9):.1f}x",
    )

    # -- device-resident scatter: host→device bytes scale with |delta| -------
    dev = device_put_instance(ing.instance())
    full_bytes = instance_nbytes(dev)
    for frac in (0.001, 0.01, 0.05):
        d = _delta(inst, rng, frac)
        plan = ing.apply(d).plan
        assert plan is not None  # updates never overflow headroom
        t_scatter = time_fn(lambda: apply_scatter_plan(dev, plan), iters=5)
        emit(
            f"service_device_scatter_f{frac:g}", t_scatter,
            f"edits={d.num_edits};plan_bytes={plan.nbytes};"
            f"full_bytes={full_bytes};"
            f"byte_save={full_bytes / max(plan.nbytes, 1):.0f}x",
        )
    t_full = time_fn(lambda: device_put_instance(ing.instance()), iters=5)
    emit("service_device_full_upload", t_full, f"bytes={full_bytes}")

    # -- warm vs cold solve ---------------------------------------------------
    small = MatchingInstanceSpec(
        num_sources=2_000, num_destinations=50, avg_degree=6.0, seed=1
    )
    sinst = generate_matching_instance(small)
    sing = DeltaIngestor(sinst, row_headroom=8)
    cold_cfg = MaximizerConfig(
        iters_per_stage=150, tol_grad=1e-4, tol_viol=1e-3
    )
    warm_cfg = MaximizerConfig(
        gammas=(0.1, 0.01), iters_per_stage=150,
        tol_grad=1e-4, tol_viol=1e-3,
    )
    z = np.zeros(sing.instance().dual_dim, np.float32)
    cold_fn = compiled_solver(cold_cfg, True)
    warm_fn = compiled_solver(warm_cfg, True)
    cold = to_solve_result(cold_fn(sing.instance(), z))
    sing.apply(_delta(sinst, rng))
    t_cold = time_fn(lambda: cold_fn(sing.instance(), z), iters=5)
    t_warm = time_fn(lambda: warm_fn(sing.instance(), cold.lam), iters=5)
    warm = to_solve_result(warm_fn(sing.instance(), cold.lam))
    cold2 = to_solve_result(cold_fn(sing.instance(), z))
    emit(
        "service_cold_solve", t_cold,
        f"iters={cold2.total_iters_used}",
    )
    emit(
        "service_warm_solve", t_warm,
        f"iters={warm.total_iters_used};"
        f"iter_save={cold2.total_iters_used / max(warm.total_iters_used, 1):.1f}x;"
        f"speedup={t_cold / max(t_warm, 1e-9):.1f}x",
    )

    # -- batched pool vs sequential -------------------------------------------
    B = 8
    tenants = []
    for b in range(B):
        ti = DeltaIngestor(sinst, row_headroom=8)
        ti.apply(_delta(sinst, np.random.default_rng(100 + b), frac=0.05))
        tenants.append(ti.instance())
    pool = BatchedSolvePool(cold_cfg, normalize=True)
    t_pool = time_fn(lambda: pool.solve(tenants), iters=3)

    def sequential():
        # symmetric with pool.solve: include the host-side result conversion
        return [to_solve_result(cold_fn(t, z)) for t in tenants]

    t_seq = time_fn(sequential, iters=3)
    emit("service_pool_batched", t_pool, f"tenants={B}")
    emit(
        "service_pool_sequential", t_seq,
        f"tenants={B};batch_speedup={t_seq / max(t_pool, 1e-9):.2f}x",
    )

    # -- pipelined cadences: host ingest overlapped with device solve --------
    C = 4
    svc = ServiceConfig(cold=cold_cfg, warm_gammas=(0.1, 0.01), row_headroom=8)
    cadence_deltas = [None] + [
        {
            f"t{b}": _delta(
                sinst, np.random.default_rng(500 + 10 * c + b), frac=0.25
            )
            for b in range(B)
        }
        for c in range(1, C)
    ]

    def mk():
        s = Scheduler(svc)
        for b in range(B):
            s.add_tenant(f"t{b}", sinst)
        return s

    warmup = mk()  # populate the shared compile caches before timing
    for d in cadence_deltas:
        warmup.run_cadence(d)

    s_sync = mk()
    t0 = time.perf_counter()
    for d in cadence_deltas:
        s_sync.run_cadence(d)
    t_sync = (time.perf_counter() - t0) * 1e6

    s_pipe = mk()
    t0 = time.perf_counter()
    outs = s_pipe.run_pipeline(cadence_deltas)
    t_pipe = (time.perf_counter() - t0) * 1e6

    steady_up = sum(o.upload_bytes for o in outs[1:]) / max(len(outs) - 1, 1)
    emit("service_cadences_sync", t_sync, f"cadences={C};tenants={B}")
    emit(
        "service_cadences_pipelined", t_pipe,
        f"cadences={C};tenants={B};"
        f"overlap_speedup={t_sync / max(t_pipe, 1e-9):.2f}x;"
        f"steady_upload_bytes_per_cadence={steady_up:.0f}",
    )
