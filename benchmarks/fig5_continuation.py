"""Figure 5: gamma continuation vs fixed regularization.

Same total iteration budget; continuation (paper: decay 0.16 -> 0.01 halving
every 25 iterations) vs fixed gamma=0.01 vs fixed gamma=0.16.  Metric: final
dual objective evaluated at the target gamma=0.01 (higher is better) and the
primal objective of the recovered solution.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cpu_instance, emit
from repro.core import Maximizer, MaximizerConfig, MatchingObjective
from repro.formulation import capacity_cap_formulation


def run() -> None:
    _, packed, scaled = cpu_instance(50_000, destinations=1000)
    obj = MatchingObjective(scaled)
    total = 125
    # paper Fig. 5 schedule: 0.16 halved every 25 iterations -> 0.01
    sched = (0.16, 0.08, 0.04, 0.02, 0.01)
    runs = {
        "continuation": MaximizerConfig(gammas=sched, iters_per_stage=total // len(sched)),
        "fixed_0.01": MaximizerConfig(gammas=(0.01,), iters_per_stage=total),
        "fixed_0.16": MaximizerConfig(gammas=(0.16,), iters_per_stage=total),
    }
    for name, cfg in runs.items():
        res = Maximizer(obj, cfg).solve()
        g_target = float(obj.calculate(res.lam, 0.01).g)
        emit(f"fig5/{name}", 0.0, f"g_at_gamma0.01={g_target:.5f}")

    # Scenario row: the same continuation schedule through the formulation
    # layer — capacity caps swap the feasible set (box-cut projection), the
    # solve loop and oracle stay untouched.
    comp = capacity_cap_formulation(cap=0.5).compile(scaled)
    cap_obj = comp.objective()
    res = Maximizer(cap_obj, runs["continuation"]).solve()
    g_target = float(cap_obj.calculate(res.lam, 0.01).g)
    emit("fig5/continuation_capacity_cap", 0.0,
         f"g_at_gamma0.01={g_target:.5f}")
