"""Table 3: Dualip (this system) vs D-PDLP-family baseline, runtime to target.

CPU-scaled instances.  Dualip runs its continuation schedule; PDHG runs to the
paper's 1e-4 relative tolerance.  Also reports the structural memory argument
from Table 3: PDHG must materialise the simplex rows explicitly (the L1/
reformulation blow-up that OOMs D-PDLP at scale), while the bucketed layout
absorbs them into the projection operator.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cpu_instance, emit
from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    PDHGConfig,
    from_edge_list,
    solve_pdhg,
)


def run() -> None:
    for sources in (20_000, 100_000):
        inst, packed, scaled = cpu_instance(sources, destinations=500)
        obj = MatchingObjective(scaled)
        cfg = MaximizerConfig(iters_per_stage=150)
        mx = Maximizer(obj, cfg)
        t0 = time.perf_counter()
        res = mx.solve()
        t_dualip = time.perf_counter() - t0

        lp = from_edge_list(inst)
        t0 = time.perf_counter()
        pres = solve_pdhg(lp, PDHGConfig(max_iters=20_000))
        jax.block_until_ready(pres.x)
        t_pdhg = time.perf_counter() - t0

        # explicit-row memory for the generic formulation vs bucketed layout
        pdhg_nnz = int(lp.rows.shape[0])
        ours_slots = sum(b.rows * b.length for b in packed.buckets)
        emit(
            f"table3/dualip_s{sources}", t_dualip * 1e6,
            f"g={float(res.g):.4f};slots={ours_slots}",
        )
        emit(
            f"table3/pdhg_s{sources}", t_pdhg * 1e6,
            f"obj={float(pres.primal_obj):.4f};iters={int(pres.iters)};"
            f"converged={bool(pres.converged)};explicit_nnz={pdhg_nnz};"
            f"nnz_blowup={pdhg_nnz / max(inst.nnz, 1):.2f}x",
        )
