"""Table 3: PDHG engine sweep vs AGD at matched tolerance.

Four PDHG variants solve the same LP to the paper's 1e-4 relative tolerance:

  * ``pdhg_coo_seed``            — the seed baseline (`core.pdhg.solve_pdhg`):
    generic COO form with the per-source simplex rows materialised explicitly
    (the reformulation blow-up D-PDLP pays), scatter-add SpMVs.
  * ``pdhg_fused``               — `engines.pdhg`: bucketed-ELL structured
    form, prox + A-apply fused through the one-pass dual-oracle kernel,
    no restarts.  On small shards the engine's dense fast path kicks in
    (buckets coalesced into one slab, sort-free comparison-matrix simplex
    prox, `A x` as one destination-major contraction, ax-free carry).
  * ``pdhg_fused_restart``       — + adaptive (sufficient-decay) restarts.
  * ``pdhg_fused_restart_warm``  — + warm start from the previous cadence's
    primal-dual pair with the engine-agnostic sigma cache (no power
    iteration), the recurring-cadence production path.

AGD (the paper's solver) runs at the same tolerance for context.  The gated
comparison (CI bench-smoke; ROADMAP acceptance) is **per-iteration wall
time** of the fused-structured engine vs the seed COO path on the standard
synthetic instance — the structured form reads each nnz once from dense
slabs while the COO form scatter-adds (m+1)x the entries (coupling rows
plus explicit simplex rows).

Full (non --quick) mode adds a scale point measured at a fixed iteration
count (to-tolerance at that size would dominate harness wall time) plus the
Table-3 structural memory argument: explicit-row nnz vs bucketed slots.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import cpu_instance, emit
from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    PDHGConfig,
    from_edge_list,
    solve_pdhg,
)
from repro.engines.pdhg import PDHGEngineConfig, pdhg_raw_solve
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)

TOL = 1e-4
BUDGET = 20_000
CHECK_EVERY = 50

# instance-tag -> {variant: measurements}; persisted into BENCH_oracle.json
# (benchmarks/run.py) as the acceptance record for the engine subsystem.
RESULTS: dict[str, dict] = {}


REPS = 7


def _timed(fn):
    """(best wall_seconds of REPS calls, result); first call compiles.

    Min-of-N because the gated quantity is a per-iteration *ratio* — single
    measurements on a shared CPU swing +-10% and would make the CI gate
    flaky; the minimum estimates the noise-free cost of each path.
    """
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _coo(inst, *, max_iters=BUDGET, tol=TOL):
    lp = from_edge_list(inst)
    cfg = PDHGConfig(max_iters=max_iters, tol=tol, check_every=CHECK_EVERY)
    wall, res = _timed(lambda: solve_pdhg(lp, cfg).x)
    res = solve_pdhg(lp, cfg)
    iters = max(int(res.iters), 1)
    return {
        "wall_s": wall,
        "iters": iters,
        "per_iter_us": wall / iters * 1e6,
        "obj": float(res.primal_obj),
        "converged": bool(res.converged),
        "explicit_nnz": int(lp.rows.shape[0]),
    }


def _structured(packed, *, restart, max_iters=BUDGET, tol=TOL,
                lam0=None, sigma_sq=None):
    cfg = MaximizerConfig(gammas=(0.01,), iters_per_stage=max_iters,
                          tol_grad=tol, check_every=CHECK_EVERY)
    pcfg = PDHGEngineConfig(restart=restart)
    l0 = jnp.zeros(packed.dual_dim, jnp.float32) if lam0 is None else lam0

    # jit the whole solve like the service's compiled_solver does — the COO
    # baseline is jitted, so an unjitted engine call would time re-tracing
    if sigma_sq is None:
        run = jax.jit(lambda i, l: pdhg_raw_solve(
            i, l, cfg, normalize=False, fused_oracle=True, pcfg=pcfg))
        args = (packed, l0)
    else:
        run = jax.jit(lambda i, l, s: pdhg_raw_solve(
            i, l, cfg, normalize=False, fused_oracle=True, sigma_sq=s,
            pcfg=pcfg))
        args = (packed, l0, sigma_sq)

    wall, raw = _timed(lambda: run(*args).lam)
    raw = run(*args)
    iters = max(int(raw.iters[0]), 1)
    return {
        "wall_s": wall,
        "iters": iters,
        "per_iter_us": wall / iters * 1e6,
        "obj": float(raw.g),
        "restarts": int(raw.restarts),
        "slots": sum(b.rows * b.length for b in packed.buckets),
        "_raw": raw,
    }


def _agd(scaled, *, tol=TOL):
    obj = MatchingObjective(scaled)
    cfg = MaximizerConfig(tol_grad=tol, tol_viol=tol,
                          check_every=CHECK_EVERY)
    mx = Maximizer(obj, cfg)
    wall, res = _timed(lambda: mx.solve().lam)
    res = mx.solve()
    iters = max(res.total_iters_used or cfg.total_iters, 1)
    return {
        "wall_s": wall,
        "iters": iters,
        "per_iter_us": wall / iters * 1e6,
        "obj": float(res.g),
    }


def _sweep_to_tol(tag: str, inst, packed) -> None:
    """All engine variants to tol 1e-4 on one instance; emits + RESULTS."""
    coo = _coo(inst)
    fused = _structured(packed, restart="none")
    restart = _structured(packed, restart="adaptive")
    cold_raw = restart.pop("_raw")
    warm = _structured(packed, restart="adaptive",
                       lam0=cold_raw.lam, sigma_sq=cold_raw.sigma_sq)
    warm.pop("_raw")
    fused.pop("_raw")

    speedup = coo["per_iter_us"] / fused["per_iter_us"]
    emit(f"table3/pdhg_coo_seed_{tag}", coo["per_iter_us"],
         f"iters={coo['iters']};wall_ms={coo['wall_s'] * 1e3:.1f};"
         f"converged={coo['converged']};explicit_nnz={coo['explicit_nnz']}")
    emit(f"table3/pdhg_fused_{tag}", fused["per_iter_us"],
         f"iters={fused['iters']};wall_ms={fused['wall_s'] * 1e3:.1f};"
         f"speedup_per_iter_vs_coo={speedup:.2f}x;slots={fused['slots']}")
    emit(f"table3/pdhg_fused_restart_{tag}", restart["per_iter_us"],
         f"iters={restart['iters']};restarts={restart['restarts']};"
         f"wall_ms={restart['wall_s'] * 1e3:.1f}")
    emit(f"table3/pdhg_fused_restart_warm_{tag}", warm["per_iter_us"],
         f"iters={warm['iters']};cold_iters={restart['iters']};"
         f"wall_ms={warm['wall_s'] * 1e3:.1f};"
         f"warm_fewer_iters={warm['iters'] < restart['iters']}")

    from repro.core import normalize_rows

    scaled, _ = normalize_rows(packed)
    agd = _agd(scaled)
    emit(f"table3/agd_{tag}", agd["per_iter_us"],
         f"iters={agd['iters']};wall_ms={agd['wall_s'] * 1e3:.1f}")

    fused["per_iter_speedup_vs_coo"] = speedup
    warm["cold_iters"] = restart["iters"]
    warm["warm_fewer_iters"] = warm["iters"] < restart["iters"]
    RESULTS[tag] = {
        "tol": TOL,
        "pdhg_coo_seed": coo,
        "pdhg_fused": fused,
        "pdhg_fused_restart": restart,
        "pdhg_fused_restart_warm": warm,
        "agd": agd,
    }


def run() -> None:
    # The gated point: the standard synthetic instance the test suite solves
    # everywhere (60 sources x 10 destinations, degree 4, seed 5).
    spec = MatchingInstanceSpec(num_sources=60, num_destinations=10,
                                avg_degree=4.0, seed=5)
    inst = generate_matching_instance(spec)
    packed = bucketize(inst)
    _sweep_to_tol("std", inst, packed)

    from benchmarks import common

    if common.QUICK:
        return

    # Scale point: per-iteration cost at fixed iteration count (running to
    # tolerance at this size would dominate the harness) + the Table-3
    # explicit-row memory blow-up argument.
    inst, packed, scaled = cpu_instance(20_000, destinations=500)
    n = 300
    coo = _coo(inst, max_iters=n, tol=0.0)
    fused = _structured(packed, restart="none", max_iters=n, tol=None)
    fused.pop("_raw")
    emit("table3/pdhg_coo_seed_s20000_fixed300", coo["per_iter_us"],
         f"explicit_nnz={coo['explicit_nnz']};"
         f"nnz_blowup={coo['explicit_nnz'] / max(inst.nnz, 1):.2f}x")
    emit("table3/pdhg_fused_s20000_fixed300", fused["per_iter_us"],
         f"slots={fused['slots']};"
         f"speedup_per_iter_vs_coo="
         f"{coo['per_iter_us'] / fused['per_iter_us']:.2f}x")
    RESULTS["s20000_fixed300"] = {
        "fixed_iters": n,
        "pdhg_coo_seed": coo,
        "pdhg_fused": fused,
    }
