"""Serving-latency benchmark: allocation queries from device-resident duals.

Measures what the serving layer promises (see docs/serving.md): once a
cadence solve has published its duals, answering "what is user u's
allocation right now" is an O(degree) gather + projection — no solve at
request time.  Three scenarios:

  * ``single_tenant_sync``  — one tenant at 10^5+ simulated users (full
    mode; ``--quick`` shrinks it), sequential query batches against a
    static snapshot: per-batch p50/p99 latency and users/second.
  * ``multi_tenant``        — the same request volume spread round-robin
    over many tenants (distinct snapshots, shared kernel cache).
  * ``pipelined_mid_solve`` — batches hammering the store WHILE the
    scheduler's double-buffered pipeline swaps generations underneath;
    every answered batch is then replayed post-hoc against the retained
    snapshot of the generation it reported and checked BIT-identical
    (``verified_bit_identical``) — the generation-fence acceptance test
    at benchmark volume.

Rows: ``serving_<scenario>,us_per_batch,derived``.  Standalone entry point
writes the BENCH_serving.json perf record and (``--metrics-out``) one
telemetry ``serving_query`` JSONL record per answered batch:

    PYTHONPATH=src python -m benchmarks.serving_latency --quick \
        --bench-out BENCH_serving.json --metrics-out serving.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit

RESULTS: dict = {}

_DEFAULT_BENCH_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json",
)


def _solve_cfg():
    from repro.core import MaximizerConfig

    # Serving latency does not depend on solve quality — a short schedule
    # just has to produce duals to publish.
    return MaximizerConfig(
        gammas=(1.0, 0.1), iters_per_stage=10, power_iters=5
    )


def _publish_tenant(store, name, num_sources, seed, *, destinations):
    """Generate, solve and publish one tenant; returns its snapshot."""
    from repro.instances import (
        DeltaIngestor,
        MatchingInstanceSpec,
        generate_matching_instance,
    )
    from repro.service import (
        compiled_solver,
        device_put_instance,
        to_solve_result,
    )

    spec = MatchingInstanceSpec(
        num_sources=num_sources,
        num_destinations=destinations,
        avg_degree=8.0,
        seed=seed,
    )
    ing = DeltaIngestor(generate_matching_instance(spec), row_headroom=4)
    dev = device_put_instance(ing.instance())
    cfg = _solve_cfg()
    lam0 = jnp.zeros((dev.dual_dim,), jnp.float32)
    res = to_solve_result(compiled_solver(cfg, True)(dev, lam0))
    return store.publish_result(
        name, dev, res.lam,
        generation=ing.generation, gamma=cfg.gammas[-1],
        bucket_of=ing.bucket_of, row_of=ing.row_of, deg=ing.deg,
    )


def _record(sink, result):
    if sink is not None:
        sink.emit("serving_query", {
            "tenant": result.tenant,
            "generation": result.generation,
            "users": int(result.num_users),
            "latency_seconds": result.latency_seconds,
        })


def _summarize(key, results, wall, extra=None):
    lats = np.asarray([r.latency_seconds for r in results])
    users = int(sum(r.num_users for r in results))
    summary = {
        "batches": len(results),
        "users_served": users,
        "p50_ms": float(np.percentile(lats, 50) * 1e3),
        "p99_ms": float(np.percentile(lats, 99) * 1e3),
        "qps_users": float(users / max(wall, 1e-9)),
        "wall_seconds": float(wall),
    }
    summary.update(extra or {})
    RESULTS[key] = summary
    emit(
        f"serving_{key}", float(np.median(lats) * 1e6),
        f"users={users};p50_ms={summary['p50_ms']:.3f};"
        f"p99_ms={summary['p99_ms']:.3f};"
        f"qps={summary['qps_users']:.0f}",
    )
    return summary


def _warm(store, tenant, users, batch):
    """Pre-compile every pad shape the timed loop can dispatch.

    A random batch splits across buckets data-dependently, so each bucket
    can see any request count in [1, batch] — padded to the next power of
    two before dispatch.  Query each bucket alone at every pow2 size up to
    the batch so the timed loop (and its p99) measures steady-state
    latency, never an XLA compile.
    """
    snap = store.snapshot(tenant)
    b_of = snap.bucket_of[users]
    top = 1
    while top < batch:
        top *= 2
    for t in np.unique(b_of):
        bu = users[b_of == t]
        s = 1
        while s <= top:
            store.query(tenant, bu[np.arange(s) % bu.size])
            s *= 2


def _hammer(store, tenant, snap, batch, n_batches, sink, seed=0):
    """Sequential query batches against the published snapshot."""
    rng = np.random.default_rng(seed)
    users = np.flatnonzero(snap.deg > 0)
    _warm(store, tenant, users, batch)
    results = []
    t0 = time.perf_counter()
    for _ in range(n_batches):
        pick = rng.integers(0, users.size, size=batch)
        r = store.query(tenant, users[pick])
        _record(sink, r)
        results.append(r)
    return results, time.perf_counter() - t0


def scenario_single_tenant(sink):
    from repro.serving import DualStore

    num_sources = 5_000 if common.QUICK else 100_000
    destinations = 50 if common.QUICK else 200
    batch = 256 if common.QUICK else 1024
    n_batches = 40 if common.QUICK else 128
    store = DualStore()
    snap = _publish_tenant(
        store, "t0", num_sources, 0, destinations=destinations
    )
    results, wall = _hammer(store, "t0", snap, batch, n_batches, sink)
    return _summarize(
        "single_tenant_sync", results, wall,
        {"tenants": 1, "num_users": snap.num_users, "batch_size": batch},
    )


def scenario_multi_tenant(sink):
    from repro.serving import DualStore

    n_tenants = 2 if common.QUICK else 8
    per_tenant = 2_000 if common.QUICK else 25_000
    destinations = 50 if common.QUICK else 200
    batch = 256 if common.QUICK else 1024
    n_batches = 20 if common.QUICK else 64
    store = DualStore()
    snaps = {
        f"t{i}": _publish_tenant(
            store, f"t{i}", per_tenant, i, destinations=destinations
        )
        for i in range(n_tenants)
    }
    rng = np.random.default_rng(1)
    live = {t: np.flatnonzero(s.deg > 0) for t, s in snaps.items()}
    for i, (t, u) in enumerate(live.items()):
        _warm(store, t, u, batch)
    results = []
    t0 = time.perf_counter()
    for i in range(n_batches * n_tenants):
        t = f"t{i % n_tenants}"
        pick = rng.integers(0, live[t].size, size=batch)
        r = store.query(t, live[t][pick])
        _record(sink, r)
        results.append(r)
    wall = time.perf_counter() - t0
    return _summarize(
        "multi_tenant", results, wall,
        {
            "tenants": n_tenants,
            "num_users": int(sum(s.num_users for s in snaps.values())),
            "batch_size": batch,
        },
    )


def scenario_pipelined(sink):
    """Queries racing the scheduler's double-buffered pipeline, bit-verified."""
    from repro.core import MaximizerConfig
    from repro.instances import (
        InstanceDelta,
        MatchingInstanceSpec,
        generate_matching_instance,
    )
    from repro.service import Scheduler, ServiceConfig
    from repro.serving import DualStore, direct_allocations

    num_sources = 2_000 if common.QUICK else 20_000
    destinations = 50 if common.QUICK else 200
    n_cadences = 2 if common.QUICK else 4
    batch = 64 if common.QUICK else 256
    rng = np.random.default_rng(2)
    spec = MatchingInstanceSpec(
        num_sources=num_sources, num_destinations=destinations,
        avg_degree=8.0, seed=3,
    )
    base = generate_matching_instance(spec)
    cfg = ServiceConfig(
        cold=MaximizerConfig(
            gammas=(1.0, 0.1), iters_per_stage=40, power_iters=10
        ),
        warm_gammas=(0.1,),
        row_headroom=4,
    )
    store = DualStore(history=n_cadences + 2)
    sched = Scheduler(cfg, dual_store=store)
    sched.add_tenant("t0", base)
    sched.run_cadence()  # initial publication

    def delta():
        n = max(1, base.src.size // 50)
        pick = rng.choice(base.src.size, size=n, replace=False)
        return InstanceDelta(
            update_src=base.src[pick], update_dst=base.dst[pick],
            update_values=base.values[pick] * rng.uniform(0.9, 1.1, n),
        )

    snap0 = store.snapshot("t0")
    users = np.flatnonzero(snap0.deg > 0)
    _warm(store, "t0", users, batch)
    results = []
    stop = threading.Event()

    def hammer():
        qrng = np.random.default_rng(4)
        while not stop.is_set():
            pick = qrng.integers(0, users.size, size=batch)
            r = store.query("t0", users[pick])
            _record(sink, r)
            results.append(r)

    worker = threading.Thread(target=hammer, daemon=True)
    t0 = time.perf_counter()
    worker.start()
    try:
        sched.run_pipeline([{"t0": delta()} for _ in range(n_cadences)])
    finally:
        stop.set()
        worker.join(timeout=60)
    wall = time.perf_counter() - t0
    # post-hoc bit-identity replay: every batch against the retained
    # snapshot of the generation it reported
    verified = True
    directs = {}
    for r in results:
        if r.generation not in directs:
            directs[r.generation] = direct_allocations(
                store.get("t0", r.generation)
            )
        xs = directs[r.generation]
        for ba in r.slabs:
            if not np.array_equal(ba.x, np.asarray(xs[ba.bucket])[ba.rows]):
                verified = False
    gens = sorted({r.generation for r in results})
    return _summarize(
        "pipelined_mid_solve", results, wall,
        {
            "tenants": 1,
            "num_users": snap0.num_users,
            "batch_size": batch,
            "cadences": n_cadences,
            "generations_observed": [int(g) for g in gens],
            "verified_bit_identical": verified,
        },
    )


def run(sink=None) -> None:
    scenario_single_tenant(sink)
    scenario_multi_tenant(sink)
    scenario_pipelined(sink)


def _write_bench(path: str) -> None:
    record = {
        "suite": "allocation serving from device-resident duals",
        "quick": common.QUICK,
        "scenarios": RESULTS,
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shrunken volumes (CI smoke)")
    ap.add_argument("--bench-out", default=_DEFAULT_BENCH_OUT,
                    help="where to write BENCH_serving.json "
                         "(empty string disables)")
    ap.add_argument("--metrics-out", default="",
                    help="emit one serving_query JSONL record per batch "
                         "here (empty string disables)")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
        if args.bench_out == _DEFAULT_BENCH_OUT:
            # a reduced smoke sweep must not clobber the committed
            # full-volume record; pass --bench-out to force a path
            args.bench_out = ""
            print("# --quick: skipping BENCH_serving.json (reduced sweep); "
                  "pass --bench-out explicitly to write one", file=sys.stderr)
    sink = None
    if args.metrics_out:
        from repro.telemetry import JsonlSink

        sink = JsonlSink(args.metrics_out)
    print("name,us_per_call,derived")
    try:
        run(sink)
    finally:
        if sink is not None:
            sink.close()
    if args.bench_out:
        _write_bench(args.bench_out)
    pipelined = RESULTS.get("pipelined_mid_solve", {})
    if not pipelined.get("verified_bit_identical", False):
        print("# FAIL: mid-solve batches not bit-identical to their "
              "generation's direct projection", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
