"""Figure 2: geometric bucketing vs single-slab baseline (batching=False).

Measures per-iteration time and the exact slab memory of both layouts on the
same instance — the paper's ~1.2x time and ~24% memory gains come from not
computing/storing zero padding; both quantities are directly measurable here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import MatchingObjective, normalize_rows
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    pack_single_slab,
)


def _slab_bytes(packed) -> int:
    tot = 0
    for b in packed.buckets:
        m = b.coeff.shape[0]
        tot += b.rows * b.length * 4 * (3 + m)  # idx, cost, mask, coeff[m]
    return tot


def run() -> None:
    spec = MatchingInstanceSpec(
        num_sources=100_000, num_destinations=1000, avg_degree=8.0,
        breadth_sigma=1.5, seed=0,
    )
    inst = generate_matching_instance(spec)
    for name, packed in (
        ("bucketed", bucketize(inst)),
        ("single_slab", pack_single_slab(inst)),
    ):
        scaled, _ = normalize_rows(packed)
        obj = MatchingObjective(scaled)

        @jax.jit
        def it(lam):
            ev = obj.calculate(lam, jnp.float32(1.0))
            return jnp.maximum(lam + 1e-2 * ev.grad, 0.0)

        t = time_fn(it, jnp.zeros((obj.dual_dim,), jnp.float32))
        mem = _slab_bytes(packed)
        pad = 1.0 - inst.nnz / (mem / (4 * 4))
        emit(
            f"fig2/{name}", t,
            f"slab_bytes={mem};padding_frac={pad:.3f};"
            f"buckets={len(packed.buckets)}",
        )
