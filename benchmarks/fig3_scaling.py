"""Figure 3: multi-device scaling of the column-sharded solver.

Subprocess sweep over 1/2/4/8 forced host devices (iteration wall time), plus
the production-mesh communication model from the dry-run artifacts: the
per-iteration reduce volume is independent of sources and shard count, so
scaling is bounded by local compute — the paper's central scaling claim.

NOTE: on a single-physical-core host the N forced devices timeshare one core,
so wall-clock speedup reads ~1.0x by construction; the structural evidence
(flat reduce volume, shard-count-invariant trajectories) carries the claim.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_SCRIPT = r"""
import os, sys, json, time
n = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import DistributedMaximizer, DistConfig, MaximizerConfig
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import normalize_rows

spec = MatchingInstanceSpec(num_sources=200_000, num_destinations=1000,
                            avg_degree=8.0, seed=0)
packed = bucketize(generate_matching_instance(spec), shard_multiple=n)
scaled, _ = normalize_rows(packed)
mesh = compat.make_mesh((n,), ("data",))
iters = 50
dm = DistributedMaximizer(scaled, mesh, MaximizerConfig(iters_per_stage=iters),
                          DistConfig(axes="data"))
dm.place()
lam = jnp.zeros((scaled.dual_dim,), jnp.float32)
g = jnp.float32(1.0); eta = jnp.float32(1e-2)
with compat.set_mesh(mesh):
    out = dm._stage_fn(lam, g, eta, dm.inst); jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    for _ in range(3):
        out = dm._stage_fn(lam, g, eta, dm.inst); jax.block_until_ready(out[0])
    dt = (time.perf_counter() - t0) / 3 / iters
print("RESULT:" + json.dumps({"n": n, "us_per_iter": dt * 1e6}))
"""


def run() -> None:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base = None
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(repo, "src")
        out = subprocess.run(
            [sys.executable, "-c", _SCRIPT, str(n)],
            capture_output=True, text=True, timeout=900, env=env,
        )
        if out.returncode != 0:
            emit(f"fig3/shards_{n}", -1, "FAILED")
            continue
        res = json.loads(out.stdout.split("RESULT:")[1])
        us = res["us_per_iter"]
        if base is None:
            base = us
        emit(
            f"fig3/shards_{n}", us,
            f"speedup={base / us:.2f}x;efficiency={base / us / n:.2f}",
        )
