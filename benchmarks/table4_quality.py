"""Table 4: solution quality — primal/dual objectives, gap, constraint slack.

Dualip runs the paper's six-stage gamma schedule; PDHG terminates at 1e-4
residuals; scipy HiGHS provides exact ground truth at this scale.  The paper's
claim checked here: both solvers agree on the optimum once gamma is small
(<=1e-2), with Dualip reaching a much smaller primal-dual gap.
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linprog

from benchmarks.common import cpu_instance, emit
from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    PDHGConfig,
    from_edge_list,
    solve_pdhg,
)
from repro.instances import unpack_primal


def run() -> None:
    inst, packed, scaled = cpu_instance(2_000, destinations=100, avg_degree=5.0)
    spec = inst.spec
    obj = MatchingObjective(scaled)
    res = Maximizer(obj, MaximizerConfig(iters_per_stage=500)).solve()
    x = unpack_primal(packed, res.x_slabs)
    primal = float(np.dot(inst.cost, x))
    gamma = 0.01
    ridge = gamma / 2 * float((x ** 2).sum())
    dual = float(res.g)
    # original-space violation
    A, b, c = inst.to_dense()
    cols = inst.src * spec.num_destinations + inst.dst
    slack = float(np.maximum(A[:, cols] @ x - b, 0).max())
    gap = abs((primal + ridge) - dual) / (1 + abs(dual))
    emit("table4/dualip_primal", 0.0, f"{primal:.6f}")
    emit("table4/dualip_dual", 0.0, f"{dual:.6f};gap={gap:.2e};slack={slack:.2e}")

    pres = solve_pdhg(from_edge_list(inst), PDHGConfig())
    emit(
        "table4/pdhg", 0.0,
        f"primal={float(pres.primal_obj):.6f};dual={float(pres.dual_obj):.6f};"
        f"gap={float(pres.rel_gap):.2e};pres={float(pres.primal_res):.2e}",
    )

    S = np.zeros((spec.num_sources, inst.nnz))
    S[inst.src, np.arange(inst.nnz)] = 1.0
    r = linprog(
        c[cols], A_ub=np.vstack([A[:, cols], S]),
        b_ub=np.concatenate([b, np.ones(spec.num_sources)]),
        bounds=(0, None), method="highs",
    )
    emit(
        "table4/highs_truth", 0.0,
        f"obj={r.fun:.6f};dualip_relerr={abs(primal - r.fun) / abs(r.fun):.2e};"
        f"pdhg_relerr={abs(float(pres.primal_obj) - r.fun) / abs(r.fun):.2e}",
    )
