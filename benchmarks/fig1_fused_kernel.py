"""Figure 1 analog: fused projection vs multi-op eager Duchi, and the
one-pass fused dual oracle vs the multi-launch oracle chain.

On-TPU the fused Pallas kernels remove inter-stage HBM traffic; on this CPU
host we measure (a) the multi-op eager pipeline (one dispatch per stage — the
paper's 'PyTorch eager' role), (b) the jit'd single-program pipeline, and
report the *analytic* HBM traffic each variant implies on the TPU target
(the quantity Figure 1's memory panel measures).

The oracle rows extend the same comparison one level up: the unfused oracle
is three separately-jitted launches (primal step, gradient segment-sum,
objective scalars) with the primal slab and the [m, n, L] contribution
intermediates crossing HBM between them; the fused oracle is one launch
returning (x, A x histogram, c'x, ||x||^2) from a single slab pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core.objective import binned_segment_sum
from repro.kernels import ref as kref


def _eager_duchi(v, mask):
    with jax.disable_jit():
        return kref.simplex_ref(v, mask)


_jit_duchi = jax.jit(kref.simplex_ref)


def _run_projection() -> None:
    rng = np.random.default_rng(0)
    cases = (
        ((20_000, 64),) if common.QUICK
        else ((20_000, 64), (100_000, 64), (20_000, 512))
    )
    for n, L in cases:
        v = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32))
        mask = jnp.asarray((rng.random((n, L)) < 0.8).astype(np.float32))
        t_eager = time_fn(_eager_duchi, v, mask, warmup=1, iters=3)
        t_jit = time_fn(_jit_duchi, v, mask)
        # TPU-target HBM traffic per projection call (fp32):
        #   eager: sort r/w + cumsum r/w + cond r/w + theta r + output w ~ 9x
        #   fused kernel: read v,mask + write out = 3x
        slab = n * L * 4
        emit(f"fig1/eager_n{n}_L{L}", t_eager, f"hbm_bytes~{9 * slab}")
        emit(
            f"fig1/fused_n{n}_L{L}", t_jit,
            f"hbm_bytes~{3 * slab};speedup={t_eager / max(t_jit, 1e-9):.1f}x;"
            f"traffic_reduction={9 / 3:.1f}x",
        )


def _run_oracle() -> None:
    rng = np.random.default_rng(1)
    m, J = 1, 1_000
    cases = (
        ((20_000, 8),) if common.QUICK
        else ((20_000, 8), (100_000, 8), (20_000, 64))
    )
    # the unfused oracle as three separate launches (multi-launch role)
    primal = jax.jit(
        lambda idx, coeff, cost, mask, lam, gamma: kref.dual_primal_ref(
            idx, coeff, cost, mask, lam, gamma, J
        )
    )
    segsum = jax.jit(
        lambda idx, coeff, x: binned_segment_sum(idx, coeff * x[None], J)
    )
    scalars = jax.jit(lambda cost, x: (jnp.vdot(cost, x), jnp.vdot(x, x)))
    fused = jax.jit(
        lambda idx, coeff, cost, mask, lam, gamma: kref.dual_oracle_ref(
            idx, coeff, cost, mask, lam, gamma, J
        )
    )

    def multi_launch(idx, coeff, cost, mask, lam, gamma):
        x = primal(idx, coeff, cost, mask, lam, gamma)
        hist = segsum(idx, coeff, x)
        lin, sq = scalars(cost, x)
        return x, hist, lin, sq

    for n, L in cases:
        idx = jnp.asarray(rng.integers(0, J, size=(n, L)), jnp.int32)
        coeff = jnp.asarray(rng.random((m, n, L)).astype(np.float32))
        cost = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32))
        mask = jnp.asarray((rng.random((n, L)) < 0.8).astype(np.float32))
        lam = jnp.asarray(rng.random(m * J).astype(np.float32))
        gamma = jnp.float32(1.0)
        t_multi = time_fn(multi_launch, idx, coeff, cost, mask, lam, gamma)
        t_fused = time_fn(fused, idx, coeff, cost, mask, lam, gamma)
        # TPU-target slab bytes/iter: primal (idx+coeff+cost+mask r, x w) then
        # re-reads for segment-sum (idx+coeff+x) and scalars (cost+x) vs one
        # pass + O(grid*m*J) histogram partials
        slab = n * L * 4
        b_multi = (5 + 5) * slab
        b_fused = 5 * slab
        emit(
            f"fig1/oracle_multi_n{n}_L{L}", t_multi, f"hbm_bytes~{b_multi}"
        )
        emit(
            f"fig1/oracle_fused_n{n}_L{L}", t_fused,
            f"hbm_bytes~{b_fused};"
            f"speedup={t_multi / max(t_fused, 1e-9):.2f}x;"
            f"traffic_reduction={b_multi / b_fused:.1f}x",
        )


def run() -> None:
    _run_projection()
    _run_oracle()
