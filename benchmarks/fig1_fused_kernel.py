"""Figure 1 analog: fused projection vs multi-op eager Duchi.

On-TPU the fused Pallas kernel removes inter-stage HBM traffic; on this CPU
host we measure (a) the multi-op eager pipeline (one dispatch per stage — the
paper's 'PyTorch eager' role), (b) the jit'd single-program pipeline, and
report the *analytic* HBM traffic each variant implies on the TPU target
(the quantity Figure 1's memory panel measures).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ref as kref


def _eager_duchi(v, mask):
    with jax.disable_jit():
        return kref.simplex_ref(v, mask)


_jit_duchi = jax.jit(kref.simplex_ref)


def run() -> None:
    rng = np.random.default_rng(0)
    for n, L in ((20_000, 64), (100_000, 64), (20_000, 512)):
        v = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32))
        mask = jnp.asarray((rng.random((n, L)) < 0.8).astype(np.float32))
        t_eager = time_fn(_eager_duchi, v, mask, warmup=1, iters=3)
        t_jit = time_fn(_jit_duchi, v, mask)
        # TPU-target HBM traffic per projection call (fp32):
        #   eager: sort r/w + cumsum r/w + cond r/w + theta r + output w ~ 9x
        #   fused kernel: read v,mask + write out = 3x
        slab = n * L * 4
        emit(f"fig1/eager_n{n}_L{L}", t_eager, f"hbm_bytes~{9 * slab}")
        emit(
            f"fig1/fused_n{n}_L{L}", t_jit,
            f"hbm_bytes~{3 * slab};speedup={t_eager / max(t_jit, 1e-9):.1f}x;"
            f"traffic_reduction={9 / 3:.1f}x",
        )
