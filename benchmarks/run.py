"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig1,table2] [--quick]

After the table2 suite runs, its oracle measurements are persisted to
``BENCH_oracle.json`` (``--bench-out``) — the perf-trajectory record of the
per-iteration hot path (fused one-pass dual oracle vs the unfused / legacy
iterations, wall time + analytic HBM bytes/iter).  ``--quick`` shrinks every
suite's sweep for the CI smoke step.

``--bench-history h.jsonl`` additionally APPENDS one timestamped record per
harness run in the telemetry JSONL schema (kind ``bench``; validate with
``tools/check_metrics.py``): where BENCH_oracle.json is the latest snapshot,
the history file accumulates the perf trajectory run over run — CI's
bench-smoke step uploads it as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

SUITES = [
    "table2_iteration_time",
    "fig1_fused_kernel",
    "fig2_bucketing",
    "fig3_scaling",
    "table3_vs_pdhg",
    "table4_quality",
    "fig4_preconditioning",
    "fig5_continuation",
    "service_cadence",
    "serving_latency",
    "roofline_report",
]

_DEFAULT_BENCH_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_oracle.json",
)


def _write_oracle_bench(path: str) -> None:
    from benchmarks import common, table2_iteration_time, table3_vs_pdhg

    if not table2_iteration_time.RESULTS:
        return
    fig1_rows = {
        name: {"us_per_call": us, "derived": derived}
        for name, us, derived in common.ROWS
        if name.startswith("fig1/oracle_")
    }
    record = {
        "suite": "fused dual oracle (one-pass Ax + objective reduction)",
        "quick": common.QUICK,
        "iteration_by_sources": {
            str(k): v for k, v in sorted(table2_iteration_time.RESULTS.items())
        },
        "fig1_oracle_rows": fig1_rows,
    }
    if table3_vs_pdhg.RESULTS:
        # engine-subsystem acceptance record: fused structured PDHG vs the
        # seed COO path at matched tolerance (per-iteration speedup gated
        # >= 5x on the standard instance by CI's bench-smoke step)
        record["pdhg_engines"] = table3_vs_pdhg.RESULTS
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)


def _append_bench_history(path: str, only: set, failures: int) -> None:
    from benchmarks import common
    from repro.telemetry import JsonlSink

    with JsonlSink(path) as sink:
        sink.emit("bench", {
            "suite": ",".join(sorted(only)) if only else "all",
            "quick": common.QUICK,
            "slab_dtypes": list(common.SLAB_DTYPES),
            "failures": failures,
            "results": [
                {"name": name, "us_per_call": us, "derived": derived}
                for name, us, derived in common.ROWS
            ],
        })
    print(f"# appended bench record to {path}", file=sys.stderr)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="shrunken sweeps (CI smoke)")
    ap.add_argument("--bench-out", default=_DEFAULT_BENCH_OUT,
                    help="where to write the oracle perf record "
                         "(empty string disables)")
    ap.add_argument("--bench-history", default="",
                    help="append one timestamped telemetry-schema JSONL "
                         "record per run here (empty string disables)")
    ap.add_argument("--slab-dtypes", default="",
                    help="comma list of slab storage dtypes for table2's "
                         "mixed-precision sweep (default: float32,bfloat16,"
                         "int8; float32 is always included as the baseline)")
    args = ap.parse_args()
    if args.slab_dtypes:
        from benchmarks import common

        dtypes = [s.strip() for s in args.slab_dtypes.split(",") if s.strip()]
        if "float32" not in dtypes:
            dtypes.insert(0, "float32")
        common.SLAB_DTYPES = tuple(dtypes)
    if args.quick:
        from benchmarks import common

        common.QUICK = True
        if args.bench_out == _DEFAULT_BENCH_OUT:
            # never let a reduced smoke sweep clobber the committed
            # full-sweep trajectory record; pass --bench-out to force a path
            args.bench_out = ""
            print("# --quick: skipping BENCH_oracle.json (reduced sweep); "
                  "pass --bench-out explicitly to write one", file=sys.stderr)
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    print("name,us_per_call,derived")
    failures = 0
    for name in SUITES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.bench_out:
        _write_oracle_bench(args.bench_out)
    if args.bench_history:
        _append_bench_history(args.bench_history, only, failures)
    return failures


if __name__ == "__main__":
    sys.exit(main())
