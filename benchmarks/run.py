"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig1,table2]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    "table2_iteration_time",
    "fig1_fused_kernel",
    "fig2_bucketing",
    "fig3_scaling",
    "table3_vs_pdhg",
    "table4_quality",
    "fig4_preconditioning",
    "fig5_continuation",
    "service_cadence",
    "roofline_report",
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    print("name,us_per_call,derived")
    failures = 0
    for name in SUITES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    return failures


if __name__ == "__main__":
    sys.exit(main())
