"""Batched serving demo: continuous-batching engine over a small LM.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models.model import Model
from repro.serving.lm_demo.engine import Request, ServeEngine


def main():
    cfg = get_reduced_config("qwen3-8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, slots=4, max_seq=96)

    rng = np.random.default_rng(0)
    prompt_len = 16
    for rid in range(8):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new_tokens=24,
        ))
    t0 = time.time()
    steps = 0
    done = []
    while engine.queue or any(r is not None for r in engine.active):
        active_before = [r for r in engine.active if r is not None]
        engine.step()
        steps += 1
        for r in active_before:
            if r.done and r not in done:
                done.append(r)
    dt = time.time() - t0
    total_tokens = sum(8 * [24])
    print(f"served 8 requests x 24 tokens in {steps} engine steps, {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
