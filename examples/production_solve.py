"""End-to-end production driver: recurring solves with stability control.

Simulates the paper's production cadence: day-0 solve, then a day-1 solve on
perturbed data, warm-started from day-0 duals, with the gamma floor bounding
run-to-run primal drift (paper contribution 2).  Reports solve quality, drift,
and the theoretical bound.

    PYTHONPATH=src python examples/production_solve.py [--sources 100000]
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.core import (
    MaximizerConfig,
    RecurringSolver,
    drift_bound,
    normalize_rows,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sources", type=int, default=50_000)
    ap.add_argument("--destinations", type=int, default=1_000)
    ap.add_argument("--gamma-floor", type=float, default=0.01)
    args = ap.parse_args()

    gammas = tuple(
        g for g in (1e3, 1e2, 10.0, 1.0, 0.1, 0.01) if g >= args.gamma_floor
    )
    solver = RecurringSolver(
        MaximizerConfig(gammas=gammas, iters_per_stage=120)
    )

    spec0 = MatchingInstanceSpec(
        num_sources=args.sources, num_destinations=args.destinations,
        avg_degree=8.0, seed=0,
    )
    day0 = generate_matching_instance(spec0)
    packed0, _ = normalize_rows(bucketize(day0))

    t0 = time.time()
    res0, _ = solver.solve(packed0)
    print(f"[day 0] solved in {time.time() - t0:.1f}s  g={float(res0.g):.4f}  "
          f"viol={float(res0.stats[-1].max_violation[-1]):.2e}")

    # day 1: same graph, values perturbed ~2% (slowly evolving inputs)
    day1 = dataclasses.replace(day0)
    rng = np.random.default_rng(1)
    noise = 1.0 + 0.02 * rng.standard_normal(day1.nnz)
    day1.values = day1.values * noise
    day1.coeff = day1.coeff * noise
    packed1, _ = normalize_rows(bucketize(day1))

    t0 = time.time()
    res1, report = solver.solve(packed1)
    dc = float(np.linalg.norm(packed1.buckets[0].cost - packed0.buckets[0].cost))
    bound = drift_bound(args.gamma_floor, dc_norm=dc, dlam_norm=float(
        np.linalg.norm(np.asarray(res1.lam) - np.asarray(res0.lam))))
    print(f"[day 1] warm-started solve in {time.time() - t0:.1f}s  "
          f"g={float(res1.g):.4f}")
    print(f"        primal drift ||x1-x0|| = {report['drift_l2']:.4f} "
          f"(relative {report['drift_rel']:.4f})")
    print(f"        theoretical bound (gamma={args.gamma_floor}): {bound:.4f}")
    assert report["drift_l2"] <= bound * 1.01, "drift bound violated!"
    print("        drift within the gamma-control bound — stability holds")


if __name__ == "__main__":
    main()
