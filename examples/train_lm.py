"""End-to-end LM training driver with fault-tolerant loop.

Default: a ~10M-param qwen3-family model for 200 steps (CPU-friendly).
--preset 100m trains a ~100M-param model (same pipeline, longer wall time).
Demonstrates: data pipeline, AdamW, checkpoint/resume (kill it mid-run and
restart — it continues from the last checkpoint).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--preset 10m]
"""
import argparse
import dataclasses
import logging

from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLMData
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.training.loop import TrainLoopConfig, train_loop
from repro.training.optimizer import AdamWConfig

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

PRESETS = {
    "10m": ModelConfig(
        name="qwen3-10m", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=8192,
        qk_norm=True, remat=False,
    ),
    "100m": ModelConfig(
        name="qwen3-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
        qk_norm=True, remat=False,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = Model(cfg)
    print(f"model: {cfg.name}  params={model.param_count():,}")
    data = SyntheticLMData(cfg, batch=args.batch, seq=args.seq, seed=0)
    state = train_loop(
        model,
        data,
        AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        TrainLoopConfig(total_steps=args.steps, save_every=50, log_every=10),
        ckpt_dir=args.ckpt_dir,
    )
    print(f"finished at step {int(state.step)}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
