"""LP-routed MoE: the paper's solver as a balanced token->expert router.

Token->expert assignment IS a matching LP (tokens = sources under a top-k
simplex constraint, experts = destinations under capacity constraints), so a
few regularized dual-ascent iterations produce a BASE-layers-style balanced
routing.  This demo compares expert load balance and drop rate between the
standard top-k router and the LP router on the same logits.

    PYTHONPATH=src python examples/lp_moe_routing.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models.model import Model
from repro.models.moe import lp_route


def load_stats(ids, weights, E, C):
    load = np.zeros(E)
    for e in range(E):
        load[e] = float((np.asarray(ids) == e).sum())
    drop = float(np.maximum(load - C, 0).sum() / max(load.sum(), 1))
    return load, drop


def main():
    rng = np.random.default_rng(0)
    T, E, k = 4096, 16, 2
    C = int(T * k / E * 1.25)
    # skewed router logits: a few "hot" experts (the pathological case)
    hot = rng.normal(size=E) * 2.0
    logits = rng.normal(size=(T, E)).astype(np.float32) + hot[None, :]
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)

    w_top, id_top = jax.lax.top_k(probs, k)
    load_top, drop_top = load_stats(id_top.reshape(-1), w_top, E, C * 1.0)

    x = lp_route(probs, k, capacity=float(C), iters=64, gamma=0.05)
    w_lp, id_lp = jax.lax.top_k(x, k)
    load_lp, drop_lp = load_stats(id_lp.reshape(-1), w_lp, E, C * 1.0)

    print(f"tokens={T} experts={E} top_k={k} capacity/expert={C}")
    print(f"top-k router : max load {load_top.max():.0f}  "
          f"imbalance {load_top.max() / load_top.mean():.2f}x  "
          f"dropped {drop_top:.1%}")
    print(f"LP router    : max load {load_lp.max():.0f}  "
          f"imbalance {load_lp.max() / load_lp.mean():.2f}x  "
          f"dropped {drop_lp:.1%}")
    assert load_lp.max() <= load_top.max() + 1e-6

    # and inside a real MoE model: flip the reduced kimi config to router="lp"
    cfg = get_reduced_config("kimi-k2-1t-a32b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router="lp", lp_iters=16)
    )
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    loss = jax.jit(model.loss)(params, batch)
    print(f"kimi-k2 (reduced) with router='lp': loss={float(loss):.4f} (finite OK)")


if __name__ == "__main__":
    main()
