"""Quickstart: build a matching LP, solve it with the paper's pipeline, check it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    normalize_rows,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    unpack_primal,
)


def main():
    # 1. a synthetic matching workload (Appendix A): 5k users x 200 items
    spec = MatchingInstanceSpec(
        num_sources=5_000, num_destinations=200, avg_degree=6.0, seed=0
    )
    inst = generate_matching_instance(spec)
    print(f"instance: {spec.num_sources} sources, {spec.num_destinations} "
          f"destinations, {inst.nnz} eligible pairs")

    # 2. pack into the TPU bucketed-ELL layout + Jacobi row normalization
    packed = bucketize(inst)
    scaled, _ = normalize_rows(packed)
    print("buckets:", [(b.length, b.rows) for b in scaled.buckets])

    # 3. solve: accelerated dual ascent with the paper's gamma continuation
    obj = MatchingObjective(scaled)
    res = Maximizer(obj, MaximizerConfig(iters_per_stage=300)).solve()
    print(f"dual objective g = {float(res.g):.4f}  "
          f"(sigma_max^2 = {float(res.sigma_sq):.3f})")

    # 4. recover and check the primal
    x = unpack_primal(packed, res.x_slabs)
    matched_value = -float(np.dot(inst.cost, x))
    viol = float(res.stats[-1].max_violation[-1])
    print(f"matched value = {matched_value:.4f}, max violation = {viol:.2e}")
    print(f"assignment mass per source (mean) = {x.sum() / spec.num_sources:.3f}")


if __name__ == "__main__":
    main()
