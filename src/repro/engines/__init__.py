"""Solver engines: the layer between the dual oracle and the service.

See `repro.engines.base` for the contract, `repro.engines.agd` /
`repro.engines.pdhg` for the two implementations, and
`repro.engines.selector` for the per-tenant adaptive routing policy.
Documented in docs/solvers.md.
"""
from repro.engines.base import ENGINES, Engine, RawSolve, resolve_engine
from repro.engines.selector import EngineSelector

__all__ = [
    "ENGINES",
    "Engine",
    "EngineSelector",
    "RawSolve",
    "resolve_engine",
]
