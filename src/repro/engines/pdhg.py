"""Structured PDHG engine on the bucketed-ELL form (cuPDLP/D-PDLP family).

The seed repo carried PDHG only as a COO strawman (`repro.core.pdhg`): K and
K' as unstructured scatter-adds over an edge list, fixed ergodic restarts, no
warm starts, no fused kernels.  This module is the production engine the
ROADMAP's "Solver diversity" item calls for — the same algorithm family, but
run directly on the bucketed slabs the rest of the system already uses:

  minimize   c'x   s.t.  A x <= b,   x in C  (per-source simplex rows)

with the standard primal-dual hybrid gradient iteration

  x+ = Proj_C(x - tau * (c + A'y))
  y+ = max(0, y + sig * (A (2 x+ - x) - b)),      tau * sig * ||A||^2 < 1.

Four systems points (see docs/solvers.md):

  * **Fused applies.**  The primal prox step is the dual oracle in disguise:
    `x - tau*(c + A'y) = -(A'y + (c - x/tau)) / (1/tau)`, so the one-pass
    fused oracle kernel (`kernels.ops.fused_pdhg_step`) performs the prox AND
    emits this bucket's `A x+` histogram from a single slab read — one launch
    per bucket per iteration where the COO path needs a gather plus a
    scatter-add.
  * **Restarts.**  `none | ergodic | adaptive | halpern` (PAPERS.md, GPU
    first-order-methods overview).  Ergodic resets to the running average on
    a fixed cadence; Halpern anchors (`x <- (t+1)/(t+2) x+ + 1/(t+2) x0`)
    with periodic re-anchoring; adaptive evaluates current-vs-average merit
    `max(rel_primal, rel_dual, rel_gap)` at every check and restarts to the
    better candidate when it beats the last restart's merit by a fixed
    factor (the D-PDLP sufficient-decay rule).
  * **Dense small-shard fast path.**  When a shard is small enough
    (`PDHGEngineConfig.dense`), the per-length buckets are coalesced into a
    single padded slab, the per-row simplex prox switches to the sort-free
    comparison-matrix projection (`core.projections.project_simplex_cmp`)
    and `A x` becomes one dense contraction against a precomputed one-hot
    destination matrix.  The iteration collapses from
    `num_buckets x (gather, sort, cumsum, reductions, segment-scatter)` to
    roughly four XLA thunks, which is what the per-iteration wall time of a
    small shard is actually made of — the math is bit-for-bit the same
    polytope and the iterates agree with the bucketed path to fp rounding.
  * **Termination.**  D-PDLP-style relative residuals, checked every
    `cfg.check_every` iterations through the SAME chunked early-stop
    machinery as AGD (`maximizer._chunked_early_scan`), including the psum'd
    all-shards-agree predicate in the distributed wrapper — so early exit
    keeps every shard at the same while_loop trip count.

Warm starts: `lam0` is the previous cadence's duals (the engine contract
keeps both engines in the same [m*J] dual space) and the primal is
reconstructed as `x0 = Proj_C(-(A'lam0 + c) / gamma_floor)` — exactly the
primal that serving publishes for those duals, so a warm cadence resumes
from the pair the system last acted on.

PDHG solves the *unsmoothed* LP: `ridge_weight` never enters the iteration
(there is no gamma), which is exactly why the scheduler may prefer it for
formulations where AGD's smoothing bias hurts (`repro.engines.selector`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.core.maximizer import (
    MaximizerConfig,
    SolveResult,
    StageStats,
    _chunked_early_scan,
)
from repro.core.objective import (
    MatchingObjective,
    _gather_at_lam,
    normalize_rows_traced,
)
from repro.core.projections import UnitSimplexProjection
from repro.engines.base import RawSolve
from repro.instances.buckets import BucketedInstance
from repro.kernels import ops as kops

__all__ = [
    "PDHGEngine",
    "PDHG_ENGINE",
    "PDHGEngineConfig",
    "RESTART_SCHEMES",
    "pdhg_raw_solve",
    "solve_pdhg_sharded",
]

RESTART_SCHEMES = ("none", "ergodic", "adaptive", "halpern")


@dataclasses.dataclass(frozen=True)
class PDHGEngineConfig:
    """PDHG-specific knobs; everything budget/tolerance comes from
    `MaximizerConfig` so the two engines stay swappable under one service
    config (total iteration budget = `cfg.total_iter_budget`, check cadence =
    `cfg.check_every`, tolerance = `cfg.tol_grad` falling back to
    `cfg.tol_viol`)."""

    restart: str = "adaptive"
    restart_every: int = 100  # ergodic/halpern cadence (iterations)
    step_ratio: float = 1.0  # omega = tau/sig balance
    step_margin: float = 0.9  # tau*sig*||A||^2 = margin^2 < 1
    restart_threshold: float = 0.8  # adaptive sufficient-decay factor
    # dense small-shard fast path: coalesce buckets + sort-free projection +
    # one-hot A-apply.  "auto" enables it when the one-hot matrix stays under
    # `dense_max_cells` entries and padding doesn't blow the slab up.
    dense: str = "auto"
    dense_max_cells: int = 1 << 22

    def __post_init__(self):
        if self.restart not in RESTART_SCHEMES:
            raise ValueError(
                f"restart={self.restart!r} not in {RESTART_SCHEMES}"
            )
        if not (0.0 < self.step_margin < 1.0):
            raise ValueError("step_margin must lie in (0, 1)")
        if self.dense not in ("auto", "on", "off"):
            raise ValueError('dense must be one of "auto" | "on" | "off"')


def _uniform_simplex(obj: MatchingObjective) -> UnitSimplexProjection:
    """PDHG's dual objective needs a closed-form min over C; simplex only.

    `min_{x in C} (c + A'y)'x` decomposes per source row as
    `radius * min(0, min_j r_j)` (inequality simplex) or
    `radius * min_j r_j` (equality); other feasible sets would need their own
    support function, so they are rejected rather than silently mis-scored.
    """
    projs = {obj._proj(i) for i in range(len(obj.instance.buckets))}
    if len(projs) != 1 or not isinstance(
        next(iter(projs)), UnitSimplexProjection
    ):
        raise NotImplementedError(
            "PDHG engine supports a uniform simplex feasible set; "
            f"got {projs}"
        )
    return next(iter(projs))


def _use_dense(buckets, num_destinations: int, pcfg: PDHGEngineConfig) -> bool:
    """Static (shape-only) decision for the dense small-shard fast path."""
    if pcfg.dense == "off" or not buckets:
        return False
    if pcfg.dense == "on":
        return True
    l_max = max(int(b.idx.shape[-1]) for b in buckets)
    rows = sum(int(b.idx.shape[0]) for b in buckets)
    slots = sum(int(b.idx.shape[0]) * int(b.idx.shape[-1]) for b in buckets)
    merged = rows * l_max
    # the one-hot apply matrix is [merged, J]; padding every row to the
    # longest bucket must also not blow the working set up
    return (
        merged * num_destinations <= pcfg.dense_max_cells
        and merged <= 4 * max(slots, 1)
    )


def _merge_buckets(buckets, costs):
    """Coalesce per-length bucket slabs into one [rows, L_max] pseudo-bucket.

    Pad entries carry mask 0 / coeff 0, so they behave exactly like the pad
    slots the bucketed form already has; `_gather_at_lam` and the residual
    loop work on the result unchanged.
    """
    from repro.instances.buckets import Bucket

    l_max = max(int(b.idx.shape[-1]) for b in buckets)

    def padded(a):
        pad = [(0, 0)] * (a.ndim - 1) + [(0, l_max - a.shape[-1])]
        return jnp.pad(jnp.asarray(a), pad)

    return Bucket(
        idx=jnp.concatenate(
            [padded(b.idx) for b in buckets], axis=0
        ).astype(jnp.int32),
        coeff=jnp.concatenate([padded(b.coeff) for b in buckets], axis=1),
        cost=jnp.concatenate(
            [padded(c) for c in costs], axis=0
        ).astype(jnp.float32),
        mask=jnp.concatenate(
            [padded(b.mask) for b in buckets], axis=0
        ).astype(jnp.float32),
        length=l_max,
    )


def _dense_onehot(mb, num_destinations: int) -> jax.Array:
    """[J, slots] one-hot destination matrix: `A x` = one dense contraction.

    Built once per solve (a single scatter); pad slots point at bin 0 with
    weight 0 so they contribute nothing.  Stored destination-major so the
    in-loop matvec streams each destination's row contiguously — the
    [slots, J] orientation costs ~20% more per iteration on CPU.
    """
    flat_idx = mb.idx.reshape(-1)
    onehot = jnp.zeros((num_destinations, flat_idx.shape[0]), jnp.float32)
    return onehot.at[
        flat_idx, jnp.arange(flat_idx.shape[0])
    ].set(mb.mask.reshape(-1).astype(jnp.float32))


def _pdhg_core(
    obj: MatchingObjective,
    lam0: jax.Array,
    cfg: MaximizerConfig,
    pcfg: PDHGEngineConfig,
    *,
    fused_oracle: bool,
    kernel_interpret: Optional[bool],
    sigma_sq: jax.Array,
    reduce_sum: Optional[Callable] = None,
    stop_reduce: Optional[Callable] = None,
) -> RawSolve:
    """Pure traced PDHG solve; `reduce_sum` sums partials across shards
    (identity on a single device, `psum` under shard_map)."""
    inst = obj.instance
    m, J = inst.num_families, inst.num_destinations
    proj = _uniform_simplex(obj)
    radius, inequality = proj.radius, proj.inequality
    if reduce_sum is None:
        reduce_sum = lambda v: v  # noqa: E731 - single-shard identity

    buckets = obj._buckets  # fp32 compute views (no-op for fp32 storage)
    costs = tuple(obj._scaled_cost(b) for b in buckets)
    rhs = jnp.asarray(inst.rhs, jnp.float32)
    rhs_norm = jnp.linalg.norm(rhs)
    c_sq_local = sum(
        jnp.vdot(c * b.mask, c * b.mask) for b, c in zip(buckets, costs)
    )
    c_norm = jnp.sqrt(reduce_sum(jnp.asarray(c_sq_local, jnp.float32)))

    sigma = jnp.sqrt(jnp.maximum(jnp.asarray(sigma_sq, jnp.float32), 1e-20))
    tau = jnp.asarray(pcfg.step_margin * pcfg.step_ratio, jnp.float32) / sigma
    sig = jnp.asarray(pcfg.step_margin / pcfg.step_ratio, jnp.float32) / sigma

    # ---- dense small-shard fast path (see module docstring) ---------------
    dense = _use_dense(buckets, J, pcfg)
    if dense:
        from repro.core.projections import project_simplex_cmp

        split_shapes = [
            (int(b.idx.shape[0]), int(b.idx.shape[-1])) for b in buckets
        ]
        mb = _merge_buckets(buckets, costs)
        onehot = _dense_onehot(mb, J)
        buckets = (mb,)
        costs = (mb.cost,)
        projs = [
            lambda z, mask: project_simplex_cmp(
                z, mask, radius, inequality=inequality
            )
        ]

        def dense_apply_a(xs):
            contrib = (mb.coeff * xs).reshape(m, -1)
            # contract slots against the [J, slots] one-hot: rows stream
            # contiguously, result is [m, J]
            return jax.lax.dot_general(
                contrib, onehot, (((1,), (1,)), ((), ()))
            ).reshape(-1)

    else:
        projs = [obj._proj(i) for i in range(len(buckets))]

    # ---- one primal prox step + the A x+ apply ----------------------------
    if dense:
        # ax-free iteration: A is linear, so the dual step's extrapolated
        # apply folds into the single dense contraction, A(2 x+ - x).  The
        # scan then carries only (x, y) — no A x buffer, no carry copies —
        # and residual checks recompute A x with one extra dot per check.
        def primal_step(x, y):
            y2 = y.reshape(m, J)
            z = x[0] - tau * (_gather_at_lam(mb, y2) + mb.cost)
            xn = projs[0](z, mb.mask)
            axbar = reduce_sum(dense_apply_a(2.0 * xn - x[0]))
            return (xn,), axbar

    elif fused_oracle:

        def primal_step(x, y):
            new = []
            ax = jnp.zeros((m, J), jnp.float32)
            for b, c, xs in zip(buckets, costs, x):
                xn, hist = kops.fused_pdhg_step(
                    b.idx, b.coeff, c, b.mask, xs, y, tau,
                    num_destinations=J,
                    radius=radius,
                    inequality=inequality,
                    interpret=kernel_interpret,
                )
                new.append(xn)
                ax = ax + hist
            return tuple(new), reduce_sum(ax.reshape(-1))

    else:

        def primal_step(x, y):
            y2 = y.reshape(m, J)
            new = []
            for i, (b, c, xs) in enumerate(zip(buckets, costs, x)):
                z = xs - tau * (_gather_at_lam(b, y2) + c)
                new.append(obj._proj(i)(z, b.mask))
            xt = tuple(new)
            return xt, reduce_sum(obj.apply_A(xt))

    # ---- D-PDLP relative residuals ----------------------------------------
    def residuals(x, y, ax):
        """(primal_obj, dual_obj, rel_primal, rel_dual, rel_gap)."""
        viol = jnp.maximum(ax - rhs, 0.0)
        pr = jnp.linalg.norm(viol) / (1.0 + rhs_norm)
        y2 = y.reshape(m, J)
        pobj_loc = jnp.float32(0.0)
        dr_loc = jnp.float32(0.0)
        dual_loc = jnp.float32(0.0)
        for i, (b, c, xs) in enumerate(zip(buckets, costs, x)):
            r = _gather_at_lam(b, y2) + c
            pg = xs - projs[i](xs - r, b.mask)
            pobj_loc = pobj_loc + jnp.vdot(c * b.mask, xs)
            dr_loc = dr_loc + jnp.vdot(pg, pg)
            rmin = jnp.min(jnp.where(b.mask > 0, r, jnp.inf), axis=-1)
            has = jnp.any(b.mask > 0, axis=-1)
            contrib = radius * (
                jnp.minimum(rmin, 0.0) if inequality else rmin
            )
            dual_loc = dual_loc + jnp.sum(jnp.where(has, contrib, 0.0))
        sums = reduce_sum(jnp.stack([pobj_loc, dual_loc, dr_loc]))
        pobj = sums[0]
        dobj = sums[1] - jnp.vdot(rhs, y)
        dr = jnp.sqrt(jnp.maximum(sums[2], 0.0)) / (1.0 + c_norm)
        gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
        return pobj, dobj, pr, dr, gap

    # ---- iteration body with the selected restart scheme ------------------
    scheme = pcfg.restart
    every = int(pcfg.restart_every)

    def one_iter(state, _):
        x, y, ax, it, restarts, extra = state
        xn, axn = primal_step(x, y)
        if dense:
            # primal_step returned A(2 x+ - x) directly; nothing is carried
            yn = jnp.maximum(y + sig * (axn - rhs), 0.0)
            axn = None
        else:
            yn = jnp.maximum(y + sig * (2.0 * axn - ax - rhs), 0.0)
        # only the fixed-cadence schemes read the in-loop counter; keeping it
        # frozen otherwise saves a whole dispatch per iteration on the dense
        # fast path (reported iteration counts come from `checks_used`)
        it1 = it + 1 if scheme in ("ergodic", "halpern") else it
        if scheme == "none":
            return (xn, yn, axn, it1, restarts, extra), None
        if scheme in ("ergodic", "adaptive"):
            xs_sum, y_sum, ax_sum, win = extra[:4]
            xs_sum = jax.tree.map(lambda s, v: s + v, xs_sum, xn)
            y_sum, win = y_sum + yn, win + 1
            ax_sum = None if dense else ax_sum + axn
            if scheme == "ergodic":
                do = (it1 % every) == 0
                wf = jnp.maximum(win.astype(jnp.float32), 1.0)
                xn = jax.tree.map(
                    lambda s, v: jnp.where(do, s / wf, v), xs_sum, xn
                )
                yn = jnp.where(do, y_sum / wf, yn)
                if not dense:
                    axn = jnp.where(do, ax_sum / wf, axn)
                zero = lambda s: jnp.where(do, jnp.zeros_like(s), s)  # noqa: E731
                xs_sum = jax.tree.map(zero, xs_sum)
                y_sum = zero(y_sum)
                ax_sum = None if dense else zero(ax_sum)
                win = jnp.where(do, 0, win)
                restarts = restarts + do.astype(jnp.int32)
            extra = (xs_sum, y_sum, ax_sum, win) + extra[4:]
            return (xn, yn, axn, it1, restarts, extra), None
        # halpern: blend toward the anchor, re-anchor on a fixed cadence
        xa, ya, axa, t = extra
        w = (t + 1.0) / (t + 2.0)
        xn = jax.tree.map(lambda v, a: w * v + (1.0 - w) * a, xn, xa)
        yn = w * yn + (1.0 - w) * ya
        if not dense:
            axn = w * axn + (1.0 - w) * axa
        do = (it1 % every) == 0
        xa = jax.tree.map(lambda a, v: jnp.where(do, v, a), xa, xn)
        ya = jnp.where(do, yn, ya)
        axa = None if dense else jnp.where(do, axn, axa)
        t = jnp.where(do, 0.0, t + 1.0)
        restarts = restarts + do.astype(jnp.int32)
        return (xn, yn, axn, it1, restarts, (xa, ya, axa, t)), None

    total = int(cfg.total_iter_budget)
    inner = max(1, min(int(cfg.check_every), total))
    n_checks = -(-total // inner)
    tol = cfg.tol_grad if cfg.tol_grad is not None else cfg.tol_viol

    def body(carry, _):
        carry, _ = jax.lax.scan(one_iter, carry, None, length=inner)
        x, y, ax, it, restarts, extra = carry
        if dense:
            # the ax-free dense carry recomputes A x once per check
            ax = reduce_sum(dense_apply_a(x[0]))
        if scheme == "adaptive":
            # D-PDLP sufficient-decay restart: compare the current iterate
            # against the window average by merit, adopt the better one when
            # it beats the merit at the last restart by `restart_threshold`.
            xs_sum, y_sum, ax_sum, win, merit_last = extra
            wf = jnp.maximum(win.astype(jnp.float32), 1.0)
            x_avg = jax.tree.map(lambda s: s / wf, xs_sum)
            y_avg = y_sum / wf
            ax_avg = (
                reduce_sum(dense_apply_a(x_avg[0])) if dense
                else ax_sum / wf
            )
            po_c, _, pr_c, dr_c, gap_c = residuals(x, y, ax)
            po_a, _, pr_a, dr_a, gap_a = residuals(x_avg, y_avg, ax_avg)
            merit_c = jnp.maximum(gap_c, jnp.maximum(pr_c, dr_c))
            merit_a = jnp.maximum(gap_a, jnp.maximum(pr_a, dr_a))
            use_avg = merit_a < merit_c
            merit_cand = jnp.minimum(merit_a, merit_c)
            do = merit_cand <= pcfg.restart_threshold * merit_last
            adopt_avg = jnp.logical_and(do, use_avg)
            sel = lambda a, c: jnp.where(adopt_avg, a, c)  # noqa: E731
            x = jax.tree.map(sel, x_avg, x)
            y, ax = sel(y_avg, y), sel(ax_avg, ax)
            po, pr = sel(po_a, po_c), sel(pr_a, pr_c)
            dr, gap = sel(dr_a, dr_c), sel(gap_a, gap_c)
            zero = lambda s: jnp.where(do, jnp.zeros_like(s), s)  # noqa: E731
            xs_sum = jax.tree.map(zero, xs_sum)
            y_sum = zero(y_sum)
            ax_sum = None if dense else zero(ax_sum)
            win = jnp.where(do, 0, win)
            merit_last = jnp.where(do, merit_cand, merit_last)
            restarts = restarts + do.astype(jnp.int32)
            extra = (xs_sum, y_sum, ax_sum, win, merit_last)
        else:
            po, _, pr, dr, gap = residuals(x, y, ax)
        if dense:
            ax = None  # keep the scan carry ax-free
        f32 = lambda v: v.astype(jnp.float32)  # noqa: E731
        return (
            (x, y, ax, it, restarts, extra),
            (f32(po), f32(dr), f32(pr), f32(gap)),
        )

    def stop_predicate(traces):
        if tol is None:
            return jnp.asarray(False)
        _, dr, pr, gap = traces
        t = jnp.float32(tol)
        return jnp.logical_and(
            jnp.logical_and(pr[-1] <= t, dr[-1] <= t), gap[-1] <= t
        )

    # ---- initial point: reconstruct the primal serving publishes ----------
    y0 = jnp.asarray(lam0, jnp.float32)
    x0 = obj.primal_candidate(y0, jnp.float32(cfg.gammas[-1]))
    x0 = tuple(xs.astype(jnp.float32) for xs in x0)
    if dense:
        l_max = mb.idx.shape[-1]
        x0 = (
            jnp.concatenate(
                [
                    jnp.pad(xs, ((0, 0), (0, l_max - xs.shape[-1])))
                    for xs in x0
                ],
                axis=0,
            ),
        )
        ax0 = None  # ax-free carry; recomputed from x at check boundaries
    else:
        ax0 = reduce_sum(obj.apply_A(x0)).astype(jnp.float32)
    zero_x = jax.tree.map(jnp.zeros_like, x0)
    i32 = partial(jnp.asarray, dtype=jnp.int32)
    if scheme in ("ergodic", "adaptive"):
        ax_sum0 = None if dense else jnp.zeros_like(ax0)
        extra0 = (zero_x, jnp.zeros_like(y0), ax_sum0, i32(0))
        if scheme == "adaptive":
            extra0 = extra0 + (jnp.float32(jnp.inf),)
    elif scheme == "halpern":
        extra0 = (x0, y0, ax0, jnp.float32(0.0))
    else:
        extra0 = ()
    carry0 = (x0, y0, ax0, i32(0), i32(0), extra0)

    final, bufs, checks_used = _chunked_early_scan(
        body,
        carry0,
        n_checks,
        check_every=1,  # `body` already runs `inner` iterations per call
        trace_dtype=jnp.float32,
        num_traces=4,
        stop_predicate=stop_predicate,
        stop_reduce=stop_reduce,
    )
    x, y, ax, _, restarts, _ = final
    if dense:
        ax = reduce_sum(dense_apply_a(x[0]))
    pobj, _, _, _, _ = residuals(x, y, ax)
    iters = (checks_used * inner).astype(jnp.int32)
    if dense:
        # hand back per-bucket slabs (the RawSolve contract serving relies
        # on); pad columns beyond each bucket's true length are exact zeros
        merged_x, parts, off = x[0], [], 0
        for rows_i, len_i in split_shapes:
            parts.append(merged_x[off:off + rows_i, :len_i])
            off += rows_i
        x = tuple(parts)
    stats = (
        StageStats(g=bufs[0], grad_norm=bufs[1], max_violation=bufs[2]),
    )
    return RawSolve(
        lam=y,
        x_slabs=x,
        g=pobj,
        stats=stats,
        sigma_sq=jnp.asarray(sigma_sq, jnp.float32),
        etas=jnp.stack([tau]),
        iters=jnp.stack([iters]),
        restarts=restarts,
    )


def pdhg_raw_solve(
    inst: BucketedInstance,
    lam0: jax.Array,
    cfg: MaximizerConfig,
    normalize: bool,
    fused_oracle: bool = False,
    sigma_sq: Optional[jax.Array] = None,
    pcfg: PDHGEngineConfig = PDHGEngineConfig(),
    kernel_interpret: Optional[bool] = None,
) -> RawSolve:
    """Single-shard (or vmapped) structured PDHG solve -> RawSolve.

    Mirrors `agd_raw_solve`'s contract exactly: pure in the instance pytree,
    Jacobi-normalizes device-side when asked, runs the power iteration only
    when no `sigma_sq` is supplied (the service's engine-agnostic sigma
    cache feeds both engines — sigma_max(A) doesn't care which solver uses
    it).
    """
    if normalize:
        inst, _ = normalize_rows_traced(inst)
    obj = MatchingObjective(inst, kernel_interpret=kernel_interpret)
    if sigma_sq is None:
        sigma_sq = obj.power_iteration(
            jax.random.key(cfg.seed), iters=cfg.power_iters
        )
    return _pdhg_core(
        obj, lam0, cfg, pcfg,
        fused_oracle=fused_oracle,
        kernel_interpret=kernel_interpret,
        sigma_sq=sigma_sq,
    )


class PDHGEngine:
    """Engine-protocol wrapper over `pdhg_raw_solve`."""

    name = "pdhg"

    @staticmethod
    def raw_solve(
        inst,
        lam0,
        cfg: MaximizerConfig,
        *,
        normalize: bool,
        fused_oracle: bool = False,
        sigma_sq=None,
    ) -> RawSolve:
        return pdhg_raw_solve(
            inst, lam0, cfg, normalize, fused_oracle, sigma_sq
        )


PDHG_ENGINE = PDHGEngine()


# ---------------------------------------------------------------------------
# Distributed wrapper: same core, psum hooks, collective early stop.
# ---------------------------------------------------------------------------


def _sharded_fns(inst, mesh, cfg, dist, pcfg, projection):
    """Build the shard_map'ped (power_fn, solve_fn) pair for `inst`'s shapes.

    Shared by the run path (`solve_pdhg_sharded`) and the dry-run lowering
    path (`lower_pdhg_sharded`) so both compile the identical program.
    """
    from repro.core.sharding import instance_pspecs, num_shards
    axes = dist.axes_tuple
    specs = instance_pspecs(inst, dist.axes)
    slab_specs = tuple(P(dist.axes, None) for _ in inst.buckets)
    n_shards = num_shards(mesh, dist)
    psum = lambda v: jax.lax.psum(v, axes)  # noqa: E731

    def psum_all_converged(done):
        votes = jax.lax.psum(done.astype(jnp.int32), axes)
        return votes == n_shards

    def local_objective(inst_local):
        return MatchingObjective(
            inst_local,
            projection=projection or UnitSimplexProjection(),
            include_rhs=False,
            kernel_interpret=dist.kernel_interpret,
        )

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), specs),
        out_specs=P(),
        check_rep=False,
    )
    def power_fn(u0, inst_local):
        obj = local_objective(inst_local)

        def body(u, _):
            atl = obj.apply_AT(u / jnp.linalg.norm(u))
            au = psum(obj.apply_A(atl))
            return au, jnp.linalg.norm(au)

        _, norms = jax.lax.scan(body, u0, None, length=cfg.power_iters)
        return norms[-1]

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), specs),
        out_specs=(
            P(),
            slab_specs,
            P(),
            StageStats(P(), P(), P()),
            P(),
            P(),
            P(),
        ),
        check_rep=False,
    )
    def solve_fn(lam_in, sigma_sq_in, inst_local):
        obj = local_objective(inst_local)
        raw = _pdhg_core(
            obj, lam_in, cfg, pcfg,
            fused_oracle=dist.fused_oracle,
            kernel_interpret=dist.kernel_interpret,
            sigma_sq=sigma_sq_in,
            reduce_sum=psum,
            stop_reduce=psum_all_converged,
        )
        return (
            raw.lam, raw.x_slabs, raw.g, raw.stats[0],
            raw.etas, raw.iters, raw.restarts,
        )

    return power_fn, solve_fn


def solve_pdhg_sharded(
    inst: BucketedInstance,
    mesh: Mesh,
    cfg: MaximizerConfig = MaximizerConfig(),
    dist=None,
    pcfg: PDHGEngineConfig = PDHGEngineConfig(),
    lam0: Optional[jax.Array] = None,
    projection=None,
) -> SolveResult:
    """Column-sharded PDHG over a device mesh (paper §4.4 layout).

    The engine core is reused verbatim with two hooks swapped in: partial
    sums cross shards through ONE `psum` per iteration (the `A x+` vector;
    residual scalars piggyback once per check), and the early-stop predicate
    is reduced with the same unanimous-vote psum as the distributed AGD path
    (`core.sharding.DistributedMaximizer`), keeping every shard at an
    identical while_loop trip count.

    Instances should be pre-normalized host-side (`normalize_rows`) when
    Jacobi conditioning is wanted — row norms are a global reduction, so the
    traced per-shard `normalize_rows_traced` doesn't apply here (same policy
    as the distributed AGD driver).  PDHG ignores `dist.comm_mode`/`compress`
    (always plain psum, no error feedback).
    """
    from repro.core.sharding import DistConfig

    dist = dist or DistConfig()
    power_fn, solve_fn = _sharded_fns(inst, mesh, cfg, dist, pcfg, projection)
    dual_dim = inst.dual_dim
    lam = (
        jnp.zeros((dual_dim,), jnp.float32) if lam0 is None
        else jnp.asarray(lam0, jnp.float32)
    )
    u0 = jax.random.normal(
        jax.random.key(cfg.seed), (dual_dim,), jnp.float32
    )
    with compat.set_mesh(mesh):
        sigma_sq = jax.jit(power_fn)(u0, inst)
        lam, x_slabs, g, st, etas, iters, restarts = jax.jit(solve_fn)(
            lam, sigma_sq, inst
        )
    return SolveResult(
        lam=lam,
        x_slabs=x_slabs,
        g=g,
        stats=(st,),
        sigma_sq=sigma_sq,
        steps=(float(etas[0]),),
        iters_used=(int(iters[0]),),
        restarts=int(restarts),
    )


def lower_pdhg_sharded(
    inst: BucketedInstance,
    mesh: Mesh,
    cfg: MaximizerConfig = MaximizerConfig(),
    dist=None,
    pcfg: PDHGEngineConfig = PDHGEngineConfig(),
    projection=None,
):
    """Lower (without running) the sharded PDHG solve under its production
    shardings — the dry-run coherence proof (`launch/dryrun.py`): the
    returned Lowered yields memory/cost analysis and collective bytes after
    `.compile()`.  Accepts a spec-shaped instance (ShapeDtypeStruct leaves).
    """
    from repro.core.sharding import DistConfig

    dist = dist or DistConfig()
    _, solve_fn = _sharded_fns(inst, mesh, cfg, dist, pcfg, projection)
    lam = jax.ShapeDtypeStruct((inst.dual_dim,), jnp.float32)
    sigma_sq = jax.ShapeDtypeStruct((), jnp.float32)
    with compat.set_mesh(mesh):
        return jax.jit(solve_fn).lower(lam, sigma_sq, inst)
