"""Per-tenant adaptive engine selection (`ServiceConfig.engine = "auto"`).

The scheduler observes, per tenant, how many iterations each engine needed
to hit tolerance, keeps a decayed (EWMA) score per (tenant, engine), and
routes the tenant to the cheaper engine at dispatch time.  Cheap by design:

  * **Exploration** is bounded and deterministic — each engine must be tried
    `explore_cadences` times before scores are trusted, and the exploration
    ORDER is rotated by a stable hash of the tenant name (crc32, not
    Python's salted `hash`), so a mixed workload exercises both engines from
    cadence 0 and a restored checkpoint replays identical routing.
  * **Non-convergence is penalized**, not ignored: a solve that exhausted
    its budget scores `iters * penalty`, so an engine that burns the whole
    budget without converging loses to one that converges in the same
    iterations.
  * **Scores decay** (`s <- decay * s + (1-decay) * obs`), so a tenant whose
    instance drifts toward the other engine's sweet spot migrates after a
    few cadences instead of being grandfathered forever.

State is two plain dicts (JSON-serializable), checkpointed through the
scheduler's meta blob (`Scheduler.state_dict()["meta"]["engine_selector"]`)
and surfaced per solve in `solve_report.engine` plus the
`engine_selected_total{tenant,engine}` counter.
"""
from __future__ import annotations

import zlib
from typing import Dict, Optional

from repro.engines.base import ENGINES

__all__ = ["EngineSelector"]


def _stable_rotation(tenant: str, n: int) -> int:
    return zlib.crc32(tenant.encode("utf-8")) % n


class EngineSelector:
    """Decaying iterations-to-tol tracker with deterministic routing."""

    def __init__(
        self,
        decay: float = 0.7,
        explore_cadences: int = 1,
        penalty: float = 2.0,
    ):
        if not (0.0 <= decay < 1.0):
            raise ValueError("decay must lie in [0, 1)")
        self.decay = float(decay)
        self.explore_cadences = int(explore_cadences)
        self.penalty = float(penalty)
        self._scores: Dict[str, Dict[str, float]] = {}
        self._counts: Dict[str, Dict[str, int]] = {}

    # ---- routing ----------------------------------------------------------
    def exploration_order(self, tenant: str) -> tuple[str, ...]:
        r = _stable_rotation(tenant, len(ENGINES))
        return ENGINES[r:] + ENGINES[:r]

    def choose(self, tenant: str) -> str:
        """Engine for this tenant's next solve (pure given observed state)."""
        counts = self._counts.get(tenant, {})
        order = self.exploration_order(tenant)
        for engine in order:
            if counts.get(engine, 0) < self.explore_cadences:
                return engine
        scores = self._scores[tenant]
        # ties break on the engine name so routing is reproducible
        return min(order, key=lambda e: (scores[e], e))

    # ---- observation ------------------------------------------------------
    def observe(
        self, tenant: str, engine: str, iters: int, converged: bool
    ) -> None:
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}")
        obs = float(iters) * (1.0 if converged else self.penalty)
        scores = self._scores.setdefault(tenant, {})
        counts = self._counts.setdefault(tenant, {})
        if engine in scores:
            scores[engine] = self.decay * scores[engine] + (
                1.0 - self.decay
            ) * obs
        else:
            scores[engine] = obs
        counts[engine] = counts.get(engine, 0) + 1

    # ---- checkpointing ----------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "decay": self.decay,
            "explore_cadences": self.explore_cadences,
            "penalty": self.penalty,
            "scores": {t: dict(s) for t, s in self._scores.items()},
            "counts": {t: dict(c) for t, c in self._counts.items()},
        }

    def load_state(self, state: Optional[dict]) -> None:
        if not state:
            return
        self.decay = float(state.get("decay", self.decay))
        self.explore_cadences = int(
            state.get("explore_cadences", self.explore_cadences)
        )
        self.penalty = float(state.get("penalty", self.penalty))
        self._scores = {
            t: {e: float(v) for e, v in s.items()}
            for t, s in state.get("scores", {}).items()
        }
        self._counts = {
            t: {e: int(v) for e, v in c.items()}
            for t, c in state.get("counts", {}).items()
        }
