"""AGD engine: the paper's smoothed-dual continuation solve as an Engine.

This is the service's original `_raw_solve` (repro.service.engine) relocated
behind the engine contract — the full gamma-continuation schedule of
accelerated projected dual ascent, with convergence-based early stopping per
stage when the config carries tolerances.  The service keeps compiling and
caching it exactly as before; the move only makes "which solver" a value
(`repro.engines.base.resolve_engine`) instead of an assumption.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.maximizer import (
    MaximizerConfig,
    StageStats,
    _stage_scan,
    _stage_scan_early,
    step_size,
)
from repro.core.objective import MatchingObjective, normalize_rows_traced
from repro.engines.base import RawSolve
from repro.instances.buckets import BucketedInstance

__all__ = ["AGDEngine", "AGD_ENGINE", "agd_raw_solve"]


def agd_raw_solve(
    inst: BucketedInstance,
    lam0: jax.Array,
    cfg: MaximizerConfig,
    normalize: bool,
    fused_oracle: bool = False,
    sigma_sq: Optional[jax.Array] = None,
) -> RawSolve:
    """Full continuation solve as a pure traced function of the instance.

    ``sigma_sq=None`` runs the power iteration (~cfg.power_iters oracle
    calls); a traced scalar skips it and reuses the caller's estimate — the
    warm-cadence path (`SolveSession`) passes the previous solve's value when
    the coefficients haven't drifted, since sigma_max(A) is a function of A
    alone (see `repro.service.engine.compiled_solver_fixed_sigma`).
    """
    if normalize:
        # Jacobi preconditioning applied device-side each solve, so the
        # delta-mutated raw slabs never need a host-side re-normalization
        inst, _ = normalize_rows_traced(inst)
    obj = MatchingObjective(inst, fused_oracle=fused_oracle)

    def calc(lam, gamma, comm):
        return obj.calculate(lam, gamma), comm

    if sigma_sq is None:
        sigma_sq = obj.power_iteration(
            jax.random.key(cfg.seed), iters=cfg.power_iters
        )
    lam = lam0
    stats: list[StageStats] = []
    etas: list[jax.Array] = []
    iters: list[jax.Array] = []
    for gamma in cfg.gammas:
        eta = step_size(cfg, sigma_sq, gamma).astype(lam.dtype)
        gamma_t = jnp.asarray(gamma, lam.dtype)
        if cfg.early_stop:
            # stop_reduce=None: the service engine is single-shard (or
            # vmapped, where the batch runs lockstep anyway), so the local
            # convergence predicate IS the global one.  The distributed path
            # (core.sharding) passes a psum'd all-shards-agree reduction here.
            lam, st, _, used = _stage_scan_early(
                calc, lam, gamma_t, eta, cfg.iters_per_stage,
                acceleration=cfg.acceleration,
                adaptive_restart=cfg.adaptive_restart,
                tol_grad=cfg.tol_grad,
                tol_viol=cfg.tol_viol,
                check_every=cfg.check_every,
                stop_reduce=None,
            )
        else:
            lam, st, _ = _stage_scan(
                calc, lam, gamma_t, eta, cfg.iters_per_stage,
                acceleration=cfg.acceleration,
                adaptive_restart=cfg.adaptive_restart,
            )
            used = jnp.asarray(cfg.iters_per_stage, jnp.int32)
        stats.append(st)
        etas.append(eta)
        iters.append(used)
    final = obj.calculate(lam, jnp.asarray(cfg.gammas[-1], lam.dtype))
    return RawSolve(
        lam=lam,
        x_slabs=final.x_slabs,
        g=final.g,
        stats=tuple(stats),
        sigma_sq=sigma_sq,
        etas=jnp.stack(etas),
        iters=jnp.stack(iters),
        # AGD's O'Donoghue–Candès momentum resets happen inside the scan and
        # are not individually counted; the restart budget telemetry is a
        # PDHG concept (anchor/ergodic restarts).
        restarts=jnp.asarray(0, jnp.int32),
    )


class AGDEngine:
    """Engine-protocol wrapper over `agd_raw_solve`."""

    name = "agd"

    @staticmethod
    def raw_solve(
        inst,
        lam0,
        cfg: MaximizerConfig,
        *,
        normalize: bool,
        fused_oracle: bool = False,
        sigma_sq=None,
    ) -> RawSolve:
        return agd_raw_solve(
            inst, lam0, cfg, normalize, fused_oracle, sigma_sq
        )


AGD_ENGINE = AGDEngine()
