"""Engine contract: the layer between the dual oracle and the service.

A solver *engine* is anything that can run one full solve of a
`BucketedInstance` as a pure traced function and return a `RawSolve` — the
vmap-friendly device pytree the service compiles, caches, batches and absorbs
(`repro.service.engine`).  Two engines ship today:

  * ``"agd"``  — smoothed-dual accelerated gradient ascent with
    gamma-continuation (the paper's Maximizer; `repro.engines.agd`);
  * ``"pdhg"`` — structured primal-dual hybrid gradient on the same
    bucketed-ELL form, with restarts and D-PDLP-style relative-residual
    termination (`repro.engines.pdhg`).

The contract every engine satisfies:

  * **solve**: ``raw_solve(inst, lam0, cfg, normalize=..., fused_oracle=...,
    sigma_sq=None) -> RawSolve`` is pure in the instance pytree (jit / vmap /
    shard_map safe), derives every hyperparameter from the shared
    `MaximizerConfig` (budgets, tolerances, check cadence), runs the power
    iteration itself when ``sigma_sq`` is None and reuses the caller's
    estimate otherwise (sigma_max(A) is a function of A alone, so the
    service's sigma cache is engine-agnostic).
  * **warm state**: the dual vector ``lam`` lives in the SAME [m*J] space for
    every engine (the coupling-row multipliers, Jacobi-scaled when
    ``normalize``), so yesterday's duals warm-start either engine — the
    scheduler can re-route a tenant without losing its warm state.
  * **stats**: ``RawSolve.stats`` is a tuple of `StageStats` traces and
    ``iters`` the per-stage iteration counts, consumed unchanged by
    `telemetry.ConvergenceTrace.from_result` (PDHG emits one stage at
    `check_every` resolution; `trace_stride` bridges the granularity).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax

from repro.core.maximizer import MaximizerConfig, StageStats

__all__ = ["ENGINES", "Engine", "RawSolve", "resolve_engine"]

#: Engine names the service accepts; "auto" is a scheduler policy on top
#: (`repro.engines.selector`), not an engine.
ENGINES: tuple[str, ...] = ("agd", "pdhg")


class RawSolve(NamedTuple):
    """Device-side output of one engine solve (vmap-friendly pytree)."""

    lam: jax.Array  # [dual_dim]
    x_slabs: tuple[jax.Array, ...]
    g: jax.Array  # final objective value (scalar; engine-native sign)
    stats: tuple[StageStats, ...]  # one per stage, traces of length budget
    sigma_sq: jax.Array
    etas: jax.Array  # [num_stages] step sizes
    iters: jax.Array  # [num_stages] iterations executed (int32)
    restarts: jax.Array  # scalar int32: momentum/anchor restarts taken


@runtime_checkable
class Engine(Protocol):
    """Static engine object: a name plus the pure raw-solve entry point."""

    name: str

    def raw_solve(
        self,
        inst,
        lam0: jax.Array,
        cfg: MaximizerConfig,
        *,
        normalize: bool,
        fused_oracle: bool = False,
        sigma_sq: Optional[jax.Array] = None,
    ) -> RawSolve:
        ...


def resolve_engine(name: str) -> Engine:
    """Engine registry lookup; raises ValueError on unknown names."""
    from repro.engines.agd import AGD_ENGINE
    from repro.engines.pdhg import PDHG_ENGINE

    engines = {"agd": AGD_ENGINE, "pdhg": PDHG_ENGINE}
    try:
        return engines[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; choose from {ENGINES}"
        ) from None
