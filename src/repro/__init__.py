"""repro: Large-Scale Regularized Matching on TPU Pods.

JAX/Pallas reproduction of Rahmattalabi et al. (CS.DC 2026) — distributed
ridge-regularized matching LP solver — plus the assigned 10-architecture LM
pool on the same multi-pod substrate.  See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
