"""Pure-jnp oracles for the Pallas kernels.

`simplex_ref` is exactly the paper's multi-launch PyTorch-eager Duchi pipeline
(sort -> cumsum -> cutoff -> threshold -> subtract-and-clamp); `dual_primal_ref`
is the unfused primal step  x = Pi_simplex( -(A^T lam + c) / gamma )  for one
bucket slab; `dual_oracle_ref` is the whole one-pass oracle (primal slab +
this bucket's A x histogram + the c'x / ||x||^2 partials) expressed as a
single traced function — it is both the ground truth the dual-oracle kernel
tests compare against and the off-TPU execution path `ops.fused_dual_oracle`
dispatches to (XLA fuses its passes; the kernel's one-hot MXU contraction
does not pay off on a scalar backend).  Kernel tests sweep shapes/dtypes and
assert_allclose against these.

Mixed-precision slabs: both oracles accept narrow-dtype (bf16 / int8+scales)
slabs and mirror the kernels' accumulation contract — inputs are widened to
fp32 on load (`_f32`; int8 additionally multiplied by its per-bucket scales),
every reduction (projection, Ax histogram, c'x, ||x||^2) runs in fp32, and
the primal slab is written back in the storage dtype for float storage (fp32
for int8).  The widening is a *host-level dtype branch*: fp32 inputs take
the exact pre-slab_dtype expressions, so the default path's jaxpr is
bit-identical (the `--slab-dtype float32` array_equal pin relies on this).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.objective import binned_segment_sum
from repro.core.projections import project_simplex

__all__ = ["simplex_ref", "dual_primal_ref", "dual_oracle_ref"]


def _f32(a: jax.Array) -> jax.Array:
    """Widen to fp32 — host no-op (same object, same jaxpr) for fp32 input."""
    return a if a.dtype == jnp.float32 else a.astype(jnp.float32)


def simplex_ref(
    v: jax.Array,
    mask: jax.Array,
    radius: float = 1.0,
    *,
    inequality: bool = True,
) -> jax.Array:
    """Reference masked Duchi projection (identical semantics to the kernel)."""
    return project_simplex(v, mask, radius, inequality=inequality)


def dual_primal_ref(
    idx: jax.Array,  # [n, L] int32 destination ids
    coeff: jax.Array,  # [m, n, L] constraint coefficients (slab dtype)
    cost: jax.Array,  # [n, L] (slab dtype)
    mask: jax.Array,  # [n, L] (slab dtype)
    lam: jax.Array,  # [m * J] fp32
    gamma,
    J: int,
    radius: float = 1.0,
    *,
    inequality: bool = True,
    coeff_scale: Optional[jax.Array] = None,  # [m, 1, 1] f32 (int8 slabs)
    cost_scale: Optional[jax.Array] = None,  # [1, 1] f32 (int8 slabs)
) -> jax.Array:
    """Unfused primal step for one bucket: gather, axpy, scale, project.

    Narrow slab dtypes are widened to fp32 (dequantized for int8) before the
    gather/axpy; the projection runs in fp32 and the result is cast back to
    the storage dtype for float storage (fp32 when quantized).
    """
    out_dtype = cost.dtype if coeff_scale is None else jnp.float32
    coeff, cost, mask = _f32(coeff), _f32(cost), _f32(mask)
    if coeff_scale is not None:
        coeff = coeff * coeff_scale
    if cost_scale is not None:
        cost = cost * cost_scale
    m = coeff.shape[0]
    lam2 = lam.reshape(m, J)
    atl = jnp.einsum("mnl,mnl->nl", coeff, jnp.take(lam2, idx, axis=1))
    z = -(atl + cost) / jnp.asarray(gamma, cost.dtype)
    x = project_simplex(z, mask, radius, inequality=inequality)
    return x if x.dtype == out_dtype else x.astype(out_dtype)


def dual_oracle_ref(
    idx: jax.Array,  # [n, L] int32 destination ids
    coeff: jax.Array,  # [m, n, L] constraint coefficients (slab dtype)
    cost: jax.Array,  # [n, L] (slab dtype)
    mask: jax.Array,  # [n, L] (slab dtype)
    lam: jax.Array,  # [m * J] fp32
    gamma,
    J: int,
    radius: float = 1.0,
    *,
    inequality: bool = True,
    coeff_scale: Optional[jax.Array] = None,  # [m, 1, 1] f32 (int8 slabs)
    cost_scale: Optional[jax.Array] = None,  # [1, 1] f32 (int8 slabs)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass oracle for one bucket: `(x, hist, lin, sq)` where

        x    [n, L]  = Pi_simplex( -(A^T lam + c)/gamma )
        hist [m, J]  = this bucket's contribution to A x
        lin  scalar  = c'x        (this bucket's part)
        sq   scalar  = ||x||^2    (this bucket's part)

    Mathematically identical to primal-then-`_segment_sum_ax`-then-vdots, but
    expressed as one traced function so a single jit fuses all passes and no
    [m, n, L] gradient intermediates outlive the oracle.  The projection
    multiplies by `mask`, so x is already exact-zero on padded slots and the
    histogram/scalars need no re-masking.

    Accumulation contract (matches the kernel): hist/lin/sq reduce the fp32
    primal tile; the returned x is in the slab storage dtype for float
    storage (fp32 when quantized), exactly what the kernel writes back.
    """
    out_dtype = cost.dtype if coeff_scale is None else jnp.float32
    coeff, cost, mask = _f32(coeff), _f32(cost), _f32(mask)
    if coeff_scale is not None:
        coeff = coeff * coeff_scale
    if cost_scale is not None:
        cost = cost * cost_scale
    x = dual_primal_ref(
        idx, coeff, cost, mask, lam, gamma, J, radius, inequality=inequality
    )
    hist = binned_segment_sum(idx, (coeff * x[None]).astype(jnp.float32), J)
    lin = jnp.vdot(cost, x)
    sq = jnp.vdot(x, x)
    x_out = x if x.dtype == out_dtype else x.astype(out_dtype)
    return x_out, hist, lin.astype(jnp.float32), sq.astype(jnp.float32)
