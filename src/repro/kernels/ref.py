"""Pure-jnp oracles for the Pallas kernels.

`simplex_ref` is exactly the paper's multi-launch PyTorch-eager Duchi pipeline
(sort -> cumsum -> cutoff -> threshold -> subtract-and-clamp); `dual_primal_ref`
is the unfused primal step  x = Pi_simplex( -(A^T lam + c) / gamma )  for one
bucket slab.  Kernel tests sweep shapes/dtypes and assert_allclose against
these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projections import project_simplex

__all__ = ["simplex_ref", "dual_primal_ref"]


def simplex_ref(
    v: jax.Array,
    mask: jax.Array,
    radius: float = 1.0,
    *,
    inequality: bool = True,
) -> jax.Array:
    """Reference masked Duchi projection (identical semantics to the kernel)."""
    return project_simplex(v, mask, radius, inequality=inequality)


def dual_primal_ref(
    idx: jax.Array,  # [n, L] int32 destination ids
    coeff: jax.Array,  # [m, n, L] constraint coefficients
    cost: jax.Array,  # [n, L]
    mask: jax.Array,  # [n, L]
    lam: jax.Array,  # [m * J]
    gamma,
    J: int,
    radius: float = 1.0,
    *,
    inequality: bool = True,
) -> jax.Array:
    """Unfused primal step for one bucket: gather, axpy, scale, project."""
    m = coeff.shape[0]
    lam2 = lam.reshape(m, J)
    atl = jnp.einsum("mnl,mnl->nl", coeff, jnp.take(lam2, idx, axis=1))
    z = -(atl + cost) / jnp.asarray(gamma, cost.dtype)
    return project_simplex(z, mask, radius, inequality=inequality)
