"""Pure-jnp oracles for the Pallas kernels.

`simplex_ref` is exactly the paper's multi-launch PyTorch-eager Duchi pipeline
(sort -> cumsum -> cutoff -> threshold -> subtract-and-clamp); `dual_primal_ref`
is the unfused primal step  x = Pi_simplex( -(A^T lam + c) / gamma )  for one
bucket slab; `dual_oracle_ref` is the whole one-pass oracle (primal slab +
this bucket's A x histogram + the c'x / ||x||^2 partials) expressed as a
single traced function — it is both the ground truth the dual-oracle kernel
tests compare against and the off-TPU execution path `ops.fused_dual_oracle`
dispatches to (XLA fuses its passes; the kernel's one-hot MXU contraction
does not pay off on a scalar backend).  Kernel tests sweep shapes/dtypes and
assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objective import binned_segment_sum
from repro.core.projections import project_simplex

__all__ = ["simplex_ref", "dual_primal_ref", "dual_oracle_ref"]


def simplex_ref(
    v: jax.Array,
    mask: jax.Array,
    radius: float = 1.0,
    *,
    inequality: bool = True,
) -> jax.Array:
    """Reference masked Duchi projection (identical semantics to the kernel)."""
    return project_simplex(v, mask, radius, inequality=inequality)


def dual_primal_ref(
    idx: jax.Array,  # [n, L] int32 destination ids
    coeff: jax.Array,  # [m, n, L] constraint coefficients
    cost: jax.Array,  # [n, L]
    mask: jax.Array,  # [n, L]
    lam: jax.Array,  # [m * J]
    gamma,
    J: int,
    radius: float = 1.0,
    *,
    inequality: bool = True,
) -> jax.Array:
    """Unfused primal step for one bucket: gather, axpy, scale, project."""
    m = coeff.shape[0]
    lam2 = lam.reshape(m, J)
    atl = jnp.einsum("mnl,mnl->nl", coeff, jnp.take(lam2, idx, axis=1))
    z = -(atl + cost) / jnp.asarray(gamma, cost.dtype)
    return project_simplex(z, mask, radius, inequality=inequality)


def dual_oracle_ref(
    idx: jax.Array,  # [n, L] int32 destination ids
    coeff: jax.Array,  # [m, n, L] constraint coefficients
    cost: jax.Array,  # [n, L]
    mask: jax.Array,  # [n, L]
    lam: jax.Array,  # [m * J]
    gamma,
    J: int,
    radius: float = 1.0,
    *,
    inequality: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass oracle for one bucket: `(x, hist, lin, sq)` where

        x    [n, L]  = Pi_simplex( -(A^T lam + c)/gamma )
        hist [m, J]  = this bucket's contribution to A x
        lin  scalar  = c'x        (this bucket's part)
        sq   scalar  = ||x||^2    (this bucket's part)

    Mathematically identical to primal-then-`_segment_sum_ax`-then-vdots, but
    expressed as one traced function so a single jit fuses all passes and no
    [m, n, L] gradient intermediates outlive the oracle.  The projection
    multiplies by `mask`, so x is already exact-zero on padded slots and the
    histogram/scalars need no re-masking.
    """
    x = dual_primal_ref(
        idx, coeff, cost, mask, lam, gamma, J, radius, inequality=inequality
    )
    hist = binned_segment_sum(idx, (coeff * x[None]).astype(jnp.float32), J)
    lin = jnp.vdot(cost, x)
    sq = jnp.vdot(x, x)
    return x, hist, lin.astype(jnp.float32), sq.astype(jnp.float32)
