"""Fused simplex-projection Pallas kernel — TPU adaptation of the paper's §4.3.

The paper fuses the Duchi pipeline into one Triton kernel where each *program*
owns one column and sorts in registers.  That design leans on CUDA warp
semantics; the TPU-native formulation instead processes a whole
``(block_rows, L)`` VMEM tile per grid step and runs the sort as a **bitonic
compare-exchange network along lanes**, data-parallel across rows on the VPU:

  * bitonic sort (descending): log2(L)*(log2(L)+1)/2 compare-exchange stages,
    each expressed as roll + elementwise min/max/where (no gather, no scatter,
    no cross-lane divergence).  Bucket widths are powers of two by
    construction (§4.2), so the network needs no padding logic.
  * inclusive prefix sum: Hillis-Steele scan, log2(L) shifted adds.
  * cutoff rho via a boolean reduction over the monotone Duchi condition,
    threshold theta via a masked reduction, then subtract-and-clamp — all in
    the same tile, nothing is materialised to HBM between stages.
  * inequality early exit (paper: "in-kernel early exit"): feasible rows take
    the clamp-only path, selected per row with a vector `where` (branchless —
    on TPU a uniform early `return` would stall the pipeline anyway).

Matching the paper's Triton kernel: fp32 internally, column lengths up to
MAX_FUSED_LENGTH = 8192, multi-op fallback beyond (see ops.py).

VMEM budget: the kernel keeps ~5 live (block_rows, L) fp32 tiles (input, mask,
sorted, scan, output); ops.py picks block_rows so the working set stays under
~4 MiB of the ~16 MiB VMEM, and rounds block_rows to the 8-sublane register
shape.  All shapes are static; grid iterates over row blocks only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["simplex_kernel_body", "MAX_FUSED_LENGTH", "bitonic_sort_desc", "inclusive_scan"]

MAX_FUSED_LENGTH = 8192
_NEG = -1.0e30


def _lane_iota(shape, dtype=jnp.int32):
    return jax.lax.broadcasted_iota(dtype, shape, len(shape) - 1)


def _roll(x: jax.Array, shift: int) -> jax.Array:
    """Circular roll along lanes via two static slices (Pallas-friendly)."""
    if shift == 0:
        return x
    L = x.shape[-1]
    shift = shift % L
    return jnp.concatenate([x[..., L - shift :], x[..., : L - shift]], axis=-1)


def bitonic_sort_desc(x: jax.Array) -> jax.Array:
    """Descending bitonic sort along the last axis (length must be a power of 2).

    Every stage is roll + min/max/where over the whole tile: the partner of
    lane i at substage j is i XOR j, reached by rolling left for lanes with
    bit j clear and right for lanes with bit j set.
    """
    L = x.shape[-1]
    assert L & (L - 1) == 0, f"bitonic sort needs power-of-2 length, got {L}"
    if L == 1:
        return x
    iota = _lane_iota(x.shape)
    log_l = L.bit_length() - 1
    for k_exp in range(1, log_l + 1):
        k = 1 << k_exp
        for j_exp in range(k_exp - 1, -1, -1):
            j = 1 << j_exp
            partner = jnp.where((iota & j) == 0, _roll(x, -j), _roll(x, j))
            mn = jnp.minimum(x, partner)
            mx = jnp.maximum(x, partner)
            # descending overall: invert the classic ascending direction bit.
            # (At the final merge k == L the bit is always clear, making every
            # comparison descending — the whole row comes out descending.)
            asc = (iota & k) != 0
            lower = (iota & j) == 0
            x = jnp.where(lower == asc, mn, mx)
    return x


def inclusive_scan(x: jax.Array) -> jax.Array:
    """Hillis-Steele inclusive prefix sum along lanes (log2 L shifted adds)."""
    L = x.shape[-1]
    iota = _lane_iota(x.shape)
    s = 1
    while s < L:
        shifted = jnp.where(iota >= s, _roll(x, s), 0.0)
        x = x + shifted
        s *= 2
    return x


def simplex_kernel_body(
    v_ref, mask_ref, out_ref, *, radius: float, inequality: bool
):
    """Kernel body: one (block_rows, L) tile, entire Duchi pipeline fused."""
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)
    L = v.shape[-1]
    z = jnp.float32(radius)

    vm = jnp.where(mask > 0, v, _NEG)
    u = bitonic_sort_desc(vm)
    css = inclusive_scan(u)
    j = (_lane_iota(v.shape).astype(jnp.float32)) + 1.0
    cond = u * j > css - z  # monotone Duchi condition
    rho = jnp.maximum(jnp.sum(cond.astype(jnp.float32), axis=-1, keepdims=True), 1.0)
    css_rho = jnp.sum(jnp.where(j == rho, css, 0.0), axis=-1, keepdims=True)
    theta = (css_rho - z) / rho
    w_eq = jnp.maximum(vm - theta, 0.0) * mask
    if inequality:
        w0 = jnp.maximum(v, 0.0) * mask
        feasible = jnp.sum(w0, axis=-1, keepdims=True) <= z
        out = jnp.where(feasible, w0, w_eq)
    else:
        out = w_eq
    out_ref[...] = out.astype(out_ref.dtype)


def make_simplex_call(
    n_rows: int,
    length: int,
    block_rows: int,
    dtype,
    *,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool = True,
):
    """Build the pallas_call for an (n_rows, length) slab.

    BlockSpec tiles the row dimension; each grid step owns a full-width
    (block_rows, length) VMEM tile — the projection is a per-row reduction so
    the lane dimension must stay unsplit.
    """
    assert n_rows % block_rows == 0
    assert length <= MAX_FUSED_LENGTH
    grid = (n_rows // block_rows,)
    spec = pl.BlockSpec((block_rows, length), lambda i: (i, 0))
    body = functools.partial(
        simplex_kernel_body, radius=radius, inequality=inequality
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((n_rows, length), dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=interpret,
    )
