"""One-pass fused dual-oracle Pallas kernel — the whole oracle, one slab read.

`dual_primal.py` fuses the *forward* half of the oracle (eq. 3): the slab is
read once and the projected primal tile is written back.  But every AGD
iteration also needs the gradient half (eq. 4) — `A x` — plus the objective
scalars `c'x` and `||x||^2`, and the unfused path re-reads the slab for each:
a segment-sum pass over (idx, coeff, x) for `A x` and two more reduction
passes for the scalars, ~3x the slab traffic per iteration, with a
materialised `[m, n, L]` `coeff * x` intermediate in between.

This kernel computes *everything the oracle emits* in the same
one-pass-over-VMEM-tiles schedule:

  per grid step i over (block_rows, L) tiles:
    x_tile   = Pi_simplex( -(A^T lam + c)/gamma )      -> x[i]      [block, L]
    hist[i]  = this tile's binned contribution to A x  -> [1, m, J]
    scal[i]  = (sum c*x_tile, sum x_tile^2)            -> [1, 2]

so one kernel launch per bucket yields `(x, [grid, m, J], [grid, 2])` and the
caller finishes with an O(grid*m*J) tree-sum — the slab is read exactly once
per iteration and the `[m, n, L]` gradient intermediates never exist.

The in-kernel binned scatter is a **one-hot MXU contraction**, not a scatter:
TPU has no efficient VMEM scatter-add, but `hist[k, j] += coeff[k,e] * x[e]`
over the tile's edges e with `idx[e] == j` is exactly

    hist += einsum('re,rej->rj'-style dot)  with  onehot[e, j] = (idx[e] == j)

a dense [m, chunk] x [chunk, J] matmul against a comparison-generated one-hot
tile.  Edges are processed in row chunks sized so the one-hot tile stays
within its VMEM budget (`_ONEHOT_TILE_ELEMS`).  Partial histograms per grid
step + a tree-sum outside the kernel replace global atomics, which TPU lacks
(and which on GPU serialise under contention anyway) — determinism comes for
free because every partial has a fixed slot in the [grid, m, J] output.

Padded rows are mask-zero, so their x tile is exactly 0.0 and they contribute
exact zeros to the histogram and both scalars (same guarantee `bucketize`
documents for gradients).

As with every kernel in this repo, correctness is *validated* in interpret
mode on CPU (tests/test_dual_oracle.py); `kernels/ops.py` dispatches to the
fused one-pass reference (`kernels/ref.dual_oracle_ref`) off-TPU because the
one-hot contraction is an MXU trick — on a scalar interpreter it costs
O(edges * J) real multiplies, while XLA-CPU fuses the reference's
segment-sum formulation natively.  See ops.fused_dual_oracle for the full
fallback matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dual_primal import fused_primal_tile
from repro.kernels.simplex_proj import MAX_FUSED_LENGTH

__all__ = ["make_dual_oracle_call", "pick_row_chunk", "fits_onehot_budget"]

# VMEM budget for the one-hot [chunk*L, J] fp32 tile of the histogram
# contraction: 512k elements = 2 MiB, alongside the ~5 live tiles of the
# primal pipeline this keeps the working set under the ops.py tile budget.
_ONEHOT_TILE_ELEMS = 1 << 19


def fits_onehot_budget(length: int, num_destinations: int) -> bool:
    """True iff even a single-row chunk's one-hot tile [L, J] respects the
    VMEM budget — the dispatch-level gate `ops.fused_dual_oracle` checks
    before taking the kernel path (very wide slabs x very many destinations
    fall back to the reference oracle, like the paper's >MAX_FUSED_LENGTH
    multi-launch fallback)."""
    return length * num_destinations <= _ONEHOT_TILE_ELEMS


def pick_row_chunk(block_rows: int, length: int, num_destinations: int) -> int:
    """Rows per one-hot contraction chunk: largest divisor of block_rows whose
    [chunk*L, J] one-hot tile fits in _ONEHOT_TILE_ELEMS (floor 1 row;
    callers gate on `fits_onehot_budget` so the floor respects the budget)."""
    cap = max(1, _ONEHOT_TILE_ELEMS // max(length * num_destinations, 1))
    chunk = min(block_rows, cap)
    while block_rows % chunk:
        chunk -= 1
    return max(chunk, 1)


def dual_oracle_kernel_body(
    idx_ref,  # [block, L] int32
    coeff_ref,  # [m, block, L] slab dtype (fp32 / bf16 / int8)
    cost_ref,  # [block, L] slab dtype
    mask_ref,  # [block, L] slab dtype
    lam_ref,  # [m, J]  whole dual vector resident in VMEM
    ginv_ref,  # [1, 1]  1/gamma (traced; continuation changes it per stage)
    *rest,  # quantized: (coeff_scale_ref [m,1], cost_scale_ref [1,1]) prepended
    # outputs (always last three refs):
    #   x_ref     [block, L] out: primal tile (storage dtype; f32 for int8)
    #   hist_ref  [1, m, J] out: this grid step's partial A x (f32)
    #   scal_ref  [1, 2] out: (c'x, ||x||^2) partials (f32)
    radius: float,
    inequality: bool,
    row_chunk: int,
):
    if len(rest) == 5:
        coeff_scale_ref, cost_scale_ref, x_ref, hist_ref, scal_ref = rest
    else:
        coeff_scale_ref = cost_scale_ref = None
        x_ref, hist_ref, scal_ref = rest
    x = fused_primal_tile(
        idx_ref, coeff_ref, cost_ref, mask_ref, lam_ref, ginv_ref,
        radius=radius, inequality=inequality,
        coeff_scale_ref=coeff_scale_ref, cost_scale_ref=cost_scale_ref,
    )
    x_ref[...] = x.astype(x_ref.dtype)

    m = coeff_ref.shape[0]
    block, L = x.shape
    J = lam_ref.shape[1]
    idx = idx_ref[...]
    coeff = coeff_ref[...].astype(jnp.float32)
    if coeff_scale_ref is not None:
        coeff = coeff * coeff_scale_ref[...].reshape(m, 1, 1)

    # scalar partials: cost/x are exact zeros on padded slots already
    cost_f32 = cost_ref[...].astype(jnp.float32)
    if cost_scale_ref is not None:
        cost_f32 = cost_f32 * cost_scale_ref[0, 0]
    scal_ref[0, 0] = jnp.sum(cost_f32 * x)
    scal_ref[0, 1] = jnp.sum(x * x)

    # binned scatter as a chunked one-hot contraction:
    #   contrib[k, r, l] = coeff[k, r, l] * x[r, l]   (x is already masked)
    #   hist[k, j]      += sum_{r,l} contrib[k, r, l] * [idx[r, l] == j]
    contrib = coeff * x[None]  # [m, block, L]
    n_chunks = block // row_chunk

    def chunk_step(c, hist):
        r0 = c * row_chunk
        ids = jax.lax.dynamic_slice(idx, (r0, 0), (row_chunk, L))
        con = jax.lax.dynamic_slice(
            contrib, (0, r0, 0), (m, row_chunk, L)
        ).reshape(m, row_chunk * L)
        onehot = (
            ids.reshape(row_chunk * L, 1)
            == jax.lax.broadcasted_iota(jnp.int32, (row_chunk * L, J), 1)
        ).astype(jnp.float32)
        return hist + jax.lax.dot_general(
            con, onehot,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    hist = jax.lax.fori_loop(
        0, n_chunks, chunk_step, jnp.zeros((m, J), jnp.float32)
    )
    hist_ref[0] = hist.astype(hist_ref.dtype)


def make_dual_oracle_call(
    n_rows: int,
    length: int,
    num_families: int,
    num_destinations: int,
    block_rows: int,
    dtype,
    *,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool = True,
    quantized: bool = False,
    out_dtype=None,
):
    """pallas_call for one bucket slab returning (x, hist_partials, scalar_partials).

    Call-time arguments: (idx, coeff, cost, mask, lam2, gamma_inv) exactly as
    `make_dual_primal_call`; with ``quantized`` two more — (coeff_scale
    [m, 1] f32, cost_scale [1, 1] f32), dequantized in-kernel.  Outputs:
      x               [n_rows, length]       projected primal slab, written
                                             in ``out_dtype`` (defaults to
                                             the storage ``dtype``; ops.py
                                             passes fp32 for int8 slabs)
      hist_partials   [grid, m, J] fp32      per-grid-step partial A x
      scalar_partials [grid, 2] fp32         per-grid-step (c'x, ||x||^2)
    The caller tree-sums the partials over the grid axis (O(grid*(m*J + 2))).
    All partials accumulate in fp32 regardless of the storage dtype.
    """
    assert n_rows % block_rows == 0
    assert length <= MAX_FUSED_LENGTH
    grid_n = n_rows // block_rows
    grid = (grid_n,)
    row_spec = pl.BlockSpec((block_rows, length), lambda i: (i, 0))
    coeff_spec = pl.BlockSpec(
        (num_families, block_rows, length), lambda i: (0, i, 0)
    )
    lam_spec = pl.BlockSpec(
        (num_families, num_destinations), lambda i: (0, 0)
    )
    ginv_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    hist_spec = pl.BlockSpec(
        (1, num_families, num_destinations), lambda i: (i, 0, 0)
    )
    scal_spec = pl.BlockSpec((1, 2), lambda i: (i, 0))
    in_specs = [row_spec, coeff_spec, row_spec, row_spec, lam_spec, ginv_spec]
    if quantized:
        in_specs += [
            pl.BlockSpec((num_families, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ]
    body = functools.partial(
        dual_oracle_kernel_body,
        radius=radius,
        inequality=inequality,
        row_chunk=pick_row_chunk(block_rows, length, num_destinations),
    )
    return pl.pallas_call(
        body,
        out_shape=(
            jax.ShapeDtypeStruct(
                (n_rows, length), dtype if out_dtype is None else out_dtype
            ),
            jax.ShapeDtypeStruct(
                (grid_n, num_families, num_destinations), jnp.float32
            ),
            jax.ShapeDtypeStruct((grid_n, 2), jnp.float32),
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(row_spec, hist_spec, scal_spec),
        interpret=interpret,
    )
