"""Pallas TPU kernels for the paper's compute hot-spots.

simplex_proj.py  fused Duchi simplex projection (paper §4.3): bitonic sort
                 network + Hillis-Steele scan along lanes, VMEM-tiled.
dual_primal.py   beyond-paper fusion of the whole primal step (eq. 3):
                 gather(lam) -> axpy -> scale -> project in one kernel.
dual_oracle.py   one-pass fusion of the ENTIRE oracle: the primal-step
                 kernel additionally emits per-grid-step partial A x
                 histograms (one-hot MXU contraction vs the VMEM-resident
                 [m, J] dual shape) and (c'x, ||x||^2) partials, so one
                 launch per bucket yields g, grad and x from a single
                 slab read per iteration.
ops.py           jit'd wrappers: block sizing, padding, bucket dispatch,
                 >8192-width fallback, interpret/TPU switch.
ref.py           pure-jnp oracles (the kernel tests' ground truth and the
                 off-TPU execution path of the fused dual oracle).

Validated with interpret=True on CPU; BlockSpecs target TPU v5e VMEM.
"""
from repro.kernels.ops import (
    fused_dual_oracle,
    fused_dual_primal,
    fused_project_simplex,
)

__all__ = ["fused_dual_oracle", "fused_dual_primal", "fused_project_simplex"]
