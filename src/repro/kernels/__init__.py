"""Pallas TPU kernels for the paper's compute hot-spots.

simplex_proj.py  fused Duchi simplex projection (paper §4.3): bitonic sort
                 network + Hillis-Steele scan along lanes, VMEM-tiled.
dual_primal.py   beyond-paper fusion of the whole primal step (eq. 3):
                 gather(lam) -> axpy -> scale -> project in one kernel.
ops.py           jit'd wrappers: block sizing, padding, bucket dispatch,
                 >8192-width fallback, interpret/TPU switch.
ref.py           pure-jnp oracles (the kernel tests' ground truth).

Validated with interpret=True on CPU; BlockSpecs target TPU v5e VMEM.
"""
from repro.kernels.ops import fused_dual_primal, fused_project_simplex

__all__ = ["fused_dual_primal", "fused_project_simplex"]
