"""jit'd wrappers around the Pallas kernels (block sizing, padding, fallbacks).

Responsibilities (mirrors the paper's dispatch policy, §4.3):
  * pick `block_rows` so the VMEM working set stays bounded and row counts
    stay register-shaped (multiples of 8 sublanes);
  * pad row counts up to the block multiple, strip padding on the way out
    (padded rows are mask-zero, so they project to exact zeros);
  * fall back to the multi-op reference implementation for slab widths beyond
    MAX_FUSED_LENGTH = 8192 or non-power-of-two widths — "beyond this limit,
    execution falls back to the multi-launch implementation";
  * route unsupported slab storage dtypes (anything outside fp32 / bf16 /
    int8-with-scales) through the dtype-faithful reference oracle, which
    widens to fp32 on load and accumulates in fp32 exactly like the kernel;
  * `interpret=None` auto-selects: real Mosaic lowering on TPU backends,
    interpret mode (Python execution of the same kernel body) on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.dual_oracle import fits_onehot_budget, make_dual_oracle_call
from repro.kernels.dual_primal import make_dual_primal_call
from repro.kernels.simplex_proj import MAX_FUSED_LENGTH, make_simplex_call

__all__ = [
    "fused_project_simplex",
    "fused_dual_primal",
    "fused_dual_oracle",
    "fused_pdhg_step",
    "oracle_hist_partial_bytes",
    "oracle_slab_slot_bytes",
    "pick_block_rows",
]

# Budget for live fp32 tiles inside the kernel (~5 copies), kept well under
# the ~16 MiB VMEM of TPU v5e: 4 MiB / (5 copies * 4 B) = ~200k elements.
_VMEM_TILE_ELEMS = 1 << 17  # 128k fp32 elements per tile


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# Slab storage dtypes the Pallas kernels load natively (widened to fp32 in
# VMEM; see kernels/dual_primal.fused_primal_tile).  int8 additionally needs
# its per-bucket dequant scales; anything else takes the reference path.
_KERNEL_SLAB_DTYPES = (jnp.float32, jnp.bfloat16, jnp.int8)


def _kernel_supports_dtype(dtype, quantized: bool) -> bool:
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.int8):
        return quantized
    return d in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16))


def _primal_out_dtype(storage_dtype, quantized: bool):
    """The dtype the primal slab x is written back in: the storage dtype for
    float storage (so the x write shares the slab's HBM width), fp32 for
    quantized slabs (x is a simplex point, not a scaled integer)."""
    return jnp.dtype(jnp.float32) if quantized else jnp.dtype(storage_dtype)


def oracle_slab_slot_bytes(num_families: int, slab_dtype="float32") -> int:
    """Analytic per-slot HBM bytes of one fused-oracle iteration: the idx
    read (int32) + coeff/cost/mask reads at the storage width + the x write
    at the primal-out width (storage width for float slabs, fp32 for int8).
    Shared by `launch.dryrun` and `benchmarks.table2_iteration_time` — the
    two records must agree for the perf trajectory to be comparable."""
    d = jnp.dtype(jnp.bfloat16) if slab_dtype == "bfloat16" else jnp.dtype(slab_dtype)
    quantized = d == jnp.dtype(jnp.int8)
    x_bytes = _primal_out_dtype(d, quantized).itemsize
    return 4 + (num_families + 2) * d.itemsize + x_bytes


def pick_block_rows(n_rows: int, length: int) -> int:
    """Rows per VMEM tile: 8-sublane aligned, tile <= _VMEM_TILE_ELEMS."""
    max_rows = max(1, _VMEM_TILE_ELEMS // max(length, 1))
    # round down to a multiple of 8 (sublane count), floor at 8
    block = max(8, (max_rows // 8) * 8)
    return min(block, max(8, n_rows))


def oracle_hist_partial_bytes(
    n_rows: int, length: int, num_families: int, num_destinations: int
) -> int:
    """Fused-oracle per-iteration partial-histogram HBM traffic for one
    bucket: one [m, J] fp32 write + read per grid step (the tree-sum).

    The single source of the analytic model — `launch.dryrun` and
    `benchmarks.table2_iteration_time` both report it, and the two records
    must agree for the perf trajectory to be comparable.
    """
    grid = -(-n_rows // pick_block_rows(n_rows, length))
    return 2 * 4 * grid * num_families * num_destinations


def _pad_rows(x: jax.Array, target: int) -> jax.Array:
    pad = target - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


def _use_interpret(interpret) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("radius", "inequality", "interpret")
)
def fused_project_simplex(
    v: jax.Array,
    mask: jax.Array,
    *,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused Duchi simplex projection of slab rows (paper §4.3).

    v, mask: [n, L].  Falls back to the reference pipeline when L is not a
    power of two or exceeds MAX_FUSED_LENGTH.
    """
    n, L = v.shape
    if not _is_pow2(L) or L > MAX_FUSED_LENGTH:
        return kref.simplex_ref(v, mask, radius, inequality=inequality)
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_simplex_call(
        n_pad,
        L,
        block,
        v.dtype,
        radius=radius,
        inequality=inequality,
        interpret=_use_interpret(interpret),
    )
    out = call(_pad_rows(v, n_pad), _pad_rows(mask, n_pad))
    return out[:n]


@functools.partial(
    jax.jit,
    static_argnames=("num_destinations", "radius", "inequality", "interpret"),
)
def fused_dual_primal(
    idx: jax.Array,  # [n, L] int32
    coeff: jax.Array,  # [m, n, L] slab dtype
    cost: jax.Array,  # [n, L] slab dtype
    mask: jax.Array,  # [n, L] slab dtype
    lam: jax.Array,  # [m * J] fp32
    gamma: jax.Array,  # scalar
    *,
    num_destinations: int,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
    coeff_scale: Optional[jax.Array] = None,  # [m, 1, 1] f32 (int8 slabs)
    cost_scale: Optional[jax.Array] = None,  # [1, 1] f32 (int8 slabs)
) -> jax.Array:
    """Whole fused primal step  x = Pi( -(A^T lam + c)/gamma )  for one bucket."""
    n, L = cost.shape
    m = coeff.shape[0]
    quantized = coeff_scale is not None
    if (
        not _is_pow2(L)
        or L > MAX_FUSED_LENGTH
        or not _kernel_supports_dtype(cost.dtype, quantized)
    ):
        return kref.dual_primal_ref(
            idx, coeff, cost, mask, lam, gamma, num_destinations,
            radius, inequality=inequality,
            coeff_scale=coeff_scale, cost_scale=cost_scale,
        )
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_dual_primal_call(
        n_pad,
        L,
        m,
        num_destinations,
        block,
        cost.dtype,
        radius=radius,
        inequality=inequality,
        interpret=_use_interpret(interpret),
        quantized=quantized,
        out_dtype=_primal_out_dtype(cost.dtype, quantized),
    )
    ginv = (1.0 / gamma).astype(jnp.float32).reshape(1, 1)
    operands = [
        _pad_rows(idx, n_pad),
        _pad_rows(coeff.swapaxes(0, 1), n_pad).swapaxes(0, 1),
        _pad_rows(cost, n_pad),
        _pad_rows(mask, n_pad),
        lam.reshape(m, num_destinations),
        ginv,
    ]
    if quantized:
        operands += [
            coeff_scale.astype(jnp.float32).reshape(m, 1),
            jnp.asarray(cost_scale, jnp.float32).reshape(1, 1),
        ]
    out = call(*operands)
    return out[:n]


def fused_dual_oracle(
    idx: jax.Array,  # [n, L] int32
    coeff: jax.Array,  # [m, n, L] slab dtype
    cost: jax.Array,  # [n, L] slab dtype
    mask: jax.Array,  # [n, L] slab dtype
    lam: jax.Array,  # [m * J] fp32
    gamma: jax.Array,  # scalar
    *,
    num_destinations: int,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
    coeff_scale: Optional[jax.Array] = None,  # [m, 1, 1] f32 (int8 slabs)
    cost_scale: Optional[jax.Array] = None,  # [1, 1] f32 (int8 slabs)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass fused dual oracle for one bucket: `(x, hist, lin, sq)`.

    One kernel launch computes the projected primal slab `x` AND this
    bucket's gradient/objective partials (`hist = A x` contribution [m, J],
    `lin = c'x`, `sq = ||x||^2`) from a single read of the slab; the
    per-grid-step histogram partials are tree-summed here (O(grid*m*J)).

    Storage dtypes: fp32, bf16, and int8-with-scales slabs take the kernel
    path (loaded narrow into VMEM, widened to fp32, partials accumulated in
    fp32; the x slab is written back in the storage dtype — fp32 for int8);
    any other dtype routes to the dtype-faithful reference below.

    Fallback matrix (see also docs/architecture.md):
      * L not a power of two or L > MAX_FUSED_LENGTH -> `dual_oracle_ref`
        (the paper's multi-launch fallback policy, §4.3);
      * slab dtype outside {fp32, bf16, int8+scales} -> `dual_oracle_ref`
        (same widen-to-fp32 accumulation contract, so quality is identical
        up to reduction order);
      * L * J beyond the one-hot contraction's VMEM budget
        (`fits_onehot_budget`) -> `dual_oracle_ref`: even a one-row chunk's
        [L, J] one-hot tile would blow the kernel's working set;
      * `interpret=None` off-TPU -> `dual_oracle_ref` as well: unlike the
        elementwise dual-primal kernel, the oracle's in-kernel histogram is
        a one-hot MXU contraction — O(edges * J) scalar multiplies on a
        non-matrix backend — while XLA-CPU fuses the reference's
        segment-sum formulation natively, so interpret mode is kept for
        *validation*, not execution;
      * `interpret=True` -> Pallas interpret mode (kernel-body semantics on
        any backend; what the parity tests exercise);
      * `interpret=False`/None on TPU -> real Mosaic lowering.
    Padded rows are mask-zero and contribute exact zeros to `hist`/`lin`/`sq`
    on every path.

    Deliberately NOT wrapped in its own `jax.jit` (unlike the standalone
    `fused_dual_primal`): the oracle is only ever called from inside an
    already-jitted `calculate`, and a nested jit boundary would fence off
    cross-bucket/cross-pass fusion in the surrounding program.
    """
    n, L = cost.shape
    m = coeff.shape[0]
    quantized = coeff_scale is not None
    use_kernel = (
        _is_pow2(L)
        and L <= MAX_FUSED_LENGTH
        and fits_onehot_budget(L, num_destinations)
        and _kernel_supports_dtype(cost.dtype, quantized)
    )
    if interpret is None and jax.default_backend() != "tpu":
        use_kernel = False
    if not use_kernel:
        return kref.dual_oracle_ref(
            idx, coeff, cost, mask, lam, gamma, num_destinations,
            radius, inequality=inequality,
            coeff_scale=coeff_scale, cost_scale=cost_scale,
        )
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_dual_oracle_call(
        n_pad,
        L,
        m,
        num_destinations,
        block,
        cost.dtype,
        radius=radius,
        inequality=inequality,
        interpret=bool(interpret) if interpret is not None else False,
        quantized=quantized,
        out_dtype=_primal_out_dtype(cost.dtype, quantized),
    )
    ginv = (1.0 / gamma).astype(jnp.float32).reshape(1, 1)
    operands = [
        _pad_rows(idx, n_pad),
        _pad_rows(coeff.swapaxes(0, 1), n_pad).swapaxes(0, 1),
        _pad_rows(cost, n_pad),
        _pad_rows(mask, n_pad),
        lam.reshape(m, num_destinations),
        ginv,
    ]
    if quantized:
        operands += [
            jnp.asarray(coeff_scale, jnp.float32).reshape(m, 1),
            jnp.asarray(cost_scale, jnp.float32).reshape(1, 1),
        ]
    x, hist_p, scal_p = call(*operands)
    return x[:n], hist_p.sum(axis=0), scal_p[:, 0].sum(), scal_p[:, 1].sum()


def fused_pdhg_step(
    idx: jax.Array,  # [n, L] int32
    coeff: jax.Array,  # [m, n, L] fp32 compute view
    cost: jax.Array,  # [n, L] fp32
    mask: jax.Array,  # [n, L] fp32
    x: jax.Array,  # [n, L] fp32 current primal slab
    y: jax.Array,  # [m * J] fp32 current duals
    tau: jax.Array,  # scalar primal step
    *,
    num_destinations: int,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One structured-PDHG primal prox step for one bucket: `(x_new, hist)`.

    The PDHG primal update `x+ = Proj_C(x - tau * (c + A'y))` is exactly the
    dual oracle's `Proj_C(-(A'y + cost_eff) / gamma)` with the identification
    `cost_eff = c - x / tau`, `gamma = 1 / tau` — so ONE fused launch both
    takes the prox step and emits this bucket's `hist = A x+` partial [m, J],
    which is what the extrapolated dual update needs.  That single-read fusion
    (vs the seed COO path's gather for `A'y` plus scatter-add for `A x`) is
    the structured engine's per-iteration win; see `repro.engines.pdhg`.

    Inputs must be fp32 compute views (`BucketedInstance` dequantized slabs):
    `cost_eff` is iterate-dependent, so the quantized-storage kernel variants
    (which assume a static per-bucket cost scale) don't apply here.
    """
    inv_tau = (1.0 / tau).astype(jnp.float32)
    cost_eff = cost - x * inv_tau
    x_new, hist, _, _ = fused_dual_oracle(
        idx,
        coeff,
        cost_eff,
        mask,
        y,
        inv_tau,
        num_destinations=num_destinations,
        radius=radius,
        inequality=inequality,
        interpret=interpret,
        coeff_scale=None,
        cost_scale=None,
    )
    return x_new, hist
