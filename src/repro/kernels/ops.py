"""jit'd wrappers around the Pallas kernels (block sizing, padding, fallbacks).

Responsibilities (mirrors the paper's dispatch policy, §4.3):
  * pick `block_rows` so the VMEM working set stays bounded and row counts
    stay register-shaped (multiples of 8 sublanes);
  * pad row counts up to the block multiple, strip padding on the way out
    (padded rows are mask-zero, so they project to exact zeros);
  * fall back to the multi-op reference implementation for slab widths beyond
    MAX_FUSED_LENGTH = 8192 or non-power-of-two widths — "beyond this limit,
    execution falls back to the multi-launch implementation";
  * `interpret=None` auto-selects: real Mosaic lowering on TPU backends,
    interpret mode (Python execution of the same kernel body) on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.dual_oracle import fits_onehot_budget, make_dual_oracle_call
from repro.kernels.dual_primal import make_dual_primal_call
from repro.kernels.simplex_proj import MAX_FUSED_LENGTH, make_simplex_call

__all__ = [
    "fused_project_simplex",
    "fused_dual_primal",
    "fused_dual_oracle",
    "oracle_hist_partial_bytes",
    "pick_block_rows",
]

# Budget for live fp32 tiles inside the kernel (~5 copies), kept well under
# the ~16 MiB VMEM of TPU v5e: 4 MiB / (5 copies * 4 B) = ~200k elements.
_VMEM_TILE_ELEMS = 1 << 17  # 128k fp32 elements per tile


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def pick_block_rows(n_rows: int, length: int) -> int:
    """Rows per VMEM tile: 8-sublane aligned, tile <= _VMEM_TILE_ELEMS."""
    max_rows = max(1, _VMEM_TILE_ELEMS // max(length, 1))
    # round down to a multiple of 8 (sublane count), floor at 8
    block = max(8, (max_rows // 8) * 8)
    return min(block, max(8, n_rows))


def oracle_hist_partial_bytes(
    n_rows: int, length: int, num_families: int, num_destinations: int
) -> int:
    """Fused-oracle per-iteration partial-histogram HBM traffic for one
    bucket: one [m, J] fp32 write + read per grid step (the tree-sum).

    The single source of the analytic model — `launch.dryrun` and
    `benchmarks.table2_iteration_time` both report it, and the two records
    must agree for the perf trajectory to be comparable.
    """
    grid = -(-n_rows // pick_block_rows(n_rows, length))
    return 2 * 4 * grid * num_families * num_destinations


def _pad_rows(x: jax.Array, target: int) -> jax.Array:
    pad = target - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


def _use_interpret(interpret) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("radius", "inequality", "interpret")
)
def fused_project_simplex(
    v: jax.Array,
    mask: jax.Array,
    *,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused Duchi simplex projection of slab rows (paper §4.3).

    v, mask: [n, L].  Falls back to the reference pipeline when L is not a
    power of two or exceeds MAX_FUSED_LENGTH.
    """
    n, L = v.shape
    if not _is_pow2(L) or L > MAX_FUSED_LENGTH:
        return kref.simplex_ref(v, mask, radius, inequality=inequality)
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_simplex_call(
        n_pad,
        L,
        block,
        v.dtype,
        radius=radius,
        inequality=inequality,
        interpret=_use_interpret(interpret),
    )
    out = call(_pad_rows(v, n_pad), _pad_rows(mask, n_pad))
    return out[:n]


@functools.partial(
    jax.jit,
    static_argnames=("num_destinations", "radius", "inequality", "interpret"),
)
def fused_dual_primal(
    idx: jax.Array,  # [n, L] int32
    coeff: jax.Array,  # [m, n, L]
    cost: jax.Array,  # [n, L]
    mask: jax.Array,  # [n, L]
    lam: jax.Array,  # [m * J]
    gamma: jax.Array,  # scalar
    *,
    num_destinations: int,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Whole fused primal step  x = Pi( -(A^T lam + c)/gamma )  for one bucket."""
    n, L = cost.shape
    m = coeff.shape[0]
    if not _is_pow2(L) or L > MAX_FUSED_LENGTH:
        return kref.dual_primal_ref(
            idx, coeff, cost, mask, lam, gamma, num_destinations,
            radius, inequality=inequality,
        )
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_dual_primal_call(
        n_pad,
        L,
        m,
        num_destinations,
        block,
        cost.dtype,
        radius=radius,
        inequality=inequality,
        interpret=_use_interpret(interpret),
    )
    ginv = (1.0 / gamma).astype(jnp.float32).reshape(1, 1)
    out = call(
        _pad_rows(idx, n_pad),
        _pad_rows(coeff.swapaxes(0, 1), n_pad).swapaxes(0, 1),
        _pad_rows(cost, n_pad),
        _pad_rows(mask, n_pad),
        lam.reshape(m, num_destinations),
        ginv,
    )
    return out[:n]


def fused_dual_oracle(
    idx: jax.Array,  # [n, L] int32
    coeff: jax.Array,  # [m, n, L]
    cost: jax.Array,  # [n, L]
    mask: jax.Array,  # [n, L]
    lam: jax.Array,  # [m * J]
    gamma: jax.Array,  # scalar
    *,
    num_destinations: int,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass fused dual oracle for one bucket: `(x, hist, lin, sq)`.

    One kernel launch computes the projected primal slab `x` AND this
    bucket's gradient/objective partials (`hist = A x` contribution [m, J],
    `lin = c'x`, `sq = ||x||^2`) from a single read of the slab; the
    per-grid-step histogram partials are tree-summed here (O(grid*m*J)).

    Fallback matrix (see also docs/architecture.md):
      * L not a power of two or L > MAX_FUSED_LENGTH -> `dual_oracle_ref`
        (the paper's multi-launch fallback policy, §4.3);
      * L * J beyond the one-hot contraction's VMEM budget
        (`fits_onehot_budget`) -> `dual_oracle_ref`: even a one-row chunk's
        [L, J] one-hot tile would blow the kernel's working set;
      * `interpret=None` off-TPU -> `dual_oracle_ref` as well: unlike the
        elementwise dual-primal kernel, the oracle's in-kernel histogram is
        a one-hot MXU contraction — O(edges * J) scalar multiplies on a
        non-matrix backend — while XLA-CPU fuses the reference's
        segment-sum formulation natively, so interpret mode is kept for
        *validation*, not execution;
      * `interpret=True` -> Pallas interpret mode (kernel-body semantics on
        any backend; what the parity tests exercise);
      * `interpret=False`/None on TPU -> real Mosaic lowering.
    Padded rows are mask-zero and contribute exact zeros to `hist`/`lin`/`sq`
    on every path.

    Deliberately NOT wrapped in its own `jax.jit` (unlike the standalone
    `fused_dual_primal`): the oracle is only ever called from inside an
    already-jitted `calculate`, and a nested jit boundary would fence off
    cross-bucket/cross-pass fusion in the surrounding program.
    """
    n, L = cost.shape
    m = coeff.shape[0]
    use_kernel = (
        _is_pow2(L)
        and L <= MAX_FUSED_LENGTH
        and fits_onehot_budget(L, num_destinations)
    )
    if interpret is None and jax.default_backend() != "tpu":
        use_kernel = False
    if not use_kernel:
        return kref.dual_oracle_ref(
            idx, coeff, cost, mask, lam, gamma, num_destinations,
            radius, inequality=inequality,
        )
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_dual_oracle_call(
        n_pad,
        L,
        m,
        num_destinations,
        block,
        cost.dtype,
        radius=radius,
        inequality=inequality,
        interpret=bool(interpret) if interpret is not None else False,
    )
    ginv = (1.0 / gamma).astype(jnp.float32).reshape(1, 1)
    x, hist_p, scal_p = call(
        _pad_rows(idx, n_pad),
        _pad_rows(coeff.swapaxes(0, 1), n_pad).swapaxes(0, 1),
        _pad_rows(cost, n_pad),
        _pad_rows(mask, n_pad),
        lam.reshape(m, num_destinations),
        ginv,
    )
    return x[:n], hist_p.sum(axis=0), scal_p[:, 0].sum(), scal_p[:, 1].sum()
