"""jit'd wrappers around the Pallas kernels (block sizing, padding, fallbacks).

Responsibilities (mirrors the paper's dispatch policy, §4.3):
  * pick `block_rows` so the VMEM working set stays bounded and row counts
    stay register-shaped (multiples of 8 sublanes);
  * pad row counts up to the block multiple, strip padding on the way out
    (padded rows are mask-zero, so they project to exact zeros);
  * fall back to the multi-op reference implementation for slab widths beyond
    MAX_FUSED_LENGTH = 8192 or non-power-of-two widths — "beyond this limit,
    execution falls back to the multi-launch implementation";
  * `interpret=None` auto-selects: real Mosaic lowering on TPU backends,
    interpret mode (Python execution of the same kernel body) on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.dual_primal import make_dual_primal_call
from repro.kernels.simplex_proj import MAX_FUSED_LENGTH, make_simplex_call

__all__ = [
    "fused_project_simplex",
    "fused_dual_primal",
    "pick_block_rows",
]

# Budget for live fp32 tiles inside the kernel (~5 copies), kept well under
# the ~16 MiB VMEM of TPU v5e: 4 MiB / (5 copies * 4 B) = ~200k elements.
_VMEM_TILE_ELEMS = 1 << 17  # 128k fp32 elements per tile


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def pick_block_rows(n_rows: int, length: int) -> int:
    """Rows per VMEM tile: 8-sublane aligned, tile <= _VMEM_TILE_ELEMS."""
    max_rows = max(1, _VMEM_TILE_ELEMS // max(length, 1))
    # round down to a multiple of 8 (sublane count), floor at 8
    block = max(8, (max_rows // 8) * 8)
    return min(block, max(8, n_rows))


def _pad_rows(x: jax.Array, target: int) -> jax.Array:
    pad = target - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


def _use_interpret(interpret) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("radius", "inequality", "interpret")
)
def fused_project_simplex(
    v: jax.Array,
    mask: jax.Array,
    *,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused Duchi simplex projection of slab rows (paper §4.3).

    v, mask: [n, L].  Falls back to the reference pipeline when L is not a
    power of two or exceeds MAX_FUSED_LENGTH.
    """
    n, L = v.shape
    if not _is_pow2(L) or L > MAX_FUSED_LENGTH:
        return kref.simplex_ref(v, mask, radius, inequality=inequality)
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_simplex_call(
        n_pad,
        L,
        block,
        v.dtype,
        radius=radius,
        inequality=inequality,
        interpret=_use_interpret(interpret),
    )
    out = call(_pad_rows(v, n_pad), _pad_rows(mask, n_pad))
    return out[:n]


@functools.partial(
    jax.jit,
    static_argnames=("num_destinations", "radius", "inequality", "interpret"),
)
def fused_dual_primal(
    idx: jax.Array,  # [n, L] int32
    coeff: jax.Array,  # [m, n, L]
    cost: jax.Array,  # [n, L]
    mask: jax.Array,  # [n, L]
    lam: jax.Array,  # [m * J]
    gamma: jax.Array,  # scalar
    *,
    num_destinations: int,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Whole fused primal step  x = Pi( -(A^T lam + c)/gamma )  for one bucket."""
    n, L = cost.shape
    m = coeff.shape[0]
    if not _is_pow2(L) or L > MAX_FUSED_LENGTH:
        return kref.dual_primal_ref(
            idx, coeff, cost, mask, lam, gamma, num_destinations,
            radius, inequality=inequality,
        )
    block = pick_block_rows(n, L)
    n_pad = ((n + block - 1) // block) * block
    call = make_dual_primal_call(
        n_pad,
        L,
        m,
        num_destinations,
        block,
        cost.dtype,
        radius=radius,
        inequality=inequality,
        interpret=_use_interpret(interpret),
    )
    ginv = (1.0 / gamma).astype(jnp.float32).reshape(1, 1)
    out = call(
        _pad_rows(idx, n_pad),
        _pad_rows(coeff.swapaxes(0, 1), n_pad).swapaxes(0, 1),
        _pad_rows(cost, n_pad),
        _pad_rows(mask, n_pad),
        lam.reshape(m, num_destinations),
        ginv,
    )
    return out[:n]
