"""Fused dual-primal Pallas kernel — beyond-paper fusion of the whole primal step.

The paper's Triton kernel fuses only the projection (§4.3); the candidate
z = -(A^T lam + c)/gamma is still materialised to global memory by separate
gather/axpy kernels.  On TPU the dual vector lam (m*J fp32, ~40 KiB-4 MiB for
production J) fits in VMEM, so the *entire* primal step (eq. 3)

    x = Pi_simplex( -(gather(lam)[idx] . coeff + cost) / gamma )

fuses into one kernel: lam is staged into VMEM once per grid step, the
per-edge gather runs against VMEM, and the candidate tile never touches HBM.
This removes one full slab round-trip (read z + write z = 8 bytes/edge) per
iteration relative to the paper's fusion boundary — see EXPERIMENTS.md §Perf.

The gather `lam2[k, idx]` uses dynamic indices from VMEM.  That lowers on
recent Mosaic TPU (32-bit gather within a VMEM block); as with every kernel in
this repo it is *validated* in interpret mode on CPU, and ops.py keeps the
unfused reference path as a fallback switch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.simplex_proj import (
    MAX_FUSED_LENGTH,
    bitonic_sort_desc,
    inclusive_scan,
    _lane_iota,
    _NEG,
)

__all__ = ["make_dual_primal_call", "fused_primal_tile"]


def fused_primal_tile(
    idx_ref,  # [block, L] int32
    coeff_ref,  # [m, block, L] slab dtype (fp32 / bf16 / int8)
    cost_ref,  # [block, L] slab dtype
    mask_ref,  # [block, L] slab dtype
    lam_ref,  # [m, J]  (whole dual vector in VMEM, replicated per grid step)
    ginv_ref,  # [1, 1]  1/gamma (dynamic: continuation changes it per stage)
    *,
    radius: float,
    inequality: bool,
    coeff_scale_ref=None,  # [m, 1] f32: int8 per-family dequant scales
    cost_scale_ref=None,  # [1, 1] f32: int8 cost dequant scale
) -> jax.Array:
    """One VMEM tile of x = Pi_simplex( -(A^T lam + c)/gamma ), fp32.

    Shared by the dual-primal kernel (writes x only) and the dual-oracle
    kernel (additionally reduces this tile's A x / c'x / ||x||^2 partials).
    Mask-zero (padded) slots come out exactly 0.0.

    Narrow slab dtypes are widened to fp32 on load — HBM->VMEM traffic is at
    the storage width, all arithmetic is fp32.  The scale refs are present
    only for quantized (int8) slabs (value = q * scale); their None checks
    are host-static, so the fp32/bf16 kernel body is unchanged by them.
    """
    idx = idx_ref[...]
    cost = cost_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)
    if cost_scale_ref is not None:
        cost = cost * cost_scale_ref[0, 0]
    m = coeff_ref.shape[0]

    # gather + axpy: A^T lam restricted to this tile
    atl = jnp.zeros_like(cost)
    for k in range(m):  # m is tiny (constraint families); unrolled
        lam_k = lam_ref[k, :]
        coeff_k = coeff_ref[k].astype(jnp.float32)
        if coeff_scale_ref is not None:
            coeff_k = coeff_k * coeff_scale_ref[k, 0]
        atl = atl + coeff_k * jnp.take(lam_k, idx, axis=0)
    v = -(atl + cost) * ginv_ref[0, 0].astype(jnp.float32)

    # fused Duchi projection (same pipeline as simplex_proj kernel)
    z = jnp.float32(radius)
    vm = jnp.where(mask > 0, v, _NEG)
    u = bitonic_sort_desc(vm)
    css = inclusive_scan(u)
    j = _lane_iota(v.shape).astype(jnp.float32) + 1.0
    cond = u * j > css - z
    rho = jnp.maximum(jnp.sum(cond.astype(jnp.float32), axis=-1, keepdims=True), 1.0)
    css_rho = jnp.sum(jnp.where(j == rho, css, 0.0), axis=-1, keepdims=True)
    theta = (css_rho - z) / rho
    w_eq = jnp.maximum(vm - theta, 0.0) * mask
    if inequality:
        w0 = jnp.maximum(v, 0.0) * mask
        feasible = jnp.sum(w0, axis=-1, keepdims=True) <= z
        out = jnp.where(feasible, w0, w_eq)
    else:
        out = w_eq
    return out


def dual_primal_kernel_body(
    idx_ref,
    coeff_ref,
    cost_ref,
    mask_ref,
    lam_ref,
    ginv_ref,
    *rest,  # quantized: (coeff_scale_ref, cost_scale_ref, out_ref); else (out_ref,)
    radius: float,
    inequality: bool,
):
    if len(rest) == 3:
        coeff_scale_ref, cost_scale_ref, out_ref = rest
    else:
        coeff_scale_ref = cost_scale_ref = None
        (out_ref,) = rest
    out = fused_primal_tile(
        idx_ref, coeff_ref, cost_ref, mask_ref, lam_ref, ginv_ref,
        radius=radius, inequality=inequality,
        coeff_scale_ref=coeff_scale_ref, cost_scale_ref=cost_scale_ref,
    )
    out_ref[...] = out.astype(out_ref.dtype)


def make_dual_primal_call(
    n_rows: int,
    length: int,
    num_families: int,
    num_destinations: int,
    block_rows: int,
    dtype,
    *,
    radius: float = 1.0,
    inequality: bool = True,
    interpret: bool = True,
    quantized: bool = False,
    out_dtype=None,
):
    """pallas_call for one bucket slab: x = Pi( -(A^T lam + c)/gamma ).

    Arguments at call time: (idx, coeff, cost, mask, lam2, gamma_inv) with
    lam2 = lam.reshape(m, J) staged whole into VMEM for every grid step and
    gamma_inv a (1, 1) array (traced — continuation changes it per stage
    without retracing).  ``dtype`` is the slab storage dtype; the primal
    slab comes back in ``out_dtype`` (defaults to ``dtype``; ops.py passes
    fp32 for int8 slabs).  ``quantized`` appends two call-time operands —
    (coeff_scale [m, 1] f32, cost_scale [1, 1] f32) — dequantized in-kernel.
    """
    assert n_rows % block_rows == 0
    assert length <= MAX_FUSED_LENGTH
    grid = (n_rows // block_rows,)
    row_spec = pl.BlockSpec((block_rows, length), lambda i: (i, 0))
    coeff_spec = pl.BlockSpec(
        (num_families, block_rows, length), lambda i: (0, i, 0)
    )
    lam_spec = pl.BlockSpec(
        (num_families, num_destinations), lambda i: (0, 0)
    )
    ginv_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    in_specs = [row_spec, coeff_spec, row_spec, row_spec, lam_spec, ginv_spec]
    if quantized:
        in_specs += [
            pl.BlockSpec((num_families, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ]
    body = functools.partial(
        dual_primal_kernel_body, radius=radius, inequality=inequality
    )
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct(
            (n_rows, length), dtype if out_dtype is None else out_dtype
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=row_spec,
        interpret=interpret,
    )
