"""Deterministic, resumable synthetic LM data pipeline.

Batches are a pure function of (seed, step): after a restart the loop resumes
at step k and the pipeline regenerates exactly the batch it would have seen —
the skip-ahead property real distributed loaders implement with stored
shard offsets.  Token streams are Zipf-distributed (softmax-friendly) with a
next-token structure (labels = tokens shifted), so small models actually
learn and loss curves are meaningful in the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticLMData"]


@dataclasses.dataclass
class SyntheticLMData:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    vocab_cap: int = 0  # 0: full vocab

    def __post_init__(self):
        self.vocab = self.vocab_cap or self.cfg.vocab_size
        # fixed bigram transition structure so there is signal to learn
        rng = np.random.default_rng(self.seed)
        self._shift = rng.integers(1, self.vocab, size=self.vocab)

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = self.batch, self.seq
        cfg = self.cfg
        # Zipf-ish marginal + deterministic bigram: t_{i+1} = shift[t_i] w.p. 0.5
        z = rng.zipf(1.3, size=(B, S)).clip(max=self.vocab) - 1
        toks = np.empty((B, S), dtype=np.int64)
        toks[:, 0] = z[:, 0]
        follow = rng.random((B, S)) < 0.5
        for i in range(1, S):
            toks[:, i] = np.where(
                follow[:, i], self._shift[toks[:, i - 1]], z[:, i]
            )
        tokens = toks.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((B, 1), -100, np.int32)], axis=1
        )
        out = {"tokens": tokens, "labels": labels}
        if cfg.encdec or cfg.frontend == "frame":
            out["embeds"] = rng.standard_normal(
                (B, S, cfg.d_model), dtype=np.float32
            )
        elif cfg.frontend == "patch":
            P = cfg.frontend_len
            out["embeds"] = rng.standard_normal(
                (B, P, cfg.d_model), dtype=np.float32
            )
            out["tokens"] = tokens[:, : S - P]
            # labels span patch+text positions; patches are ignored
            out["labels"] = np.concatenate(
                [np.full((B, P), -100, np.int32), labels[:, : S - P]], axis=1
            )
        return out
