"""Thread-safe in-process metrics registry: counters, gauges, histograms.

The serving layer is measured, not narrated: every subsystem (sessions,
scheduler, engine, pool, delta ingestion, the distributed maximizer) records
into one process-wide `MetricsRegistry`, and exporters (`telemetry.export`)
serialize lock-consistent snapshots as JSONL records or Prometheus text
exposition.

Design constraints the service stack imposes:

  * **Thread safety** — `Scheduler.run_pipeline` overlaps host ingestion with
    in-flight device solves and the checkpoint manager writes from a
    background thread; all mutation and the `snapshot()` read path take one
    registry lock, so a snapshot is a consistent point-in-time view even
    while another thread is incrementing.
  * **Hot-path cost** — recording is a dict upsert under a lock (no I/O, no
    device sync).  Nothing here runs per AGD iteration: convergence traces
    are read from the already-materialized `SolveResult.stats` after the
    solve fence (see `telemetry.convergence`).
  * **Labels** — every series is keyed by `(name, sorted(label items))`, the
    Prometheus data model; tenant / cadence / shard / entry-point labels keep
    fleet-wide aggregation and per-tenant drill-down in the same store.
  * **Restart continuity** — `state_dict()` / `load_state()` round-trip the
    cumulative counters through `Scheduler.save_checkpoint`, so totals like
    `service_upload_bytes_total` survive a service restart instead of
    silently resetting to zero.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Iterable, Optional

__all__ = [
    "DEFAULT_BUCKETS",
    "HistogramData",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
]

# Geometric 1-2.5-5 decades: spans microseconds-scale durations through
# multi-GB byte counters without per-metric bucket configuration.
DEFAULT_BUCKETS: tuple[float, ...] = tuple(
    m * 10.0**e for e in range(-4, 10) for m in (1.0, 2.5, 5.0)
)

LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class HistogramData:
    """Cumulative-bucket histogram (Prometheus semantics) plus min/max."""

    buckets: tuple[float, ...] = DEFAULT_BUCKETS
    counts: list[int] = dataclasses.field(default_factory=list)  # len(buckets)+1
    count: int = 0
    sum: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def __post_init__(self):
        if not self.counts:
            self.counts = [0] * (len(self.buckets) + 1)

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        for i, le in enumerate(self.buckets):
            if value <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1  # +Inf bucket

    def to_dict(self) -> dict[str, Any]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": None if self.count == 0 else self.min,
            "max": None if self.count == 0 else self.max,
            # sparse non-zero buckets: {upper_bound: count}
            "buckets": {
                ("+Inf" if i == len(self.buckets) else repr(self.buckets[i])): c
                for i, c in enumerate(self.counts)
                if c
            },
        }


class MetricsRegistry:
    """One process-wide store of labelled counters, gauges and histograms."""

    def __init__(self, histogram_buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self._lock = threading.Lock()
        self._buckets = tuple(histogram_buckets)
        self._counters: dict[tuple[str, LabelKey], float] = {}
        self._gauges: dict[tuple[str, LabelKey], float] = {}
        self._hists: dict[tuple[str, LabelKey], HistogramData] = {}

    # -- recording (hot path: one lock, one dict upsert) ---------------------

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        """Add `value` to a monotonically increasing counter series."""
        key = (name, _label_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + float(value)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        """Set a point-in-time gauge series (last write wins)."""
        with self._lock:
            self._gauges[(name, _label_key(labels))] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one observation into a histogram series."""
        key = (name, _label_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = HistogramData(buckets=self._buckets)
            h.observe(float(value))

    # -- reads ---------------------------------------------------------------

    def counter_value(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get((name, _label_key(labels)), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter across all label sets."""
        with self._lock:
            return sum(
                v for (n, _), v in self._counters.items() if n == name
            )

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        with self._lock:
            return self._gauges.get((name, _label_key(labels)))

    def snapshot(self) -> dict[str, Any]:
        """Lock-consistent JSON-able copy of every series.

        Series are rendered as ``name{k=v,...}`` strings, which keeps the
        snapshot flat (one key per series) and stable to iterate in tests and
        exporters.
        """
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: h.to_dict() for k, h in self._hists.items()}

        def render(store: dict) -> dict[str, Any]:
            return {
                _series_name(name, lk): v
                for (name, lk), v in sorted(store.items())
            }

        return {
            "counters": render(counters),
            "gauges": render(gauges),
            "histograms": render(hists),
        }

    def series(self) -> dict[str, list]:
        """Raw (name, labels, value) triples per kind — the exporter view."""
        with self._lock:
            return {
                "counters": [
                    (n, dict(lk), v) for (n, lk), v in sorted(self._counters.items())
                ],
                "gauges": [
                    (n, dict(lk), v) for (n, lk), v in sorted(self._gauges.items())
                ],
                "histograms": [
                    (n, dict(lk), dataclasses.replace(h, counts=list(h.counts)))
                    for (n, lk), h in sorted(self._hists.items())
                ],
            }

    # -- restart continuity (see Scheduler.save_checkpoint) ------------------

    def state_dict(self) -> dict[str, Any]:
        """JSON-able cumulative state: the counters (gauges and histograms
        are point-in-time / distributional views that a restarted service
        legitimately rebuilds; counters are the totals that must not reset)."""
        with self._lock:
            return {
                "counters": [
                    [name, [list(kv) for kv in lk], value]
                    for (name, lk), value in sorted(self._counters.items())
                ]
            }

    def load_state(self, state: dict[str, Any]) -> None:
        """Restore checkpointed counter totals (replacing current values)."""
        with self._lock:
            for name, lk, value in state.get("counters", []):
                key = (name, tuple((str(k), str(v)) for k, v in lk))
                self._counters[key] = float(value)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


def _series_name(name: str, lk: LabelKey) -> str:
    if not lk:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in lk) + "}"


# One default registry per process; the service stack records here unless a
# caller installs its own (tests isolate with set_registry(MetricsRegistry())).
_default = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    return _default


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Install `registry` as the process default; returns the previous one."""
    global _default
    with _default_lock:
        prev = _default
        _default = registry
    return prev
