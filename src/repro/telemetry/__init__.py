"""Telemetry: metrics registry, span tracing, convergence telemetry, export.

The observability layer of the recurring-solve service (and of one-shot
solves).  Four pieces, one import:

  * `MetricsRegistry` (`registry.py`) — thread-safe labelled counters /
    gauges / histograms; `get_registry()` is the process default every
    subsystem records into.
  * `span` (`tracing.py`) — nested wall-clock spans with Chrome-trace
    (Perfetto) export and optional `jax.profiler.TraceAnnotation`
    pass-through into XLA profiles.
  * `ConvergenceTrace` / `StallDetector` (`convergence.py`) — per-solve
    iteration traces lifted from the already-returned `SolveResult.stats`
    (no per-iteration host syncs), with budget-exhaustion stall flagging.
  * `JsonlSink` / `write_prometheus` (`export.py`) — the JSONL record schema
    (validated by `tools/check_metrics.py`) and Prometheus text exposition.

Instrumentation sites across the stack (see docs/observability.md for the
metric catalog): `service.session` (solve reports, convergence),
`service.scheduler` (cadence spans, overlap efficiency, queue depth),
`service.engine` (compile cache hits/misses, compile seconds),
`service.pool` (batch sizes, padding), `instances.deltas` (delta counts,
scatter bytes, rejections), `core.sharding` (psum early-stop checks),
`core.maximizer` (solve/stage spans).
"""
from repro.telemetry.convergence import (
    ConvergenceTrace,
    StageTrace,
    StallDetector,
)
from repro.telemetry.export import (
    SCHEMA,
    JsonlSink,
    jsonable,
    prometheus_text,
    validate_jsonl,
    validate_record,
    write_prometheus,
)
from repro.telemetry.registry import (
    DEFAULT_BUCKETS,
    HistogramData,
    MetricsRegistry,
    get_registry,
    set_registry,
)
from repro.telemetry.tracing import Tracer, get_tracer, set_tracer, span

__all__ = [
    "ConvergenceTrace",
    "StageTrace",
    "StallDetector",
    "SCHEMA",
    "JsonlSink",
    "jsonable",
    "prometheus_text",
    "validate_jsonl",
    "validate_record",
    "write_prometheus",
    "DEFAULT_BUCKETS",
    "HistogramData",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
]
