"""Span tracing: nested wall-clock spans with Chrome-trace (Perfetto) export.

    with span("cadence", tenant="t0"):
        with span("solve", mode="warm"):
            ...

Spans nest per thread (a thread-local stack), record wall-clock durations,
and serialize as Chrome trace events (``{"traceEvents": [...]}``) loadable in
Perfetto / chrome://tracing.  When a tracer is constructed with
``jax_annotations=True`` each span additionally enters a
`jax.profiler.TraceAnnotation`, so the same span names land inside XLA
profiles captured with `jax.profiler.trace` — one instrumentation site, both
timelines.

Tracing is cheap but not free (two clock reads + a list append per span), so
spans wrap cadence/solve/stage granularity, never the per-iteration AGD body
(which lives inside a single compiled `lax.scan` anyway and is invisible to
host-side tracing by construction).

The event buffer is bounded (`max_events`); overflow drops new events and
counts them (`dropped`), so a long-running service cannot leak memory through
its own observability layer.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Iterator, Optional

__all__ = ["Span", "Tracer", "get_tracer", "set_tracer", "span"]


class Span:
    """One open span; exposed so callers can attach late attributes."""

    __slots__ = ("name", "args", "t0", "wall0", "depth", "parent")

    def __init__(self, name: str, args: dict, depth: int, parent: Optional["Span"]):
        self.name = name
        self.args = args
        self.t0 = time.perf_counter()
        self.wall0 = time.time()
        self.depth = depth
        self.parent = parent

    def set(self, **attrs) -> None:
        """Attach attributes to the span while it is open."""
        self.args.update(attrs)


class Tracer:
    """Collects nested spans into a Chrome-trace-event buffer."""

    def __init__(
        self,
        *,
        jax_annotations: bool = False,
        max_events: int = 100_000,
    ):
        self._lock = threading.Lock()
        self._events: list[dict[str, Any]] = []
        self._stacks = threading.local()
        self.jax_annotations = jax_annotations
        self.max_events = int(max_events)
        self.dropped = 0
        # perf_counter origin so event timestamps start near zero
        self._origin = time.perf_counter()

    # -- span lifecycle ------------------------------------------------------

    def _stack(self) -> list[Span]:
        st = getattr(self._stacks, "stack", None)
        if st is None:
            st = self._stacks.stack = []
        return st

    def current(self) -> Optional[Span]:
        st = self._stack()
        return st[-1] if st else None

    @contextlib.contextmanager
    def span(self, name: str, **args) -> Iterator[Span]:
        stack = self._stack()
        sp = Span(name, dict(args), depth=len(stack), parent=self.current())
        stack.append(sp)
        ann = None
        if self.jax_annotations:
            try:
                import jax.profiler

                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:  # profiler unavailable: wall-clock spans only
                ann = None
        try:
            yield sp
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            stack.pop()
            self._emit(sp, time.perf_counter())

    def _emit(self, sp: Span, t1: float) -> None:
        event = {
            "name": sp.name,
            "ph": "X",  # complete event: ts + dur
            "ts": (sp.t0 - self._origin) * 1e6,  # microseconds
            "dur": (t1 - sp.t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident() % 2**31,
            "args": _jsonable(sp.args),
        }
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(event)

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_chrome_trace(self) -> dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto / chrome://tracing)."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def export_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
            f.write("\n")

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0


def _jsonable(obj):
    """Best-effort conversion of span args to JSON-able values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    try:  # numpy / jax scalars
        return float(obj)
    except (TypeError, ValueError):
        return repr(obj)


_default = Tracer()
_default_lock = threading.Lock()


def get_tracer() -> Tracer:
    return _default


def set_tracer(tracer: Tracer) -> Tracer:
    """Install `tracer` as the process default; returns the previous one."""
    global _default
    with _default_lock:
        prev = _default
        _default = tracer
    return prev


def span(name: str, **args):
    """`with span("cadence", tenant=...):` against the process-default tracer."""
    return _default.span(name, **args)
