"""Convergence telemetry: per-solve traces + stall detection.

The maximizer already materializes per-iteration `(g, grad_norm,
max_violation)` traces and per-stage `iters_used` in `SolveResult.stats` —
device arrays returned by the compiled solve, previously discarded by the
service layer.  `ConvergenceTrace.from_result` lifts them (ONE host transfer
of already-computed arrays after the solve fence; no per-iteration host
syncs) into a structured per-solve record:

  * per-stage traces truncated to the iterations actually executed;
  * per-stage `iters_used` vs the padded budget, and whether the early-stop
    predicate fired (`converged[s]`);
  * a stall flag: early stopping was configured but the final (gamma-floor)
    stage exhausted its budget without the predicate firing — the solve's
    quality claim is the floor stage's convergence, so that is the stage a
    stall is defined on.

`StallDetector` aggregates stalls per tenant across cadences and flags
tenants stalled `patience` consecutive solves — the "this tenant's budget no
longer fits its instance" alarm, exported as
``convergence_stalled_solves_total`` / ``convergence_consecutive_stalls``.

PDHG parity: `core.pdhg.solve_pdhg` emits the same `stats` shape (a 1-tuple
of `StageStats` at check-frequency resolution) plus `iters_used`, so one
`ConvergenceTrace` covers both engines (`engine="agd" | "pdhg"`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from repro.telemetry.registry import MetricsRegistry, get_registry

__all__ = ["StageTrace", "ConvergenceTrace", "StallDetector"]


@dataclasses.dataclass(frozen=True)
class StageTrace:
    """One continuation stage's iteration traces, truncated to `iters_used`.

    `trace_stride` is the iterations-per-trace-entry resolution: 1 for AGD
    (per-iteration traces), `check_every` for PDHG (residuals are only
    computed at check points).  `iters_used`/`budget` are always iterations.
    """

    g: np.ndarray
    grad_norm: np.ndarray
    max_violation: np.ndarray
    iters_used: int
    budget: int
    converged: bool  # early-stop predicate fired before the budget ran out
    trace_stride: int = 1

    def summary(self) -> dict[str, Any]:
        last = lambda a: float(a[-1]) if a.size else None
        return {
            "iters_used": self.iters_used,
            "budget": self.budget,
            "converged": self.converged,
            "g_final": last(self.g),
            "grad_norm_final": last(self.grad_norm),
            "max_violation_final": last(self.max_violation),
        }


@dataclasses.dataclass(frozen=True)
class ConvergenceTrace:
    """Structured per-solve convergence record (host numpy, post-fence)."""

    tenant: str
    cadence: int
    engine: str  # "agd" | "pdhg"
    mode: str  # "cold" | "warm" | "oneshot"
    stages: tuple[StageTrace, ...]
    early_stop: bool  # a stop predicate was configured at all

    @property
    def total_iters_used(self) -> int:
        return sum(s.iters_used for s in self.stages)

    @property
    def total_budget(self) -> int:
        return sum(s.budget for s in self.stages)

    @property
    def stalled(self) -> bool:
        """Early stopping configured, yet the gamma-floor stage never
        converged within its budget — the drift-SLA quality claim rests on
        that stage, so its exhaustion is the stall signal."""
        return bool(
            self.early_stop and self.stages and not self.stages[-1].converged
        )

    @classmethod
    def from_result(
        cls,
        res,  # core.maximizer.SolveResult or core.pdhg.PDHGResult
        *,
        tenant: str = "",
        cadence: int = 0,
        engine: str = "agd",
        mode: str = "oneshot",
        stage_budget: Optional[int] = None,
        trace_stride: int = 1,
    ) -> "ConvergenceTrace":
        """Build from an already-returned solve result.

        Reads `res.stats` (a tuple of StageStats whose arrays the solve
        already computed) and `res.iters_used`; the only work here is the
        device→host copy of those trace arrays, sized by the iteration
        budget, performed once per solve.

        `trace_stride` handles engines whose traces are coarser than one
        entry per iteration (PDHG records residuals every `check_every`
        iterations): budgets and `iters_used` stay in iterations while the
        trace arrays are truncated at entry resolution.
        """
        stats = tuple(res.stats)
        iters_used = getattr(res, "iters_used", None)
        early_stop = iters_used is not None
        stride = max(1, int(trace_stride))
        stages = []
        for s, st in enumerate(stats):
            g = np.asarray(st.g)
            gn = np.asarray(st.grad_norm)
            mv = np.asarray(st.max_violation)
            budget = (
                int(g.shape[0]) * stride
                if stage_budget is None
                else int(stage_budget)
            )
            used = int(iters_used[s]) if early_stop else budget
            used = max(0, min(used, budget))
            n = min(-(-used // stride), int(g.shape[0]))
            stages.append(
                StageTrace(
                    g=g[:n],
                    grad_norm=gn[:n],
                    max_violation=mv[:n],
                    iters_used=used,
                    budget=budget,
                    converged=bool(early_stop and used < budget),
                    trace_stride=stride,
                )
            )
        return cls(
            tenant=tenant,
            cadence=int(cadence),
            engine=engine,
            mode=mode,
            stages=tuple(stages),
            early_stop=early_stop,
        )

    def summary(self) -> dict[str, Any]:
        """Compact JSON-able view — what the JSONL exporter records."""
        final = self.stages[-1].summary() if self.stages else {}
        return {
            "tenant": self.tenant,
            "cadence": self.cadence,
            "engine": self.engine,
            "mode": self.mode,
            "num_stages": len(self.stages),
            "iters_used": [s.iters_used for s in self.stages],
            "stage_budgets": [s.budget for s in self.stages],
            "total_iters_used": self.total_iters_used,
            "total_budget": self.total_budget,
            "converged_by_stage": [s.converged for s in self.stages],
            "early_stop": self.early_stop,
            "stalled": self.stalled,
            "g_final": final.get("g_final"),
            "grad_norm_final": final.get("grad_norm_final"),
            "max_violation_final": final.get("max_violation_final"),
        }

    def record(self, registry: Optional[MetricsRegistry] = None) -> None:
        """Fold this solve's convergence telemetry into the registry."""
        reg = registry or get_registry()
        labels = dict(tenant=self.tenant, engine=self.engine, mode=self.mode)
        reg.inc("convergence_solves_total", 1, **labels)
        reg.inc("convergence_iters_total", self.total_iters_used, **labels)
        reg.observe(
            "convergence_iters_used", self.total_iters_used, engine=self.engine
        )
        if self.total_budget:
            reg.set_gauge(
                "convergence_budget_utilization",
                self.total_iters_used / self.total_budget,
                tenant=self.tenant,
            )


class StallDetector:
    """Flags tenants whose early-stop predicate keeps failing to fire.

    One stalled solve may just be a noisy cadence; `patience` consecutive
    stalls (default 1 — flag immediately) marks the tenant.  State is
    per-detector; the service layer keeps one per scheduler lifetime.
    """

    def __init__(self, patience: int = 1):
        self.patience = max(1, int(patience))
        self._consecutive: dict[str, int] = {}
        self.flagged: set[str] = set()

    def observe(
        self, trace: ConvergenceTrace, registry: Optional[MetricsRegistry] = None
    ) -> bool:
        """Record one solve; returns True when the tenant is (now) flagged."""
        reg = registry or get_registry()
        key = trace.tenant or "<default>"
        if trace.stalled:
            self._consecutive[key] = self._consecutive.get(key, 0) + 1
            reg.inc("convergence_stalled_solves_total", 1, tenant=key)
        else:
            self._consecutive[key] = 0
            self.flagged.discard(key)
        n = self._consecutive[key]
        reg.set_gauge("convergence_consecutive_stalls", n, tenant=key)
        if n >= self.patience:
            self.flagged.add(key)
        return key in self.flagged
