"""Exporters: JSONL sink, Prometheus text exposition, record schema.

The JSONL schema is the stable contract between the service and everything
downstream (perf-trajectory tooling, the bench-history artifact, CI's
`tools/check_metrics.py`).  Every record is one JSON object per line:

    {"ts": <unix seconds>, "kind": "<kind>", "payload": {...}}

with per-kind required payload keys listed in `SCHEMA`.  Adding payload keys
is backward compatible; removing or renaming a required key is a schema break
and must update `SCHEMA` (and the golden-key test) in the same change.

`write_prometheus` renders a registry snapshot in Prometheus text exposition
format (the file a node_exporter-style textfile collector or any scraper
sidecar can serve); counters get `_total`-style TYPE lines, histograms emit
cumulative `_bucket{le=...}` series plus `_sum`/`_count`.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time
from typing import Any, Optional

from repro.telemetry.registry import MetricsRegistry, get_registry

__all__ = [
    "SCHEMA",
    "JsonlSink",
    "jsonable",
    "validate_record",
    "validate_jsonl",
    "prometheus_text",
    "write_prometheus",
]

# kind -> required payload keys.  Keys may hold null; they must be present.
SCHEMA: dict[str, tuple[str, ...]] = {
    # one per tenant solve: the session's drift-SLA report
    "solve_report": (
        "tenant",
        "cadence",
        "mode",
        "engine",
        "iters_used",
        "iter_budget",
        "g",
        "max_violation",
        "dc_norm",
        "upload_mode",
        "upload_bytes",
        "drift_rel",
        "drift_bound",
        "sla_ok",
    ),
    # one per tenant solve: ConvergenceTrace.summary()
    "convergence": (
        "tenant",
        "cadence",
        "engine",
        "iters_used",
        "stage_budgets",
        "total_iters_used",
        "total_budget",
        "stalled",
        "g_final",
        "max_violation_final",
    ),
    # one per scheduler cadence
    "cadence": (
        "cadence",
        "tenants",
        "batched_fraction",
        "upload_bytes",
        "overlapped",
        "wall_seconds",
    ),
    # one per delta ingestion
    "ingest": ("tenant", "in_place", "n_insert", "n_delete", "n_update"),
    # registry snapshot (typically the final record of a run)
    "counters": ("counters", "gauges", "histograms"),
    # one per benchmark harness run (benchmarks/run.py --bench-history)
    "bench": ("suite", "quick", "results"),
    # one per served allocation batch (repro.serving; benchmarks/serving_latency)
    "serving_query": ("tenant", "generation", "users", "latency_seconds"),
}


def jsonable(obj: Any) -> Any:
    """Deep-convert numpy / jax scalars and arrays to JSON-able values."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else repr(obj)
    if hasattr(obj, "tolist"):  # numpy arrays and scalars, jax arrays
        return jsonable(obj.tolist())
    if hasattr(obj, "item"):
        return jsonable(obj.item())
    try:
        return float(obj)
    except (TypeError, ValueError):
        return repr(obj)


class JsonlSink:
    """Append-only JSONL writer for telemetry records (thread-safe).

    Opens lazily, appends by default (the perf-trajectory use case: each run
    adds timestamped records, nothing is overwritten), and flushes per record
    so a crashed run still leaves a valid prefix.
    """

    def __init__(self, path: str, *, append: bool = True):
        self.path = path
        self._mode = "a" if append else "w"
        self._lock = threading.Lock()
        self._fh = None

    def emit(self, kind: str, payload: dict[str, Any], *, ts: Optional[float] = None) -> None:
        if kind not in SCHEMA:
            raise ValueError(f"unknown telemetry record kind: {kind!r}")
        record = {
            "ts": float(time.time() if ts is None else ts),
            "kind": kind,
            "payload": jsonable(payload),
        }
        line = json.dumps(record, sort_keys=True)
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, self._mode)
                self._mode = "a"
            self._fh.write(line + "\n")
            self._fh.flush()

    def emit_counters(self, registry: Optional[MetricsRegistry] = None) -> None:
        """Append one `counters` record holding a full registry snapshot."""
        reg = registry or get_registry()
        self.emit("counters", reg.snapshot())

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# -- validation (tools/check_metrics.py) -------------------------------------


def validate_record(obj: Any) -> list[str]:
    """Schema errors of one decoded JSONL record ([] when valid)."""
    errors = []
    if not isinstance(obj, dict):
        return [f"record is not an object: {type(obj).__name__}"]
    ts = obj.get("ts")
    if not isinstance(ts, (int, float)):
        errors.append("missing/non-numeric 'ts'")
    kind = obj.get("kind")
    if kind not in SCHEMA:
        return errors + [f"unknown kind {kind!r}"]
    payload = obj.get("payload")
    if not isinstance(payload, dict):
        return errors + ["missing/non-object 'payload'"]
    for key in SCHEMA[kind]:
        if key not in payload:
            errors.append(f"kind {kind!r}: payload missing required key {key!r}")
    return errors


def validate_jsonl(path: str) -> tuple[int, list[str]]:
    """(num_records, errors) of a JSONL export file."""
    errors: list[str] = []
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {lineno}: invalid JSON ({e})")
                continue
            errors.extend(f"line {lineno}: {e}" for e in validate_record(obj))
    return n, errors


# -- Prometheus text exposition ----------------------------------------------

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_OK = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _labels_text(labels: dict[str, str], extra: Optional[dict] = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(
        f'{_LABEL_OK.sub("_", str(k))}="{_escape(str(v))}"'
        for k, v in sorted(merged.items())
    )
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() and abs(f) < 2**53 else repr(f)


def prometheus_text(registry: Optional[MetricsRegistry] = None) -> str:
    """Registry snapshot in Prometheus text exposition format."""
    reg = registry or get_registry()
    series = reg.series()
    lines: list[str] = []
    typed: set[str] = set()

    def type_line(name: str, kind: str) -> None:
        if name not in typed:
            lines.append(f"# TYPE {name} {kind}")
            typed.add(name)

    for name, labels, value in series["counters"]:
        pname = _metric_name(name)
        type_line(pname, "counter")
        lines.append(f"{pname}{_labels_text(labels)} {_fmt(value)}")
    for name, labels, value in series["gauges"]:
        pname = _metric_name(name)
        type_line(pname, "gauge")
        lines.append(f"{pname}{_labels_text(labels)} {_fmt(value)}")
    for name, labels, hist in series["histograms"]:
        pname = _metric_name(name)
        type_line(pname, "histogram")
        cum = 0
        for i, le in enumerate(hist.buckets):
            cum += hist.counts[i]
            lines.append(
                f"{pname}_bucket{_labels_text(labels, {'le': _fmt(le)})} {cum}"
            )
        cum += hist.counts[-1]
        lines.append(
            f"{pname}_bucket{_labels_text(labels, {'le': '+Inf'})} {cum}"
        )
        lines.append(f"{pname}_sum{_labels_text(labels)} {_fmt(hist.sum)}")
        lines.append(f"{pname}_count{_labels_text(labels)} {hist.count}")
    return "\n".join(lines) + "\n"


def write_prometheus(
    path: str, registry: Optional[MetricsRegistry] = None
) -> None:
    """Write the snapshot atomically (scrapers never see a partial file)."""
    import os

    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        f.write(prometheus_text(registry))
    os.replace(tmp, path)
