"""Version-compat shims for jax mesh APIs.

The distributed layer targets the modern mesh surface — ``jax.make_mesh(...,
axis_types=...)`` plus the ``jax.set_mesh`` context — but the pinned jax in
this environment predates both ``jax.sharding.AxisType`` and ``jax.set_mesh``
(and some older versions predate ``jax.make_mesh`` entirely).  Everything in
the repo that constructs or activates a mesh goes through this module, so the
same solver, launch and test code runs on either API generation:

  * `make_mesh(shape, names, devices=..., axis_types=...)` — forwards
    ``axis_types`` only when the running jax understands it; falls back to
    building a `jax.sharding.Mesh` directly when `jax.make_mesh` is absent.
  * `set_mesh(mesh)` — context manager resolving to ``jax.set_mesh`` when
    available, else ``jax.sharding.use_mesh``, else the legacy ``with mesh:``
    physical-mesh context (sufficient here: every `shard_map`/`jit` call in
    the solver passes its mesh explicitly, so the context only needs to keep
    older jax's resource-env machinery happy).
  * `default_axis_types(n)` — ``(AxisType.Auto,) * n`` or None when the enum
    does not exist.

This is what lets the `slow`-marked distributed/elastic suites run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on the pinned jax
instead of being dead code (ROADMAP: "Version-compat for subprocess
distributed tests").
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = [
    "HAS_AXIS_TYPE",
    "HAS_SET_MESH",
    "axis_size",
    "default_axis_types",
    "make_mesh",
    "set_mesh",
]

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")

# Sentinel: "give me whatever the running jax considers a plain data-parallel
# mesh" (AxisType.Auto everywhere when the enum exists, nothing otherwise).
_AUTO = "auto"


def axis_size(name: str):
    """`jax.lax.axis_size` with a psum(1) fallback for jax versions without it.

    Inside `shard_map`/`pmap` tracing, ``psum(1, name)`` constant-folds to the
    named axis's size, so the fallback costs no runtime collective.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def default_axis_types(n: int):
    """``(AxisType.Auto,) * n`` on modern jax, None on versions without it."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
    axis_types=_AUTO,
):
    """`jax.make_mesh` that tolerates jax versions without ``axis_types``."""
    axis_shapes = tuple(int(s) for s in axis_shapes)
    axis_names = tuple(axis_names)
    if axis_types is _AUTO:
        axis_types = default_axis_types(len(axis_names))
    if hasattr(jax, "make_mesh"):
        if axis_types is not None and HAS_AXIS_TYPE:
            try:
                return jax.make_mesh(
                    axis_shapes, axis_names,
                    devices=devices, axis_types=axis_types,
                )
            except TypeError:  # make_mesh exists but predates axis_types
                pass
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    devs = list(devices) if devices is not None else jax.devices()
    n = int(np.prod(axis_shapes))
    return Mesh(np.asarray(devs[:n]).reshape(axis_shapes), axis_names)


@contextmanager
def set_mesh(mesh: Mesh):
    """``with set_mesh(mesh):`` — the newest mesh-context API available."""
    if HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    elif hasattr(jax.sharding, "use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:
        with mesh:  # legacy physical-mesh context manager
            yield mesh
