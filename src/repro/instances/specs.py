"""Analytic production-scale instance layouts for dry-runs.

The dry-run lowers the solver on ShapeDtypeStructs — no 100M-source instance
is materialised.  Bucket row counts are estimated by sampling the Appendix-A
degree model at 1M sources and scaling the histogram to the target size
(padded to the shard multiple), which preserves the padding/bucket mix that
drives the roofline terms.
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.instances.buckets import Bucket, BucketedInstance, rhs_dtype
from repro.instances.generator import MatchingInstanceSpec, generate_matching_instance

__all__ = ["production_bucket_shapes", "solver_input_specs"]

_SAMPLE = 1_000_000


@lru_cache(maxsize=16)
def _degree_fractions(avg_degree: float, breadth_sigma: float, seed: int):
    """Fraction of sources per power-of-2 bucket, sampled at 1M sources."""
    spec = MatchingInstanceSpec(
        num_sources=_SAMPLE,
        num_destinations=10_000,
        avg_degree=avg_degree,
        breadth_sigma=breadth_sigma,
        seed=seed,
    )
    inst = generate_matching_instance(spec)
    deg = np.bincount(inst.src, minlength=_SAMPLE)
    deg = deg[deg > 0]
    buckets: dict[int, int] = {}
    for d in deg:
        L = 1 << max(0, int(d - 1).bit_length())
        buckets[L] = buckets.get(L, 0) + 1
    total = sum(buckets.values())
    return {L: n / total for L, n in sorted(buckets.items())}


def production_bucket_shapes(
    num_sources: int,
    num_destinations: int,
    num_families: int = 1,
    avg_degree: float = 10.0,
    breadth_sigma: float = 1.0,
    shard_multiple: int = 1,
    seed: int = 0,
) -> list[tuple[int, int]]:
    """[(bucket_length, padded_row_count)] for a production-size instance."""
    fr = _degree_fractions(avg_degree, breadth_sigma, seed)
    out = []
    for L, f in fr.items():
        rows = max(1, int(round(f * num_sources)))
        rows = int(math.ceil(rows / shard_multiple) * shard_multiple)
        out.append((L, rows))
    return out


def solver_input_specs(
    num_sources: int,
    num_destinations: int,
    num_families: int = 1,
    avg_degree: float = 10.0,
    shard_multiple: int = 1,
    dtype=jnp.float32,
) -> BucketedInstance:
    """ShapeDtypeStruct BucketedInstance at production scale (no allocation)."""
    shapes = production_bucket_shapes(
        num_sources,
        num_destinations,
        num_families,
        avg_degree,
        shard_multiple=shard_multiple,
    )
    sds = jax.ShapeDtypeStruct
    # mirror the real bucketize layout: int8 slabs carry per-bucket fp32
    # scales, and any narrow storage keeps the rhs (and hence duals) fp32
    quantized = jnp.dtype(dtype) == jnp.dtype(jnp.int8)
    buckets = tuple(
        Bucket(
            idx=sds((n, L), jnp.int32),
            coeff=sds((num_families, n, L), dtype),
            cost=sds((n, L), dtype),
            mask=sds((n, L), dtype),
            length=L,
            coeff_scale=(
                sds((num_families, 1, 1), jnp.float32) if quantized else None
            ),
            cost_scale=sds((1, 1), jnp.float32) if quantized else None,
        )
        for L, n in shapes
    )
    return BucketedInstance(
        buckets=buckets,
        rhs=sds(
            (num_families * num_destinations,), rhs_dtype(jnp.dtype(dtype))
        ),
        num_sources=num_sources,
        num_destinations=num_destinations,
        num_families=num_families,
    )
