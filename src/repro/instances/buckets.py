"""Bucketed-ELL packing of matching LPs — the TPU analogue of the paper's §4.1+§4.2.

The paper stores A in CSC (one column per source) and separately buckets the
per-source slices by length for batched projection.  On TPU both collapse into
one structure: sources whose eligible-degree d lies in (2^{t-1}, 2^t] are packed
into a dense slab of width L_t = 2^t.  Each bucket is a fixed-shape set of
arrays (gather/segment-sum friendly, shardable along rows); padding within a
bucket is bounded by 2x, exactly the paper's bound, and the number of distinct
kernel launches is 1 + floor(log2 s_max), exactly the paper's launch count.

Layout per bucket (n rows = sources, L = slab width):
  idx   [n, L] int32  destination id of each eligible edge (0 for padding)
  coeff [m, n, L]     constraint coefficient per family    (0 for padding)
  cost  [n, L]        minimisation cost c_ij               (0 for padding)
  mask  [n, L]        1.0 for real edges, 0.0 for padding

Rows are padded up to a multiple of ``shard_multiple`` so `shard_map` sees
equal per-device shapes; padded rows are all-mask-zero and contribute exact
zeros to gradients.

Slab storage dtype (``slab_dtype``): coeff/cost/mask are stored in fp32
(default), bf16, or int8.  Narrow storage halves/quarters the per-iteration
HBM traffic of the dual oracle; *accumulation* (the Ax histogram, c'x,
||x||^2, all dual/continuation math) stays fp32 on every path.  int8 slabs
carry symmetric per-bucket scales — ``coeff_scale [m,1,1]`` (per family) and
``cost_scale [1,1]``, both fp32 — and are dequantized in-kernel (value =
q * scale); mask is exact in any dtype (0/1).  The rhs and the duals stay
fp32 for narrow slab dtypes (`rhs_dtype`).
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Optional, Sequence

import jax
import ml_dtypes
import numpy as np

from repro.instances.generator import EdgeListInstance

__all__ = [
    "Bucket",
    "BucketedInstance",
    "SLAB_DTYPES",
    "bucketize",
    "convert_bucket",
    "dequantize_bucket",
    "pack_single_slab",
    "pack_source_ids",
    "resolve_slab_dtype",
    "rhs_dtype",
    "slab_dtype_name",
    "unpack_primal",
]

# Supported slab storage dtypes, by canonical name.  "bfloat16" maps to
# ml_dtypes.bfloat16 on the host (numpy slabs) and jnp.bfloat16 on device.
SLAB_DTYPES = ("float32", "bfloat16", "int8")

_INT8_QMAX = 127.0  # symmetric quantization range [-127, 127]


def resolve_slab_dtype(dtype) -> np.dtype:
    """Canonical numpy dtype of a slab-dtype name/dtype (raises on unknown)."""
    if isinstance(dtype, str) and dtype == "bfloat16":
        return np.dtype(ml_dtypes.bfloat16)
    d = np.dtype(dtype)
    if slab_dtype_name(d) not in SLAB_DTYPES:
        raise ValueError(
            f"unsupported slab dtype {dtype!r}; choose from {SLAB_DTYPES}"
        )
    return d


def slab_dtype_name(dtype) -> str:
    """Canonical name ("float32" | "bfloat16" | "int8") of a slab dtype."""
    return np.dtype(dtype).name


def rhs_dtype(slab_dtype) -> np.dtype:
    """Storage dtype of the rhs for a given slab dtype: the duals (and
    everything in dual space, rhs included) stay fp32 when slabs go narrow."""
    d = resolve_slab_dtype(slab_dtype)
    return d if slab_dtype_name(d) == "float32" else np.dtype(np.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Bucket:
    idx: jax.Array | np.ndarray  # [n, L] int32
    coeff: jax.Array | np.ndarray  # [m, n, L] slab dtype
    cost: jax.Array | np.ndarray  # [n, L] slab dtype
    mask: jax.Array | np.ndarray  # [n, L] slab dtype (exact 0/1 in any dtype)
    length: int = dataclasses.field(metadata=dict(static=True))
    # int8 storage only: symmetric per-bucket dequantization scales
    # (value = q * scale), fp32.  None for float storage — None contributes
    # no pytree leaves, so fp32/bf16 treedefs are unchanged by these fields.
    coeff_scale: Optional[jax.Array | np.ndarray] = None  # [m, 1, 1] f32
    cost_scale: Optional[jax.Array | np.ndarray] = None  # [1, 1] f32

    @property
    def rows(self) -> int:
        return int(self.idx.shape[0])

    @property
    def slab_dtype(self) -> str:
        return slab_dtype_name(self.coeff.dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BucketedInstance:
    buckets: tuple[Bucket, ...]
    rhs: jax.Array | np.ndarray  # [m * J] f32
    num_sources: int = dataclasses.field(metadata=dict(static=True))
    num_destinations: int = dataclasses.field(metadata=dict(static=True))
    num_families: int = dataclasses.field(metadata=dict(static=True))
    # Optional compiled-formulation metadata (repro.formulation.FormulationSpec,
    # hashable+frozen).  Static: it is part of the treedef, so the shape-keyed
    # jit caches in service/engine.py key executables on the formulation too,
    # and MatchingObjective (the shim) resolves it at trace time — which is how
    # a compiled formulation dispatches through the solve/service layers with
    # zero edits to maximizer/sharding/service.  None = legacy matching.
    formulation: Optional[object] = dataclasses.field(
        default=None, metadata=dict(static=True)
    )

    @property
    def dual_dim(self) -> int:
        return self.num_families * self.num_destinations

    @property
    def nnz(self) -> int:
        return int(sum(float(np.sum(np.asarray(b.mask))) for b in self.buckets))

    def row_norms_sq(self) -> np.ndarray:
        """||A_r||_2^2 per coupling row r = k*J + j (for Jacobi / Lemma B.1)."""
        m, J = self.num_families, self.num_destinations
        out = np.zeros(m * J)
        for b in self.buckets:
            idx = np.asarray(b.idx)
            coeff, _, mask = _host_dequant(b)
            for k in range(m):
                np.add.at(out, k * J + idx.ravel(), (coeff[k] ** 2 * mask).ravel())
        return out

    @property
    def slab_dtype(self) -> str:
        return self.buckets[0].slab_dtype

    def shape_dtype_structs(self) -> "BucketedInstance":
        """ShapeDtypeStruct twin of this instance (for .lower() dry-runs)."""
        as_sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        return jax.tree.map(as_sds, self)


# -- slab dtype conversion ---------------------------------------------------


def _host_dequant(b: Bucket) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(coeff, cost, mask) of one bucket as fp32 numpy arrays (host side)."""
    coeff = np.asarray(b.coeff)
    cost = np.asarray(b.cost)
    mask = np.asarray(b.mask)
    if b.slab_dtype == "float32":
        return coeff, cost, mask
    coeff = coeff.astype(np.float32)
    cost = cost.astype(np.float32)
    mask = mask.astype(np.float32)
    if b.coeff_scale is not None:
        coeff = coeff * np.asarray(b.coeff_scale, np.float32)
    if b.cost_scale is not None:
        cost = cost * np.asarray(b.cost_scale, np.float32)
    return coeff, cost, mask


def _quantize_sym(values: np.ndarray, axes: tuple[int, ...]) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization over `axes`: (q, scale) with q = round(v/s)
    clipped to [-127, 127] and s = max|v| / 127 (1/127 when all-zero, so the
    padding invariant q == 0 on mask-zero slots is preserved exactly)."""
    amax = np.abs(values).max(axis=axes, keepdims=True).astype(np.float32)
    scale = np.where(amax > 0, amax, 1.0) / _INT8_QMAX
    q = np.clip(np.rint(values / scale), -_INT8_QMAX, _INT8_QMAX)
    return q.astype(np.int8), scale


def convert_bucket(b: Bucket, dtype) -> Bucket:
    """Host-side conversion of one fp32 bucket to a storage dtype.

    bf16: plain rounding cast of coeff/cost/mask.  int8: symmetric per-bucket
    quantization (per family for coeff) with fp32 scales; mask stores its
    exact 0/1 pattern as int8.  fp32 in -> the bucket unchanged.
    """
    d = resolve_slab_dtype(dtype)
    name = slab_dtype_name(d)
    if name == slab_dtype_name(b.coeff.dtype) and b.coeff_scale is None:
        return b
    if b.slab_dtype != "float32":
        raise ValueError("convert_bucket expects an fp32 source bucket")
    coeff = np.asarray(b.coeff)
    cost = np.asarray(b.cost)
    mask = np.asarray(b.mask)
    if name == "float32":
        return b
    if name == "bfloat16":
        return dataclasses.replace(
            b, coeff=coeff.astype(d), cost=cost.astype(d), mask=mask.astype(d)
        )
    q_coeff, coeff_scale = _quantize_sym(coeff, axes=(1, 2))
    q_cost, cost_scale = _quantize_sym(cost[None], axes=(1, 2))
    return dataclasses.replace(
        b,
        coeff=q_coeff,
        cost=q_cost[0],
        mask=mask.astype(np.int8),
        coeff_scale=coeff_scale,
        cost_scale=cost_scale[0],
    )


def dequantize_bucket(b: Bucket):
    """fp32 compute view of one bucket (trace-safe; jnp ops on narrow dtypes).

    fp32 storage returns the bucket object unchanged — a host-level branch,
    so the default path's jaxpr is bit-identical to the pre-slab_dtype one
    (same trick as the formulation layer's ==1.0 scale branches).  Narrow
    storage dequantizes coeff/cost/mask to fp32; XLA fuses the convert into
    the consuming op, so HBM reads stay at the storage width.
    """
    import jax.numpy as jnp

    if b.slab_dtype == "float32":
        return b
    coeff = jnp.asarray(b.coeff).astype(jnp.float32)
    cost = jnp.asarray(b.cost).astype(jnp.float32)
    mask = jnp.asarray(b.mask).astype(jnp.float32)
    if b.coeff_scale is not None:
        coeff = coeff * jnp.asarray(b.coeff_scale, jnp.float32)
    if b.cost_scale is not None:
        cost = cost * jnp.asarray(b.cost_scale, jnp.float32)
    return dataclasses.replace(
        b, coeff=coeff, cost=cost, mask=mask,
        coeff_scale=None, cost_scale=None,
    )


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _PackInfo:
    """Host-side bookkeeping to map packed slabs back to edge order."""

    # per bucket: source id per row (-1 pad), edge offset of each row's slice
    source_ids: list[np.ndarray]
    edge_starts: list[np.ndarray]
    degrees: list[np.ndarray]


_PACK_INFO: dict[int, _PackInfo] = {}


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def _pad_rows(n: int, multiple: int) -> int:
    return int(math.ceil(max(n, 1) / multiple) * multiple)


def bucketize(
    inst: EdgeListInstance,
    *,
    shard_multiple: int = 1,
    min_length: int = 1,
    max_length: Optional[int] = None,
    dtype=np.float32,
) -> BucketedInstance:
    """Pack an edge list into the bucketed-ELL layout.

    Edges in ``inst`` must be sorted by (source, destination) — the generator
    guarantees this.  ``shard_multiple`` pads every bucket's row count so it
    divides evenly across that many shards.  ``dtype`` is the slab storage
    dtype ("float32" | "bfloat16" | "int8"; see module docstring): slabs are
    packed in fp32 and converted per bucket, and the rhs stays fp32 for
    narrow dtypes (dual space is always fp32).
    """
    slab_dt = resolve_slab_dtype(dtype)
    spec = inst.spec
    I, J, m = spec.num_sources, spec.num_destinations, spec.num_families

    deg = np.bincount(inst.src, minlength=I)
    active = np.flatnonzero(deg)  # sources with at least one edge
    if active.size == 0:
        raise ValueError("instance has no edges")
    # edge offsets per source (sorted by src)
    starts = np.zeros(I + 1, dtype=np.int64)
    np.cumsum(deg, out=starts[1:])

    max_deg = int(deg.max())
    cap = _next_pow2(max_deg)
    if max_length is not None:
        if cap > max_length:
            raise ValueError(
                f"max degree {max_deg} exceeds max bucket length {max_length}"
            )
    lengths = []
    L = max(1, _next_pow2(min_length))
    cap = max(cap, L)
    while L <= cap:
        lengths.append(L)
        L *= 2
    # bucket index per active source: smallest L >= degree, but >= min length
    b_of = np.searchsorted(np.asarray(lengths), deg[active])

    buckets: list[Bucket] = []
    info = _PackInfo(source_ids=[], edge_starts=[], degrees=[])
    for t, Lt in enumerate(lengths):
        rows_src = active[b_of == t]
        n = _pad_rows(rows_src.size, shard_multiple)
        idx = np.zeros((n, Lt), dtype=np.int32)
        coeff = np.zeros((m, n, Lt), dtype=np.float32)
        cost = np.zeros((n, Lt), dtype=np.float32)
        mask = np.zeros((n, Lt), dtype=np.float32)
        d = deg[rows_src]
        st = starts[rows_src]
        # vectorised slab fill: flat positions of each (row, within-slice) pair
        if rows_src.size:
            r = np.repeat(np.arange(rows_src.size), d)
            o = np.concatenate([np.arange(k) for k in d]) if d.size else np.empty(0, int)
            e = np.repeat(st, d) + o
            idx[r, o] = inst.dst[e]
            cost[r, o] = inst.cost[e]
            mask[r, o] = 1.0
            for k in range(m):
                coeff[k, r, o] = inst.coeff[k, e]
        buckets.append(
            convert_bucket(
                Bucket(idx=idx, coeff=coeff, cost=cost, mask=mask, length=Lt),
                slab_dt,
            )
        )
        sid = np.full(n, -1, dtype=np.int64)
        sid[: rows_src.size] = rows_src
        info.source_ids.append(sid)
        info.edge_starts.append(st)
        info.degrees.append(d)

    out = BucketedInstance(
        buckets=tuple(buckets),
        rhs=inst.rhs.astype(rhs_dtype(slab_dt)),
        num_sources=I,
        num_destinations=J,
        num_families=m,
    )
    _PACK_INFO[id(out)] = info
    weakref.finalize(out, _PACK_INFO.pop, id(out), None)
    return out


def pack_single_slab(
    inst: EdgeListInstance, *, shard_multiple: int = 1, dtype=np.float32
) -> BucketedInstance:
    """The paper's `batching=False` baseline: one slab of width next_pow2(s_max).

    Used by benchmarks/fig2_bucketing.py to reproduce Figure 2 (padding waste of
    the single-slab layout vs geometric bucketing).
    """
    deg = np.bincount(inst.src, minlength=inst.spec.num_sources)
    width = _next_pow2(int(deg.max()))
    return bucketize(
        inst, shard_multiple=shard_multiple, min_length=width, dtype=dtype
    )


def pack_source_ids(packed: BucketedInstance) -> list[np.ndarray]:
    """Per-bucket source id of each slab row (-1 for padded rows).

    Only available for instances produced by `bucketize` in this process; the
    delta-ingest layer (`repro.instances.deltas`) uses it to seed its
    row-occupancy maps.
    """
    info = _PACK_INFO.get(id(packed))
    if info is None:
        raise KeyError("pack_source_ids: packing info not found for this instance")
    return [a.copy() for a in info.source_ids]


def unpack_primal(
    packed: BucketedInstance, x_slabs: Sequence[np.ndarray | jax.Array]
) -> np.ndarray:
    """Scatter per-bucket primal slabs back to edge order (sorted by src,dst)."""
    info = _PACK_INFO.get(id(packed))
    if info is None:
        raise KeyError("unpack_primal: packing info not found for this instance")
    nnz = int(sum(d.sum() for d in info.degrees))
    x_edges = np.zeros(nnz)
    for bi, slab in enumerate(x_slabs):
        slab = np.asarray(slab)
        d = info.degrees[bi]
        st = info.edge_starts[bi]
        if d.size == 0:
            continue
        r = np.repeat(np.arange(d.size), d)
        o = np.concatenate([np.arange(k) for k in d])
        e = np.repeat(st, d) + o
        x_edges[e] = slab[r, o]
    return x_edges
