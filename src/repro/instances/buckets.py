"""Bucketed-ELL packing of matching LPs — the TPU analogue of the paper's §4.1+§4.2.

The paper stores A in CSC (one column per source) and separately buckets the
per-source slices by length for batched projection.  On TPU both collapse into
one structure: sources whose eligible-degree d lies in (2^{t-1}, 2^t] are packed
into a dense slab of width L_t = 2^t.  Each bucket is a fixed-shape set of
arrays (gather/segment-sum friendly, shardable along rows); padding within a
bucket is bounded by 2x, exactly the paper's bound, and the number of distinct
kernel launches is 1 + floor(log2 s_max), exactly the paper's launch count.

Layout per bucket (n rows = sources, L = slab width):
  idx   [n, L] int32  destination id of each eligible edge (0 for padding)
  coeff [m, n, L] f32 constraint coefficient per family    (0 for padding)
  cost  [n, L] f32    minimisation cost c_ij               (0 for padding)
  mask  [n, L] f32    1.0 for real edges, 0.0 for padding

Rows are padded up to a multiple of ``shard_multiple`` so `shard_map` sees
equal per-device shapes; padded rows are all-mask-zero and contribute exact
zeros to gradients.
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Optional, Sequence

import jax
import numpy as np

from repro.instances.generator import EdgeListInstance

__all__ = [
    "Bucket",
    "BucketedInstance",
    "bucketize",
    "pack_single_slab",
    "pack_source_ids",
    "unpack_primal",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Bucket:
    idx: jax.Array | np.ndarray  # [n, L] int32
    coeff: jax.Array | np.ndarray  # [m, n, L] f32
    cost: jax.Array | np.ndarray  # [n, L] f32
    mask: jax.Array | np.ndarray  # [n, L] f32
    length: int = dataclasses.field(metadata=dict(static=True))

    @property
    def rows(self) -> int:
        return int(self.idx.shape[0])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BucketedInstance:
    buckets: tuple[Bucket, ...]
    rhs: jax.Array | np.ndarray  # [m * J] f32
    num_sources: int = dataclasses.field(metadata=dict(static=True))
    num_destinations: int = dataclasses.field(metadata=dict(static=True))
    num_families: int = dataclasses.field(metadata=dict(static=True))
    # Optional compiled-formulation metadata (repro.formulation.FormulationSpec,
    # hashable+frozen).  Static: it is part of the treedef, so the shape-keyed
    # jit caches in service/engine.py key executables on the formulation too,
    # and MatchingObjective (the shim) resolves it at trace time — which is how
    # a compiled formulation dispatches through the solve/service layers with
    # zero edits to maximizer/sharding/service.  None = legacy matching.
    formulation: Optional[object] = dataclasses.field(
        default=None, metadata=dict(static=True)
    )

    @property
    def dual_dim(self) -> int:
        return self.num_families * self.num_destinations

    @property
    def nnz(self) -> int:
        return int(sum(float(np.sum(np.asarray(b.mask))) for b in self.buckets))

    def row_norms_sq(self) -> np.ndarray:
        """||A_r||_2^2 per coupling row r = k*J + j (for Jacobi / Lemma B.1)."""
        m, J = self.num_families, self.num_destinations
        out = np.zeros(m * J)
        for b in self.buckets:
            idx = np.asarray(b.idx)
            coeff = np.asarray(b.coeff)
            mask = np.asarray(b.mask)
            for k in range(m):
                np.add.at(out, k * J + idx.ravel(), (coeff[k] ** 2 * mask).ravel())
        return out

    def shape_dtype_structs(self) -> "BucketedInstance":
        """ShapeDtypeStruct twin of this instance (for .lower() dry-runs)."""
        as_sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        return jax.tree.map(as_sds, self)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _PackInfo:
    """Host-side bookkeeping to map packed slabs back to edge order."""

    # per bucket: source id per row (-1 pad), edge offset of each row's slice
    source_ids: list[np.ndarray]
    edge_starts: list[np.ndarray]
    degrees: list[np.ndarray]


_PACK_INFO: dict[int, _PackInfo] = {}


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def _pad_rows(n: int, multiple: int) -> int:
    return int(math.ceil(max(n, 1) / multiple) * multiple)


def bucketize(
    inst: EdgeListInstance,
    *,
    shard_multiple: int = 1,
    min_length: int = 1,
    max_length: Optional[int] = None,
    dtype=np.float32,
) -> BucketedInstance:
    """Pack an edge list into the bucketed-ELL layout.

    Edges in ``inst`` must be sorted by (source, destination) — the generator
    guarantees this.  ``shard_multiple`` pads every bucket's row count so it
    divides evenly across that many shards.
    """
    spec = inst.spec
    I, J, m = spec.num_sources, spec.num_destinations, spec.num_families

    deg = np.bincount(inst.src, minlength=I)
    active = np.flatnonzero(deg)  # sources with at least one edge
    if active.size == 0:
        raise ValueError("instance has no edges")
    # edge offsets per source (sorted by src)
    starts = np.zeros(I + 1, dtype=np.int64)
    np.cumsum(deg, out=starts[1:])

    max_deg = int(deg.max())
    cap = _next_pow2(max_deg)
    if max_length is not None:
        if cap > max_length:
            raise ValueError(
                f"max degree {max_deg} exceeds max bucket length {max_length}"
            )
    lengths = []
    L = max(1, _next_pow2(min_length))
    cap = max(cap, L)
    while L <= cap:
        lengths.append(L)
        L *= 2
    # bucket index per active source: smallest L >= degree, but >= min length
    b_of = np.searchsorted(np.asarray(lengths), deg[active])

    buckets: list[Bucket] = []
    info = _PackInfo(source_ids=[], edge_starts=[], degrees=[])
    for t, Lt in enumerate(lengths):
        rows_src = active[b_of == t]
        n = _pad_rows(rows_src.size, shard_multiple)
        idx = np.zeros((n, Lt), dtype=np.int32)
        coeff = np.zeros((m, n, Lt), dtype=dtype)
        cost = np.zeros((n, Lt), dtype=dtype)
        mask = np.zeros((n, Lt), dtype=dtype)
        d = deg[rows_src]
        st = starts[rows_src]
        # vectorised slab fill: flat positions of each (row, within-slice) pair
        if rows_src.size:
            r = np.repeat(np.arange(rows_src.size), d)
            o = np.concatenate([np.arange(k) for k in d]) if d.size else np.empty(0, int)
            e = np.repeat(st, d) + o
            idx[r, o] = inst.dst[e]
            cost[r, o] = inst.cost[e]
            mask[r, o] = 1.0
            for k in range(m):
                coeff[k, r, o] = inst.coeff[k, e]
        buckets.append(
            Bucket(idx=idx, coeff=coeff, cost=cost, mask=mask, length=Lt)
        )
        sid = np.full(n, -1, dtype=np.int64)
        sid[: rows_src.size] = rows_src
        info.source_ids.append(sid)
        info.edge_starts.append(st)
        info.degrees.append(d)

    out = BucketedInstance(
        buckets=tuple(buckets),
        rhs=inst.rhs.astype(dtype),
        num_sources=I,
        num_destinations=J,
        num_families=m,
    )
    _PACK_INFO[id(out)] = info
    weakref.finalize(out, _PACK_INFO.pop, id(out), None)
    return out


def pack_single_slab(
    inst: EdgeListInstance, *, shard_multiple: int = 1, dtype=np.float32
) -> BucketedInstance:
    """The paper's `batching=False` baseline: one slab of width next_pow2(s_max).

    Used by benchmarks/fig2_bucketing.py to reproduce Figure 2 (padding waste of
    the single-slab layout vs geometric bucketing).
    """
    deg = np.bincount(inst.src, minlength=inst.spec.num_sources)
    width = _next_pow2(int(deg.max()))
    return bucketize(
        inst, shard_multiple=shard_multiple, min_length=width, dtype=dtype
    )


def pack_source_ids(packed: BucketedInstance) -> list[np.ndarray]:
    """Per-bucket source id of each slab row (-1 for padded rows).

    Only available for instances produced by `bucketize` in this process; the
    delta-ingest layer (`repro.instances.deltas`) uses it to seed its
    row-occupancy maps.
    """
    info = _PACK_INFO.get(id(packed))
    if info is None:
        raise KeyError("pack_source_ids: packing info not found for this instance")
    return [a.copy() for a in info.source_ids]


def unpack_primal(
    packed: BucketedInstance, x_slabs: Sequence[np.ndarray | jax.Array]
) -> np.ndarray:
    """Scatter per-bucket primal slabs back to edge order (sorted by src,dst)."""
    info = _PACK_INFO.get(id(packed))
    if info is None:
        raise KeyError("unpack_primal: packing info not found for this instance")
    nnz = int(sum(d.sum() for d in info.degrees))
    x_edges = np.zeros(nnz)
    for bi, slab in enumerate(x_slabs):
        slab = np.asarray(slab)
        d = info.degrees[bi]
        st = info.edge_starts[bi]
        if d.size == 0:
            continue
        r = np.repeat(np.arange(d.size), d)
        o = np.concatenate([np.arange(k) for k in d])
        e = np.repeat(st, d) + o
        x_edges[e] = slab[r, o]
    return x_edges
