"""Synthetic matching-LP instances (paper Appendix A) and TPU-native packing.

The paper stores the coupling matrix in CSC with one column per source.  On TPU
we use the equivalent *bucketed ELL* layout (`buckets.py`): per length-bucket
dense slabs of destination indices / coefficients, which simultaneously realises
the paper's CSC compactness (§4.1) and its batched-projection bucketing (§4.2).
"""
from repro.instances.generator import (
    MatchingInstanceSpec,
    generate_matching_instance,
    EdgeListInstance,
)
from repro.instances.buckets import (
    SLAB_DTYPES,
    Bucket,
    BucketedInstance,
    bucketize,
    pack_single_slab,
    pack_source_ids,
    resolve_slab_dtype,
    slab_dtype_name,
    unpack_primal,
)
from repro.instances.deltas import (
    InstanceDelta,
    DeltaReport,
    BucketScatter,
    ScatterPlan,
    DeltaIngestor,
    apply_delta_to_edge_list,
)

__all__ = [
    "SLAB_DTYPES",
    "resolve_slab_dtype",
    "slab_dtype_name",
    "MatchingInstanceSpec",
    "generate_matching_instance",
    "EdgeListInstance",
    "Bucket",
    "BucketedInstance",
    "bucketize",
    "pack_single_slab",
    "pack_source_ids",
    "unpack_primal",
    "InstanceDelta",
    "DeltaReport",
    "BucketScatter",
    "ScatterPlan",
    "DeltaIngestor",
    "apply_delta_to_edge_list",
]
