"""Delta ingestion for recurring solves: O(delta) updates on bucketed-ELL slabs.

The paper's workload is "solved repeatedly on recurring cadences over slowly
evolving inputs": day-over-day the eligibility graph gains/loses a small set of
edges and costs/budgets shift, while the vast majority of nonzeros are
unchanged.  Re-running `bucketize` (O(nnz) host work) and re-compiling the
stage functions (new slab shapes => jit cache miss) every cadence throws that
structure away.

`DeltaIngestor` instead keeps the packed `BucketedInstance` as the mutable
source of truth and applies an `InstanceDelta` *in place* on the slabs:

  * cost / coefficient updates overwrite the edge's slot;
  * deletions swap the row's last active slot into the hole (active slots of a
    row stay contiguous in ``[0, degree)``, the invariant `bucketize`
    establishes);
  * insertions fill the row's padding headroom (slab width L >= degree);
  * a source whose new degree outgrows its slab width is *moved* to a
    wider bucket's free (padded) row — row headroom can be reserved at build
    time via ``row_headroom``;
  * RHS updates replace the budget vector.

Every in-place path preserves slab shapes exactly, so downstream jitted stage
functions keyed on shapes are reused with zero recompilation.  Only when a
bucket runs out of headroom (or a degree exceeds the widest bucket) does the
ingestor fall back to a full re-bucketize — reported, so the serving layer can
account for the recompile.

Padding stays exact-zero everywhere (mask 0, coeff 0), so gradients are
unaffected — the same guarantee `bucketize` documents.

Invariants the service layer builds on:

  * **Scatter-plan emission** — every in-place `apply` also returns a compact
    `ScatterPlan` (`DeltaReport.plan`): the exact set of touched (bucket, row,
    slot) cells plus their post-delta values, gathered from the mutated host
    slabs.  Replaying the plan against any array copy of the pre-delta slabs
    (host or device, `.at[].set`) reproduces the post-delta slabs
    *bit-for-bit*, because the plan's payload IS the authoritative host value.
    Plan size is O(delta), so the serving layer's per-cadence host→device
    transfer is O(delta) instead of O(nnz).  The re-bucketize fallback emits
    no plan (`plan=None`, shapes may have changed): consumers must re-upload.
  * **Generation counter** — `generation` increments once per *successful*
    `apply` (in-place or fallback) and each plan is stamped with the
    generation it produces.  A consumer holding device slabs at generation g
    may apply a plan iff `plan.generation == g + 1`; anything else means a
    missed or out-of-order delta and requires a full re-upload.
  * **Atomicity** — validation (`_validate` + `_precheck` + move planning)
    completes before the first mutation, so a rejected delta raises without
    touching the slabs, the occupancy maps, the drift accounting, or the
    generation counter.  A rejected delta therefore never half-applies, on
    host or (via the missing plan) on device.
  * **Headroom-overflow fallback** — when a delta cannot be absorbed in place
    (degree beyond the widest bucket, or a bucket out of free rows), `apply`
    re-bucketizes the reconstructed edge list; `DeltaReport.rebucketized` and
    `fallback_reason` say so, and `shapes_changed` tells the caller whether
    compiled executables keyed on the old shapes are now stale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro import telemetry
from repro.instances.buckets import (
    Bucket,
    BucketedInstance,
    bucketize,
    pack_source_ids,
    resolve_slab_dtype,
    rhs_dtype,
)
from repro.instances.generator import EdgeListInstance, MatchingInstanceSpec

__all__ = [
    "InstanceDelta",
    "DeltaReport",
    "BucketScatter",
    "ScatterPlan",
    "DeltaIngestor",
    "apply_delta_to_edge_list",
]


def _as_1d(a, dtype) -> np.ndarray:
    out = np.asarray([] if a is None else a, dtype=dtype)
    return out.reshape(-1)


@dataclasses.dataclass(frozen=True)
class InstanceDelta:
    """A batch of edits to a matching LP between two cadences.

    Edge edits are addressed by (source, destination) pairs; ``values`` follow
    the generator convention (positive matched value, the solver minimises
    ``cost = -value``).  ``insert_coeff``/``update_coeff`` have shape
    ``[m, k]`` (one row per coupling family).  ``rhs`` replaces the full
    ``[m * J]`` budget vector when given.
    """

    insert_src: np.ndarray = None
    insert_dst: np.ndarray = None
    insert_values: np.ndarray = None
    insert_coeff: np.ndarray = None  # [m, k_ins]
    delete_src: np.ndarray = None
    delete_dst: np.ndarray = None
    update_src: np.ndarray = None
    update_dst: np.ndarray = None
    update_values: Optional[np.ndarray] = None  # None: keep values
    update_coeff: Optional[np.ndarray] = None  # [m, k_upd]; None: keep coeff
    rhs: Optional[np.ndarray] = None  # [m * J] replacement

    def __post_init__(self):
        s = object.__setattr__
        s(self, "insert_src", _as_1d(self.insert_src, np.int64))
        s(self, "insert_dst", _as_1d(self.insert_dst, np.int64))
        s(self, "insert_values", _as_1d(self.insert_values, np.float64))
        coeff = self.insert_coeff
        if coeff is None:
            coeff = np.zeros((0, self.insert_src.size), np.float64)
        s(self, "insert_coeff", np.atleast_2d(np.asarray(coeff, np.float64)))
        s(self, "delete_src", _as_1d(self.delete_src, np.int64))
        s(self, "delete_dst", _as_1d(self.delete_dst, np.int64))
        s(self, "update_src", _as_1d(self.update_src, np.int64))
        s(self, "update_dst", _as_1d(self.update_dst, np.int64))
        if self.update_values is not None:
            s(self, "update_values", _as_1d(self.update_values, np.float64))
        if self.update_coeff is not None:
            s(self, "update_coeff",
              np.atleast_2d(np.asarray(self.update_coeff, np.float64)))
        if self.rhs is not None:
            s(self, "rhs", _as_1d(self.rhs, np.float64))
        if self.insert_src.size != self.insert_dst.size:
            raise ValueError("insert_src/insert_dst size mismatch")
        if self.insert_src.size != self.insert_values.size:
            raise ValueError("insert_values size mismatch")
        if self.insert_src.size and self.insert_coeff.shape[1] != self.insert_src.size:
            raise ValueError("insert_coeff must be [m, k_ins]")
        if self.delete_src.size != self.delete_dst.size:
            raise ValueError("delete_src/delete_dst size mismatch")
        if self.update_src.size != self.update_dst.size:
            raise ValueError("update_src/update_dst size mismatch")
        if self.update_values is not None and self.update_values.size != self.update_src.size:
            raise ValueError("update_values size mismatch")
        if self.update_coeff is not None and self.update_coeff.shape[1] != self.update_src.size:
            raise ValueError("update_coeff must be [m, k_upd]")

    @property
    def num_edits(self) -> int:
        return int(
            self.insert_src.size + self.delete_src.size + self.update_src.size
        )

    @property
    def is_empty(self) -> bool:
        return self.num_edits == 0 and self.rhs is None


@dataclasses.dataclass(frozen=True)
class BucketScatter:
    """Touched cells of one bucket's slabs, with their post-delta values.

    Cell addresses are **run-length compacted**: a run is a maximal set of
    consecutive slots ``[run_slots[r], run_slots[r] + run_lengths[r])`` in
    row ``run_rows[r]``.  Deltas touch contiguous slot spans by construction
    — row moves rewrite ``[0, d)`` of both the old and new row, deletes touch
    ``{j, d-1}``, inserts append at ``d`` — so high-degree sources compress
    from O(d) index pairs to O(1) run descriptors while the value payload
    stays per-cell.  The expanded views (`rows`/`slots` properties, host
    numpy) remain unique and sorted row-major, so `.at[rows, slots].set(...)`
    is deterministic regardless of backend scatter order; the device replay
    (`service.engine.apply_scatter_plan`) transfers only the runs + values
    and re-expands on device.
    """

    bucket: int
    run_rows: np.ndarray  # [R] int32 row of each run
    run_slots: np.ndarray  # [R] int32 first slot of each run
    run_lengths: np.ndarray  # [R] int32 cells in each run
    idx: np.ndarray  # [k] int32 destination ids (run order)
    cost: np.ndarray  # [k] slab dtype
    mask: np.ndarray  # [k] slab dtype
    coeff: np.ndarray  # [m, k] slab dtype

    @classmethod
    def from_cells(
        cls,
        bucket: int,
        rows: np.ndarray,
        slots: np.ndarray,
        idx: np.ndarray,
        cost: np.ndarray,
        mask: np.ndarray,
        coeff: np.ndarray,
    ) -> "BucketScatter":
        """Compact unique row-major-sorted (rows, slots) cells into runs."""
        rows = np.asarray(rows, np.int32)
        slots = np.asarray(slots, np.int32)
        if rows.size == 0:
            starts = np.zeros(0, bool)
        else:
            starts = np.empty(rows.size, bool)
            starts[0] = True
            starts[1:] = (rows[1:] != rows[:-1]) | (slots[1:] != slots[:-1] + 1)
        first = np.flatnonzero(starts)
        bounds = np.append(first, rows.size)
        return cls(
            bucket=bucket,
            run_rows=rows[first],
            run_slots=slots[first],
            run_lengths=np.diff(bounds).astype(np.int32),
            idx=idx,
            cost=cost,
            mask=mask,
            coeff=coeff,
        )

    @property
    def num_cells(self) -> int:
        return int(self.idx.size)

    @property
    def num_runs(self) -> int:
        return int(self.run_rows.size)

    @property
    def rows(self) -> np.ndarray:
        """Expanded per-cell row addresses (host-side view of the runs)."""
        return np.repeat(self.run_rows, self.run_lengths)

    @property
    def slots(self) -> np.ndarray:
        """Expanded per-cell slot addresses (host-side view of the runs)."""
        k = self.num_cells
        run_of = np.repeat(np.arange(self.num_runs), self.run_lengths)
        starts = np.cumsum(self.run_lengths) - self.run_lengths
        return (
            self.run_slots[run_of] + (np.arange(k) - starts[run_of])
        ).astype(np.int32)

    @property
    def nbytes(self) -> int:
        """Bytes a consumer transfers to replay: run descriptors + values."""
        return int(
            self.run_rows.nbytes + self.run_slots.nbytes
            + self.run_lengths.nbytes + self.idx.nbytes
            + self.cost.nbytes + self.mask.nbytes + self.coeff.nbytes
        )


@dataclasses.dataclass(frozen=True)
class ScatterPlan:
    """Compact O(delta) description of one applied in-place delta.

    Replaying ``ops`` (plus the optional ``rhs`` replacement) on a copy of the
    pre-delta slabs — host numpy or device `.at[].set` — reproduces the
    ingestor's post-delta slabs bit-for-bit.  ``generation`` is the ingestor
    generation the plan produces: apply it only to state at generation
    ``generation - 1``.
    """

    generation: int
    ops: tuple[BucketScatter, ...]
    rhs: Optional[np.ndarray] = None  # full [m * J] replacement, slab dtype

    @property
    def num_cells(self) -> int:
        return sum(op.num_cells for op in self.ops)

    @property
    def num_runs(self) -> int:
        """Contiguous-slot runs across all ops (index overhead is O(runs))."""
        return sum(op.num_runs for op in self.ops)

    @property
    def nbytes(self) -> int:
        """Host→device bytes a consumer must transfer to replay this plan."""
        n = sum(op.nbytes for op in self.ops)
        if self.rhs is not None:
            n += int(self.rhs.nbytes)
        return n


@dataclasses.dataclass(frozen=True)
class DeltaReport:
    """What a `DeltaIngestor.apply` call did."""

    in_place: bool  # True: slabs mutated, shapes untouched
    rebucketized: bool  # True: fell back to a full re-pack
    shapes_changed: bool  # only possible when rebucketized
    n_insert: int
    n_delete: int
    n_update: int
    rhs_updated: bool
    moved_rows: int  # sources relocated to a wider bucket
    fallback_reason: Optional[str] = None
    # In-place applies carry the device-replayable scatter plan; the
    # re-bucketize fallback emits None (consumers must re-upload the slabs).
    plan: Optional[ScatterPlan] = None
    generation: int = 0  # ingestor generation after this apply


class DeltaIngestor:
    """Owns the mutable packed instance of one tenant and applies deltas.

    The packed slabs (numpy, host-side) are the source of truth; the original
    edge list is only reconstructed on demand (``to_edge_list``) or when an
    overflow forces the re-bucketize fallback.  ``row_headroom`` reserves that
    many extra all-padding rows per bucket at build time so that new sources
    and bucket promotions can be absorbed in place.
    """

    def __init__(
        self,
        inst: EdgeListInstance,
        *,
        shard_multiple: int = 1,
        min_length: int = 1,
        row_headroom: int = 0,
        dtype=np.float32,
    ):
        self.spec: MatchingInstanceSpec = inst.spec
        self.shard_multiple = int(shard_multiple)
        self.min_length = int(min_length)
        self.row_headroom = int(row_headroom)
        self.dtype = resolve_slab_dtype(dtype)
        if np.dtype(self.dtype) == np.int8:
            # In-place slab surgery on quantised cells is unsound: a delta's
            # new coefficient can exceed the bucket's frozen per-family scale,
            # and rescaling would rewrite every cell (O(nnz), defeating the
            # O(delta) ScatterPlan contract).  bf16 is the serving-path narrow
            # storage; int8 stays batch-only (launch/solve.py).
            raise ValueError(
                "DeltaIngestor does not support int8 slabs; use float32 or "
                "bfloat16"
            )
        # Label for this ingestor's telemetry series; the owning session sets
        # it to its tenant name ("" keeps standalone ingestors unlabelled).
        self.telemetry_tenant = ""
        self._rhs64 = np.asarray(inst.rhs, np.float64).copy()
        # ||Delta c||^2 accumulated since the last drain — feeds the paper's
        # gamma drift bound (core.stability.drift_bound) in SLA reports.
        self._pending_dc_sq = 0.0
        # Bumped once per successful apply(); plans are stamped with it so
        # device-resident consumers can fence out-of-order application.
        self.generation = 0
        # During apply(): per-bucket set of touched (row, slot) cells, turned
        # into the ScatterPlan once the mutation completes.  None outside.
        self._touched: Optional[dict[int, set[tuple[int, int]]]] = None
        self._build(inst)

    # -- construction -------------------------------------------------------

    def _build(self, inst: EdgeListInstance) -> None:
        packed = bucketize(
            inst,
            shard_multiple=self.shard_multiple,
            min_length=self.min_length,
            dtype=self.dtype,
        )
        source_ids = pack_source_ids(packed)
        I = self.spec.num_sources
        buckets = []
        sids = []
        extra = self.row_headroom
        if extra:
            extra = -(-extra // self.shard_multiple) * self.shard_multiple
        for b, sid in zip(packed.buckets, source_ids):
            idx = np.array(b.idx)  # own, writable copies
            coeff = np.array(b.coeff)
            cost = np.array(b.cost)
            mask = np.array(b.mask)
            if extra:
                idx = np.pad(idx, ((0, extra), (0, 0)))
                coeff = np.pad(coeff, ((0, 0), (0, extra), (0, 0)))
                cost = np.pad(cost, ((0, extra), (0, 0)))
                mask = np.pad(mask, ((0, extra), (0, 0)))
                sid = np.concatenate([sid, np.full(extra, -1, np.int64)])
            buckets.append(
                Bucket(idx=idx, coeff=coeff, cost=cost, mask=mask, length=b.length)
            )
            sids.append(np.asarray(sid, np.int64))
        self.packed = BucketedInstance(
            buckets=tuple(buckets),
            rhs=self._rhs64.astype(rhs_dtype(self.dtype)),
            num_sources=packed.num_sources,
            num_destinations=packed.num_destinations,
            num_families=packed.num_families,
        )
        self._source_ids = sids
        self._lengths = [b.length for b in buckets]
        self.deg = np.bincount(inst.src, minlength=I).astype(np.int64)
        self.bucket_of = np.full(I, -1, np.int64)
        self.row_of = np.full(I, -1, np.int64)
        self._free_rows: list[list[int]] = []
        for t, sid in enumerate(sids):
            occupied = sid >= 0
            self.bucket_of[sid[occupied]] = t
            self.row_of[sid[occupied]] = np.flatnonzero(occupied)
            self._free_rows.append(list(np.flatnonzero(~occupied)[::-1]))

    # -- views ---------------------------------------------------------------

    def instance(self) -> BucketedInstance:
        """The current packed instance (live view; do not mutate externally)."""
        return self.packed

    @property
    def nnz(self) -> int:
        return int(self.deg.sum())

    def headroom(self) -> list[int]:
        """Free (all-padding) rows per bucket."""
        return [len(fr) for fr in self._free_rows]

    def drain_cost_drift(self) -> float:
        """||Delta c||_2 accumulated since the last drain (then reset)."""
        out = float(np.sqrt(self._pending_dc_sq))
        self._pending_dc_sq = 0.0
        return out

    def primal_unpacker(self):
        """Freeze the CURRENT occupancy maps into an `x_slabs -> (keys, x)` fn.

        The returned closure owns copies of the slot coordinates and edge
        keys, so it stays correct for primal slabs solved against *this*
        generation's layout even after later deltas mutate the maps (or a
        fallback re-shapes the slabs).  Overlapped drivers capture it at
        dispatch time and apply it after the fence (`Scheduler._dispatch`).
        """
        J = self.spec.num_destinations
        per_bucket: list[tuple[int, np.ndarray, np.ndarray]] = []
        keys = []
        for t, b in enumerate(self.packed.buckets):
            sid = self._source_ids[t]
            rows = np.flatnonzero(sid >= 0)
            if rows.size == 0:
                continue
            d = self.deg[sid[rows]]
            live = d > 0
            rows, d = rows[live], d[live]
            if rows.size == 0:
                continue
            r = np.repeat(rows, d)
            o = np.concatenate([np.arange(k) for k in d])
            per_bucket.append((t, r, o))
            keys.append(
                np.repeat(sid[rows], d) * J + b.idx[r, o].astype(np.int64)
            )
        k = np.concatenate(keys) if keys else np.zeros(0, np.int64)
        order = np.argsort(k)
        k_sorted = k[order]

        def unpack(x_slabs: Sequence[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
            vals = [
                np.asarray(x_slabs[t])[r, o].astype(np.float64)
                for t, r, o in per_bucket
            ]
            v = np.concatenate(vals) if vals else np.zeros(0)
            return k_sorted, v[order]

        return unpack

    def unpack_primal(
        self, x_slabs: Sequence[np.ndarray]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Primal slab values keyed by edge: `(keys, x)`, keys sorted.

        ``keys[e] = src * J + dst``.  Unlike slab-position comparisons, this
        keying survives row relocations and re-bucketizes, so cadence-over-
        cadence drift can always be metered edge-by-edge.
        """
        return self.primal_unpacker()(x_slabs)

    def to_edge_list(self) -> EdgeListInstance:
        """Reconstruct the current state as a sorted edge list (O(nnz))."""
        srcs, dsts, vals, coeffs = [], [], [], []
        m = self.packed.num_families
        for t, b in enumerate(self.packed.buckets):
            sid = self._source_ids[t]
            rows = np.flatnonzero(sid >= 0)
            if rows.size == 0:
                continue
            d = self.deg[sid[rows]]
            live = d > 0
            rows, d = rows[live], d[live]
            if rows.size == 0:
                continue
            r = np.repeat(rows, d)
            o = np.concatenate([np.arange(k) for k in d])
            srcs.append(np.repeat(sid[rows], d))
            dsts.append(b.idx[r, o].astype(np.int64))
            vals.append(-b.cost[r, o].astype(np.float64))
            coeffs.append(b.coeff[:, r, o].astype(np.float64))
        src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
        dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
        values = np.concatenate(vals) if vals else np.zeros(0)
        coeff = (
            np.concatenate(coeffs, axis=1) if coeffs else np.zeros((m, 0))
        )
        order = np.lexsort((dst, src))
        return EdgeListInstance(
            spec=self.spec,
            src=src[order],
            dst=dst[order],
            values=values[order],
            coeff=coeff[:, order],
            rhs=self._rhs64.copy(),
        )

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> tuple[dict[str, np.ndarray], dict]:
        """(arrays, meta) capturing the exact packed state for checkpointing.

        `from_state` rebuilds an ingestor with identical slabs, occupancy maps,
        free-row stacks and generation — no re-bucketize, so row placement
        (and therefore all future scatter plans) matches the checkpointed
        ingestor bit-for-bit.  ``arrays`` is flat str→ndarray (checkpoint
        friendly); ``meta`` is JSON-able construction parameters.
        """
        arrays: dict[str, np.ndarray] = {
            "rhs64": self._rhs64.copy(),
            "deg": self.deg.copy(),
            "bucket_of": self.bucket_of.copy(),
            "row_of": self.row_of.copy(),
            "generation": np.asarray(self.generation, np.int64),
            "pending_dc_sq": np.asarray(self._pending_dc_sq, np.float64),
        }
        for t, b in enumerate(self.packed.buckets):
            arrays[f"bucket{t}.idx"] = np.asarray(b.idx).copy()
            arrays[f"bucket{t}.coeff"] = np.asarray(b.coeff).copy()
            arrays[f"bucket{t}.cost"] = np.asarray(b.cost).copy()
            arrays[f"bucket{t}.mask"] = np.asarray(b.mask).copy()
            arrays[f"bucket{t}.source_ids"] = self._source_ids[t].copy()
            # free rows are a stack (pop/append order matters for future row
            # assignment), so persist the exact order, not just membership
            arrays[f"bucket{t}.free_rows"] = np.asarray(
                self._free_rows[t], np.int64
            )
        meta = {
            "spec": dataclasses.asdict(self.spec),
            "shard_multiple": self.shard_multiple,
            "min_length": self.min_length,
            "row_headroom": self.row_headroom,
            "dtype": np.dtype(self.dtype).name,
            "lengths": [int(L) for L in self._lengths],
        }
        return arrays, meta

    @classmethod
    def from_state(
        cls, arrays: dict[str, np.ndarray], meta: dict
    ) -> "DeltaIngestor":
        """Rebuild an ingestor from `state_dict` output (exact restore)."""
        self = cls.__new__(cls)
        self.spec = MatchingInstanceSpec(**meta["spec"])
        self.shard_multiple = int(meta["shard_multiple"])
        self.min_length = int(meta["min_length"])
        self.row_headroom = int(meta["row_headroom"])
        self.dtype = resolve_slab_dtype(meta["dtype"])
        self._rhs64 = np.asarray(arrays["rhs64"], np.float64).copy()
        self._pending_dc_sq = float(arrays["pending_dc_sq"])
        self.generation = int(arrays["generation"])
        self._touched = None
        self.telemetry_tenant = ""
        lengths = [int(L) for L in meta["lengths"]]
        buckets, sids, free = [], [], []
        for t, L in enumerate(lengths):
            buckets.append(
                Bucket(
                    idx=np.asarray(arrays[f"bucket{t}.idx"]).copy(),
                    coeff=np.asarray(arrays[f"bucket{t}.coeff"]).copy(),
                    cost=np.asarray(arrays[f"bucket{t}.cost"]).copy(),
                    mask=np.asarray(arrays[f"bucket{t}.mask"]).copy(),
                    length=L,
                )
            )
            sids.append(
                np.asarray(arrays[f"bucket{t}.source_ids"], np.int64).copy()
            )
            free.append(
                [int(r) for r in np.asarray(arrays[f"bucket{t}.free_rows"])]
            )
        self.packed = BucketedInstance(
            buckets=tuple(buckets),
            rhs=self._rhs64.astype(rhs_dtype(self.dtype)),
            num_sources=self.spec.num_sources,
            num_destinations=self.spec.num_destinations,
            num_families=self.spec.num_families,
        )
        self._source_ids = sids
        self._lengths = lengths
        self.deg = np.asarray(arrays["deg"], np.int64).copy()
        self.bucket_of = np.asarray(arrays["bucket_of"], np.int64).copy()
        self.row_of = np.asarray(arrays["row_of"], np.int64).copy()
        self._free_rows = free
        return self

    # -- the delta path ------------------------------------------------------

    def apply(self, delta: InstanceDelta) -> DeltaReport:
        """Apply one delta; in place when headroom allows, else re-bucketize.

        Validation is complete before the first mutation (`_validate` +
        `_precheck` + move planning), so a rejected delta raises without
        touching the slabs, the occupancy maps, the drift accounting, or the
        generation counter — the caller can correct and retry.  In-place
        applies return a `DeltaReport` whose ``plan`` replays the exact slab
        edits on any copy of the pre-delta slabs (see `ScatterPlan`).
        """
        reg = telemetry.get_registry()
        tenant = self.telemetry_tenant
        try:
            report = self._apply(delta)
        except (ValueError, KeyError):
            reg.inc("delta_rejections_total", 1, tenant=tenant)
            raise
        path = "in_place" if report.in_place else "rebucketize"
        reg.inc("deltas_applied_total", 1, tenant=tenant, path=path)
        if report.n_insert:
            reg.inc("delta_edits_total", report.n_insert, op="insert")
        if report.n_delete:
            reg.inc("delta_edits_total", report.n_delete, op="delete")
        if report.n_update:
            reg.inc("delta_edits_total", report.n_update, op="update")
        if report.rebucketized:
            reg.inc("delta_rebucketize_total", 1, tenant=tenant)
        if report.plan is not None:
            reg.inc(
                "scatter_bytes_total", report.plan.nbytes, tenant=tenant
            )
            reg.inc(
                "scatter_cells_total", report.plan.num_cells, tenant=tenant
            )
        if report.moved_rows:
            reg.inc("delta_moved_rows_total", report.moved_rows, tenant=tenant)
        return report

    def _apply(self, delta: InstanceDelta) -> DeltaReport:
        self._validate(delta)
        self._precheck(delta)
        plan_or_reason = self._plan_moves(delta)
        if isinstance(plan_or_reason, str):
            return self._fallback(delta, plan_or_reason)
        moves, to_free = plan_or_reason

        self._touched = {}
        try:
            # 1. deletions (rows stay owned even at transient degree 0, so a
            #    delete-all-then-reinsert delta keeps the source's row)
            for s, d in zip(delta.delete_src, delta.delete_dst):
                self._delete_edge(int(s), int(d))
            # 2. release rows of sources whose *final* degree is 0
            #    (planner-known), making them available to the relocation pass
            for s in to_free:
                self._release_row(s)
            # 3. row relocations / allocations for grown sources
            for s, t_new in moves:
                self._move_row(s, t_new)
            # 4. insertions into (now sufficient) row headroom
            for j, (s, d) in enumerate(zip(delta.insert_src, delta.insert_dst)):
                self._insert_edge(
                    int(s), int(d),
                    float(delta.insert_values[j]), delta.insert_coeff[:, j],
                )
            # 5. cost/coefficient updates
            for j, (s, d) in enumerate(zip(delta.update_src, delta.update_dst)):
                val = None if delta.update_values is None else float(delta.update_values[j])
                co = None if delta.update_coeff is None else delta.update_coeff[:, j]
                self._update_edge(int(s), int(d), val, co)
            # 6. budgets
            if delta.rhs is not None:
                self._rhs64[:] = delta.rhs
                self.packed.rhs = self._rhs64.astype(rhs_dtype(self.dtype))
            self.generation += 1
            plan = self._emit_plan(rhs_updated=delta.rhs is not None)
        finally:
            self._touched = None
        return DeltaReport(
            in_place=True,
            rebucketized=False,
            shapes_changed=False,
            n_insert=int(delta.insert_src.size),
            n_delete=int(delta.delete_src.size),
            n_update=int(delta.update_src.size),
            rhs_updated=delta.rhs is not None,
            moved_rows=len(moves),
            plan=plan,
            generation=self.generation,
        )

    def _record(self, t: int, row: int, slot: int) -> None:
        """Mark one slab cell as touched (all four arrays at that cell)."""
        if self._touched is not None:
            self._touched.setdefault(t, set()).add((row, slot))

    def _emit_plan(self, *, rhs_updated: bool) -> ScatterPlan:
        """Gather post-delta values at the touched cells into a ScatterPlan."""
        ops = []
        for t in sorted(self._touched or ()):
            cells = self._touched[t]
            if not cells:
                continue
            b = self.packed.buckets[t]
            rc = np.array(sorted(cells), np.int32)  # [k, 2] row-major order
            rows, slots = rc[:, 0], rc[:, 1]
            ops.append(
                BucketScatter.from_cells(
                    bucket=t,
                    rows=rows,
                    slots=slots,
                    idx=b.idx[rows, slots].copy(),
                    cost=b.cost[rows, slots].copy(),
                    mask=b.mask[rows, slots].copy(),
                    coeff=b.coeff[:, rows, slots].copy(),
                )
            )
        return ScatterPlan(
            generation=self.generation,
            ops=tuple(ops),
            rhs=np.asarray(self.packed.rhs).copy() if rhs_updated else None,
        )

    def _validate(self, delta: InstanceDelta) -> None:
        I, J, m = (
            self.spec.num_sources,
            self.spec.num_destinations,
            self.spec.num_families,
        )
        for name in ("insert", "delete", "update"):
            src = getattr(delta, f"{name}_src")
            dst = getattr(delta, f"{name}_dst")
            if src.size and (src.min() < 0 or src.max() >= I):
                raise ValueError(f"{name}_src out of range [0, {I})")
            if dst.size and (dst.min() < 0 or dst.max() >= J):
                raise ValueError(f"{name}_dst out of range [0, {J})")
        if delta.insert_src.size and delta.insert_coeff.shape[0] != m:
            raise ValueError(f"insert_coeff must have {m} families")
        if delta.update_coeff is not None and delta.update_coeff.shape[0] != m:
            raise ValueError(f"update_coeff must have {m} families")
        if delta.rhs is not None and delta.rhs.size != m * J:
            raise ValueError(f"rhs must have {m * J} entries")

    def _edge_exists(self, s: int, d: int) -> bool:
        t = int(self.bucket_of[s])
        if t < 0:
            return False
        b = self.packed.buckets[t]
        dd = int(self.deg[s])
        return dd > 0 and bool(np.any(b.idx[int(self.row_of[s]), :dd] == d))

    def _precheck(self, delta: InstanceDelta) -> None:
        """Reject bad edits BEFORE any mutation, keeping `apply` atomic.

        Semantics mirror the apply order (deletes, inserts, updates): an
        insert may re-create an edge deleted by the same delta, and an
        update may target an edge inserted by the same delta.
        """
        J = self.spec.num_destinations
        deleted: set = set()
        for s, d in zip(delta.delete_src, delta.delete_dst):
            key = int(s) * J + int(d)
            if key in deleted:
                raise KeyError(f"delete: duplicate edge ({s}, {d}) in delta")
            if not self._edge_exists(int(s), int(d)):
                raise KeyError(f"delete: edge ({s}, {d}) not present")
            deleted.add(key)
        inserted: set = set()
        for s, d in zip(delta.insert_src, delta.insert_dst):
            key = int(s) * J + int(d)
            if key in inserted:
                raise KeyError(f"insert: duplicate edge ({s}, {d}) in delta")
            if key not in deleted and self._edge_exists(int(s), int(d)):
                raise KeyError(f"insert: edge ({s}, {d}) already present")
            inserted.add(key)
        updated: set = set()
        for s, d in zip(delta.update_src, delta.update_dst):
            key = int(s) * J + int(d)
            if key in updated:
                # duplicates would make drift accounting order-dependent
                # (and diverge between the in-place and fallback paths)
                raise KeyError(f"update: duplicate edge ({s}, {d}) in delta")
            alive = key in inserted or (
                key not in deleted and self._edge_exists(int(s), int(d))
            )
            if not alive:
                raise KeyError(f"update: edge ({s}, {d}) not present")
            updated.add(key)

    def _plan_moves(self, delta: InstanceDelta):
        """Per-source final degrees -> list of (source, target_bucket) moves.

        Returns a fallback-reason string when the delta cannot be absorbed in
        place (degree beyond the widest bucket, or not enough free rows).
        """
        net: dict[int, int] = {}
        for s in delta.insert_src:
            net[int(s)] = net.get(int(s), 0) + 1
        for s in delta.delete_src:
            net[int(s)] = net.get(int(s), 0) - 1
        lengths = self._lengths
        moves: list[tuple[int, int]] = []
        to_free: list[int] = []
        free = [len(fr) for fr in self._free_rows]
        for s, dd in net.items():
            d_new = int(self.deg[s]) + dd
            if d_new < 0:
                raise ValueError(f"source {s}: more deletions than edges")
            if d_new == 0:
                t = int(self.bucket_of[s])
                if t >= 0:
                    free[t] += 1  # released before the relocation pass
                    to_free.append(s)
                continue
            if d_new > lengths[-1]:
                return (
                    f"source {s} degree {d_new} exceeds widest bucket "
                    f"L={lengths[-1]}"
                )
            t_cur = int(self.bucket_of[s])
            if t_cur >= 0 and d_new <= lengths[t_cur]:
                continue  # fits where it is
            t_new = int(np.searchsorted(lengths, d_new))
            moves.append((s, t_new))
        # Greedy feasibility, widest target first: rows vacated by a move are
        # in narrower buckets and so can host later (narrower-target) moves.
        moves.sort(key=lambda st: -st[1])
        for s, t_new in moves:
            if free[t_new] == 0:
                return f"bucket L={lengths[t_new]} has no free rows"
            free[t_new] -= 1
            t_cur = int(self.bucket_of[s])
            if t_cur >= 0:
                free[t_cur] += 1
        return moves, to_free

    def _fallback(self, delta: InstanceDelta, reason: str) -> DeltaReport:
        old_shapes = [(b.rows, b.length) for b in self.packed.buckets]
        cur = self.to_edge_list()
        # cost-drift bookkeeping (edge lists are (src, dst)-sorted, so the
        # (src*J + dst) key is sorted and searchsorted locates exact hits)
        J = self.spec.num_destinations
        key = cur.src * J + cur.dst
        dc_sq = float(np.sum(delta.insert_values**2))
        if delta.delete_src.size:
            pos = np.searchsorted(key, delta.delete_src * J + delta.delete_dst)
            pos = np.clip(pos, 0, key.size - 1)
            hit = key[pos] == delta.delete_src * J + delta.delete_dst
            dc_sq += float(np.sum(cur.values[pos[hit]] ** 2))
        if delta.update_src.size and delta.update_values is not None:
            pos = np.searchsorted(key, delta.update_src * J + delta.update_dst)
            pos = np.clip(pos, 0, key.size - 1)
            hit = key[pos] == delta.update_src * J + delta.update_dst
            dc_sq += float(
                np.sum((cur.values[pos[hit]] - delta.update_values[hit]) ** 2)
            )
        self._pending_dc_sq += dc_sq
        mutated = apply_delta_to_edge_list(cur, delta)
        self._rhs64 = np.asarray(mutated.rhs, np.float64).copy()
        self._build(mutated)
        self.generation += 1
        new_shapes = [(b.rows, b.length) for b in self.packed.buckets]
        return DeltaReport(
            in_place=False,
            rebucketized=True,
            shapes_changed=old_shapes != new_shapes,
            n_insert=int(delta.insert_src.size),
            n_delete=int(delta.delete_src.size),
            n_update=int(delta.update_src.size),
            rhs_updated=delta.rhs is not None,
            moved_rows=0,
            fallback_reason=reason,
            plan=None,
            generation=self.generation,
        )

    # -- slab surgery --------------------------------------------------------

    def _slot_of(self, s: int, d: int) -> tuple[int, int, int]:
        t = int(self.bucket_of[s])
        if t < 0:
            raise KeyError(f"source {s} has no edges")
        r = int(self.row_of[s])
        b = self.packed.buckets[t]
        dd = int(self.deg[s])
        hits = np.flatnonzero(b.idx[r, :dd] == d)
        if hits.size == 0:
            raise KeyError(f"edge ({s}, {d}) not present")
        return t, r, int(hits[0])

    def _delete_edge(self, s: int, d: int) -> None:
        t, r, j = self._slot_of(s, d)
        b = self.packed.buckets[t]
        self._pending_dc_sq += float(b.cost[r, j]) ** 2
        last = int(self.deg[s]) - 1
        for arr in (b.idx, b.cost, b.mask):
            arr[r, j] = arr[r, last]
            arr[r, last] = 0
        b.coeff[:, r, j] = b.coeff[:, r, last]
        b.coeff[:, r, last] = 0
        self.deg[s] = last
        self._record(t, r, j)
        self._record(t, r, last)

    def _release_row(self, s: int) -> None:
        if self.deg[s] != 0:
            raise RuntimeError(f"releasing row of source {s} with edges left")
        t, r = int(self.bucket_of[s]), int(self.row_of[s])
        self._source_ids[t][r] = -1
        self._free_rows[t].append(r)
        self.bucket_of[s] = -1
        self.row_of[s] = -1

    def _insert_edge(self, s: int, d: int, value: float, coeff: np.ndarray) -> None:
        t = int(self.bucket_of[s])
        dd = int(self.deg[s])
        b = self.packed.buckets[t]
        if dd and np.any(b.idx[int(self.row_of[s]), :dd] == d):
            raise KeyError(f"edge ({s}, {d}) already present")
        r = int(self.row_of[s])
        b.idx[r, dd] = d
        b.cost[r, dd] = -value
        b.mask[r, dd] = 1.0
        b.coeff[:, r, dd] = coeff
        self.deg[s] = dd + 1
        self._pending_dc_sq += value**2
        self._record(t, r, dd)

    def _update_edge(
        self, s: int, d: int, value: Optional[float], coeff: Optional[np.ndarray]
    ) -> None:
        t, r, j = self._slot_of(s, d)
        b = self.packed.buckets[t]
        if value is not None:
            self._pending_dc_sq += (float(b.cost[r, j]) + value) ** 2
            b.cost[r, j] = -value
        if coeff is not None:
            b.coeff[:, r, j] = coeff
        self._record(t, r, j)

    def _move_row(self, s: int, t_new: int) -> None:
        """Relocate source s to a free row of bucket t_new (or claim one)."""
        if not self._free_rows[t_new]:
            raise RuntimeError("move planned without a free row (planner bug)")
        r_new = self._free_rows[t_new].pop()
        t_old = int(self.bucket_of[s])
        if t_old >= 0:
            r_old = int(self.row_of[s])
            bo, bn = self.packed.buckets[t_old], self.packed.buckets[t_new]
            d = int(self.deg[s])
            for src_arr, dst_arr in (
                (bo.idx, bn.idx), (bo.cost, bn.cost), (bo.mask, bn.mask),
            ):
                dst_arr[r_new, :d] = src_arr[r_old, :d]
                src_arr[r_old, :d] = 0
            bn.coeff[:, r_new, :d] = bo.coeff[:, r_old, :d]
            bo.coeff[:, r_old, :d] = 0
            for j in range(d):
                self._record(t_old, r_old, j)
                self._record(t_new, r_new, j)
            self._source_ids[t_old][r_old] = -1
            self._free_rows[t_old].append(r_old)
        self._source_ids[t_new][r_new] = s
        self.bucket_of[s] = t_new
        self.row_of[s] = r_new


# ---------------------------------------------------------------------------


def apply_delta_to_edge_list(
    inst: EdgeListInstance, delta: InstanceDelta
) -> EdgeListInstance:
    """Reference (O(nnz)) application of a delta on the edge-list form.

    This is the slow path the ingestor falls back to, and the oracle the
    equivalence tests compare the in-place slab surgery against.  Edit order
    matches the in-place path: deletions, then insertions, then updates (so an
    update may target an edge inserted by the same delta).
    """
    J = inst.spec.num_destinations

    def locate(key_sorted, perm, src, dst, what):
        k = np.asarray(src) * J + np.asarray(dst)
        pos = np.searchsorted(key_sorted, k)
        ok = (pos < key_sorted.size) & (
            key_sorted[np.minimum(pos, key_sorted.size - 1)] == k
        )
        if not np.all(ok):
            missing = np.flatnonzero(~ok)[0]
            raise KeyError(
                f"{what}: edge ({src[missing]}, {dst[missing]}) not present"
            )
        return perm[pos]

    values = inst.values.copy()
    coeff = inst.coeff.copy()
    src, dst = inst.src.copy(), inst.dst.copy()

    if delta.delete_src.size:
        key = src * J + dst
        perm = np.argsort(key)
        e = locate(key[perm], perm, delta.delete_src, delta.delete_dst, "delete")
        keep = np.ones(src.size, bool)
        keep[e] = False
        src, dst, values, coeff = src[keep], dst[keep], values[keep], coeff[:, keep]

    if delta.insert_src.size:
        new_key = delta.insert_src * J + delta.insert_dst
        if np.intersect1d(new_key, src * J + dst).size:
            raise KeyError("insert: edge already present")
        if np.unique(new_key).size != new_key.size:
            raise KeyError("insert: duplicate edges in delta")
        src = np.concatenate([src, delta.insert_src])
        dst = np.concatenate([dst, delta.insert_dst])
        values = np.concatenate([values, delta.insert_values])
        coeff = np.concatenate([coeff, delta.insert_coeff], axis=1)

    if delta.update_src.size:
        key = src * J + dst
        perm = np.argsort(key)
        e = locate(key[perm], perm, delta.update_src, delta.update_dst, "update")
        if delta.update_values is not None:
            values[e] = delta.update_values
        if delta.update_coeff is not None:
            coeff[:, e] = delta.update_coeff

    order = np.lexsort((dst, src))
    rhs = inst.rhs.copy() if delta.rhs is None else np.asarray(delta.rhs, np.float64)
    return EdgeListInstance(
        spec=inst.spec,
        src=src[order],
        dst=dst[order],
        values=values[order],
        coeff=coeff[:, order],
        rhs=rhs,
    )
