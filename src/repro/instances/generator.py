"""Synthetic matching-LP generator — faithful to the paper's Appendix A.

Construction (Appendix A, "Synthetic LP construction"):

1. Draw a lognormal "breadth" parameter beta_j per resource (destination) j,
   normalise to probabilities p_j, and sample the number of incident requests
   K_j ~ Poisson(p_j * I * nu), truncated at I, where nu is the desired average
   number of nonzeros per row.
2. For each resource j, select K_j distinct requests i and create edges (i, j).
3. On each edge draw a resource value scale v_j, a request responsiveness u_i,
   multiplicative noise eps_ij, and set  c_ij = min(v_j * u_i * eps_ij, c_max).
4. Constraint coefficients a_ij = s_j * c_ij with lognormal per-resource s_j.
5. RHS: greedy load l_j = sum over requests of their single largest incident
   a_ij (assigned to that resource), then b_j = rho_j * (l_j + eps) with
   rho_j ~ U[0.5, 1.0].

Signs are adjusted to the minimisation convention: the solver receives
c = -value so that minimising c'x maximises matched value.

Generation is host-side numpy (this is the data pipeline, not the solver); the
output is an edge list that `buckets.bucketize` packs into the TPU layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "MatchingInstanceSpec",
    "EdgeListInstance",
    "generate_matching_instance",
]


@dataclasses.dataclass(frozen=True)
class MatchingInstanceSpec:
    """Parameters of the Appendix-A synthetic generator."""

    num_sources: int  # I  (requests / users)
    num_destinations: int  # J  (resources / items)
    avg_degree: float = 10.0  # nu: average eligible destinations per source
    num_families: int = 1  # m: coupling-constraint families (Def. 1)
    breadth_sigma: float = 1.0  # lognormal sigma of resource breadth
    value_sigma: float = 0.5  # lognormal sigma of v_j
    responsiveness_sigma: float = 0.5  # lognormal sigma of u_i
    noise_sigma: float = 0.25  # lognormal sigma of eps_ij
    scale_sigma: float = 0.5  # lognormal sigma of s_j (a_ij = s_j c_ij)
    c_max: float = 10.0
    rhs_eps: float = 1e-3
    seed: int = 0

    def __post_init__(self) -> None:
        if self.num_sources <= 0 or self.num_destinations <= 0:
            raise ValueError("num_sources/num_destinations must be positive")
        if self.num_families < 1:
            raise ValueError("need at least one coupling family")


@dataclasses.dataclass
class EdgeListInstance:
    """Edge-list form of a matching LP (host-side, pre-packing).

    Edges are sorted by (source, destination).  ``values`` holds the *positive*
    matched value; ``cost`` = -values is what the solver minimises.  ``coeff``
    has shape [m, nnz]: constraint coefficients per family.  ``rhs`` has shape
    [m * J] in family-major order (row r = k * J + j).
    """

    spec: MatchingInstanceSpec
    src: np.ndarray  # [nnz] int64 source ids
    dst: np.ndarray  # [nnz] int64 destination ids
    values: np.ndarray  # [nnz] f64 positive values
    coeff: np.ndarray  # [m, nnz] f64 constraint coefficients
    rhs: np.ndarray  # [m * J] f64

    @property
    def nnz(self) -> int:
        return int(self.src.shape[0])

    @property
    def cost(self) -> np.ndarray:
        return -self.values

    def degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.spec.num_sources)

    def to_dense(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Materialise (A, b, c) densely — tests/small instances only.

        A: [m*J, I*J] with the Def.-1 diagonal block structure, x stacked
        source-major (x_ij at column i*J + j).
        """
        spec = self.spec
        I, J, m = spec.num_sources, spec.num_destinations, spec.num_families
        if I * J > 4_000_000:
            raise ValueError("to_dense() is for small test instances only")
        A = np.zeros((m * J, I * J))
        c = np.zeros(I * J)
        cols = self.src * J + self.dst
        c[cols] = self.cost
        for k in range(m):
            A[k * J + self.dst, cols] = self.coeff[k]
        return A, self.rhs.copy(), c


def _lognormal(rng: np.random.Generator, sigma: float, size) -> np.ndarray:
    # mean-1 lognormal: exp(N(-sigma^2/2, sigma^2))
    return rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma, size=size)


def generate_matching_instance(spec: MatchingInstanceSpec) -> EdgeListInstance:
    """Generate an Appendix-A synthetic matching LP as an edge list."""
    rng = np.random.default_rng(spec.seed)
    I, J, m = spec.num_sources, spec.num_destinations, spec.num_families

    # --- 1. bipartite graph: resource breadth -> Poisson degrees ------------
    breadth = _lognormal(rng, spec.breadth_sigma, J)
    p = breadth / breadth.sum()
    K = np.minimum(rng.poisson(p * I * spec.avg_degree), I)  # [J], truncated at I

    # For each resource j select K_j distinct requests.  Vectorised: draw all
    # (request, resource) pairs then dedupe; re-draw collisions cheaply by
    # sampling with replacement and dropping duplicates (the collision rate is
    # negligible at production sparsity; any shortfall only perturbs K_j which
    # is itself random).
    dst = np.repeat(np.arange(J, dtype=np.int64), K)
    src = rng.integers(0, I, size=dst.shape[0], dtype=np.int64)
    if dst.size == 0:  # degenerate tiny instance: keep at least one edge
        src = np.zeros(1, dtype=np.int64)
        dst = np.asarray([int(np.argmax(p))], dtype=np.int64)
    eid = src * J + dst
    _, keep = np.unique(eid, return_index=True)
    src, dst = src[keep], dst[keep]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    nnz = src.shape[0]

    # --- 2. edge values ------------------------------------------------------
    v = _lognormal(rng, spec.value_sigma, J)  # per-resource value scale
    u = _lognormal(rng, spec.responsiveness_sigma, I)  # per-request factor
    eps = _lognormal(rng, spec.noise_sigma, nnz)
    values = np.minimum(v[dst] * u[src] * eps, spec.c_max)

    # --- 3. constraint coefficients per family -------------------------------
    coeff = np.empty((m, nnz))
    for k in range(m):
        s = _lognormal(rng, spec.scale_sigma, J)
        coeff[k] = s[dst] * values

    # --- 4. greedy-load RHS ---------------------------------------------------
    rhs = np.empty(m * J)
    for k in range(m):
        # per request: largest incident a_ij -> assign to that resource.
        # Vectorised segmented argmax: sort edges by (src, -a); the first edge
        # of each source segment is its greedy winner.
        a = coeff[k]
        order_k = np.lexsort((-a, src))
        first_pos = np.unique(src[order_k], return_index=True)[1]
        winners = order_k[first_pos]
        load = np.zeros(J)
        np.add.at(load, dst[winners], a[winners])
        rho = rng.uniform(0.5, 1.0, size=J)
        rhs[k * J : (k + 1) * J] = rho * (load + spec.rhs_eps)

    return EdgeListInstance(
        spec=spec, src=src, dst=dst, values=values, coeff=coeff, rhs=rhs
    )
