"""Per-tenant solve session: delta ingestion + warm-started cadence solves.

A `SolveSession` owns everything one tenant needs across cadences:

  * its `DeltaIngestor` (the mutable packed instance + headroom bookkeeping);
  * the previous duals / primal slabs for warm starts and drift metering;
  * access to the shared shape-keyed compiled solvers (`service.engine`).

The cadence loop the paper targets ("solved repeatedly on recurring cadences
over slowly evolving inputs") becomes:

    session.ingest(delta)          # O(delta) slab surgery, shapes preserved
    result, report = session.solve()  # warm start + shortened continuation

Warm starts skip the large-gamma continuation stages (yesterday's duals are
already near the small-gamma optimum) and rely on convergence-based early
stopping to exit once the iterate re-converges, so a quiet day costs a small
fraction of the cold iteration budget.  Guards fall back to a cold start when
the dual dimension drifts (resized instance) or when explicitly forced, and
the report says so (`cold_reason`).

Drift-SLA: each solve reports the empirical primal drift vs the previous
cadence together with the analytic bound `(sigma ||dlam|| + ||dc||) / gamma`
(core.stability), and flags `sla_ok` against the configured relative-drift
SLA — the run-to-run stability control the paper's ridge term exists for.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maximizer import MaximizerConfig, SolveResult
from repro.core.stability import drift_bound
from repro.instances.deltas import DeltaIngestor, DeltaReport, InstanceDelta
from repro.instances.generator import EdgeListInstance
from repro.service.engine import compiled_solver, to_solve_result

__all__ = ["ServiceConfig", "SolveSession"]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the recurring-solve service (shared by all tenants)."""

    # Cold starts run the full continuation schedule; early stopping is on by
    # default so even cold solves exit stages once converged.
    cold: MaximizerConfig = dataclasses.field(
        default_factory=lambda: MaximizerConfig(
            tol_grad=1e-4, tol_viol=1e-4, check_every=25
        )
    )
    # Warm starts resume from yesterday's duals on a shortened continuation
    # tail (the large-gamma stages exist to *reach* the small-gamma basin,
    # which a warm iterate is already in).
    warm_gammas: tuple[float, ...] = (1e-1, 1e-2)
    warm_iters_per_stage: Optional[int] = None  # None: same as cold
    # Relative primal-drift SLA (||x_t - x_{t-1}|| / ||x_t||); None disables.
    drift_sla_rel: Optional[float] = None
    # Jacobi row normalization applied device-side inside every compiled
    # solve (normalize_rows_traced) — the paper's preconditioning without a
    # host-side O(nnz) repack per cadence.
    normalize: bool = True
    # Packing knobs forwarded to each tenant's DeltaIngestor.
    row_headroom: int = 8
    min_length: int = 1
    shard_multiple: int = 1

    @property
    def warm(self) -> MaximizerConfig:
        iters = (
            self.cold.iters_per_stage
            if self.warm_iters_per_stage is None
            else self.warm_iters_per_stage
        )
        return dataclasses.replace(
            self.cold, gammas=self.warm_gammas, iters_per_stage=iters
        )


class SolveSession:
    """State and cadence driver of one tenant."""

    def __init__(
        self, tenant: str, inst: EdgeListInstance, config: ServiceConfig
    ):
        self.tenant = tenant
        self.config = config
        self.ingestor = DeltaIngestor(
            inst,
            shard_multiple=config.shard_multiple,
            min_length=config.min_length,
            row_headroom=config.row_headroom,
        )
        self.lam_prev: Optional[jax.Array] = None
        # previous primal in edge space: (sorted edge keys, values) — robust
        # to row relocations and re-bucketizes, unlike slab positions
        self.prev_primal: Optional[tuple[np.ndarray, np.ndarray]] = None
        self.cadence = 0
        self.last_ingest: Optional[DeltaReport] = None
        self.last_report: Optional[dict[str, Any]] = None

    # -- cadence inputs ------------------------------------------------------

    def instance(self):
        return self.ingestor.instance()

    def ingest(self, delta: InstanceDelta) -> DeltaReport:
        rep = self.ingestor.apply(delta)
        self.last_ingest = rep
        return rep

    # -- solve ---------------------------------------------------------------

    def _start_state(
        self, force_cold: bool
    ) -> tuple[bool, Optional[str], jax.Array]:
        """(cold?, reason, lam0) with the shape-drift guard applied."""
        dual_dim = self.instance().dual_dim
        if force_cold:
            reason = "forced"
        elif self.lam_prev is None:
            reason = "first_solve"
        elif self.lam_prev.shape != (dual_dim,):
            # a resized instance makes yesterday's duals meaningless (and
            # passing them into the jitted solver would be a shape error)
            reason = "dual_dim_drift"
        else:
            return False, None, self.lam_prev
        return True, reason, jnp.zeros((dual_dim,), jnp.float32)

    def solve(self, *, force_cold: bool = False) -> tuple[SolveResult, dict]:
        cold, reason, lam0 = self._start_state(force_cold)
        cfg = self.config.cold if cold else self.config.warm
        raw = compiled_solver(cfg, self.config.normalize)(self.instance(), lam0)
        res = to_solve_result(raw)
        report = self.absorb(res, cold=cold, cold_reason=reason, batched=False)
        return res, report

    def absorb(
        self,
        res: SolveResult,
        *,
        cold: bool,
        cold_reason: Optional[str],
        batched: bool,
    ) -> dict[str, Any]:
        """Fold a finished solve (own or pool-produced) into session state."""
        cfg = self.config.cold if cold else self.config.warm
        gamma_floor = cfg.gammas[-1]
        dc_norm = self.ingestor.drain_cost_drift()
        report: dict[str, Any] = {
            "tenant": self.tenant,
            "cadence": self.cadence,
            "mode": "cold" if cold else "warm",
            "cold_reason": cold_reason,
            "batched": batched,
            "iters_used": res.total_iters_used or cfg.total_iters,
            "iter_budget": cfg.total_iter_budget,
            "g": float(res.g),
            "max_violation": float(res.stats[-1].max_violation[-1]),
            "gamma_floor": gamma_floor,
            "dc_norm": dc_norm,
            "drift_l2": None,
            "drift_rel": None,
            "drift_bound": None,
            "sla_rel": self.config.drift_sla_rel,
            "sla_ok": None,
        }
        keys, x = self.ingestor.unpack_primal(res.x_slabs)
        if self.prev_primal is not None:
            drift = _edge_drift(self.prev_primal, (keys, x))
            x_norm = float(np.linalg.norm(x))
            dlam = (
                float(jnp.linalg.norm(res.lam - self.lam_prev))
                if self.lam_prev is not None
                and self.lam_prev.shape == res.lam.shape
                else 0.0
            )
            sigma = float(jnp.sqrt(res.sigma_sq))
            report["drift_l2"] = drift
            report["drift_rel"] = drift / max(x_norm, 1e-12)
            report["drift_bound"] = drift_bound(
                gamma_floor, dc_norm=dc_norm, dlam_norm=dlam, sigma_max=sigma
            )
            if self.config.drift_sla_rel is not None:
                report["sla_ok"] = bool(
                    report["drift_rel"] <= self.config.drift_sla_rel
                )
        self.lam_prev = res.lam
        self.prev_primal = (keys, x)
        self.cadence += 1
        self.last_report = report
        return report


def _edge_drift(
    prev: tuple[np.ndarray, np.ndarray], cur: tuple[np.ndarray, np.ndarray]
) -> float:
    """||x_t - x_{t-1}||_2 over the union of edges (missing edges count 0).

    Both inputs are (sorted keys, values) from `DeltaIngestor.unpack_primal`;
    inserted/deleted edges contribute their full allocation to the drift —
    exactly the downstream churn a drift SLA is about.
    """
    pk, px = prev
    ck, cx = cur
    sq = 0.0
    if pk.size:
        pos = np.clip(np.searchsorted(pk, ck), 0, pk.size - 1)
        hit = pk[pos] == ck
        sq += float(np.sum((cx[hit] - px[pos[hit]]) ** 2))
        sq += float(np.sum(cx[~hit] ** 2))  # edges new this cadence
        if ck.size:
            pos2 = np.clip(np.searchsorted(ck, pk), 0, ck.size - 1)
            gone = ck[pos2] != pk
        else:
            gone = np.ones(pk.size, bool)
        sq += float(np.sum(px[gone] ** 2))  # edges removed this cadence
    else:
        sq = float(np.sum(cx**2))
    return float(np.sqrt(sq))
