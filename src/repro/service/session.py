"""Per-tenant solve session: delta ingestion + warm-started cadence solves.

A `SolveSession` owns everything one tenant needs across cadences:

  * its `DeltaIngestor` (the mutable packed instance + headroom bookkeeping);
  * the previous duals / primal slabs for warm starts and drift metering;
  * access to the shared shape-keyed compiled solvers (`service.engine`).

The cadence loop the paper targets ("solved repeatedly on recurring cadences
over slowly evolving inputs") becomes:

    session.ingest(delta)          # O(delta) slab surgery, shapes preserved
    result, report = session.solve()  # warm start + shortened continuation

Warm starts skip the large-gamma continuation stages (yesterday's duals are
already near the small-gamma optimum) and rely on convergence-based early
stopping to exit once the iterate re-converges, so a quiet day costs a small
fraction of the cold iteration budget.  Guards fall back to a cold start when
the dual dimension drifts (resized instance) or when explicitly forced, and
the report says so (`cold_reason`).

Drift-SLA: each solve reports the empirical primal drift vs the previous
cadence together with the analytic bound `(sigma ||dlam|| + ||dc||) / gamma`
(core.stability), and flags `sla_ok` against the configured relative-drift
SLA — the run-to-run stability control the paper's ridge term exists for.

Slabs are device-resident across cadences: `device_instance()` keeps a jax
copy of the host slabs synced by replaying the ingestor's scatter plans
(generation-fenced), so steady-state host→device transfer is O(delta); and
`state_dict()`/`from_state()` persist everything needed for a restarted
service to resume this tenant warm (see docs/service.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.maximizer import MaximizerConfig, SolveResult
from repro.core.stability import drift_bound
from repro.telemetry import ConvergenceTrace, StallDetector
from repro.instances.buckets import slab_dtype_name
from repro.instances.deltas import (
    DeltaIngestor,
    DeltaReport,
    InstanceDelta,
    ScatterPlan,
)
from repro.instances.generator import EdgeListInstance
from repro.service.engine import (
    apply_scatter_plan,
    compiled_solver,
    compiled_solver_fixed_sigma,
    device_put_instance,
    instance_nbytes,
    to_solve_result,
)

__all__ = ["ServiceConfig", "SolveSession"]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the recurring-solve service (shared by all tenants)."""

    # Cold starts run the full continuation schedule; early stopping is on by
    # default so even cold solves exit stages once converged.
    cold: MaximizerConfig = dataclasses.field(
        default_factory=lambda: MaximizerConfig(
            tol_grad=1e-4, tol_viol=1e-4, check_every=25
        )
    )
    # Warm starts resume from yesterday's duals on a shortened continuation
    # tail (the large-gamma stages exist to *reach* the small-gamma basin,
    # which a warm iterate is already in).
    warm_gammas: tuple[float, ...] = (1e-1, 1e-2)
    warm_iters_per_stage: Optional[int] = None  # None: same as cold
    # Relative primal-drift SLA (||x_t - x_{t-1}|| / ||x_t||); None disables.
    drift_sla_rel: Optional[float] = None
    # Jacobi row normalization applied device-side inside every compiled
    # solve (normalize_rows_traced) — the paper's preconditioning without a
    # host-side O(nnz) repack per cadence.
    normalize: bool = True
    # One-pass fused dual oracle inside every compiled solve (see
    # core.objective.MatchingObjective.fused_oracle): each AGD iteration
    # reads every slab once instead of ~3x.  Off-TPU this routes through the
    # fused reference oracle; results match the unfused path to fp32 noise.
    fused_oracle: bool = False
    # Warm cadences whose ingested cost drift ||dc|| is at or below this
    # threshold reuse the previous solve's sigma_max(A)^2 estimate instead of
    # re-running the ~power_iters-oracle-call power iteration.  sigma_max(A)
    # is a function of the coefficients alone, so reuse additionally requires
    # that no delta since the estimate touched A: any insert/delete,
    # coefficient update, or re-bucketize marks the cache dirty and forces a
    # recompute (cost-only updates — the common quiet cadence — keep it
    # valid; dc_norm then only gates how quiet the cadence was).  Cold starts
    # always recompute.  None disables reuse.  Honored by the synchronous
    # `SolveSession.solve`, the scheduler's solo dispatch path, and — when
    # every member of a warm shape-group is reuse-ready — the batched
    # (vmapped) pool via `compiled_batch_solver_fixed_sigma`; mixed groups
    # recompute (a vmapped lane cannot skip its power iteration alone).
    sigma_reuse_dc_threshold: Optional[float] = None
    # Escalating warm-start schedule.  None keeps the fixed `warm_gammas`
    # tail.  A tuple of ascending relative-drift thresholds turns the warm
    # schedule adaptive: after each cadence the session compares the observed
    # relative primal drift (`drift_rel`, falling back to the analytic
    # thresholds (first cadences with no previous primal stay at level 0) —
    # each threshold exceeded adds one escalation level, and a
    # failed drift SLA (`sla_ok is False`) adds one more.  Escalation level e
    # prepends the e smallest cold-schedule gammas that are still above
    # `warm_gammas[0]` (re-entering that much of the continuation run-up), so
    # a quiet tenant keeps the short tail while a churning tenant climbs back
    # toward the cold schedule instead of thrashing inside the small-gamma
    # basin.  The chosen schedule is reported (`report["warm_schedule"]`) and
    # is part of the scheduler's batching key — tenants at different
    # escalation levels never share a vmapped executable.
    warm_escalation: Optional[tuple[float, ...]] = None
    # Slab storage dtype for every tenant's packed instance ("float32" or
    # "bfloat16"; int8 is batch-only — see DeltaIngestor).  Narrow storage
    # halves steady-state slab HBM traffic per oracle read; duals, rhs and
    # all in-kernel accumulation stay fp32 (see docs/architecture.md,
    # "Mixed-precision slabs").
    slab_dtype: str = "float32"
    # Solver engine every tenant dispatches on: "agd" (the paper's smoothed
    # continuation solve), "pdhg" (structured primal-dual, repro.engines),
    # or "auto" — per-tenant adaptive routing from observed iterations-to-tol
    # (`repro.engines.EngineSelector`; the scheduler owns the selector and
    # checkpoints it).  A session driven outside a scheduler treats "auto"
    # as "agd" until a selector is attached.
    engine: str = "agd"
    # Packing knobs forwarded to each tenant's DeltaIngestor.
    row_headroom: int = 8
    min_length: int = 1
    shard_multiple: int = 1

    def __post_init__(self):
        from repro.engines.base import ENGINES
        from repro.instances.buckets import SLAB_DTYPES

        if self.slab_dtype not in SLAB_DTYPES or self.slab_dtype == "int8":
            raise ValueError(
                f"ServiceConfig.slab_dtype={self.slab_dtype!r}: the service "
                "path supports 'float32' and 'bfloat16' (int8 requires "
                "frozen per-bucket scales, incompatible with O(delta) slab "
                "surgery)"
            )
        if self.engine not in ENGINES + ("auto",):
            raise ValueError(
                f"ServiceConfig.engine={self.engine!r}: choose from "
                f"{ENGINES + ('auto',)}"
            )

    @property
    def warm(self) -> MaximizerConfig:
        """The warm-start solver config: `cold` with the shortened gamma tail."""
        return self.warm_for(0)

    def escalated_warm_gammas(self, level: int) -> tuple[float, ...]:
        """The warm gamma schedule at escalation level ``level``.

        Level 0 is the configured `warm_gammas` tail; each level above it
        prepends the next-smallest cold-schedule gamma still above the tail's
        head, re-entering that much of the continuation run-up (ordered
        descending, as continuation schedules are).  Saturates once the full
        cold run-up is prepended.
        """
        if level <= 0:
            return self.warm_gammas
        runup = sorted(g for g in self.cold.gammas if g > self.warm_gammas[0])
        prepend = tuple(sorted(runup[: min(level, len(runup))], reverse=True))
        return prepend + self.warm_gammas

    def warm_for(self, level: int) -> MaximizerConfig:
        """The warm solver config at escalation level ``level``."""
        iters = (
            self.cold.iters_per_stage
            if self.warm_iters_per_stage is None
            else self.warm_iters_per_stage
        )
        return dataclasses.replace(
            self.cold,
            gammas=self.escalated_warm_gammas(level),
            iters_per_stage=iters,
        )


class SolveSession:
    """State and cadence driver of one tenant."""

    def __init__(
        self, tenant: str, inst: EdgeListInstance, config: ServiceConfig
    ):
        self.tenant = tenant
        self.config = config
        self.ingestor = DeltaIngestor(
            inst,
            shard_multiple=config.shard_multiple,
            min_length=config.min_length,
            row_headroom=config.row_headroom,
            dtype=config.slab_dtype,
        )
        self.ingestor.telemetry_tenant = tenant
        # per-tenant stall detection over the ConvergenceTraces absorb builds
        self._stall = StallDetector()
        self.last_convergence: Optional[ConvergenceTrace] = None
        self.lam_prev: Optional[jax.Array] = None
        # previous primal in edge space: (sorted edge keys, values) — robust
        # to row relocations and re-bucketizes, unlike slab positions
        self.prev_primal: Optional[tuple[np.ndarray, np.ndarray]] = None
        self.cadence = 0
        self.last_ingest: Optional[DeltaReport] = None
        self.last_report: Optional[dict[str, Any]] = None
        # Device-resident copy of the packed slabs, kept in sync with the host
        # ingestor through scatter plans.  `_device_generation` is the
        # ingestor generation the device copy reflects; `_pending_plans` are
        # plans ingested but not yet replayed on device.
        self._device_inst = None
        self._device_generation = -1
        self._pending_plans: list[ScatterPlan] = []
        # What the last device sync transferred: {"mode": "full"|"scatter"|
        # "none", "bytes": int} — the benchmark's O(delta)-vs-O(nnz) evidence.
        self.last_transfer: Optional[dict[str, Any]] = None
        # Previous solve's sigma_max(A)^2 estimate for the warm-cadence
        # power-iteration skip (sigma_reuse_dc_threshold).  `_dirty_count`
        # increments on every ingested delta that touches A (inserts,
        # deletes, coefficient updates, re-bucketizes); `_sigma_clean_at` is
        # the count the stored estimate was computed under, snapshotted at
        # dispatch time so the overlapped scheduler's ingest-during-solve
        # cannot launder a stale estimate into validity.
        self._sigma_sq: Optional[float] = None
        self._dirty_count = 0
        self._sigma_clean_at = -1
        # Warm-escalation level chosen for the NEXT warm solve (see
        # `ServiceConfig.warm_escalation`); updated from the observed drift
        # at every absorb, 0 while no escalation thresholds are configured.
        self.warm_level = 0
        # Attached allocation-serving store (repro.serving.DualStore).  When
        # set, every absorbed solve publishes its duals as an immutable
        # generation-stamped snapshot (see `_publish_duals`); queries are
        # then answered from device-resident duals without touching the
        # solver.  Attach via `Scheduler(dual_store=...)` or directly.
        self.dual_store = None
        # Engine routing policy for `config.engine == "auto"`; attached by
        # the owning Scheduler (which also checkpoints it).  None means
        # "auto" degrades to "agd".
        self.engine_selector = None

    # -- cadence inputs ------------------------------------------------------

    def instance(self):
        """The host-side packed instance (numpy slabs; the source of truth)."""
        return self.ingestor.instance()

    def device_instance(self):
        """The device-resident packed instance, synced to the host state.

        First call (and any loss of sync: re-bucketize fallback, or host
        mutations that bypassed this session) performs the full O(nnz)
        upload; steady-state calls replay only the pending scatter plans —
        O(delta) host→device bytes per cadence.  `last_transfer` records
        which path ran and how many bytes moved.
        """
        gen = self.ingestor.generation
        plans = self._pending_plans
        in_sync = (
            self._device_inst is not None
            and self._device_generation + len(plans) == gen
            and all(
                p.generation == self._device_generation + i + 1
                for i, p in enumerate(plans)
            )
        )
        if not in_sync:
            self._device_inst = device_put_instance(self.instance())
            self._device_generation = gen
            self._pending_plans = []
            self.last_transfer = {
                "mode": "full",
                "bytes": instance_nbytes(self._device_inst),
            }
            # Slab bytes the narrow storage dtype saves vs fp32 — both the
            # resident-HBM footprint and (x1 per oracle read) the per-
            # iteration traffic reduction evidence (0 for fp32 slabs).
            telemetry.get_registry().set_gauge(
                "service_slab_bytes_saved",
                float(_slab_bytes_saved(self._device_inst)),
                tenant=self.tenant,
                slab_dtype=slab_dtype_name(self.ingestor.dtype),
            )
        elif plans:
            nbytes = 0
            for plan in plans:
                self._device_inst = apply_scatter_plan(self._device_inst, plan)
                self._device_generation = plan.generation
                nbytes += plan.nbytes
            self._pending_plans = []
            self.last_transfer = {"mode": "scatter", "bytes": nbytes}
        else:
            self.last_transfer = {"mode": "none", "bytes": 0}
        return self._device_inst

    def ingest(self, delta: InstanceDelta) -> DeltaReport:
        """Apply one delta to the host slabs and queue its device replay.

        Host application is atomic (`DeltaIngestor.apply`): a rejected delta
        raises here without mutating the host slabs, queueing a plan, or
        bumping the generation — so the device copy stays exactly at the last
        good state and the next solve sees no partial edits.
        """
        rep = self.ingestor.apply(delta)
        self.last_ingest = rep
        if rep.plan is not None:
            self._pending_plans.append(rep.plan)
        else:
            # re-bucketize fallback: shapes/placement changed, the device
            # copy is unsalvageable — force a full re-upload on next access
            self._device_inst = None
            self._pending_plans = []
        # Anything that touches the coefficients of A invalidates the cached
        # sigma_max estimate: structural edits (insert/delete change the
        # sparsity), coefficient updates (which meter NO cost drift, so
        # dc_norm alone would be blind to them), and re-bucketizes.
        # Cost-only updates leave A — and therefore sigma — untouched.
        if (
            rep.rebucketized
            or rep.n_insert
            or rep.n_delete
            or delta.update_coeff is not None
        ):
            self._dirty_count += 1
        return rep

    def sigma_reuse_ready(self, dc_norm: float) -> bool:
        """True iff the next solve may skip the power iteration: a cached
        estimate exists, no A-touching delta landed since it was computed,
        and this cadence's cost drift is within the configured threshold."""
        thr = self.config.sigma_reuse_dc_threshold
        return (
            thr is not None
            and self._sigma_sq is not None
            and self._sigma_clean_at == self._dirty_count
            and dc_norm <= thr
        )

    def warm_config(self) -> MaximizerConfig:
        """The warm solver config this tenant's next warm solve should use —
        `ServiceConfig.warm` escalated to the drift-chosen level.  The
        scheduler keys its batching groups on this config's gamma schedule,
        so escalated tenants never share an executable with quiet ones."""
        return self.config.warm_for(self.warm_level)

    def engine_choice(self) -> str:
        """The engine this tenant's next solve dispatches on.

        Resolves `config.engine == "auto"` through the attached
        `EngineSelector` (deterministic given its observed state; "agd" when
        no selector is attached).  Called exactly once per dispatch decision
        — by `solve()` and by the scheduler's `_dispatch` — and emits the
        `engine_selected_total{tenant,engine}` counter there, so routing is
        observable on both the solo and the batched path.
        """
        engine = self.config.engine
        if engine == "auto":
            engine = (
                "agd"
                if self.engine_selector is None
                else self.engine_selector.choose(self.tenant)
            )
        telemetry.get_registry().inc(
            "engine_selected_total", 1, tenant=self.tenant, engine=engine
        )
        return engine

    def dispatch_raw(
        self, cfg, lam0, dc_norm: float, *, cold: bool,
        engine: Optional[str] = None,
    ):
        """Dispatch one compiled solve of the device-resident instance.

        The single site choosing between the fixed-sigma entry point
        (power-iteration skip, `sigma_reuse_ready`) and the full solver —
        both the synchronous `solve()` and the scheduler's solo dispatch go
        through here, so the reuse gating cannot drift between them.  The
        sigma-reuse fast path is engine-agnostic: sigma_max(A) depends only
        on A, so an estimate computed under one engine stays valid when the
        selector re-routes the tenant.  Returns
        `(RawSolve device futures, sigma_reused)`.
        """
        if engine is None:
            engine = self.engine_choice()
        reuse = not cold and self.sigma_reuse_ready(dc_norm)
        if reuse:
            raw = compiled_solver_fixed_sigma(
                cfg, self.config.normalize, self.config.fused_oracle, engine
            )(self.device_instance(), lam0, jnp.float32(self._sigma_sq))
        else:
            raw = compiled_solver(
                cfg, self.config.normalize, self.config.fused_oracle, engine
            )(self.device_instance(), lam0)
        return raw, reuse

    def serving_capture(self) -> Optional[dict[str, Any]]:
        """Freeze what publishing duals after the fence needs, at dispatch time.

        Must run right after a dispatch's `device_instance()` sync (every
        dispatch path performs one): the device instance and the copied
        occupancy maps then reflect the same ingestor generation, so the
        snapshot eventually published is internally consistent even though
        the overlapped pipeline mutates the host slabs while the solve is
        still in flight.  Stamped with `_device_generation` — the generation
        the device copy actually reflects.  None when no store is attached.
        """
        if self.dual_store is None or self._device_inst is None:
            return None
        return {
            "instance": self._device_inst,
            "generation": self._device_generation,
            "bucket_of": self.ingestor.bucket_of.copy(),
            "row_of": self.ingestor.row_of.copy(),
            "deg": self.ingestor.deg.copy(),
        }

    # -- solve ---------------------------------------------------------------

    def _start_state(
        self, force_cold: bool
    ) -> tuple[bool, Optional[str], jax.Array]:
        """(cold?, reason, lam0) with the shape-drift guard applied."""
        dual_dim = self.instance().dual_dim
        if force_cold:
            reason = "forced"
        elif self.lam_prev is None:
            reason = "first_solve"
        elif self.lam_prev.shape != (dual_dim,):
            # a resized instance makes yesterday's duals meaningless (and
            # passing them into the jitted solver would be a shape error)
            reason = "dual_dim_drift"
        else:
            return False, None, self.lam_prev
        return True, reason, jnp.zeros((dual_dim,), jnp.float32)

    def solve(self, *, force_cold: bool = False) -> tuple[SolveResult, dict]:
        """One warm-started (or guarded-cold) solve of the current instance.

        Solves against the device-resident slabs (`device_instance`), so the
        per-cadence transfer is the pending scatter plans, not the slabs.
        Warm cadences below `sigma_reuse_dc_threshold` additionally skip the
        power iteration by reusing the previous solve's sigma_max estimate
        (`compiled_solver_fixed_sigma`); the report says so (`sigma_reused`).
        """
        cold, reason, lam0 = self._start_state(force_cold)
        cfg = self.config.cold if cold else self.warm_config()
        dc_norm = self.ingestor.drain_cost_drift()
        dirty_count = self._dirty_count  # A-state the solve runs against
        engine = self.engine_choice()
        with telemetry.span(
            "tenant_solve", tenant=self.tenant, mode="cold" if cold else "warm"
        ):
            raw, reuse_sigma = self.dispatch_raw(
                cfg, lam0, dc_norm, cold=cold, engine=engine
            )
            serving = self.serving_capture()
            res = to_solve_result(raw)
            report = self.absorb(
                res, cold=cold, cold_reason=reason, batched=False,
                dc_norm=dc_norm, sigma_reused=reuse_sigma,
                dirty_count=dirty_count, serving=serving, engine=engine,
            )
        return res, report

    def absorb(
        self,
        res: SolveResult,
        *,
        cold: bool,
        cold_reason: Optional[str],
        batched: bool,
        dc_norm: Optional[float] = None,
        unpack=None,
        sigma_reused: bool = False,
        dirty_count: Optional[int] = None,
        serving: Optional[dict[str, Any]] = None,
        engine: str = "agd",
    ) -> dict[str, Any]:
        """Fold a finished solve (own or pool-produced) into session state.

        ``dc_norm`` is the cost drift ingested *for* this solve; when None it
        is drained here (correct for synchronous callers).  ``unpack`` is the
        primal unpacker frozen when the solve was dispatched; when None the
        ingestor's current maps are used.  Overlapped drivers must capture
        both at dispatch time, or the next cadence's in-flight ingest would
        corrupt this one's drift metering (see `Scheduler._dispatch`).
        ``serving`` is the `serving_capture()` taken at dispatch time; when
        present (a DualStore is attached) the finished duals are published
        against exactly that captured instance.
        """
        with telemetry.span(
            "tenant_absorb",
            tenant=self.tenant,
            mode="cold" if cold else "warm",
            batched=batched,
        ):
            return self._absorb(
                res,
                cold=cold,
                cold_reason=cold_reason,
                batched=batched,
                dc_norm=dc_norm,
                unpack=unpack,
                sigma_reused=sigma_reused,
                dirty_count=dirty_count,
                serving=serving,
                engine=engine,
            )

    def _absorb(
        self,
        res: SolveResult,
        *,
        cold: bool,
        cold_reason: Optional[str],
        batched: bool,
        dc_norm: Optional[float] = None,
        unpack=None,
        sigma_reused: bool = False,
        dirty_count: Optional[int] = None,
        serving: Optional[dict[str, Any]] = None,
        engine: str = "agd",
    ) -> dict[str, Any]:
        cfg = self.config.cold if cold else self.warm_config()
        gamma_floor = cfg.gammas[-1]
        if dc_norm is None:
            dc_norm = self.ingestor.drain_cost_drift()
        if unpack is None:
            unpack = self.ingestor.primal_unpacker()
        report: dict[str, Any] = {
            "tenant": self.tenant,
            "cadence": self.cadence,
            "mode": "cold" if cold else "warm",
            "cold_reason": cold_reason,
            "batched": batched,
            "engine": engine,
            "iters_used": res.total_iters_used or cfg.total_iters,
            "iter_budget": cfg.total_iter_budget,
            "g": float(res.g),
            "max_violation": float(res.stats[-1].max_violation[-1]),
            "gamma_floor": gamma_floor,
            "dc_norm": dc_norm,
            "sigma_reused": sigma_reused,
            # the gamma schedule this solve actually ran (escalation-aware
            # for warm solves; the full cold schedule otherwise) and the
            # escalation level it was chosen at
            "warm_schedule": [float(g) for g in cfg.gammas],
            "warm_level": 0 if cold else self.warm_level,
            "upload_mode": (
                self.last_transfer["mode"] if self.last_transfer else None
            ),
            "upload_bytes": (
                self.last_transfer["bytes"] if self.last_transfer else None
            ),
            "drift_l2": None,
            "drift_rel": None,
            "drift_bound": None,
            "dual_resized": False,
            "published_generation": None,
            "sla_rel": self.config.drift_sla_rel,
            "sla_ok": None,
        }
        keys, x = unpack(res.x_slabs)
        if self.prev_primal is not None:
            drift = _edge_drift(self.prev_primal, (keys, x))
            x_norm = float(np.linalg.norm(x))
            report["drift_l2"] = drift
            report["drift_rel"] = drift / max(x_norm, 1e-12)
            resized = (
                self.lam_prev is not None
                and self.lam_prev.shape != res.lam.shape
            )
            if resized:
                # Dual-dim resize: ||dlam|| is undefined across dual spaces,
                # so the analytic (sigma ||dlam|| + ||dc||)/gamma bound does
                # not apply — report it as unbounded rather than letting a
                # silent dlam=0 make the one cadence guaranteed to churn
                # look like the quietest (`jsonable` serializes inf NaN-safe
                # as "inf"; cold_reason carries "dual_dim_drift").
                report["dual_resized"] = True
                report["drift_bound"] = float("inf")
            else:
                dlam = (
                    float(jnp.linalg.norm(res.lam - self.lam_prev))
                    if self.lam_prev is not None
                    else 0.0
                )
                sigma = float(jnp.sqrt(res.sigma_sq))
                report["drift_bound"] = drift_bound(
                    gamma_floor, dc_norm=dc_norm, dlam_norm=dlam,
                    sigma_max=sigma,
                )
            if self.config.drift_sla_rel is not None:
                report["sla_ok"] = bool(
                    report["drift_rel"] <= self.config.drift_sla_rel
                )
        self._record_telemetry(res, report, cfg)
        if self.engine_selector is not None and self.config.engine == "auto":
            # feed the routing policy what it routes on: iterations-to-tol,
            # with budget exhaustion flagged as non-convergence
            self.engine_selector.observe(
                self.tenant,
                engine,
                report["iters_used"],
                converged=report["iters_used"] < report["iter_budget"],
            )
        self.lam_prev = res.lam
        self.prev_primal = (keys, x)
        # The solve's sigma estimate (recomputed or echoed) corresponds to
        # the A captured at dispatch time — the caller's `dirty_count`
        # snapshot.  Under the overlapped pipeline a later cadence's
        # A-touching delta may have landed meanwhile; tagging with the
        # dispatch-time count (rather than the current one) keeps such an
        # estimate marked stale.  Callers that cannot snapshot pass None and
        # the estimate is stored but never considered clean.
        self._sigma_sq = float(res.sigma_sq)
        self._sigma_clean_at = -1 if dirty_count is None else dirty_count
        self.warm_level = self._next_warm_level(report)
        self.cadence += 1
        self.last_report = report
        if serving is not None and self.dual_store is not None:
            self._publish_duals(res, serving, gamma_floor, report)
        return report

    def _next_warm_level(self, report: dict[str, Any]) -> int:
        """Escalation level for the NEXT warm solve, from this cadence's drift.

        One level per `warm_escalation` threshold the observed relative drift
        exceeded, plus one when the drift SLA failed outright; 0 when
        escalation is disabled or no drift was measurable yet (first solve).
        The level is recomputed fresh each cadence — a tenant that goes quiet
        de-escalates immediately rather than ratcheting.
        """
        thresholds = self.config.warm_escalation
        if not thresholds:
            return 0
        level = 0
        drift_rel = report.get("drift_rel")
        if drift_rel is not None:
            level = sum(1 for t in sorted(thresholds) if drift_rel > t)
        if report.get("sla_ok") is False:
            level += 1
        return level

    def _publish_duals(
        self,
        res: SolveResult,
        serving: dict[str, Any],
        gamma_floor: float,
        report: dict[str, Any],
    ) -> None:
        """Publish this solve's duals for request serving (atomic slot swap).

        Duals of a normalized solve live in the Jacobi-scaled space
        (lam_original = D lam'); `compute_lam_eff` descales them against the
        dispatch-time device instance, so the serving kernel gathers the raw
        slabs directly.  The snapshot is immutable — queries in flight keep
        serving the previous generation until their next slot read.
        """
        from repro.serving.duals import DualSnapshot, compute_lam_eff

        snap = DualSnapshot(
            tenant=self.tenant,
            generation=int(serving["generation"]),
            cadence=report["cadence"],
            gamma=float(gamma_floor),
            lam_eff=compute_lam_eff(
                serving["instance"], res.lam, normalize=self.config.normalize
            ),
            instance=serving["instance"],
            bucket_of=serving["bucket_of"],
            row_of=serving["row_of"],
            deg=serving["deg"],
        )
        self.dual_store.publish(snap)
        report["published_generation"] = snap.generation

    def _record_telemetry(
        self, res: SolveResult, report: dict[str, Any], cfg
    ) -> None:
        """Route the finished solve into the metrics registry + stall detector.

        Builds the per-solve `ConvergenceTrace` from the already-returned
        `SolveResult.stats` (one host copy of trace arrays after the fence —
        never a per-iteration sync) and attaches its summary + stall flags to
        the report, so every exporter sees one self-contained record.  PDHG
        stats are one trace entry per residual check, not per iteration;
        `trace_stride` carries that granularity into the trace's budget
        accounting.
        """
        engine = report.get("engine", "agd")
        stride = (
            max(1, min(cfg.check_every, cfg.total_iter_budget))
            if engine == "pdhg"
            else 1
        )
        trace = ConvergenceTrace.from_result(
            res,
            tenant=self.tenant,
            cadence=self.cadence,
            engine=engine,
            mode=report["mode"],
            trace_stride=stride,
        )
        self.last_convergence = trace
        report["convergence"] = trace.summary()
        report["stalled"] = trace.stalled
        trace.record()
        report["stall_flagged"] = self._stall.observe(trace)

        reg = telemetry.get_registry()
        labels = dict(tenant=self.tenant, mode=report["mode"])
        reg.inc("service_solves_total", 1, **labels)
        reg.inc("service_iters_total", report["iters_used"], **labels)
        reg.inc(
            "service_upload_bytes_total",
            report["upload_bytes"] or 0,
            tenant=self.tenant,
        )
        if report["sigma_reused"]:
            reg.inc("service_sigma_reuse_total", 1, tenant=self.tenant)
        if res.restarts:
            reg.inc(
                "engine_restarts_total",
                int(res.restarts),
                tenant=self.tenant,
                engine=engine,
            )
        reg.observe("service_solve_iters", report["iters_used"], mode=report["mode"])
        reg.set_gauge("service_last_g", report["g"], tenant=self.tenant)
        reg.set_gauge(
            "service_last_max_violation",
            report["max_violation"],
            tenant=self.tenant,
        )
        reg.set_gauge("service_cadence", self.cadence, tenant=self.tenant)
        if report["drift_rel"] is not None:
            reg.set_gauge(
                "service_drift_rel", report["drift_rel"], tenant=self.tenant
            )
        if report["sla_ok"] is False:
            reg.inc("service_sla_violations_total", 1, tenant=self.tenant)

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> tuple[dict[str, np.ndarray], dict]:
        """(arrays, meta) of everything a restarted service needs to resume warm.

        Covers the duals (`lam_prev`), the edge-space previous primal (drift
        metering), the full ingestor state (slabs + occupancy + generation +
        drift accounting) and the continuation position (`cadence`).  The
        device-resident copy is deliberately NOT saved — it is a cache the
        restored session rebuilds with one upload on first solve.
        """
        arrays, ing_meta = self.ingestor.state_dict()
        arrays = {f"ingestor.{k}": v for k, v in arrays.items()}
        meta = {
            "tenant": self.tenant,
            "cadence": self.cadence,
            "ingestor": ing_meta,
            "has_lam": self.lam_prev is not None,
            "has_primal": self.prev_primal is not None,
            "sigma_clean": bool(self._sigma_clean_at == self._dirty_count),
            # The ingestor generation the sigma-clean claim was made under.
            # `from_state` only honors `sigma_clean` when the restored
            # ingestor proves it is at this exact generation — a checkpoint
            # whose instance arrays were mutated out-of-band (offline delta)
            # must re-run the power iteration.
            "sigma_generation": int(self.ingestor.generation),
            "warm_level": int(self.warm_level),
        }
        if self._sigma_sq is not None:
            arrays["sigma_sq"] = np.asarray(self._sigma_sq, np.float64)
        if self.lam_prev is not None:
            arrays["lam_prev"] = np.asarray(self.lam_prev)
        if self.prev_primal is not None:
            arrays["primal_keys"] = self.prev_primal[0].copy()
            arrays["primal_vals"] = self.prev_primal[1].copy()
        return arrays, meta

    @classmethod
    def from_state(
        cls,
        config: ServiceConfig,
        arrays: dict[str, np.ndarray],
        meta: dict,
    ) -> "SolveSession":
        """Rebuild a session from `state_dict` output; next solve starts warm."""
        self = cls.__new__(cls)
        self.tenant = meta["tenant"]
        self.config = config
        self.ingestor = DeltaIngestor.from_state(
            {
                k[len("ingestor."):]: v
                for k, v in arrays.items()
                if k.startswith("ingestor.")
            },
            meta["ingestor"],
        )
        self.ingestor.telemetry_tenant = self.tenant
        self._stall = StallDetector()
        self.last_convergence = None
        self.lam_prev = (
            jnp.asarray(arrays["lam_prev"]) if meta["has_lam"] else None
        )
        self.prev_primal = (
            (arrays["primal_keys"].copy(), arrays["primal_vals"].copy())
            if meta["has_primal"]
            else None
        )
        self.cadence = int(meta["cadence"])
        self.last_ingest = None
        self.last_report = None
        self._device_inst = None
        self._device_generation = -1
        self._pending_plans = []
        self.last_transfer = None
        # older checkpoints carry no sigma cache: resume with a recompute
        self._sigma_sq = (
            float(arrays["sigma_sq"]) if "sigma_sq" in arrays else None
        )
        self._dirty_count = 0
        # Trust the checkpointed sigma cache only when the checkpoint can
        # PROVE the restored instance is the one the estimate was computed
        # over: the clean flag must hold AND the generation recorded at
        # save time must match the restored ingestor's.  An instance mutated
        # offline (a delta applied out-of-band bumps the persisted ingestor
        # generation without touching the session meta) — or an older
        # checkpoint that never recorded the generation — restores dirty,
        # forcing a sigma_max re-estimation on the next solve.
        clean = bool(meta.get("sigma_clean", False)) and (
            meta.get("sigma_generation") == self.ingestor.generation
        )
        self._sigma_clean_at = 0 if clean else -1
        # older checkpoints restore at base level; one noisy cadence re-raises
        self.warm_level = int(meta.get("warm_level", 0))
        self.dual_store = None
        self.engine_selector = None
        return self


def _slab_bytes_saved(inst) -> int:
    """Bytes the storage dtype saves vs fp32 slabs (idx/rhs are unaffected).

    Computed from shapes+dtypes only — never forces a device transfer.
    Negative never happens (no slab dtype is wider than fp32).
    """
    saved = 0
    for b in inst.buckets:
        for leaf in (b.coeff, b.cost, b.mask):
            saved += leaf.size * (4 - np.dtype(leaf.dtype).itemsize)
    return saved


def _edge_drift(
    prev: tuple[np.ndarray, np.ndarray], cur: tuple[np.ndarray, np.ndarray]
) -> float:
    """||x_t - x_{t-1}||_2 over the union of edges (missing edges count 0).

    Both inputs are (sorted keys, values) from `DeltaIngestor.unpack_primal`;
    inserted/deleted edges contribute their full allocation to the drift —
    exactly the downstream churn a drift SLA is about.
    """
    pk, px = prev
    ck, cx = cur
    sq = 0.0
    if pk.size:
        pos = np.clip(np.searchsorted(pk, ck), 0, pk.size - 1)
        hit = pk[pos] == ck
        sq += float(np.sum((cx[hit] - px[pos[hit]]) ** 2))
        sq += float(np.sum(cx[~hit] ** 2))  # edges new this cadence
        if ck.size:
            pos2 = np.clip(np.searchsorted(ck, pk), 0, ck.size - 1)
            gone = ck[pos2] != pk
        else:
            gone = np.ones(pk.size, bool)
        sq += float(np.sum(px[gone] ** 2))  # edges removed this cadence
    else:
        sq = float(np.sum(cx**2))
    return float(np.sqrt(sq))
