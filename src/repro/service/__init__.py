"""Recurring-solve service: the serving layer for production cadences.

The paper's premise is that matching LPs are "solved repeatedly on recurring
cadences over slowly evolving inputs".  This package turns the one-shot
`Maximizer.solve()` into that serving loop:

    Scheduler.run_cadence({tenant: delta})
        |
        |-- SolveSession.ingest(delta)          session.py
        |       DeltaIngestor applies edge inserts/deletes and cost/rhs
        |       updates IN PLACE on the bucketed-ELL slabs (O(delta), shapes
        |       preserved; re-bucketize only on headroom overflow)
        |                                        instances/deltas.py
        |-- group tenants by (shape signature, warm/cold)
        |       shape-identical tenants share one compiled executable
        |                                        pool.py / engine.py
        |-- solve
        |       groups  -> ONE vmapped batched continuation solve
        |       singles -> per-tenant solve, same shape-keyed compile cache
        |       warm starts resume from yesterday's duals on a shortened
        |       continuation tail; convergence-based early stopping
        |       (core.maximizer) exits stages once
        |       ||grad|| <= tol_grad * max(1, |g|) and viol <= tol_viol
        |
        '-- per-tenant drift-SLA report
                empirical primal drift vs previous cadence, the analytic
                gamma bound (core.stability.drift_bound), iterations used
                vs budget, cold-start reasons (e.g. dual-dim drift guard)

Architecture invariants:

  * The packed instance is a *traced argument* of the compiled solvers, never
    a closed-over constant — slab updates are always visible, and the jit
    cache keys executables on bucket shapes, so a tenant whose deltas stay
    within padding headroom never recompiles.
  * Shape identity is the batching currency: `ServiceConfig.row_headroom`
    buys shape stability; the scheduler monetises it by vmapping
    shape-identical tenants together.
  * Slabs are device-resident across cadences: the host `DeltaIngestor` is
    the source of truth, each applied delta emits an O(delta) `ScatterPlan`,
    and `engine.apply_scatter_plan` replays it on the device copy with
    `.at[].set` — bit-for-bit equal to re-uploading, at O(delta) transfer.
  * `Scheduler.run_pipeline` double-buffers cadences: host-side delta
    validation + plan construction for cadence t+1 overlaps the device solve
    of cadence t, fenced by `jax.block_until_ready`; per-tenant generation
    counters guarantee a rejected delta never half-applies.
  * Sessions checkpoint/restore through `checkpoint.CheckpointManager`
    (`Scheduler.save_checkpoint` / `restore_checkpoint`): a restarted
    service resumes every tenant warm.  Distributed execution composes
    underneath via `core.sharding` (the operator-centric boundary).

See docs/service.md for the operator-facing walkthrough and
docs/architecture.md for the package map.

Drift-SLA knobs (`ServiceConfig`): `drift_sla_rel` sets the relative
run-to-run primal drift SLA checked each cadence; `cold.gammas[-1]` (the
continuation floor) is the stability/fidelity trade-off the paper exposes;
`warm_gammas` controls how much of the schedule warm starts replay.
"""
from repro.service.engine import (
    RawSolve,
    compiled_solver,
    compiled_solver_fixed_sigma,
    compiled_batch_solver,
    compiled_batch_solver_fixed_sigma,
    to_solve_result,
    to_solve_results,
    compile_cache_report,
    device_put_instance,
    apply_scatter_plan,
    instance_nbytes,
)
from repro.service.pool import BatchedSolvePool, shape_signature, stack_instances
from repro.service.scheduler import CadenceReport, Scheduler
from repro.service.session import ServiceConfig, SolveSession

__all__ = [
    "RawSolve",
    "compiled_solver",
    "compiled_solver_fixed_sigma",
    "compiled_batch_solver",
    "compiled_batch_solver_fixed_sigma",
    "to_solve_result",
    "to_solve_results",
    "compile_cache_report",
    "device_put_instance",
    "apply_scatter_plan",
    "instance_nbytes",
    "BatchedSolvePool",
    "shape_signature",
    "stack_instances",
    "CadenceReport",
    "Scheduler",
    "ServiceConfig",
    "SolveSession",
]
