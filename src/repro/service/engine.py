"""Shared solve engine for the recurring-solve service.

`Maximizer` closes over its instance, so the packed slabs are baked into the
jaxpr as compile-time constants: every new day's instance retraces, and
in-place slab mutations (repro.instances.deltas) would be silently ignored by
the stale compiled constant.  The service therefore re-expresses the full
continuation solve as a *pure function of the instance pytree*:

    raw = _raw_solve(instance, lam0, cfg)

and compiles it once per `MaximizerConfig`.  Because `BucketedInstance` is a
registered pytree whose leaves enter as traced arguments, XLA's jit cache then
keys executables on the bucket shapes — tenants (and cadences) that share slab
shapes share one executable, which is exactly the reuse the delta-ingest layer
preserves shapes for.  `jax.vmap` over a leading tenant axis turns the same
function into the batched multi-tenant pool kernel.

Invariants:

  * **Shape-keyed compilation cache** — `compiled_solver` /
    `compiled_batch_solver` hold one jitted entry point per
    (MaximizerConfig, normalize) pair, and within each XLA re-keys on the
    instance's bucket shapes.  Shape-preserving deltas therefore never
    recompile; `compile_cache_report` exposes the executable counts.
  * **Device residency** — `device_put_instance` uploads the packed slabs
    once (O(nnz)); after that, each cadence's `ScatterPlan` is replayed with
    `apply_scatter_plan` (`.at[].set` of the touched cells), so the
    steady-state host→device traffic is O(delta) per cadence.  Because the
    plan payload is gathered from the mutated host slabs, the scattered
    device slabs equal the host slabs bit-for-bit — the host `DeltaIngestor`
    stays the source of truth, the device copy is a faithful cache.
  * **Asynchrony** — solver entry points only *dispatch* work; the returned
    `RawSolve` holds device futures.  Callers that overlap host work with
    the solve must fence with `jax.block_until_ready` (see
    `service.scheduler`) before converting results host-side.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.maximizer import MaximizerConfig, SolveResult
from repro.engines.base import RawSolve, resolve_engine
from repro.instances.buckets import BucketedInstance
from repro.instances.deltas import ScatterPlan

__all__ = [
    "RawSolve",
    "compiled_solver",
    "compiled_solver_fixed_sigma",
    "compiled_batch_solver",
    "compiled_batch_solver_fixed_sigma",
    "to_solve_result",
    "to_solve_results",
    "compile_cache_report",
    "device_put_instance",
    "apply_scatter_plan",
    "instance_nbytes",
]


def _raw_solve(
    inst: BucketedInstance,
    lam0: jax.Array,
    cfg: MaximizerConfig,
    normalize: bool,
    fused_oracle: bool = False,
    sigma_sq: Optional[jax.Array] = None,
    engine: str = "agd",
) -> RawSolve:
    """Full solve as a pure traced function of the instance, on the named
    engine (`repro.engines`).  ``"agd"`` is the paper's continuation solve
    (the body formerly inlined here, now `repro.engines.agd`); ``"pdhg"`` is
    the structured primal-dual engine.  Both share the RawSolve contract and
    the [m*J] dual space, so everything downstream (caches, pools, sessions,
    warm starts, sigma reuse) is engine-agnostic.

    ``sigma_sq=None`` runs the power iteration (~cfg.power_iters oracle
    calls); a traced scalar skips it and reuses the caller's estimate — the
    warm-cadence path (`SolveSession`) passes the previous solve's value when
    the coefficients haven't drifted, since sigma_max(A) is a function of A
    alone (see `compiled_solver_fixed_sigma`) and not of the engine.
    """
    return resolve_engine(engine).raw_solve(
        inst,
        lam0,
        cfg,
        normalize=normalize,
        fused_oracle=fused_oracle,
        sigma_sq=sigma_sq,
    )


# One compiled entry point per (MaximizerConfig, normalize, fused_oracle,
# engine) tuple (the config is a hashable frozen dataclass); within each,
# XLA's jit cache keys executables on the instance's bucket shapes.  Shared
# process-wide across sessions, schedulers and pools.
_SINGLE: dict[tuple, object] = {}
_SINGLE_SIGMA: dict[tuple, object] = {}
_BATCH: dict[tuple, object] = {}
_BATCH_SIGMA: dict[tuple, object] = {}


def _shape_key(inst) -> str:
    """Short stable digest of a pytree's leaf shapes — the compile-cache key
    XLA re-keys executables on, rendered as a telemetry label."""
    shapes = tuple(tuple(l.shape) for l in jax.tree.leaves(inst))
    return hashlib.md5(repr(shapes).encode()).hexdigest()[:10]


def _instrument(fn, entry: str):
    """Wrap a jitted entry point with compile-cache hit/miss accounting.

    jax traces + compiles synchronously inside the dispatching call, so when
    the jit cache grows across a call its wall time is (almost entirely) the
    trace+compile cost of the new shape key; cached dispatches are recorded
    as hits.  The underlying jitted fn stays reachable (`_jit_fn`) for
    `compile_cache_report` and `.lower()` users.
    """

    def wrapper(*args):
        reg = telemetry.get_registry()
        try:
            before = fn._cache_size()
        except AttributeError:
            before = None
        t0 = time.perf_counter()
        out = fn(*args)
        dt = time.perf_counter() - t0
        try:
            after = fn._cache_size()
        except AttributeError:
            after = None
        if before is not None and after is not None and after > before:
            key = _shape_key(args[0])
            reg.inc("engine_compiles_total", 1, entry=entry)
            reg.inc(
                "engine_compile_seconds_total", dt, entry=entry, shapes=key
            )
            reg.observe("engine_compile_seconds", dt, entry=entry)
        else:
            reg.inc("engine_cache_hits_total", 1, entry=entry)
        return out

    wrapper._jit_fn = fn
    return wrapper


def compiled_solver(
    cfg: MaximizerConfig, normalize: bool = False, fused_oracle: bool = False,
    engine: str = "agd",
):
    """Jitted `(instance, lam0) -> RawSolve` for one tenant."""
    key = (cfg, normalize, fused_oracle, engine)
    fn = _SINGLE.get(key)
    if fn is None:
        fn = _instrument(
            jax.jit(
                lambda inst, lam0: _raw_solve(
                    inst, lam0, cfg, normalize, fused_oracle, engine=engine
                )
            ),
            "single",
        )
        _SINGLE[key] = fn
    return fn


def compiled_solver_fixed_sigma(
    cfg: MaximizerConfig, normalize: bool = False, fused_oracle: bool = False,
    engine: str = "agd",
):
    """Jitted `(instance, lam0, sigma_sq) -> RawSolve` skipping power iteration.

    The power iteration costs ~`cfg.power_iters` (default 30) oracle calls
    per solve, each a full pass over every slab — a large fraction of a warm
    cadence's total work.  sigma_max(A) depends only on the coefficients, so
    when a cadence's drift is below the session's threshold the previous
    estimate is still (approximately) valid and the warm solve skips the
    recomputation entirely.  `RawSolve.sigma_sq` echoes the passed value.
    """
    key = (cfg, normalize, fused_oracle, engine)
    fn = _SINGLE_SIGMA.get(key)
    if fn is None:
        fn = _instrument(
            jax.jit(
                lambda inst, lam0, sigma_sq: _raw_solve(
                    inst, lam0, cfg, normalize, fused_oracle,
                    sigma_sq=sigma_sq, engine=engine,
                )
            ),
            "single_sigma",
        )
        _SINGLE_SIGMA[key] = fn
    return fn


def compiled_batch_solver(
    cfg: MaximizerConfig, normalize: bool = False, fused_oracle: bool = False,
    engine: str = "agd",
):
    """Jitted, vmapped `(stacked_instance, lam0s[B, :]) -> RawSolve` pool kernel.

    All per-stage work runs lockstep across the tenant batch; with early
    stopping enabled the batch exits a stage once *every* tenant has converged.
    """
    key = (cfg, normalize, fused_oracle, engine)
    fn = _BATCH.get(key)
    if fn is None:
        fn = _instrument(
            jax.jit(
                jax.vmap(
                    lambda inst, lam0: _raw_solve(
                        inst, lam0, cfg, normalize, fused_oracle,
                        engine=engine,
                    )
                )
            ),
            "batch",
        )
        _BATCH[key] = fn
    return fn


def compiled_batch_solver_fixed_sigma(
    cfg: MaximizerConfig, normalize: bool = False, fused_oracle: bool = False,
    engine: str = "agd",
):
    """Jitted, vmapped `(stacked_instance, lam0s[B, :], sigma_sqs[B]) ->
    RawSolve` — the batched counterpart of `compiled_solver_fixed_sigma`.

    Gives batched warm tenants the same sigma-reuse fast path solo dispatch
    has: each lane skips its power iteration (~cfg.power_iters oracle calls)
    and runs from its own carried sigma_max(A)^2 estimate.  The scheduler
    dispatches a warm shape-group here only when *every* member's estimate is
    clean (`SolveSession.sigma_reuse_ready`); mixed groups fall back to
    `compiled_batch_solver`.  `RawSolve.sigma_sq` echoes the per-lane values.
    """
    key = (cfg, normalize, fused_oracle, engine)
    fn = _BATCH_SIGMA.get(key)
    if fn is None:
        fn = _instrument(
            jax.jit(
                jax.vmap(
                    lambda inst, lam0, sigma_sq: _raw_solve(
                        inst, lam0, cfg, normalize, fused_oracle,
                        sigma_sq=sigma_sq, engine=engine,
                    )
                )
            ),
            "batch_sigma",
        )
        _BATCH_SIGMA[key] = fn
    return fn


def to_solve_result(raw: RawSolve) -> SolveResult:
    """Host-side `SolveResult` view of a (single-tenant) RawSolve."""
    return SolveResult(
        lam=raw.lam,
        x_slabs=raw.x_slabs,
        g=raw.g,
        stats=raw.stats,
        sigma_sq=raw.sigma_sq,
        steps=tuple(float(e) for e in raw.etas),
        iters_used=tuple(int(i) for i in raw.iters),
        restarts=int(raw.restarts),
    )


def to_solve_results(raw: RawSolve) -> list[SolveResult]:
    """Split a batched RawSolve (leading tenant axis) into per-tenant results."""
    batch = int(raw.lam.shape[0])
    out = []
    for b in range(batch):
        take = lambda a: a[b]
        out.append(
            SolveResult(
                lam=raw.lam[b],
                x_slabs=tuple(x[b] for x in raw.x_slabs),
                g=raw.g[b],
                stats=tuple(jax.tree.map(take, st) for st in raw.stats),
                sigma_sq=raw.sigma_sq[b],
                steps=tuple(float(e) for e in raw.etas[b]),
                iters_used=tuple(int(i) for i in raw.iters[b]),
                restarts=int(raw.restarts[b]),
            )
        )
    return out


def device_put_instance(inst: BucketedInstance) -> BucketedInstance:
    """Upload every slab leaf to device once (the O(nnz) bootstrap transfer).

    The returned instance is leaf-wise `jax.Array` and OWNS its buffers:
    on the CPU backend `jnp.asarray` may zero-copy alias an aligned numpy
    slab, which the ingestor keeps mutating in place — an aliased "device
    copy" would silently track later host edits (corrupting the generation
    the resident instance is supposed to be pinned at, and any published
    `DualSnapshot` holding it), so numpy leaves are copied first.
    Subsequent cadences keep the instance resident and mutate it with
    `apply_scatter_plan` (O(delta) transfer, functional updates).
    """
    return jax.tree.map(
        lambda leaf: jnp.asarray(
            leaf.copy() if isinstance(leaf, np.ndarray) else leaf
        ),
        inst,
    )


def _expand_runs(op) -> tuple[jax.Array, jax.Array]:
    """Device-side expansion of a BucketScatter's run encoding to cell coords.

    Only the [R] run descriptors are uploaded; the per-cell (rows, slots)
    addresses are rebuilt on device with shape-static `jnp.repeat`
    (total_repeat_length = num_cells, known on host), so index transfer is
    O(runs) while the scatter itself stays per-cell.
    """
    k = op.num_cells
    run_rows = jnp.asarray(op.run_rows)
    run_slots = jnp.asarray(op.run_slots)
    run_lengths = jnp.asarray(op.run_lengths)
    run_of = jnp.repeat(
        jnp.arange(run_rows.size, dtype=jnp.int32),
        run_lengths,
        total_repeat_length=k,
    )
    starts = jnp.cumsum(run_lengths) - run_lengths
    rows = run_rows[run_of]
    slots = run_slots[run_of] + (jnp.arange(k, dtype=jnp.int32) - starts[run_of])
    return rows, slots


def apply_scatter_plan(
    inst: BucketedInstance, plan: ScatterPlan
) -> BucketedInstance:
    """Replay one `ScatterPlan` on device-resident slabs with `.at[].set`.

    Only the plan's compact run/value arrays cross the host→device boundary
    (contiguous slot spans are run-length encoded; see
    `instances.deltas.BucketScatter`); the slabs themselves never round-trip.
    Touched cells receive the exact host-slab values the plan carries, so the
    result is bit-for-bit equal to re-uploading the mutated host slabs — at
    O(delta) instead of O(nnz) cost.
    """
    buckets = list(inst.buckets)
    for op in plan.ops:
        b = buckets[op.bucket]
        rows, slots = _expand_runs(op)
        # Delta payloads are gathered from the ingestor's host slabs, so they
        # already carry the storage dtype (bf16 slabs ship bf16 cells); the
        # explicit casts below are no-op safeties that keep the replayed slab
        # dtype-identical to a re-upload.  `dataclasses.replace` preserves the
        # per-bucket quantisation scales untouched (int8 ingest is rejected
        # upstream, but the invariant costs nothing to keep).
        buckets[op.bucket] = dataclasses.replace(
            b,
            idx=jnp.asarray(b.idx).at[rows, slots].set(jnp.asarray(op.idx)),
            coeff=jnp.asarray(b.coeff).at[:, rows, slots].set(
                jnp.asarray(op.coeff, dtype=jnp.asarray(b.coeff).dtype)
            ),
            cost=jnp.asarray(b.cost).at[rows, slots].set(
                jnp.asarray(op.cost, dtype=jnp.asarray(b.cost).dtype)
            ),
            mask=jnp.asarray(b.mask).at[rows, slots].set(
                jnp.asarray(op.mask, dtype=jnp.asarray(b.mask).dtype)
            ),
        )
    rhs = inst.rhs if plan.rhs is None else jnp.asarray(plan.rhs)
    return dataclasses.replace(inst, buckets=tuple(buckets), rhs=rhs)


def instance_nbytes(inst: BucketedInstance) -> int:
    """Total slab bytes — what a full (re-)upload of the instance transfers."""
    return int(
        sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(inst))
    )


def compile_cache_report() -> dict[str, int]:
    """Number of compiled executables per entry point (shape-keyed reuse)."""
    report = {}
    for name, cache in (
        ("single", _SINGLE),
        ("single_sigma", _SINGLE_SIGMA),
        ("batch", _BATCH),
        ("batch_sigma", _BATCH_SIGMA),
    ):
        for (cfg, normalize, fused_oracle, engine), fn in cache.items():
            key = (
                f"{name}:engine={engine},gammas={cfg.gammas},"
                f"iters={cfg.iters_per_stage},"
                f"tol=({cfg.tol_grad},{cfg.tol_viol}),norm={normalize},"
                f"fused={fused_oracle}"
            )
            try:
                report[key] = fn._cache_size()
            except AttributeError:  # jax version without _cache_size
                report[key] = -1
    return report
