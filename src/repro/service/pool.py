"""Batched multi-tenant solve pool: many small LPs in one vmapped solve.

Production serving rarely has one giant LP — it has many *tenants* (markets,
scenario variants, A/B arms) whose instances are small enough that a single
solve underutilises the accelerator.  Following the batched-LP line of work
(arXiv:1802.08557), tenants whose packed instances share identical bucket
shapes are stacked leaf-wise along a new leading axis and solved by ONE
`jax.vmap`-ed continuation solve: every AGD iteration then performs the
gather / segment-sum / projection for all tenants simultaneously, amortising
kernel-launch and scheduling overhead across the batch.

Shape identity is the grouping key (`shape_signature`); the scheduler falls
back to per-tenant solves for singleton groups.  The delta-ingest layer's
shape-preserving updates are what keep a tenant inside its pool group day
over day.

Invariants:

  * **Shape identity is the batching currency** — `stack_instances` refuses
    mixed signatures; `ServiceConfig.row_headroom` is what buys tenants a
    stable signature across deltas, and the vmapped solve is how the fleet
    monetises it.
  * **Stacking is a device op** — when the per-tenant leaves are already
    device-resident (`service.engine.device_put_instance`), `jnp.stack`
    runs on device: batching adds no host→device traffic on top of the
    O(delta) scatter plans.
  * **Dispatch/fence split** — `solve_async` only dispatches the vmapped
    executable and returns a `RawSolve` of device futures; `finish` fences
    (`jax.block_until_ready`) and converts host-side.  `solve` composes the
    two; the scheduler's double-buffered pipeline keeps them apart so host
    ingestion of the next cadence overlaps the in-flight batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.maximizer import MaximizerConfig, SolveResult
from repro.instances.buckets import BucketedInstance
from repro.service.engine import (
    RawSolve,
    compiled_batch_solver,
    compiled_batch_solver_fixed_sigma,
    to_solve_results,
)

__all__ = [
    "shape_signature",
    "stack_instances",
    "BatchedSolvePool",
]


def shape_signature(inst: BucketedInstance) -> tuple:
    """Hashable key identifying pytree structure + leaf shapes/dtypes.

    Two instances with equal signatures can be stacked and solved by the same
    compiled executable; the static fields (bucket lengths, dimensions) are
    part of the treedef and hence of the signature.
    """
    leaves, treedef = jax.tree.flatten(inst)
    return (
        str(treedef),
        tuple((tuple(l.shape), np.dtype(l.dtype).name) for l in leaves),
    )


def stack_instances(insts: Sequence[BucketedInstance]) -> BucketedInstance:
    """Stack shape-identical instances leaf-wise along a new tenant axis."""
    if not insts:
        raise ValueError("stack_instances: empty batch")
    sig0 = shape_signature(insts[0])
    for i, inst in enumerate(insts[1:], start=1):
        if shape_signature(inst) != sig0:
            raise ValueError(
                f"instance {i} has a different shape signature; "
                "group tenants with shape_signature() before stacking"
            )
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *insts)


@dataclasses.dataclass
class BatchedSolvePool:
    """Solves a batch of shape-identical tenant instances in one vmapped call."""

    config: MaximizerConfig = dataclasses.field(default_factory=MaximizerConfig)
    # device-side Jacobi row normalization inside the solve (see engine)
    normalize: bool = False
    # one-pass fused dual oracle inside the vmapped solve (see engine);
    # vmap adds the tenant axis outside the per-bucket oracle launches
    fused_oracle: bool = False
    # solver engine the whole batch runs on ("agd" | "pdhg"); one vmapped
    # executable runs one engine's program, so the scheduler keys its shape
    # groups on the routed engine
    engine: str = "agd"

    def solve_async(
        self,
        instances: Sequence[BucketedInstance],
        lam0s: Optional[Sequence[Optional[jax.Array]]] = None,
        sigma_sqs: Optional[Sequence[float]] = None,
    ) -> RawSolve:
        """Dispatch one batched solve; `lam0s[i] = None` cold-starts that tenant.

        ``sigma_sqs`` — one carried sigma_max(A)^2 estimate per tenant —
        routes the batch through the fixed-sigma vmapped solver: every lane
        skips its power iteration and runs from its own estimate (the batched
        counterpart of `SolveSession.dispatch_raw`'s solo reuse path).  All
        tenants must supply one (partial reuse inside a single vmapped call
        would make the skip lane-divergent); the scheduler partitions groups
        by reuse-readiness instead.

        Returns immediately with a `RawSolve` of device futures — pair with
        `finish` (or `jax.block_until_ready`) to consume results.  Host work
        scheduled between the two overlaps the device solve.
        """
        stacked = stack_instances(instances)
        dual_dim = instances[0].dual_dim
        batch = len(instances)
        if lam0s is None:
            lam0s = [None] * batch
        if len(lam0s) != batch:
            raise ValueError("lam0s must match the instance batch")
        rows = [
            jnp.zeros((dual_dim,), jnp.float32) if l is None else jnp.asarray(l)
            for l in lam0s
        ]
        for i, r in enumerate(rows):
            if r.shape != (dual_dim,):
                raise ValueError(
                    f"lam0s[{i}] has shape {r.shape}, expected ({dual_dim},)"
                )
        reg = telemetry.get_registry()
        reg.inc("pool_batched_solves_total", 1)
        reg.inc("pool_tenant_solves_total", batch)
        reg.observe("pool_batch_size", batch)
        # Padded slab cells per tenant in this batch's shape group — the
        # denominator of padding-waste ratios (the scheduler supplies the
        # nnz numerator; computing active cells here would force a device
        # sync on the mask leaves mid-dispatch).
        cells = sum(
            int(np.prod(b.idx.shape)) for b in instances[0].buckets
        )
        reg.set_gauge("pool_padded_cells", cells * batch)
        if sigma_sqs is not None:
            if len(sigma_sqs) != batch:
                raise ValueError("sigma_sqs must match the instance batch")
            if any(s is None for s in sigma_sqs):
                raise ValueError(
                    "sigma_sqs must be provided for every tenant in the "
                    "batch; split reuse-ready tenants into their own group"
                )
            reg.inc("pool_sigma_reuse_solves_total", batch)
            return compiled_batch_solver_fixed_sigma(
                self.config, self.normalize, self.fused_oracle, self.engine
            )(
                stacked,
                jnp.stack(rows),
                jnp.asarray(list(sigma_sqs), jnp.float32),
            )
        return compiled_batch_solver(
            self.config, self.normalize, self.fused_oracle, self.engine
        )(stacked, jnp.stack(rows))

    @staticmethod
    def finish(raw: RawSolve) -> list[SolveResult]:
        """Fence a `solve_async` dispatch and split it into per-tenant results."""
        jax.block_until_ready(raw)
        return to_solve_results(raw)

    def solve(
        self,
        instances: Sequence[BucketedInstance],
        lam0s: Optional[Sequence[Optional[jax.Array]]] = None,
        sigma_sqs: Optional[Sequence[float]] = None,
    ) -> list[SolveResult]:
        """One blocking batched solve (`solve_async` + `finish`)."""
        return self.finish(self.solve_async(instances, lam0s, sigma_sqs))
