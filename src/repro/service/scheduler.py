"""Multi-tenant cadence scheduler: ingest, group, batch, solve, report.

One `Scheduler` owns all tenant `SolveSession`s and drives a cadence:

  1. apply each tenant's `InstanceDelta` (O(delta) in-place when headroom
     allows — see `repro.instances.deltas`);
  2. partition tenants by `(shape_signature, warm/cold)` — shape-identical
     tenants in the same start mode can share one compiled executable;
  3. groups of >= `batch_min` tenants are solved by ONE vmapped call through
     `BatchedSolvePool`; the rest solve individually (still sharing the
     shape-keyed compile cache);
  4. every tenant's session absorbs its result and emits its drift-SLA report.

The scheduler is deliberately synchronous and deterministic — async ingestion
and cross-cadence checkpointing are ROADMAP follow-ons.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.instances.deltas import DeltaReport, InstanceDelta
from repro.instances.generator import EdgeListInstance
from repro.service.engine import compiled_batch_solver, compile_cache_report, to_solve_results
from repro.service.pool import shape_signature, stack_instances
from repro.service.session import ServiceConfig, SolveSession

__all__ = ["CadenceReport", "Scheduler"]


@dataclasses.dataclass
class CadenceReport:
    """Outcome of one `Scheduler.run_cadence` call."""

    reports: dict[str, dict[str, Any]]  # per-tenant solve reports
    ingest: dict[str, DeltaReport]  # per-tenant delta reports
    batched_groups: list[list[str]]  # tenant groups solved in one vmapped call
    solo_tenants: list[str]
    compile_cache: dict[str, int]

    @property
    def batched_fraction(self) -> float:
        n = len(self.reports)
        return sum(len(g) for g in self.batched_groups) / max(n, 1)


class Scheduler:
    def __init__(self, config: Optional[ServiceConfig] = None, *, batch_min: int = 2):
        self.config = config or ServiceConfig()
        self.batch_min = max(2, int(batch_min))
        self.sessions: dict[str, SolveSession] = {}

    def add_tenant(self, name: str, inst: EdgeListInstance) -> SolveSession:
        if name in self.sessions:
            raise ValueError(f"tenant {name!r} already registered")
        s = SolveSession(name, inst, self.config)
        self.sessions[name] = s
        return s

    def run_cadence(
        self,
        deltas: Optional[dict[str, InstanceDelta]] = None,
        *,
        force_cold: bool = False,
    ) -> CadenceReport:
        """Ingest deltas and solve every tenant once."""
        ingest: dict[str, DeltaReport] = {}
        for name, delta in (deltas or {}).items():
            ingest[name] = self.sessions[name].ingest(delta)

        # group tenants that can share one vmapped executable
        groups: dict[tuple, list[str]] = {}
        starts: dict[str, tuple] = {}
        for name, s in self.sessions.items():
            cold, reason, lam0 = s._start_state(force_cold)
            starts[name] = (cold, reason, lam0)
            key = (shape_signature(s.instance()), cold)
            groups.setdefault(key, []).append(name)

        reports: dict[str, dict[str, Any]] = {}
        batched_groups: list[list[str]] = []
        solo: list[str] = []
        for (_, cold), names in groups.items():
            if len(names) >= self.batch_min:
                batched_groups.append(list(names))
                cfg = self.config.cold if cold else self.config.warm
                stacked = stack_instances(
                    [self.sessions[n].instance() for n in names]
                )
                lam0s = jnp.stack([starts[n][2] for n in names])
                raw = compiled_batch_solver(cfg, self.config.normalize)(
                    stacked, lam0s
                )
                for name, res in zip(names, to_solve_results(raw)):
                    reports[name] = self.sessions[name].absorb(
                        res,
                        cold=cold,
                        cold_reason=starts[name][1],
                        batched=True,
                    )
            else:
                solo.extend(names)
        for name in solo:
            _, report = self.sessions[name].solve(force_cold=force_cold)
            reports[name] = report

        return CadenceReport(
            reports=reports,
            ingest=ingest,
            batched_groups=batched_groups,
            solo_tenants=solo,
            compile_cache=compile_cache_report(),
        )
