"""Multi-tenant cadence scheduler: ingest, group, batch, solve, report.

One `Scheduler` owns all tenant `SolveSession`s and drives a cadence:

  1. apply each tenant's `InstanceDelta` on the host slabs (O(delta) in-place
     when headroom allows — see `repro.instances.deltas`), queueing the
     emitted scatter plans for the device-resident copies;
  2. partition tenants by `(shape_signature, warm/cold, warm gamma schedule,
     sigma-reuse readiness)` — shape-identical tenants in the same start
     mode, at the same warm-escalation level, with uniform power-iteration
     skip eligibility can share one compiled executable;
  3. groups of >= `batch_min` tenants are solved by ONE vmapped call through
     the shared engine; the rest solve individually (still sharing the
     shape-keyed compile cache).  Solves run against device-resident slabs,
     so the per-cadence host→device transfer is the scatter plans, O(delta);
  4. every tenant's session absorbs its result and emits its drift-SLA report.

`run_cadence` is the synchronous single-step driver.  `run_pipeline` is the
double-buffered multi-cadence driver: solves are *dispatched* (jax dispatch is
asynchronous — the returned `RawSolve` holds device futures), then the NEXT
cadence's delta validation, host slab surgery and scatter-plan construction
run on the host while the devices are still solving, and only then does the
scheduler fence with `jax.block_until_ready` and absorb results.  Steady
state, the host ingest cost is hidden entirely behind the device solve.

Fencing invariants of the overlap:

  * Host ingestion for cadence t+1 mutates only the host slabs; the device
    copies were materialised at dispatch time and are immutable jax arrays,
    so the in-flight solve of cadence t can never observe cadence t+1 edits.
  * A delta rejected during the overlap raises inside `DeltaIngestor.apply`
    *before* any mutation: the host slabs, the scatter-plan queue and the
    per-tenant generation counter are untouched, so nothing half-applies and
    cadence t+1 simply solves the last good state (the rejection is reported
    in `CadenceReport.ingest_errors`).
  * Results are absorbed only after the fence, so drift metering always
    compares completed cadence t against completed cadence t-1.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro import telemetry
from repro.engines.selector import EngineSelector
from repro.instances.deltas import DeltaReport, InstanceDelta
from repro.instances.generator import EdgeListInstance
from repro.service.engine import (
    compile_cache_report,
    to_solve_result,
)
from repro.service.pool import BatchedSolvePool, shape_signature
from repro.service.session import ServiceConfig, SolveSession

__all__ = ["CadenceReport", "Scheduler"]


@dataclasses.dataclass
class CadenceReport:
    """Outcome of one scheduler cadence (`run_cadence` / `run_pipeline` step)."""

    reports: dict[str, dict[str, Any]]  # per-tenant solve reports
    ingest: dict[str, DeltaReport]  # per-tenant delta reports
    batched_groups: list[list[str]]  # tenant groups solved in one vmapped call
    solo_tenants: list[str]
    compile_cache: dict[str, int]
    # deltas rejected during ingestion (pipeline mode): tenant -> error; the
    # tenant's state is untouched and it solved the last good generation
    ingest_errors: dict[str, str] = dataclasses.field(default_factory=dict)
    # True when this cadence's ingest ran overlapped with the previous solve
    overlapped: bool = False

    @property
    def batched_fraction(self) -> float:
        """Fraction of tenants solved inside a vmapped pool group."""
        n = len(self.reports)
        return sum(len(g) for g in self.batched_groups) / max(n, 1)

    @property
    def upload_bytes(self) -> int:
        """Total host→device bytes this cadence's solves transferred."""
        return sum(r.get("upload_bytes") or 0 for r in self.reports.values())


class Scheduler:
    """Owns all tenant sessions and drives synchronous or pipelined cadences."""

    def __init__(
        self,
        config: Optional[ServiceConfig] = None,
        *,
        batch_min: int = 2,
        dual_store=None,
    ):
        self.config = config or ServiceConfig()
        self.batch_min = max(2, int(batch_min))
        self.sessions: dict[str, SolveSession] = {}
        # Per-tenant engine routing policy (`config.engine == "auto"`):
        # the scheduler owns it so observations from every tenant land in
        # one place and the state checkpoints with the service
        # (meta["engine_selector"]).  Constructed even when the engine is
        # pinned — attaching costs nothing and a config flip mid-life
        # starts routing from whatever history accumulated.
        self.engine_selector = EngineSelector()
        # Attached allocation-serving store (repro.serving.DualStore): when
        # set, every tenant session publishes its duals after absorb, so
        # requests are answered from the last COMPLETED cadence while the
        # next one is still in flight (the store's snapshot swap is the
        # generation fence; see docs/serving.md).
        self.dual_store = dual_store

    def add_tenant(self, name: str, inst: EdgeListInstance) -> SolveSession:
        """Register a tenant with its bootstrap instance (cold first solve)."""
        if name in self.sessions:
            raise ValueError(f"tenant {name!r} already registered")
        s = SolveSession(name, inst, self.config)
        s.dual_store = self.dual_store
        s.engine_selector = self.engine_selector
        self.sessions[name] = s
        return s

    # -- cadence phases ------------------------------------------------------

    def _ingest_all(
        self, deltas: Optional[dict[str, InstanceDelta]], *, strict: bool
    ) -> tuple[dict[str, DeltaReport], dict[str, str]]:
        """Apply per-tenant deltas on the host; collect rejections if not strict."""
        ingest: dict[str, DeltaReport] = {}
        errors: dict[str, str] = {}
        for name, delta in (deltas or {}).items():
            try:
                ingest[name] = self.sessions[name].ingest(delta)
            except (KeyError, ValueError) as e:
                if strict:
                    raise
                errors[name] = f"{type(e).__name__}: {e}"
        return ingest, errors

    def _dispatch(self, force_cold: bool):
        """Group tenants and dispatch every solve; returns device futures.

        Nothing here blocks on device results: the batched/solo `RawSolve`s
        are asynchronous, which is what `run_pipeline` overlaps host
        ingestion against.
        """
        groups: dict[tuple, list[str]] = {}
        starts: dict[str, tuple] = {}
        for name, s in self.sessions.items():
            cold, reason, lam0 = s._start_state(force_cold)
            # Snapshot NOW everything absorb will need after the fence: the
            # cost drift drained for THIS cadence, a primal unpacker frozen
            # over this generation's occupancy maps, and the sigma dirty
            # count the solve's A corresponds to.  Deltas ingested during
            # the overlap then cannot be attributed to — or corrupt the
            # drift metering / sigma-cache validity of — the in-flight solve.
            dc_norm = s.ingestor.drain_cost_drift()
            # The engine is part of the dispatch decision: resolved HERE
            # (possibly through the selector) so the choice is frozen with
            # the rest of the start snapshot and reported after the fence.
            engine = s.engine_choice()
            starts[name] = (
                cold,
                reason,
                lam0,
                dc_norm,
                s.ingestor.primal_unpacker(),
                s._dirty_count,
                engine,
            )
            # Batching key beyond shape+mode: the escalation-chosen warm
            # gamma schedule (tenants at different escalation levels run
            # different continuation tails — different executables),
            # sigma-reuse readiness (the fixed-sigma vmapped solver skips
            # the power iteration for ALL lanes, so a group must be
            # uniformly ready or uniformly not), and the routed engine (a
            # vmapped executable runs ONE engine's program).
            reuse = (not cold) and s.sigma_reuse_ready(dc_norm)
            warm_key = None if cold else s.warm_config().gammas
            key = (
                shape_signature(s.instance()), cold, warm_key, reuse, engine,
            )
            groups.setdefault(key, []).append(name)

        batched: list[tuple[list[str], bool, Any, bool]] = []
        solo: list[tuple[str, bool, Any, bool]] = []
        for (_, cold, _, reuse, engine), names in groups.items():
            cfg = (
                self.config.cold
                if cold
                else self.sessions[names[0]].warm_config()
            )
            if len(names) >= self.batch_min:
                pool = BatchedSolvePool(
                    cfg,
                    normalize=self.config.normalize,
                    fused_oracle=self.config.fused_oracle,
                    engine=engine,
                )
                raw = pool.solve_async(
                    [self.sessions[n].device_instance() for n in names],
                    [starts[n][2] for n in names],
                    sigma_sqs=(
                        [self.sessions[n]._sigma_sq for n in names]
                        if reuse
                        else None
                    ),
                )
                self._record_group_padding(names)
                batched.append((list(names), cold, raw, reuse))
            else:
                for name in names:
                    # dispatch_raw owns the per-tenant power-iteration skip
                    # on quiet warm cadences (recomputing `reuse` there is
                    # equivalent — same inputs)
                    raw, solo_reuse = self.sessions[name].dispatch_raw(
                        cfg, starts[name][2], starts[name][3], cold=cold,
                        engine=engine,
                    )
                    solo.append((name, cold, raw, solo_reuse))
        # Serving capture runs after every dispatch path has synced its
        # device copy, so the captured instance + occupancy maps reflect
        # exactly the generation this cadence is solving; absorb publishes
        # the finished duals against that capture (None without a store).
        serving = {
            name: s.serving_capture() for name, s in self.sessions.items()
        }
        return batched, solo, starts, serving

    def _record_group_padding(self, names: Sequence[str]) -> None:
        """Padding waste of one vmapped group, from host-side occupancy.

        The pool itself records batch sizes and padded-cell counts; active
        cells per tenant are only known host-side (`DeltaIngestor.deg`), so
        the nnz-based waste fraction is recorded here without touching the
        device-resident slabs.
        """
        reg = telemetry.get_registry()
        cells = active = 0
        for n in names:
            ing = self.sessions[n].ingestor
            cells += sum(
                int(np.prod(b.idx.shape)) for b in ing.instance().buckets
            )
            active += ing.nnz
        if cells:
            reg.set_gauge(
                "pool_padding_waste",
                1.0 - active / cells,
                group=",".join(sorted(names)[:4]),
            )

    @staticmethod
    def _fence(dispatched) -> None:
        """Block until every dispatched solve's device work is complete."""
        batched, solo, _, _ = dispatched
        jax.block_until_ready(
            [raw for _, _, raw, _ in batched] + [raw for _, _, raw, _ in solo]
        )

    def _absorb(self, dispatched):
        """Fold finished solves into their sessions; build per-tenant reports."""
        batched, solo, starts, serving = dispatched
        reports: dict[str, dict[str, Any]] = {}
        batched_groups: list[list[str]] = []
        solo_names: list[str] = []
        for names, cold, raw, reuse in batched:
            batched_groups.append(list(names))
            for name, res in zip(names, BatchedSolvePool.finish(raw)):
                reports[name] = self.sessions[name].absorb(
                    res,
                    cold=cold,
                    cold_reason=starts[name][1],
                    batched=True,
                    dc_norm=starts[name][3],
                    unpack=starts[name][4],
                    sigma_reused=reuse,
                    dirty_count=starts[name][5],
                    serving=serving[name],
                    engine=starts[name][6],
                )
        for name, cold, raw, sigma_reused in solo:
            solo_names.append(name)
            reports[name] = self.sessions[name].absorb(
                to_solve_result(raw),
                cold=cold,
                cold_reason=starts[name][1],
                batched=False,
                dc_norm=starts[name][3],
                unpack=starts[name][4],
                sigma_reused=sigma_reused,
                dirty_count=starts[name][5],
                serving=serving[name],
                engine=starts[name][6],
            )
        return reports, batched_groups, solo_names

    # -- drivers -------------------------------------------------------------

    def run_cadence(
        self,
        deltas: Optional[dict[str, InstanceDelta]] = None,
        *,
        force_cold: bool = False,
    ) -> CadenceReport:
        """Ingest deltas and solve every tenant once (synchronous driver)."""
        t0 = time.perf_counter()
        with telemetry.span("cadence", driver="sync", tenants=len(self.sessions)):
            with telemetry.span("ingest"):
                ingest, _ = self._ingest_all(deltas, strict=True)
            with telemetry.span("dispatch"):
                dispatched = self._dispatch(force_cold)
            with telemetry.span("solve_fence"):
                self._fence(dispatched)
            with telemetry.span("absorb"):
                reports, batched_groups, solo = self._absorb(dispatched)
        self._record_cadence(time.perf_counter() - t0, overlapped=False)
        return CadenceReport(
            reports=reports,
            ingest=ingest,
            batched_groups=batched_groups,
            solo_tenants=solo,
            compile_cache=compile_cache_report(),
        )

    def run_pipeline(
        self,
        cadence_deltas: Sequence[Optional[dict[str, InstanceDelta]]],
        *,
        force_cold: bool = False,
    ) -> list[CadenceReport]:
        """Run several cadences with host ingest overlapped against device solves.

        ``cadence_deltas[t]`` are the deltas ingested *for* cadence t; while
        cadence t's solves run on device, cadence t+1's deltas are validated
        and applied on the host (scatter plans queued, device copies
        untouched).  Rejected deltas never half-apply — they surface in the
        next cadence's `ingest_errors` and that tenant solves its last good
        state.  Equivalent to a `run_cadence` loop, minus the host-ingest
        wall time.
        """
        deltas = list(cadence_deltas)
        reg = telemetry.get_registry()
        out: list[CadenceReport] = []
        with telemetry.span("pipeline_ingest", cadence_index=0):
            ingest, errors = self._ingest_all(
                deltas[0] if deltas else None, strict=False
            )
        if errors:
            reg.inc("scheduler_ingest_errors_total", len(errors))
        for t in range(len(deltas)):
            # cadences not yet dispatched, including this one — the host-side
            # backlog a stuck device solve would grow
            reg.set_gauge("scheduler_queue_depth", len(deltas) - t)
            t0 = time.perf_counter()
            with telemetry.span("cadence", driver="pipeline", index=t):
                with telemetry.span("dispatch"):
                    dispatched = self._dispatch(force_cold)
                t_dispatched = time.perf_counter()
                if t + 1 < len(deltas):
                    # the overlap: host-side validation + slab surgery + plan
                    # construction for cadence t+1 while cadence t solves
                    with telemetry.span("overlap_ingest", cadence_index=t + 1):
                        next_ingest, next_errors = self._ingest_all(
                            deltas[t + 1], strict=False
                        )
                else:
                    next_ingest, next_errors = {}, {}
                t_ingested = time.perf_counter()
                with telemetry.span("solve_fence"):
                    self._fence(dispatched)
                t_fenced = time.perf_counter()
                with telemetry.span("absorb"):
                    reports, batched_groups, solo = self._absorb(dispatched)
            # Overlap efficiency: what fraction of the device-solve window
            # (dispatch -> fence completion) the host spent doing next-cadence
            # ingest work.  1.0 means ingest was entirely hidden; ~0 means the
            # host sat idle (or there was nothing to ingest).
            solve_window = max(t_fenced - t_dispatched, 1e-9)
            overlap = min((t_ingested - t_dispatched) / solve_window, 1.0)
            reg.set_gauge("scheduler_overlap_efficiency", overlap)
            reg.inc(
                "scheduler_overlap_ingest_seconds_total",
                t_ingested - t_dispatched,
            )
            reg.inc("scheduler_solve_window_seconds_total", solve_window)
            if next_errors:
                reg.inc("scheduler_ingest_errors_total", len(next_errors))
            self._record_cadence(time.perf_counter() - t0, overlapped=t > 0)
            out.append(
                CadenceReport(
                    reports=reports,
                    ingest=ingest,
                    batched_groups=batched_groups,
                    solo_tenants=solo,
                    compile_cache=compile_cache_report(),
                    ingest_errors=errors,
                    overlapped=t > 0,
                )
            )
            ingest, errors = next_ingest, next_errors
        reg.set_gauge("scheduler_queue_depth", 0)
        return out

    def _record_cadence(self, wall_seconds: float, *, overlapped: bool) -> None:
        reg = telemetry.get_registry()
        reg.inc("scheduler_cadences_total", 1)
        reg.set_gauge("scheduler_tenants", len(self.sessions))
        reg.observe(
            "scheduler_cadence_seconds",
            wall_seconds,
            overlapped=str(overlapped).lower(),
        )

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> tuple[dict[str, Any], dict]:
        """(arrays, meta) of every tenant session, namespaced by tenant name.

        ``meta["telemetry"]`` carries the registry's cumulative counters
        (cadence totals, upload-bytes totals, rejection counts, ...), so a
        restarted service resumes its monotone series instead of silently
        resetting them to zero — restart-invariant rate queries downstream.
        """
        arrays: dict[str, Any] = {}
        meta: dict = {"tenants": {}}
        for name, s in self.sessions.items():
            s_arrays, s_meta = s.state_dict()
            for k, v in s_arrays.items():
                arrays[f"{name}/{k}"] = v
            meta["tenants"][name] = s_meta
        meta["telemetry"] = telemetry.get_registry().state_dict()
        meta["engine_selector"] = self.engine_selector.state_dict()
        return arrays, meta

    def load_state(self, arrays: dict[str, Any], meta: dict) -> None:
        """Rebuild all tenant sessions from `state_dict` output (warm resume)."""
        self.sessions = {}
        for name, s_meta in meta["tenants"].items():
            prefix = f"{name}/"
            s_arrays = {
                k[len(prefix):]: v
                for k, v in arrays.items()
                if k.startswith(prefix)
            }
            self.sessions[name] = SolveSession.from_state(
                self.config, s_arrays, s_meta
            )
            self.sessions[name].dual_store = self.dual_store
            self.sessions[name].engine_selector = self.engine_selector
        # older checkpoints (pre-telemetry) carry no counter state: keep zeros
        if "telemetry" in meta:
            telemetry.get_registry().load_state(meta["telemetry"])
        # pre-engine checkpoints carry no routing history: start exploring
        self.engine_selector.load_state(meta.get("engine_selector"))

    def save_checkpoint(self, manager, step: int, *, block: bool = False) -> None:
        """Persist every session through a `checkpoint.CheckpointManager`.

        Async by default (`block=False`): the state is snapshotted
        synchronously, the file write happens on the manager's background
        thread while the next cadence proceeds.
        """
        arrays, meta = self.state_dict()
        manager.save(step, arrays, block=block, meta=meta)

    def restore_checkpoint(self, manager, step: int) -> None:
        """Rebuild all sessions from a checkpoint; next cadence resumes warm."""
        arrays, meta = manager.restore_flat(step)
        self.load_state(arrays, meta)
