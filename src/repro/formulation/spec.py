"""FormulationSpec — the compiled, instance-attachable formulation record.

`Formulation.compile(instance)` lowers the declarative composition down to
this frozen, hashable spec and attaches it to `BucketedInstance.formulation`
(a *static* pytree field).  Because the spec is part of the treedef:

  * the shape-keyed jit caches in `service/engine.py` key executables on the
    formulation automatically (a capacity-cap tenant never shares a wrongly
    specialised executable with a matching tenant);
  * `MatchingObjective.__post_init__` sees it at trace time and resolves the
    per-bucket projections + term scales via `lower_spec` below — which is
    the entire dispatch mechanism: zero edits to maximizer, sharding or the
    service layer.

This module deliberately imports only the feasible-set catalog (never the
objective), so `core/objective.py` can lazy-import it without a cycle.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Union

from repro.core.projections import ProjectionMap
from repro.formulation.feasible import FeasibleSet

__all__ = ["FormulationSpec", "LoweredFormulation", "lower_spec"]


@dataclasses.dataclass(frozen=True)
class FormulationSpec:
    """Static compile output: per-bucket feasible sets + lowered term scales.

    `feasible` holds either one shared set (applied to every bucket) or one
    set per bucket, in bucket order.  All fields are hashable — required for
    a static pytree field.
    """

    feasible: tuple[FeasibleSet, ...]
    cost_scale: float = 1.0
    ridge_weight: float = 1.0
    name: str = "matching"


class LoweredFormulation(NamedTuple):
    projections: tuple[ProjectionMap, ...]  # one per bucket
    cost_scale: float
    ridge_weight: float
    name: str


def lower_spec(
    spec: FormulationSpec, instance=None, *, num_buckets: Union[int, None] = None
) -> LoweredFormulation:
    """Lower a spec to the per-bucket `ProjectionMap`s the oracle executes.

    `instance` (or `num_buckets`) fixes how a shared feasible set broadcasts;
    a per-bucket tuple must match the instance's bucket count exactly.
    """
    if num_buckets is None:
        num_buckets = len(instance.buckets) if instance is not None else None
    sets = spec.feasible
    if num_buckets is not None:
        if len(sets) == 1:
            sets = sets * num_buckets
        elif len(sets) != num_buckets:
            raise ValueError(
                f"formulation {spec.name!r} declares {len(spec.feasible)} "
                f"feasible sets for {num_buckets} buckets (give one shared "
                "set or exactly one per bucket)"
            )
    return LoweredFormulation(
        projections=tuple(s.lower() for s in sets),
        cost_scale=spec.cost_scale,
        ridge_weight=spec.ridge_weight,
        name=spec.name,
    )
