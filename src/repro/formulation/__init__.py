"""Operator-centric formulation layer (the paper's third pillar).

Composable problem descriptions over one dual oracle:

    from repro.formulation import Formulation, CappedSimplex

    comp = Formulation(feasible_sets=CappedSimplex(cap=0.5)).compile(packed)
    res = comp.solve(MaximizerConfig())              # unchanged Maximizer
    raw = compiled_solver(cfg)(comp.instance, lam0)  # unchanged service engine

A `Formulation(feasible_sets, terms, couplings)` lowers via `.compile` onto
the existing oracle/kernels: feasible sets to `ProjectionMap`s
(`FeasibleSet.lower()`), terms to oracle scales, couplings to an rhs
transform — packaged as a static `FormulationSpec` the `MatchingObjective`
shim resolves at trace time.  New constraint families need no solve-loop
changes; see docs/formulation.md for the catalog, lowering rules and worked
capacity-cap / fairness-floor examples.
"""
from repro.formulation.couplings import Coupling, PackedCoupling
from repro.formulation.feasible import (
    Box,
    BudgetPacedBox,
    CappedSimplex,
    FairnessFloor,
    FeasibleSet,
    Simplex,
)
from repro.formulation.formulation import (
    SCENARIOS,
    CompiledFormulation,
    Formulation,
    attach,
    budget_pacing_formulation,
    capacity_cap_formulation,
    fairness_floor_formulation,
    matching_formulation,
    scenario_formulation,
    strip,
)
from repro.formulation.spec import FormulationSpec, LoweredFormulation, lower_spec
from repro.formulation.terms import LinearCost, RidgeSmoothing, Term

__all__ = [
    "Coupling",
    "PackedCoupling",
    "Box",
    "BudgetPacedBox",
    "CappedSimplex",
    "FairnessFloor",
    "FeasibleSet",
    "Simplex",
    "SCENARIOS",
    "CompiledFormulation",
    "Formulation",
    "attach",
    "budget_pacing_formulation",
    "capacity_cap_formulation",
    "fairness_floor_formulation",
    "matching_formulation",
    "scenario_formulation",
    "strip",
    "FormulationSpec",
    "LoweredFormulation",
    "lower_spec",
    "LinearCost",
    "RidgeSmoothing",
    "Term",
]
