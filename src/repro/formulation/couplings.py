"""Coupling constraints — the shared rows A x <= b tying sources together.

The packed `BucketedInstance` already materialises the coupling block as its
[m, J]-shaped rhs plus the per-bucket coefficient slabs; a `Coupling`
primitive therefore lowers to an *rhs transform* applied once at compile
time, never to solve-loop changes.  Today one kind is supported:

  PackedCoupling(families, sense="le", rhs_scale) — the instance's packed
  coupling family block, optionally tightened/loosened by scaling b
  (e.g. rhs_scale=0.8 reserves 20% capacity headroom fleet-wide).

The dual ascent maximises over lam >= 0, which encodes `A x <= b`; an "eq"
or "ge" sense would need a sign-free dual block, which the maximizer does
not implement — compile rejects it rather than silently mis-solving.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.instances.buckets import BucketedInstance

__all__ = ["Coupling", "PackedCoupling", "resolve_couplings"]


class Coupling:
    """Marker base for coupling primitives (frozen, hashable subclasses)."""


@dataclasses.dataclass(frozen=True)
class PackedCoupling(Coupling):
    name: str = "packed"
    # expected number of constraint families; None = accept the instance's
    families: Optional[int] = None
    sense: str = "le"  # only "le" lowers onto the lam >= 0 dual ascent
    rhs_scale: float = 1.0

    def validate(self, instance: BucketedInstance) -> None:
        if self.sense != "le":
            raise ValueError(
                f"coupling {self.name!r}: sense={self.sense!r} is not "
                "lowerable — the dual ascent over lam >= 0 encodes 'le' rows"
            )
        if self.rhs_scale <= 0:
            raise ValueError(
                f"coupling {self.name!r}: rhs_scale={self.rhs_scale} must be > 0"
            )
        if (
            self.families is not None
            and self.families != instance.num_families
        ):
            raise ValueError(
                f"coupling {self.name!r} declares {self.families} families "
                f"but the instance packs {instance.num_families}"
            )


def resolve_couplings(
    couplings: Sequence[Coupling], instance: BucketedInstance
) -> float:
    """Validate the composition against the packed instance; return the
    combined rhs scale (compile applies it to `instance.rhs` once)."""
    scale = 1.0
    seen_packed = False
    for c in couplings:
        if not isinstance(c, PackedCoupling):
            raise ValueError(
                f"unsupported coupling {c!r}: only PackedCoupling lowers "
                "onto the bucketed-ELL layout"
            )
        if seen_packed:
            raise ValueError(
                "duplicate PackedCoupling: the packed instance has one "
                "coupling block; scale its rhs instead of repeating it"
            )
        seen_packed = True
        c.validate(instance)
        scale *= c.rhs_scale
    if not seen_packed:
        raise ValueError(
            "a Formulation needs exactly one PackedCoupling describing the "
            "instance's A x <= b block"
        )
    return scale
