"""Formulation — composable problem descriptions over one dual oracle.

The paper's third pillar replaces the schema-bound solver interface with
primitives: a `Formulation(feasible_sets, terms, couplings)` is a declarative
composition, and `.compile(instance)` lowers it onto the existing
oracle/kernel stack —

    feasible sets -> per-bucket ProjectionMap          (FeasibleSet.lower)
    terms         -> (cost_scale, ridge_weight) scalars (terms.resolve_terms)
    couplings     -> a one-time rhs transform           (couplings.resolve_couplings)

— packaged as a static `FormulationSpec` attached to the instance.  From
there every existing entry point dispatches it unchanged: `Maximizer` /
`DistributedMaximizer` via the `MatchingObjective` shim, and the whole
recurring-solve service via the engine's instance-pytree argument (the spec
is part of the treedef, so the shape-keyed compile caches key on it).

New constraint families therefore ship as a `FeasibleSet` (+ its `lower()`
projection) and nothing else — zero edits to `core/maximizer.py`,
`core/sharding.py` or `service/`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import jax

from repro import telemetry
from repro.core.maximizer import Maximizer, MaximizerConfig, SolveResult
from repro.core.objective import MatchingObjective
from repro.core.projections import ProjectionMap
from repro.formulation.couplings import Coupling, PackedCoupling, resolve_couplings
from repro.formulation.feasible import (
    BudgetPacedBox,
    CappedSimplex,
    FairnessFloor,
    FeasibleSet,
    Simplex,
)
from repro.formulation.spec import FormulationSpec, lower_spec
from repro.formulation.terms import LinearCost, RidgeSmoothing, Term, resolve_terms
from repro.instances.buckets import BucketedInstance

__all__ = [
    "Formulation",
    "CompiledFormulation",
    "attach",
    "strip",
    "matching_formulation",
    "capacity_cap_formulation",
    "fairness_floor_formulation",
    "budget_pacing_formulation",
    "scenario_formulation",
    "SCENARIOS",
]


def attach(
    instance: BucketedInstance, spec: FormulationSpec
) -> BucketedInstance:
    """Return the instance carrying `spec` as its static formulation field."""
    return dataclasses.replace(instance, formulation=spec)


def strip(instance: BucketedInstance) -> BucketedInstance:
    """Drop the formulation spec (e.g. for `core.sharding.instance_pspecs`,
    whose spec pytree is built formulation-free)."""
    if getattr(instance, "formulation", None) is None:
        return instance
    return dataclasses.replace(instance, formulation=None)


@dataclasses.dataclass(frozen=True)
class Formulation:
    """Declarative composition of feasible sets, objective terms, couplings.

    `feasible_sets` is one shared `FeasibleSet` or a per-bucket tuple.
    Defaults reproduce the ridge-regularized matching formulation exactly.
    """

    feasible_sets: Union[FeasibleSet, tuple[FeasibleSet, ...]] = Simplex()
    terms: tuple[Term, ...] = (LinearCost(), RidgeSmoothing())
    couplings: tuple[Coupling, ...] = (PackedCoupling(),)
    name: str = "matching"

    @property
    def feasible_tuple(self) -> tuple[FeasibleSet, ...]:
        fs = self.feasible_sets
        return (fs,) if isinstance(fs, FeasibleSet) else tuple(fs)

    def shared_projection(self) -> ProjectionMap:
        """Lower the (shared) feasible set without an instance — for callers
        like `DistributedMaximizer(projection=...)` and dry-run lowering."""
        sets = self.feasible_tuple
        if len(set(sets)) != 1:
            raise ValueError(
                f"formulation {self.name!r} has per-bucket feasible sets; "
                "compile against an instance to lower them"
            )
        sets[0].validate()
        return sets[0].lower()

    def compile(self, instance: BucketedInstance) -> "CompiledFormulation":
        """Lower the composition onto `instance` (spans/counters emitted).

        Returns a `CompiledFormulation` whose `.instance` carries the static
        spec — ready for `Maximizer`, the service engine's compiled solvers,
        and (spec-stripped, projection passed explicitly) the distributed
        layer.
        """
        reg = telemetry.get_registry()
        t0 = time.perf_counter()
        with telemetry.span(
            "formulation_compile",
            formulation=self.name,
            primitives=len(self.feasible_tuple),
        ):
            sets = self.feasible_tuple
            if not sets:
                raise ValueError("a Formulation needs at least one FeasibleSet")
            for s in sets:
                s.validate()
            cost_scale, ridge_weight = resolve_terms(self.terms)
            rhs_scale = resolve_couplings(self.couplings, instance)
            spec = FormulationSpec(
                feasible=sets,
                cost_scale=cost_scale,
                ridge_weight=ridge_weight,
                name=self.name,
            )
            # validates set-count vs bucket-count and that every set lowers
            lowered = lower_spec(spec, instance)
            rhs = instance.rhs if rhs_scale == 1.0 else instance.rhs * rhs_scale
            compiled_inst = dataclasses.replace(
                instance, rhs=rhs, formulation=spec
            )
        dt = time.perf_counter() - t0
        reg.inc("formulation_compiles_total", 1, formulation=self.name)
        reg.inc(
            "formulation_primitives_total", len(sets), formulation=self.name
        )
        reg.observe(
            "formulation_compile_seconds", dt, formulation=self.name
        )
        return CompiledFormulation(
            formulation=self,
            spec=spec,
            instance=compiled_inst,
            projections=lowered.projections,
        )


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledFormulation:
    """A formulation lowered against one packed instance.

    * `instance` — spec-carrying `BucketedInstance`; hand it to the existing
      `service.engine.compiled_solver`/`compiled_batch_solver`, a
      `SolveSession`, or `objective()` below.  The spec is static treedef
      metadata, so executables re-key on it automatically.
    * `projections` — the lowered per-bucket `ProjectionMap`s (the
      distributed layer takes the shared one via `projection=`).
    """

    formulation: Formulation
    spec: FormulationSpec
    instance: BucketedInstance
    projections: tuple[ProjectionMap, ...]

    @property
    def projection(self) -> ProjectionMap:
        """The shared projection (raises if the buckets differ)."""
        if len(set(self.projections)) != 1:
            raise ValueError(
                f"formulation {self.spec.name!r} lowers per-bucket "
                "projections; use .projections"
            )
        return self.projections[0]

    def sharded_instance(self) -> BucketedInstance:
        """Spec-stripped instance for `DistributedMaximizer`/`shard_instance`
        (their PartitionSpec pytrees are built formulation-free; pass
        `projection=self.projection` alongside)."""
        return strip(self.instance)

    def objective(self, **objective_kwargs) -> MatchingObjective:
        """The dual oracle for this compiled formulation (the shim resolves
        the attached spec; kwargs = fused_kernel/fused_oracle/include_rhs/...)."""
        return MatchingObjective(self.instance, **objective_kwargs)

    def solve(
        self,
        config: MaximizerConfig = MaximizerConfig(),
        lam0: Optional[jax.Array] = None,
        **objective_kwargs,
    ) -> SolveResult:
        """One-shot solve through the unchanged Maximizer."""
        return Maximizer(self.objective(**objective_kwargs), config).solve(lam0)


# ---------------------------------------------------------------------------
# Scenario presets — each new workload is a composition, not a solver change.
# ---------------------------------------------------------------------------


def matching_formulation(radius: float = 1.0) -> Formulation:
    """The paper's ridge-regularized matching LP, expressed as primitives.

    Compiling this against an instance reproduces the legacy
    `MatchingObjective` bit-for-bit (same projection, unit term scales,
    untouched rhs) — tests/test_formulation.py pins that parity.
    """
    return Formulation(feasible_sets=Simplex(radius), name="matching")


def capacity_cap_formulation(
    cap: float = 0.5, radius: float = 1.0, rhs_scale: float = 1.0
) -> Formulation:
    """Capacity caps: no destination takes more than `cap` of a source's
    unit allocation; optional fleet-wide rhs tightening."""
    return Formulation(
        feasible_sets=CappedSimplex(cap=cap, radius=radius),
        couplings=(PackedCoupling(rhs_scale=rhs_scale),),
        name="capacity_cap",
    )


def fairness_floor_formulation(
    floor: float = 0.02, hi: float = 1.0, radius: float = 1.0
) -> Formulation:
    """Fairness floors: every eligible edge gets at least `floor` allocation."""
    return Formulation(
        feasible_sets=FairnessFloor(floor=floor, hi=hi, radius=radius),
        name="fairness_floor",
    )


def budget_pacing_formulation(
    pace: float = 0.25, budget: float = 2.0
) -> Formulation:
    """Budget pacing (box + cut): per-edge spend rate `pace`, row budget."""
    return Formulation(
        feasible_sets=BudgetPacedBox(pace=pace, budget=budget),
        name="budget_pacing",
    )


SCENARIOS = {
    "matching": matching_formulation,
    "capacity-cap": capacity_cap_formulation,
    "fairness-floor": fairness_floor_formulation,
    "budget-pacing": budget_pacing_formulation,
}


def scenario_formulation(
    name: str, param: Optional[float] = None
) -> Formulation:
    """Build a preset scenario by CLI name; `param` overrides the primary
    knob (cap / floor / pace) when given."""
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown formulation scenario {name!r}; "
            f"choose from {sorted(SCENARIOS)}"
        ) from None
    return builder(param) if param is not None else builder()
