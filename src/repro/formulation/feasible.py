"""FeasibleSet primitives — the operator-centric feasible-region catalog.

A `FeasibleSet` describes the per-source feasible polytope C_i declaratively;
`lower()` translates it to the `ProjectionMap` the dual oracle actually
executes (reusing `core/projections.py` — the projections are where such
solvers silently go wrong, so every set here is covered by the property suite
in tests/test_feasible_sets.py: idempotence, non-expansiveness, membership).

Catalog (paper Table 1 / DuaLip constraint families):

  Box(lo, hi)                elementwise bounds
  Simplex(radius)            {w >= 0, sum w <= radius} (or == with
                             inequality=False) — the matching feasible set
  CappedSimplex(cap, radius) capacity caps: {0 <= w <= cap, sum w <= radius}
  FairnessFloor(floor, hi,   minimum exposure per eligible edge:
                radius)      {floor <= w <= hi, sum w <= radius}
  BudgetPacedBox(pace,       budget pacing ("box + cut"):
                 budget)     {0 <= w <= pace, sum w <= budget}

All sets are frozen dataclasses — hashable, so they can ride inside the
static `FormulationSpec` attached to a `BucketedInstance` and be closed over
under jit.  `contains()` is the host-side membership predicate the property
tests check projector outputs against; it honours the padding convention
(masked-out entries must be exactly zero and are exempt from bounds).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.projections import (
    BoxCutProjection,
    BoxProjection,
    ProjectionMap,
    UnitSimplexProjection,
)

__all__ = [
    "FeasibleSet",
    "Box",
    "Simplex",
    "CappedSimplex",
    "FairnessFloor",
    "BudgetPacedBox",
]


class FeasibleSet:
    """Declarative per-source feasible region; `lower()` yields its projector.

    Subclasses implement:
      * `lower() -> ProjectionMap` — the executable projection operator
      * `contains(w, mask) -> bool` — host-side membership (property tests)
    New constraint families implement only this pair; the oracle, maximizer,
    sharding and service layers are reused unchanged (paper §5).
    """

    def lower(self) -> ProjectionMap:
        raise NotImplementedError

    def contains(self, w, mask, atol: float = 1e-4) -> bool:
        raise NotImplementedError

    def validate(self) -> None:
        """Raise ValueError on parameters that make the set empty/degenerate."""


def _split(w, mask):
    w, mask = np.asarray(w), np.asarray(mask)
    return w, mask, w[mask > 0], w[mask <= 0]


def _pads_zero(pad: np.ndarray) -> bool:
    return bool(pad.size == 0 or np.all(pad == 0.0))


@dataclasses.dataclass(frozen=True)
class Box(FeasibleSet):
    """Elementwise bounds {lo <= w <= hi} on real entries."""

    lo: float = 0.0
    hi: float = 1.0

    def validate(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"Box: lo={self.lo} > hi={self.hi}")

    def lower(self) -> ProjectionMap:
        return BoxProjection(self.lo, self.hi)

    def contains(self, w, mask, atol: float = 1e-4) -> bool:
        _, _, real, pad = _split(w, mask)
        ok = np.all(real >= self.lo - atol) and np.all(real <= self.hi + atol)
        return bool(ok) and _pads_zero(pad)


@dataclasses.dataclass(frozen=True)
class Simplex(FeasibleSet):
    """The matching feasible set {w >= 0, sum w <= radius} per source row.

    `inequality=False` is the equality variant {w >= 0, sum w == radius}.
    Lowers to `UnitSimplexProjection` — with default parameters this is
    *exactly* the legacy `MatchingObjective` projection, which is what makes
    the primitive-built matching formulation bit-compatible.
    """

    radius: float = 1.0
    inequality: bool = True

    def validate(self) -> None:
        if self.radius <= 0:
            raise ValueError(f"Simplex: radius={self.radius} must be > 0")

    def lower(self) -> ProjectionMap:
        return UnitSimplexProjection(self.radius, self.inequality)

    def contains(self, w, mask, atol: float = 1e-4) -> bool:
        w_, mask_, real, pad = _split(w, mask)
        sums = (w_ * (mask_ > 0)).sum(-1)
        ok = np.all(real >= -atol)
        if self.inequality:
            ok = ok and np.all(sums <= self.radius + atol)
        else:
            # rows with at least one real entry must sum to the radius
            has_real = (mask_ > 0).any(-1)
            ok = ok and np.all(np.abs(sums[has_real] - self.radius) <= atol)
        return bool(ok) and _pads_zero(pad)


@dataclasses.dataclass(frozen=True)
class CappedSimplex(FeasibleSet):
    """Capacity caps: {0 <= w <= cap, sum w <= radius}.

    The per-edge cap prevents any single destination from absorbing a
    source's whole allocation (DuaLip's BoxCut with lo = 0).
    """

    cap: float = 0.5
    radius: float = 1.0
    bisect_iters: int = 64

    def validate(self) -> None:
        if self.cap <= 0 or self.radius <= 0:
            raise ValueError(
                f"CappedSimplex: cap={self.cap}, radius={self.radius} must be > 0"
            )

    def lower(self) -> ProjectionMap:
        return BoxCutProjection(0.0, self.cap, self.radius, self.bisect_iters)

    def contains(self, w, mask, atol: float = 1e-4) -> bool:
        w_, mask_, real, pad = _split(w, mask)
        sums = (w_ * (mask_ > 0)).sum(-1)
        ok = (
            np.all(real >= -atol)
            and np.all(real <= self.cap + atol)
            and np.all(sums <= self.radius + atol)
        )
        return bool(ok) and _pads_zero(pad)


@dataclasses.dataclass(frozen=True)
class FairnessFloor(FeasibleSet):
    """Fairness floors: {floor <= w <= hi, sum w <= radius} on real entries.

    Every *eligible* edge receives at least `floor` allocation (minimum
    exposure).  Feasibility requires floor * row_degree <= radius; rows with
    more eligible edges than radius/floor make the set empty — `compile`
    cannot see per-row degrees, so callers pick `floor` against the max
    bucket width (see docs/formulation.md worked example).
    """

    floor: float = 0.02
    hi: float = 1.0
    radius: float = 1.0
    bisect_iters: int = 64

    def validate(self) -> None:
        if not (0 <= self.floor <= self.hi):
            raise ValueError(
                f"FairnessFloor: need 0 <= floor <= hi, got "
                f"floor={self.floor}, hi={self.hi}"
            )
        if self.radius < self.floor:
            raise ValueError(
                f"FairnessFloor: radius={self.radius} < floor={self.floor} "
                "is empty for every non-degenerate row"
            )

    def lower(self) -> ProjectionMap:
        return BoxCutProjection(
            self.floor, self.hi, self.radius, self.bisect_iters
        )

    def contains(self, w, mask, atol: float = 1e-4) -> bool:
        w_, mask_, real, pad = _split(w, mask)
        sums = (w_ * (mask_ > 0)).sum(-1)
        ok = (
            np.all(real >= self.floor - atol)
            and np.all(real <= self.hi + atol)
            and np.all(sums <= self.radius + atol)
        )
        return bool(ok) and _pads_zero(pad)


@dataclasses.dataclass(frozen=True)
class BudgetPacedBox(FeasibleSet):
    """Budget pacing ("box + cut"): {0 <= w <= pace, sum w <= budget}.

    `pace` caps the per-edge spend rate, `budget` caps the row total; the
    same BoxCut lowering as capacity caps with pacing semantics — the point
    of the primitive catalog is that such families are declarations, not
    solver changes.
    """

    pace: float = 0.25
    budget: float = 2.0
    bisect_iters: int = 64

    def validate(self) -> None:
        if self.pace <= 0 or self.budget <= 0:
            raise ValueError(
                f"BudgetPacedBox: pace={self.pace}, budget={self.budget} "
                "must be > 0"
            )

    def lower(self) -> ProjectionMap:
        return BoxCutProjection(0.0, self.pace, self.budget, self.bisect_iters)

    def contains(self, w, mask, atol: float = 1e-4) -> bool:
        w_, mask_, real, pad = _split(w, mask)
        sums = (w_ * (mask_ > 0)).sum(-1)
        ok = (
            np.all(real >= -atol)
            and np.all(real <= self.pace + atol)
            and np.all(sums <= self.budget + atol)
        )
        return bool(ok) and _pads_zero(pad)
