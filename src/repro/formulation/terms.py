"""Objective terms — the composable pieces of the regularized objective.

The smoothed dual oracle solves

    min_x  cost_scale * c'x  +  ridge_weight * (gamma/2) ||x||^2
    s.t.   A x <= b,  x_i in C_i,

so a term composition lowers to exactly two scalars: the linear-cost scale
and the ridge (smoothing) weight.  Both default to 1.0, reproducing the
legacy matching objective bit-for-bit; any other composition (a re-weighted
cost, a stronger smoother) still needs *zero* solve-loop changes because the
scales fold into the oracle's existing `z = -(A^T lam + c)/gamma` step.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = ["Term", "LinearCost", "RidgeSmoothing", "resolve_terms"]


class Term:
    """Marker base for objective terms (frozen, hashable subclasses)."""


@dataclasses.dataclass(frozen=True)
class LinearCost(Term):
    """The linear objective `scale * c'x` over the instance's packed costs."""

    scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class RidgeSmoothing(Term):
    """The gamma-smoothing ridge `weight * (gamma/2) ||x||^2` (paper eq. 2).

    The weight multiplies every continuation stage's gamma; the schedule
    itself stays a `MaximizerConfig` concern.
    """

    weight: float = 1.0


def resolve_terms(terms: Sequence[Term]) -> tuple[float, float]:
    """Lower a term composition to `(cost_scale, ridge_weight)`.

    At most one term of each kind; an omitted kind keeps its default scale
    of 1.0 (the ridge is the solver's smoother, so it is always present —
    `RidgeSmoothing(weight=0)` is rejected because the oracle's closed-form
    primal step divides by gamma).
    """
    cost_scale: float | None = None
    ridge_weight: float | None = None
    for t in terms:
        if isinstance(t, LinearCost):
            if cost_scale is not None:
                raise ValueError("duplicate LinearCost term")
            cost_scale = float(t.scale)
        elif isinstance(t, RidgeSmoothing):
            if ridge_weight is not None:
                raise ValueError("duplicate RidgeSmoothing term")
            ridge_weight = float(t.weight)
        else:
            raise ValueError(
                f"unsupported term {t!r}: the oracle lowers LinearCost and "
                "RidgeSmoothing compositions"
            )
    if ridge_weight is not None and ridge_weight <= 0:
        raise ValueError(
            f"RidgeSmoothing weight must be > 0 (got {ridge_weight}): the "
            "closed-form primal step divides by the smoothed gamma"
        )
    return (
        1.0 if cost_scale is None else cost_scale,
        1.0 if ridge_weight is None else ridge_weight,
    )
