"""Assigned architecture pool: 10 LM-family transformers as framework configs.

Families: dense GQA decoders, MoE (top-k + shared experts, optional LP router
from the paper's solver), MLA (DeepSeek), SSM (Mamba2 SSD), hybrid
(Mamba2 + shared attention), encoder-decoder (audio), VLM backbone.
"""
from repro.models.config import (
    ModelConfig,
    MoEConfig,
    MLAConfig,
    SSMConfig,
    ShardingProfile,
)
from repro.models.model import Model

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ShardingProfile",
    "Model",
]
