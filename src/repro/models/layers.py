"""Core transformer layers: norms, RoPE, GQA/MLA attention, MLPs.

Pure functional style: `init_*` builds param dicts (fp32 masters), `apply_*`
consumes them, casting to the compute dtype at use.  All sequence mixing is
KV-chunked (flash-style online softmax over static chunk pairs) so activation
memory stays O(S * chunk) rather than O(S^2) — required for the 32k prefill
cells on 16 GiB/chip HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "rope",
    "init_dense",
    "init_attention",
    "apply_attention",
    "apply_attention_decode",
    "init_mlp",
    "apply_mlp",
    "init_mla",
    "apply_mla",
    "apply_mla_decode",
    "chunked_attention",
]

_NEG = -1.0e30


def _cast(x, dtype):
    return x.astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 1e4
) -> jax.Array:
    """Rotary embedding on the last dim. x: [..., S, ..., D], positions: [B?, S]."""
    D = x.shape[-1]
    half = D // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    # broadcast angles over any head dims between S and D
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, *, std: float = 0.02, bias=False):
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_dense(p, x):
    y = x @ _cast(p["w"], x.dtype)
    if "b" in p:
        y = y + _cast(p["b"], x.dtype)
    return y


# ---------------------------------------------------------------------------
# chunked (flash-style) attention
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, K, D]
    v: jax.Array,  # [B, Sk, K, Dv]
    *,
    causal: bool,
    chunk: int = 1024,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention over static (q-chunk, kv-chunk) pairs.

    Memory is O(Cq * Ck) per head per step instead of O(S^2); the scan carries
    (m, l, acc) per query chunk.  GQA: H query heads grouped over K kv heads.
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    cq = min(chunk, Sq)
    ck = min(chunk, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, cq, Sk, ck)
    nq, nk = Sq // cq, Sk // ck

    qb = q.reshape(B, nq, cq, K, G, D)
    kb = jnp.moveaxis(k.reshape(B, nk, ck, K, D), 1, 0)  # [nk, B, ck, K, D]
    vb = jnp.moveaxis(v.reshape(B, nk, ck, K, Dv), 1, 0)

    q_pos_base = jnp.arange(cq)
    k_pos_base = jnp.arange(ck)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk  # qblk [B, cq, K, G, D]
        q_pos = q_offset + qi * cq + q_pos_base  # [cq]

        def kv_step(carry, kj_blks):
            m, l, acc = carry
            kj, kblk, vblk = kj_blks
            s = jnp.einsum(
                "bqkgd,bckd->bqkgc", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale  # [B, cq, K, G, ck]
            if causal:
                k_pos = kj * ck + k_pos_base
                mask = q_pos[:, None] >= k_pos[None, :]  # [cq, ck]
                s = jnp.where(mask[None, :, None, None, :], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, cq, K, G), _NEG, jnp.float32)
        l0 = jnp.zeros((B, cq, K, G), jnp.float32)
        a0 = jnp.zeros((B, cq, K, G, Dv), jnp.float32)
        # nested remat = flash-attention backward: recompute the (cq x ck)
        # score block per kv chunk instead of saving all of them (O(S^2)).
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(
        q_step, None, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0))
    )
    # blocks: [nq, B, cq, K, G, Dv] -> [B, Sq, H, Dv]
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, K, G, Dv)
    return out.reshape(B, Sq, H, Dv)


def decode_attention(
    q: jax.Array,  # [B, 1, H, D]
    k_cache: jax.Array,  # [B, S, K, D]
    v_cache: jax.Array,  # [B, S, K, Dv]
    pos: jax.Array,  # [] current position (number of valid cache entries - 1)
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a (possibly seq-sharded) KV cache.

    Plain einsum + masked softmax: when the cache's S dim is sharded, XLA's
    partitioner emits the distributed max/sum reductions (flash-decoding
    combine) automatically.
    """
    B, _, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, K, G, D)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    d, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": init_dense(ks[0], d, H * Dh, bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], d, K * Dh, bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], d, K * Dh, bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], H * Dh, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), jnp.float32)
        p["k_norm"] = jnp.ones((Dh,), jnp.float32)
    return p


def _qkv(p, cfg, x, positions, use_rope: bool = True):
    B, S, _ = x.shape
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = apply_dense(p["wq"], x).reshape(B, S, H, Dh)
    k = apply_dense(p["wk"], x).reshape(B, S, K, Dh)
    v = apply_dense(p["wv"], x).reshape(B, S, K, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rms_norm(k, p["k_norm"], cfg.rmsnorm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attention(
    p, cfg, x, positions, *, causal=True, q_offset=0,
    kv: Optional[tuple] = None, use_rope: bool = True,
):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v)).

    kv=(k, v) switches to cross-attention against an encoder memory (no rope,
    no causal mask).
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions, use_rope=use_rope and kv is None)
    if kv is not None:  # cross-attention: keys/values from encoder memory
        k, v = kv
        causal = False
    out = chunked_attention(
        q, k, v, causal=causal, chunk=cfg.attn_chunk, q_offset=q_offset
    )
    return apply_dense(p["wo"], out.reshape(B, S, -1)), (k, v)


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-token-per-head absmax int8 quantization. x: [B, 1, K, D]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0  # [B,1,K]
    q = jnp.round(
        x.astype(jnp.float32) / jnp.maximum(scale, 1e-8)[..., None]
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def apply_attention_decode(p, cfg, x, pos, cache):
    """One-token step against a bf16 or int8 (quantized) KV cache.

    bf16 cache:  {"k", "v"} [B,S,K,D]
    int8 cache:  + {"k_scale", "v_scale"} [B,S,K] — per-token-per-head absmax
                 scales; halves cache HBM traffic (EXPERIMENTS.md §Perf H3).
    """
    B = x.shape[0]
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    quant = cache["k"].dtype == jnp.int8
    new_cache = {}
    if quant:
        k_q, k_s = quantize_kv(k_new)
        v_q, v_s = quantize_kv(v_new)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k_q, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v_q, (0, pos, 0, 0))
        ks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s, (0, pos, 0))
        vs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s, (0, pos, 0))
        new_cache = {"k": k_cache, "v": v_cache, "k_scale": ks, "v_scale": vs}
        k_deq = k_cache.astype(jnp.bfloat16) * ks[..., None].astype(jnp.bfloat16)
        v_deq = v_cache.astype(jnp.bfloat16) * vs[..., None].astype(jnp.bfloat16)
        out = decode_attention(q, k_deq, v_deq, pos)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0)
        )
        new_cache = {"k": k_cache, "v": v_cache}
        out = decode_attention(q, k_cache, v_cache, pos)
    return apply_dense(p["wo"], out.reshape(B, 1, -1)), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], d, ff),
        "w_up": init_dense(ks[1], d, ff),
        "w_down": init_dense(ks[2], ff, d),
    }


def apply_mlp(p, x, mlp_type: str = "swiglu"):
    g = apply_dense(p["w_gate"], x)
    u = apply_dense(p["w_up"], x)
    act = jax.nn.gelu(g) if mlp_type == "geglu" else jax.nn.silu(g)
    return apply_dense(p["w_down"], act * u)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_dense(ks[0], d, m.q_lora_rank),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": init_dense(ks[1], m.q_lora_rank, H * (dn + dr)),
        "wkv_a": init_dense(ks[2], d, m.kv_lora_rank + dr),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": init_dense(ks[3], m.kv_lora_rank, H * (dn + dv)),
        "wo": init_dense(ks[4], H * dv, d),
    }


def _mla_qkv(p, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ql = rms_norm(apply_dense(p["wq_a"], x), p["q_norm"], cfg.rmsnorm_eps)
    q = apply_dense(p["wq_b"], ql).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], rope(q[..., dn:], positions, cfg.rope_theta)
    kv_a = apply_dense(p["wkv_a"], x)
    latent = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.rmsnorm_eps)
    k_rope = rope(
        kv_a[..., m.kv_lora_rank:][:, :, None, :], positions, cfg.rope_theta
    )  # [B,S,1,dr] shared across heads
    return q_nope, q_rope, latent, k_rope


def apply_mla(p, cfg, x, positions, *, q_offset=0):
    """MLA for train/prefill: materialise per-head K/V from the latent."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, cfg, x, positions)
    kv = apply_dense(p["wkv_b"], latent).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1
    )
    out = chunked_attention(
        q, k, v, causal=True, chunk=cfg.attn_chunk, q_offset=q_offset,
        scale=(dn + dr) ** -0.5,
    )
    return apply_dense(p["wo"], out.reshape(B, S, -1)), latent, k_rope


def apply_mla_decode(p, cfg, x, pos, cache):
    """Absorbed MLA decode: the cache stores only the compressed latent
    [B, S, kv_lora + dr] (the 93% KV-cache reduction that motivates MLA).

    score_h = q_nope_h' Wkv_b_k_h latent + q_rope_h' k_rope   (weight absorption)
    out_h   = (attn @ latent) Wkv_b_v_h
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    r = m.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, cfg, x, positions)
    entry = jnp.concatenate(
        [latent_new, k_rope_new[:, :, 0, :]], axis=-1
    )  # [B,1,r+dr]
    lat_cache = jax.lax.dynamic_update_slice(
        cache["latent"], entry.astype(cache["latent"].dtype), (0, pos, 0)
    )
    latent, k_rope = lat_cache[..., :r], lat_cache[..., r:]
    wkv_b = p["wkv_b"]["w"].reshape(r, H, dn + dv)
    wk, wv = wkv_b[..., :dn], wkv_b[..., dn:]  # [r,H,dn], [r,H,dv]
    # absorb: q_abs [B,H,r]
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk.astype(x.dtype))
    s = (
        jnp.einsum("bhr,bsr->bhs", q_abs, latent.astype(x.dtype),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], k_rope.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    ) * (dn + dr) ** -0.5
    S = latent.shape[1]
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, :], s, _NEG)
    pw = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pw.astype(x.dtype), latent.astype(x.dtype))
    out = jnp.einsum("bhr,rhv->bhv", ctx, wv.astype(x.dtype))
    return (
        apply_dense(p["wo"], out.reshape(B, 1, -1)),
        {"latent": lat_cache},
    )
