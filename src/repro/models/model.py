"""Unified model builder: one functional Model for all assigned families.

Families and their block stacks:
  dense / vlm : [embed (+patch stub)] -> scan(attn+MLP blocks) -> head
  moe         : prefix dense layer(s) -> scan(attn+MoE blocks) -> head
                (deepseek-v2 uses MLA attention; kimi-k2 uses GQA)
  ssm         : scan(Mamba2 SSD blocks)
  hybrid      : scan(Mamba2 blocks with a *shared* attention block applied
                every `attn_period` layers via lax.cond)
  encdec      : encoder scan (bidirectional) + decoder scan (causal + cross)

All stacks scan over stacked per-layer params (compact HLO independent of
depth) with optional per-block remat.  Entry points:

  init(key)                         -> params (fp32 masters)
  loss(params, batch)               -> scalar LM loss      (train_* shapes)
  prefill(params, batch)            -> (logits_last, cache) (prefill_* shapes)
  decode_step(params, token, pos, cache) -> (logits, cache) (decode_*/long_*)
  init_cache(batch, seq)            -> cache pytree
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig

__all__ = ["Model"]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # Megatron-style sequence-parallel activation constraint: a
        # NamedSharding for [B, S, d] hiddens (set by the step builders; None
        # on single-device paths).  Applied to the residual stream between
        # blocks so the per-layer saved carries shard over the tp axis —
        # without it, scan-over-layers keeps L full-size activations per
        # device and 32k-seq training cells blow past HBM.
        self.act_sharding = None

    def _c(self, h):
        if self.act_sharding is not None and h.ndim == 3 and h.shape[1] > 1:
            return jax.lax.with_sharding_constraint(h, self.act_sharding)
        return h

    def _lowp(self, params):
        """Cast >=2D fp32 weights to the compute dtype ONCE, before the layer
        stack.  With FSDP shardings the parameter all-gathers then move bf16
        instead of fp32 — halving gather volume and peak temp memory.  Norm
        scales and biases (1D) stay fp32."""
        dt = _dtype(self.cfg)
        cast = lambda x: x.astype(dt) if (x.dtype == jnp.float32 and x.ndim >= 2) else x
        return jax.tree.map(cast, params)

    # ------------------------------------------------------------------ init
    def _init_block(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        if cfg.family == "ssm" or (cfg.family == "hybrid"):
            return {
                "ln": jnp.ones((cfg.d_model,), jnp.float32),
                "mamba": S.init_mamba(ks[0], cfg),
            }
        p: dict[str, Any] = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if cfg.mla is not None:
            p["attn"] = L.init_mla(ks[0], cfg)
        else:
            p["attn"] = L.init_attention(ks[0], cfg)
        if cfg.family == "moe":
            p["moe"] = M.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
        return p

    def _init_dense_block(self, key, ff: int) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        p = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": L.init_mlp(ks[1], cfg.d_model, ff),
        }
        if cfg.mla is not None:
            p["attn"] = L.init_mla(ks[0], cfg)
        else:
            p["attn"] = L.init_attention(ks[0], cfg)
        return p

    def _init_shared_attn(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        return {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[0], cfg),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
        }

    def _init_xblock(self, key) -> dict:
        """Encoder-decoder decoder block: self-attn + cross-attn + MLP."""
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[0], cfg),
            "xattn": L.init_attention(ks[1], cfg),
            "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_blocks, k_extra, k_head = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embed": jax.random.normal(
                k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32
            ) * 0.01,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
                * 0.01
            )
        if cfg.encdec:
            ke, kd = jax.random.split(k_blocks)
            params["enc_blocks"] = jax.vmap(self._init_dense_block, in_axes=(0, None))(
                jax.random.split(ke, cfg.enc_layers), cfg.d_ff
            )
            params["dec_blocks"] = jax.vmap(self._init_xblock)(
                jax.random.split(kd, cfg.num_layers)
            )
            return params
        n_scan = cfg.num_layers - cfg.n_dense_layers
        if cfg.n_dense_layers:
            params["prefix"] = [
                self._init_dense_block(k, cfg.dense_ff or cfg.d_ff)
                for k in jax.random.split(k_extra, cfg.n_dense_layers)
            ]
        params["blocks"] = jax.vmap(self._init_block)(
            jax.random.split(k_blocks, n_scan)
        )
        if cfg.family == "hybrid":
            params["shared_attn"] = self._init_shared_attn(k_head)
        return params

    def param_count(self, active_only: bool = False) -> int:
        shapes = jax.eval_shape(self.init, jax.random.key(0))
        total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        cfg = self.cfg
        if active_only and cfg.moe is not None:
            m = cfg.moe
            n_moe_layers = cfg.num_layers - cfg.n_dense_layers
            per_expert = 3 * cfg.d_model * m.expert_ff
            routed = n_moe_layers * m.num_experts * per_expert
            active_routed = n_moe_layers * m.top_k * per_expert
            total = total - routed + active_routed
        return total

    # -------------------------------------------------------------- blocks
    def _block_fwd(self, p, h, positions, q_offset=0):
        cfg = self.cfg
        if cfg.family in ("ssm", "hybrid"):
            out, _ = S.apply_mamba(p["mamba"], cfg, L.rms_norm(h, p["ln"], cfg.rmsnorm_eps))
            return h + out
        hn = L.rms_norm(h, p["ln1"], cfg.rmsnorm_eps)
        if cfg.mla is not None:
            a, _, _ = L.apply_mla(p["attn"], cfg, hn, positions, q_offset=q_offset)
        else:
            a, _ = L.apply_attention(
                p["attn"], cfg, hn, positions, causal=cfg.causal, q_offset=q_offset
            )
        h = h + a
        hn = L.rms_norm(h, p["ln2"], cfg.rmsnorm_eps)
        if cfg.family == "moe":
            B, Sq, d = hn.shape
            out = M.apply_moe(p["moe"], cfg, hn.reshape(B * Sq, d)).reshape(B, Sq, d)
        else:
            out = L.apply_mlp(p["mlp"], hn, cfg.mlp_type)
        return h + out

    def _dense_block_fwd(self, p, h, positions, *, causal=True, kv=None):
        """Attention + plain MLP block (prefix layers, encoder blocks)."""
        cfg = self.cfg
        hn = L.rms_norm(h, p["ln1"], cfg.rmsnorm_eps)
        if cfg.mla is not None:
            a, _, _ = L.apply_mla(p["attn"], cfg, hn, positions)
            kv_out = None
        else:
            a, kv_out = L.apply_attention(
                p["attn"], cfg, hn, positions, causal=causal, kv=kv
            )
        h = h + a
        hn = L.rms_norm(h, p["ln2"], cfg.rmsnorm_eps)
        return h + L.apply_mlp(p["mlp"], hn, cfg.mlp_type), kv_out

    def _shared_attn_fwd(self, p, h, positions):
        cfg = self.cfg
        hn = L.rms_norm(h, p["ln1"], cfg.rmsnorm_eps)
        a, _ = L.apply_attention(p["attn"], cfg, hn, positions, causal=True)
        h = h + a
        hn = L.rms_norm(h, p["ln2"], cfg.rmsnorm_eps)
        return h + L.apply_mlp(p["mlp"], hn, cfg.mlp_type)

    # ------------------------------------------------------------- forward
    def _stack(self, params, h, positions):
        """Scan the main block stack over hidden states h [B,S,d]."""
        cfg = self.cfg

        def body(carry, xs):
            p, idx = xs
            hh = self._block_fwd(p, carry, positions)
            if cfg.family == "hybrid" and cfg.attn_period:
                hh = jax.lax.cond(
                    (idx + 1) % cfg.attn_period == 0,
                    lambda v: self._shared_attn_fwd(params["shared_attn"], v, positions),
                    lambda v: v,
                    hh,
                )
            return self._c(hh), None

        fn = jax.checkpoint(body) if cfg.remat else body
        n_scan = cfg.num_layers - cfg.n_dense_layers
        h, _ = jax.lax.scan(fn, h, (params["blocks"], jnp.arange(n_scan)))
        return h

    def hidden_states(self, params, tokens, extra_embeds=None):
        """Token (+frontend) embedding -> block stack -> final norm."""
        cfg = self.cfg
        dt = _dtype(cfg)
        h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        if extra_embeds is not None:  # vlm/audio stub: precomputed embeddings
            h = jnp.concatenate([extra_embeds.astype(dt), h], axis=1)
        B, Sq, _ = h.shape
        h = self._c(h)
        positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
        for p in params.get("prefix", []):
            fwd = lambda pp, hh: self._c(self._dense_block_fwd(pp, hh, positions)[0])
            h = jax.checkpoint(fwd)(p, h) if cfg.remat else fwd(p, h)
        h = self._stack(params, h, positions)
        return L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)

    def logits(self, params, h):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return h @ w.astype(h.dtype)

    def loss(self, params, batch: dict) -> jax.Array:
        """batch: tokens [B,S], labels [B,S] (-100 = ignore), optional
        'embeds' [B,P,d] frontend stub (labels then cover P+S positions)."""
        cfg = self.cfg
        params = self._lowp(params)
        if cfg.encdec:
            return self._encdec_loss(params, batch)
        h = self.hidden_states(params, batch["tokens"], batch.get("embeds"))
        logits = self._c(self.logits(params, h))  # [B, S-tp, V]: seq-sharded
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(
            logits.astype(jnp.float32),
            jnp.maximum(labels, 0)[..., None], axis=-1,
        )[..., 0]
        valid = (labels >= 0).astype(jnp.float32)
        nll = (lse - picked) * valid
        return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1.0)

    # --------------------------------------------------------- encoder-decoder
    def _encode(self, params, embeds):
        cfg = self.cfg
        h = embeds.astype(_dtype(cfg))
        B, Sq, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))

        def body(carry, p):
            out, _ = self._dense_block_fwd(p, carry, positions, causal=False)
            return self._c(out), None

        fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(fn, h, params["enc_blocks"])
        return h

    def _decode_stack(self, params, h, positions, memory):
        cfg = self.cfg

        def body(carry, p):
            hn = L.rms_norm(carry, p["ln1"], cfg.rmsnorm_eps)
            a, _ = L.apply_attention(p["attn"], cfg, hn, positions, causal=True)
            carry = carry + a
            hn = L.rms_norm(carry, p["ln_x"], cfg.rmsnorm_eps)
            mem_k, mem_v = self._cross_kv(p, memory)
            a, _ = L.apply_attention(
                p["xattn"], cfg, hn, positions, kv=(mem_k, mem_v)
            )
            carry = carry + a
            hn = L.rms_norm(carry, p["ln2"], cfg.rmsnorm_eps)
            return self._c(carry + L.apply_mlp(p["mlp"], hn, cfg.mlp_type)), None

        fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(fn, h, params["dec_blocks"])
        return h

    def _cross_kv(self, p, memory):
        cfg = self.cfg
        B, Sm, _ = memory.shape
        K, Dh = cfg.num_kv_heads, cfg.head_dim
        k = L.apply_dense(p["xattn"]["wk"], memory).reshape(B, Sm, K, Dh)
        v = L.apply_dense(p["xattn"]["wv"], memory).reshape(B, Sm, K, Dh)
        return k, v

    def _encdec_loss(self, params, batch):
        cfg = self.cfg
        memory = self._encode(params, batch["embeds"])
        tokens = batch["tokens"]
        h = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
        B, Sq, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
        h = self._decode_stack(params, h, positions, memory)
        h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
        logits = self._c(self.logits(params, h))
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(
            logits.astype(jnp.float32), jnp.maximum(labels, 0)[..., None], axis=-1
        )[..., 0]
        valid = (labels >= 0).astype(jnp.float32)
        return jnp.sum((lse - picked) * valid) / jnp.maximum(jnp.sum(valid), 1.0)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, seq: int, dtype=None) -> dict:
        cfg = self.cfg
        if dtype is None:
            dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
        K, Dh = cfg.num_kv_heads, cfg.head_dim
        n_scan = cfg.num_layers - cfg.n_dense_layers
        if cfg.encdec:
            return {
                "self_k": jnp.zeros((cfg.num_layers, batch, seq, K, Dh), dtype),
                "self_v": jnp.zeros((cfg.num_layers, batch, seq, K, Dh), dtype),
                # cross K/V filled at prefill from the encoder memory
                "cross_k": jnp.zeros((cfg.num_layers, batch, seq, K, Dh), dtype),
                "cross_v": jnp.zeros((cfg.num_layers, batch, seq, K, Dh), dtype),
            }
        if cfg.family == "ssm":
            s = cfg.ssm
            H = s.num_heads(cfg.d_model)
            conv_dim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.state_dim
            return {
                "h": jnp.zeros((n_scan, batch, H, s.head_dim, s.state_dim), jnp.float32),
                "conv": jnp.zeros((n_scan, batch, s.conv_width - 1, conv_dim), dtype),
            }
        if cfg.family == "hybrid":
            s = cfg.ssm
            H = s.num_heads(cfg.d_model)
            conv_dim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.state_dim
            n_attn = n_scan // cfg.attn_period
            out = {
                "h": jnp.zeros((n_scan, batch, H, s.head_dim, s.state_dim), jnp.float32),
                "conv": jnp.zeros(
                    (n_scan, batch, s.conv_width - 1, conv_dim),
                    jnp.bfloat16 if dtype == jnp.int8 else dtype,
                ),
                "attn_k": jnp.zeros((n_attn, batch, seq, K, Dh), dtype),
                "attn_v": jnp.zeros((n_attn, batch, seq, K, Dh), dtype),
            }
            if dtype == jnp.int8:
                out["attn_k_scale"] = jnp.zeros((n_attn, batch, seq, K), jnp.bfloat16)
                out["attn_v_scale"] = jnp.zeros((n_attn, batch, seq, K), jnp.bfloat16)
            return out
        if cfg.mla is not None:
            r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            cache = {"latent": jnp.zeros(
                (n_scan, batch, seq, r),
                jnp.bfloat16 if dtype == jnp.int8 else dtype,
            )}
        else:
            cache = {
                "k": jnp.zeros((n_scan, batch, seq, K, Dh), dtype),
                "v": jnp.zeros((n_scan, batch, seq, K, Dh), dtype),
            }
            if dtype == jnp.int8:
                cache["k_scale"] = jnp.zeros((n_scan, batch, seq, K), jnp.bfloat16)
                cache["v_scale"] = jnp.zeros((n_scan, batch, seq, K), jnp.bfloat16)
        if cfg.n_dense_layers:
            if cfg.mla is not None:
                r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                cache["prefix_latent"] = jnp.zeros(
                    (cfg.n_dense_layers, batch, seq, r), dtype
                )
            else:
                cache["prefix_k"] = jnp.zeros(
                    (cfg.n_dense_layers, batch, seq, K, Dh), dtype
                )
                cache["prefix_v"] = jnp.zeros(
                    (cfg.n_dense_layers, batch, seq, K, Dh), dtype
                )
        return cache

    def decode_step(self, params, tokens, pos, cache):
        """One-token decode. tokens [B,1], pos scalar. Returns (logits, cache)."""
        cfg = self.cfg
        params = self._lowp(params)
        if cfg.encdec:
            return self._encdec_decode_step(params, tokens, pos, cache)
        dt = _dtype(cfg)
        h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        B = h.shape[0]
        new_cache = dict(cache)

        for i, p in enumerate(params.get("prefix", [])):
            hn = L.rms_norm(h, p["ln1"], cfg.rmsnorm_eps)
            if cfg.mla is not None:
                a, lc2 = L.apply_mla_decode(
                    p["attn"], cfg, hn, pos,
                    {"latent": cache["prefix_latent"][i]},
                )
                new_cache["prefix_latent"] = cache["prefix_latent"].at[i].set(lc2["latent"])
            else:
                lc = {"k": cache["prefix_k"][i], "v": cache["prefix_v"][i]}
                a, lc2 = L.apply_attention_decode(p["attn"], cfg, hn, pos, lc)
                new_cache["prefix_k"] = cache["prefix_k"].at[i].set(lc2["k"])
                new_cache["prefix_v"] = cache["prefix_v"].at[i].set(lc2["v"])
            h = h + a
            hn = L.rms_norm(h, p["ln2"], cfg.rmsnorm_eps)
            h = h + L.apply_mlp(p["mlp"], hn, cfg.mlp_type)

        if cfg.family in ("ssm", "hybrid"):
            h, new_cache = self._ssm_decode_scan(params, h, pos, cache, new_cache)
        else:
            h, new_cache = self._attn_decode_scan(params, h, pos, cache, new_cache)
        h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
        return self.logits(params, h), new_cache

    def _attn_decode_scan(self, params, h, pos, cache, new_cache):
        cfg = self.cfg

        quant = cfg.kv_cache_dtype == "int8" and cfg.mla is None

        def body(carry, xs):
            if cfg.mla is not None:
                p, lat = xs
                hn = L.rms_norm(carry, p["ln1"], cfg.rmsnorm_eps)
                a, c2 = L.apply_mla_decode(p["attn"], cfg, hn, pos, {"latent": lat})
                carry = carry + a
                ys = (c2["latent"],)
            else:
                if quant:
                    p, k, v, ks, vs = xs
                    lc = {"k": k, "v": v, "k_scale": ks, "v_scale": vs}
                else:
                    p, k, v = xs
                    lc = {"k": k, "v": v}
                hn = L.rms_norm(carry, p["ln1"], cfg.rmsnorm_eps)
                a, c2 = L.apply_attention_decode(p["attn"], cfg, hn, pos, lc)
                carry = carry + a
                ys = (
                    (c2["k"], c2["v"], c2["k_scale"], c2["v_scale"])
                    if quant else (c2["k"], c2["v"])
                )
            hn = L.rms_norm(carry, p["ln2"], cfg.rmsnorm_eps)
            if cfg.family == "moe":
                B = hn.shape[0]
                out = M.apply_moe(p["moe"], cfg, hn.reshape(B, -1)).reshape(B, 1, -1)
            else:
                out = L.apply_mlp(p["mlp"], hn, cfg.mlp_type)
            return carry + out, ys

        if cfg.mla is not None:
            h, (lat,) = jax.lax.scan(body, h, (params["blocks"], cache["latent"]))
            new_cache["latent"] = lat
        elif quant:
            h, (k, v, ks, vs) = jax.lax.scan(
                body, h,
                (params["blocks"], cache["k"], cache["v"],
                 cache["k_scale"], cache["v_scale"]),
            )
            new_cache["k"], new_cache["v"] = k, v
            new_cache["k_scale"], new_cache["v_scale"] = ks, vs
        else:
            h, (k, v) = jax.lax.scan(
                body, h, (params["blocks"], cache["k"], cache["v"])
            )
            new_cache["k"], new_cache["v"] = k, v
        return h, new_cache

    def _ssm_decode_scan(self, params, h, pos, cache, new_cache):
        cfg = self.cfg
        hybrid = cfg.family == "hybrid"

        def body(carry, xs):
            p, hs, conv, idx = xs
            hn = L.rms_norm(carry, p["ln"], cfg.rmsnorm_eps)
            out, c2 = S.apply_mamba_decode(
                p["mamba"], cfg, hn, {"h": hs, "conv": conv}
            )
            return carry + out, (c2["h"], c2["conv"])

        n_scan = cfg.num_layers - cfg.n_dense_layers
        if not hybrid:
            h, (hs, conv) = jax.lax.scan(
                body, h,
                (params["blocks"], cache["h"], cache["conv"], jnp.arange(n_scan)),
            )
            new_cache["h"], new_cache["conv"] = hs, conv
            return h, new_cache
        # hybrid: interleave shared attention every attn_period layers.
        # Scan over groups of attn_period mamba layers, then one shared-attn
        # application with its own (per-application) KV cache slot.
        period = cfg.attn_period
        n_groups = n_scan // period
        grp = lambda a: a.reshape((n_groups, period) + a.shape[1:])
        blocks_g = jax.tree.map(grp, params["blocks"])
        hs_g, conv_g = grp(cache["h"]), grp(cache["conv"])

        quant = cfg.kv_cache_dtype == "int8"

        def group_body(carry, xs):
            if quant:
                bg, hsg, convg, ak, av, aks, avs = xs
                lc = {"k": ak, "v": av, "k_scale": aks, "v_scale": avs}
            else:
                bg, hsg, convg, ak, av = xs
                lc = {"k": ak, "v": av}

            def inner(c, ys):
                p, hs_l, conv_l = ys
                hn = L.rms_norm(c, p["ln"], cfg.rmsnorm_eps)
                out, c2 = S.apply_mamba_decode(
                    p["mamba"], cfg, hn, {"h": hs_l, "conv": conv_l}
                )
                return c + out, (c2["h"], c2["conv"])

            c, (hs2, conv2) = jax.lax.scan(inner, carry, (bg, hsg, convg))
            sp = params["shared_attn"]
            hn = L.rms_norm(c, sp["ln1"], cfg.rmsnorm_eps)
            a, c2 = L.apply_attention_decode(sp["attn"], cfg, hn, pos, lc)
            c = c + a
            hn = L.rms_norm(c, sp["ln2"], cfg.rmsnorm_eps)
            c = c + L.apply_mlp(sp["mlp"], hn, cfg.mlp_type)
            ys_out = (
                (hs2, conv2, c2["k"], c2["v"], c2["k_scale"], c2["v_scale"])
                if quant else (hs2, conv2, c2["k"], c2["v"])
            )
            return c, ys_out

        if quant:
            h, (hs2, conv2, ak, av, aks, avs) = jax.lax.scan(
                group_body, h,
                (blocks_g, hs_g, conv_g, cache["attn_k"], cache["attn_v"],
                 cache["attn_k_scale"], cache["attn_v_scale"]),
            )
            new_cache["attn_k_scale"], new_cache["attn_v_scale"] = aks, avs
        else:
            h, (hs2, conv2, ak, av) = jax.lax.scan(
                group_body, h,
                (blocks_g, hs_g, conv_g, cache["attn_k"], cache["attn_v"]),
            )
        new_cache["h"] = hs2.reshape(cache["h"].shape)
        new_cache["conv"] = conv2.reshape(cache["conv"].shape)
        new_cache["attn_k"], new_cache["attn_v"] = ak, av
        return h, new_cache

    def _encdec_decode_step(self, params, tokens, pos, cache):
        cfg = self.cfg
        dt = _dtype(cfg)
        h = jnp.take(params["embed"], tokens, axis=0).astype(dt)

        def body(carry, xs):
            p, sk, sv, ck, cv = xs
            hn = L.rms_norm(carry, p["ln1"], cfg.rmsnorm_eps)
            a, c2 = L.apply_attention_decode(p["attn"], cfg, hn, pos, {"k": sk, "v": sv})
            carry = carry + a
            hn = L.rms_norm(carry, p["ln_x"], cfg.rmsnorm_eps)
            B = hn.shape[0]
            q = L.apply_dense(p["xattn"]["wq"], hn).reshape(
                B, 1, cfg.num_heads, cfg.head_dim
            )
            a = L.decode_attention(q, ck, cv, jnp.asarray(ck.shape[1] - 1))
            a = L.apply_dense(p["xattn"]["wo"], a.reshape(B, 1, -1))
            carry = carry + a
            hn = L.rms_norm(carry, p["ln2"], cfg.rmsnorm_eps)
            return carry + L.apply_mlp(p["mlp"], hn, cfg.mlp_type), (c2["k"], c2["v"])

        h, (sk, sv) = jax.lax.scan(
            body, h,
            (params["dec_blocks"], cache["self_k"], cache["self_v"],
             cache["cross_k"], cache["cross_v"]),
        )
        cache = dict(cache)
        cache["self_k"], cache["self_v"] = sk, sv
        h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
        return self.logits(params, h), cache

    def prefill(self, params, batch, max_seq: Optional[int] = None):
        """Prefill: full forward pass + cache population.

        Returns (last-position logits, cache).  For encdec: encode the memory
        and precompute cross K/V.  Attention families re-run K/V projections
        per layer to fill the cache (single pass, no decode loop).
        `max_seq` pads cache seq dims with headroom for subsequent decode.
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        params = self._lowp(params)
        if cfg.encdec:
            memory = self._encode(params, batch["embeds"])
            B, Sm, _ = memory.shape

            def xkv(p):
                return self._cross_kv(p, memory)

            ck, cv = jax.vmap(xkv)(params["dec_blocks"])
            cache = {
                "self_k": jnp.zeros(
                    (cfg.num_layers, B, Sm, cfg.num_kv_heads, cfg.head_dim), dt
                ),
                "self_v": jnp.zeros(
                    (cfg.num_layers, B, Sm, cfg.num_kv_heads, cfg.head_dim), dt
                ),
                "cross_k": ck.astype(dt),
                "cross_v": cv.astype(dt),
            }
            tokens = batch["tokens"]  # decoder BOS prompt [B, 1]
            h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
            positions = jnp.zeros_like(tokens)
            h = self._decode_stack(params, h, positions, memory)
            h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
            return self.logits(params, h), cache

        if cfg.family in ("ssm", "hybrid"):
            logits, cache = self._ssm_prefill(params, batch)
            if max_seq is not None and "attn_k" in cache:
                pad = max_seq - cache["attn_k"].shape[2]
                if pad > 0:
                    w = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
                    cache["attn_k"] = jnp.pad(cache["attn_k"], w)
                    cache["attn_v"] = jnp.pad(cache["attn_v"], w)
            return logits, cache

        tokens = batch["tokens"]
        extra = batch.get("embeds")
        h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        if extra is not None:
            h = jnp.concatenate([extra.astype(dt), h], axis=1)
        B, Sq, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
        caches = []

        def block_with_cache(p, hh, dense: bool = False):
            hn = L.rms_norm(hh, p["ln1"], cfg.rmsnorm_eps)
            if cfg.mla is not None:
                a, latent, k_rope = L.apply_mla(p["attn"], cfg, hn, positions)
                c = jnp.concatenate([latent, k_rope[:, :, 0, :]], axis=-1)
                cache_entry = (c.astype(dt),)
            else:
                a, (k, v) = L.apply_attention(
                    p["attn"], cfg, hn, positions, causal=cfg.causal
                )
                cache_entry = (k.astype(dt), v.astype(dt))
            hh = hh + a
            hn = L.rms_norm(hh, p["ln2"], cfg.rmsnorm_eps)
            if cfg.family == "moe" and not dense:
                out = M.apply_moe(p["moe"], cfg, hn.reshape(B * Sq, -1)).reshape(B, Sq, -1)
            else:
                out = L.apply_mlp(p["mlp"], hn, cfg.mlp_type)
            return self._c(hh + out), cache_entry

        new_cache: dict[str, Any] = {}
        for i, p in enumerate(params.get("prefix", [])):
            h, ce = block_with_cache(p, h, dense=True)
            new_cache.setdefault("prefix_entries", []).append(ce)

        def body(carry, p):
            return block_with_cache(p, carry)

        fn = jax.checkpoint(body) if cfg.remat else body
        h, entries = jax.lax.scan(fn, h, params["blocks"])
        if cfg.mla is not None:
            new_cache["latent"] = entries[0]
        else:
            new_cache["k"], new_cache["v"] = entries
        if "prefix_entries" in new_cache:
            pe = new_cache.pop("prefix_entries")
            if cfg.mla is not None:
                new_cache["prefix_latent"] = jnp.stack([e[0] for e in pe])
            else:
                new_cache["prefix_k"] = jnp.stack([e[0] for e in pe])
                new_cache["prefix_v"] = jnp.stack([e[1] for e in pe])
        if max_seq is not None:
            def pad_seq(x):
                pad = max_seq - x.shape[2]
                if pad <= 0:
                    return x
                w = [(0, 0)] * x.ndim
                w[2] = (0, pad)
                return jnp.pad(x, w)

            new_cache = {k: pad_seq(v) for k, v in new_cache.items()}
        h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
        return self.logits(params, h[:, -1:, :]), new_cache

    def _ssm_prefill(self, params, batch):
        cfg = self.cfg
        dt = _dtype(cfg)
        tokens = batch["tokens"]
        h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        B, Sq, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
        hybrid = cfg.family == "hybrid"

        def body(carry, xs):
            p, idx = xs
            hn = L.rms_norm(carry, p["ln"], cfg.rmsnorm_eps)
            out, (hs, conv_tail) = S.apply_mamba(p["mamba"], cfg, hn)
            carry = carry + out
            if hybrid and cfg.attn_period:
                def attn(v):
                    sp = params["shared_attn"]
                    hn2 = L.rms_norm(v, sp["ln1"], cfg.rmsnorm_eps)
                    a, (k, vv) = L.apply_attention(sp["attn"], cfg, hn2, positions)
                    v = v + a
                    hn2 = L.rms_norm(v, sp["ln2"], cfg.rmsnorm_eps)
                    return v + L.apply_mlp(sp["mlp"], hn2, cfg.mlp_type), k, vv

                def no(v):
                    B_, S_, _ = v.shape
                    z = jnp.zeros((B_, S_, cfg.num_kv_heads, cfg.head_dim), v.dtype)
                    return v, z, z

                carry, k, vv = jax.lax.cond(
                    (idx + 1) % cfg.attn_period == 0, attn, no, carry
                )
                return self._c(carry), (hs, conv_tail.astype(dt), k.astype(dt), vv.astype(dt))
            return self._c(carry), (hs, conv_tail.astype(dt))

        fn = jax.checkpoint(body) if cfg.remat else body
        n_scan = cfg.num_layers
        h, entries = jax.lax.scan(fn, h, (params["blocks"], jnp.arange(n_scan)))
        cache: dict[str, Any] = {"h": entries[0], "conv": entries[1]}
        if hybrid:
            # keep only the populated shared-attn cache slots
            k_all, v_all = entries[2], entries[3]
            sel = jnp.arange(1, n_scan // cfg.attn_period + 1) * cfg.attn_period - 1
            cache["attn_k"], cache["attn_v"] = k_all[sel], v_all[sel]
        h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
        return self.logits(params, h[:, -1:, :]), cache
