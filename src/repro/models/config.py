"""Model configuration covering every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ShardingProfile",
    "ModelConfig",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int  # d_ff per routed expert
    num_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25
    router: str = "topk"  # "topk" | "lp" (paper-solver balanced routing)
    lp_iters: int = 16  # dual-ascent iterations for router="lp"
    lp_gamma: float = 0.1
    # dispatch groups: 0 = one global group (baseline); >0 = group-local
    # routing (sort/rank/scatter stay within a group, which the step builders
    # align with the dp sharding so dispatch never crosses shards — only the
    # expert einsum communicates, via the canonical EP all-to-all).
    groups: int = 0
    group_size: int = 4096  # tokens per group when groups are derived


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block."""

    state_dim: int = 128  # N
    head_dim: int = 64  # P
    expand: int = 2  # d_inner = expand * d_model
    n_groups: int = 1  # B/C groups
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ShardingProfile:
    """Logical->mesh sharding rules.

    tp_axis shards weights' feature dims (Megatron column/row split);
    fsdp=True additionally shards the other weight dim over the dp axes
    (FSDP / ZeRO-3 style, for >=70B archs).  dp axes shard the batch.
    Non-divisible dims silently drop the axis (see sharding_rules.maybe).
    """

    tp_axis: str = "model"
    dp_axes: tuple[str, ...] = ("data",)  # extended with "pod" on multi-pod
    fsdp: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention flavour
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen2
    rope_theta: float = 1e4
    causal: bool = True
    # mlp flavour
    mlp_type: str = "swiglu"  # swiglu | geglu
    # optional submodules
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0  # leading dense layers in MoE stacks
    dense_ff: int = 0  # their FFN width (0 -> d_ff)
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_period: int = 0  # hybrid: shared attention block every N layers
    # encoder-decoder
    encdec: bool = False
    enc_layers: int = 0
    # modality frontend stub (precomputed embeddings via input_specs)
    frontend: Optional[str] = None  # "patch" | "frame"
    frontend_len: int = 256
    # numerics / structure
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"  # master params
    remat: bool = True
    attn_chunk: int = 1024  # KV-chunked (flash-style) attention block
    # KV-cache storage: "bfloat16" (default) or "int8" (per-token-per-head
    # absmax scales stored alongside; halves decode cache HBM traffic)
    kv_cache_dtype: str = "bfloat16"
    # long-context capability marker (sub-quadratic sequence mixing)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import Model

        return Model(self).param_count()

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k routed only)."""
        from repro.models.model import Model

        return Model(self).param_count(active_only=True)
