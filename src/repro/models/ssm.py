"""Mamba2 SSD (state-space duality) block: chunked-scan prefill, O(1) decode.

Chunked SSD (Dao & Gu 2024): within a chunk of length Q the recurrence

    h_t = exp(a_t) h_{t-1} + dt_t B_t x_t,     y_t = C_t . h_t + D x_t

is evaluated with quadratic-in-Q einsums (intra-chunk term via the decay
matrix L[i,j] = exp(cum_i - cum_j), i >= j), while chunk-to-chunk states are
carried by a linear `lax.scan` — overall O(S*Q) work and O(S) memory, the
sub-quadratic path that qualifies the SSM/hybrid archs for the long_500k
cell.  Decode is a single recurrent state update per token.

Conventions: d_inner = expand*d_model; H = d_inner/P heads of dim P; B/C in
G groups of state dim N shared across H/G heads; depthwise causal conv of
width W over the concatenated (x, B, C) channels; gated RMSNorm output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_dense, init_dense, rms_norm

__all__ = [
    "init_mamba",
    "apply_mamba",
    "apply_mamba_decode",
    "init_mamba_cache",
    "ssd_chunked",
]


def _dims(cfg):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return s, d_in, H, conv_dim


def init_mamba(key, cfg) -> dict:
    s, d_in, H, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.n_groups * s.state_dim + H  # z, x, B, C, dt
    return {
        "in_proj": init_dense(ks[0], cfg.d_model, proj_out),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_dim)) * 0.2,
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.zeros((H,)),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,)),
        "dt_bias": jnp.full((H,), -2.0),  # softplus(-2) ~ 0.13
        "norm": jnp.ones((d_in,)),
        "out_proj": init_dense(ks[2], d_in, cfg.d_model),
    }


def _split_proj(cfg, proj):
    s, d_in, H, _ = _dims(cfg)
    gn = s.n_groups * s.state_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + d_in + 2 * gn]
    dt = proj[..., d_in + d_in + 2 * gn :]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time. xbc: [B,S,C], w: [W,C]."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b[None, None, :])


def segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] -> L-matrix exponents: out[..., i, j] = sum_{j+1..i} a, i>=j."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    Q = a.shape[-1]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    xdt: jax.Array,  # [b,s,h,p]  dt-premultiplied inputs (dt_j B_j x_j form)
    a: jax.Array,  # [b,s,h]    log-decay per step (dt * A, negative)
    Bm: jax.Array,  # [b,s,g,n]
    Cm: jax.Array,  # [b,s,g,n]
    chunk: int,
    h0: Optional[jax.Array] = None,  # [b,h,p,n] initial state
):
    """Returns (y [b,s,h,p], h_final [b,h,p,n])."""
    b, S, H, Pd = xdt.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hg = H // g
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    # chunked views, scan over chunk index
    xc = jnp.moveaxis(xdt.reshape(b, nc, Q, H, Pd), 1, 0)
    ac = jnp.moveaxis(a.reshape(b, nc, Q, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(b, nc, Q, g, n), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(b, nc, Q, g, n), 1, 0)

    def step(h, blk):
        x_, a_, B_, C_ = blk  # [b,Q,H,P], [b,Q,H], [b,Q,g,n] x2
        cum = jnp.cumsum(a_, axis=1)  # [b,Q,H]
        L = jnp.exp(segsum(jnp.moveaxis(a_, -1, 1)))  # [b,H,Q,Q]
        cb = jnp.einsum("bigm,bjgm->bgij", C_, B_)  # [b,g,Q,Q]
        cb_h = jnp.repeat(cb, hg, axis=1)  # [b,H,Q,Q]
        y_diag = jnp.einsum(
            "bhij,bjhp->bihp", cb_h * L, x_, preferred_element_type=jnp.float32
        )
        # carried-state contribution: C_i exp(cum_i) h0
        c_h = jnp.repeat(C_, hg, axis=2)  # [b,Q,H,n]
        y_off = jnp.einsum(
            "bihn,bhpn,bih->bihp", c_h, h, jnp.exp(cum),
            preferred_element_type=jnp.float32,
        )
        # state update
        total = cum[:, -1, :]  # [b,H]
        decay_out = jnp.exp(total[:, None, :] - cum)  # [b,Q,H]
        b_h = jnp.repeat(B_, hg, axis=2)  # [b,Q,H,n]
        h_new = (
            jnp.exp(total)[:, :, None, None] * h
            + jnp.einsum("bjhn,bjhp,bjh->bhpn", b_h, x_, decay_out,
                         preferred_element_type=jnp.float32)
        )
        return h_new, (y_diag + y_off).astype(xdt.dtype)

    if h0 is None:
        h0 = jnp.zeros((b, H, Pd, n), jnp.float32)
    # nested remat: without it, backward through the chunk scan saves every
    # chunk's quadratic L/CB tensors ([b,H,Q,Q] x num_chunks = full-seq
    # quadratic memory); rematerialising them per chunk keeps the residuals
    # at O(state) per chunk (the SSD analogue of flash-attention backward).
    h_fin, yc = jax.lax.scan(jax.checkpoint(step), h0, (xc, ac, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, S, H, Pd)
    return y, h_fin


def apply_mamba(p, cfg, x, h0=None):
    """Full-sequence Mamba2 block. x: [B,S,d_model] -> ([B,S,d_model], state).

    state = (h_final, conv_tail): h feeds decode continuation; conv_tail is
    the last W-1 raw (pre-conv) xbc rows, i.e. the decode conv cache.
    """
    s, d_in, H, conv_dim = _dims(cfg)
    B_, S, _ = x.shape
    proj = apply_dense(p["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, proj)
    conv_tail = xbc[:, -(s.conv_width - 1):, :]
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    gn = s.n_groups * s.state_dim
    xin = xbc[..., :d_in].reshape(B_, S, H, s.head_dim)
    Bm = xbc[..., d_in : d_in + gn].reshape(B_, S, s.n_groups, s.state_dim)
    Cm = xbc[..., d_in + gn :].reshape(B_, S, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])  # [H]
    a = dt * A[None, None, :]  # [B,S,H]
    xdt = xin * dt[..., None].astype(xin.dtype)
    y, h_fin = ssd_chunked(xdt, a, Bm, Cm, cfg.ssm.chunk, h0=h0)
    y = y + xin * p["D"].astype(xin.dtype)[None, None, :, None]
    y = y.reshape(B_, S, d_in)
    y = rms_norm(y, p["norm"], cfg.rmsnorm_eps) * jax.nn.silu(z)
    out = apply_dense(p["out_proj"], y)
    return out, (h_fin, conv_tail)


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    s, d_in, H, conv_dim = _dims(cfg)
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def apply_mamba_decode(p, cfg, x, cache):
    """One-token recurrent step. x: [B,1,d_model] -> ([B,1,d_model], cache)."""
    s, d_in, H, conv_dim = _dims(cfg)
    B_ = x.shape[0]
    proj = apply_dense(p["in_proj"], x)  # [B,1,*]
    z, xbc, dt = _split_proj(cfg, proj)
    # conv over (cached W-1 inputs | new input)
    win = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", win, w) + p["conv_b"].astype(x.dtype)
    xbc1 = jax.nn.silu(conv_out)[:, None, :]  # [B,1,C]
    gn = s.n_groups * s.state_dim
    xin = xbc1[..., :d_in].reshape(B_, H, s.head_dim)
    Bm = xbc1[..., d_in : d_in + gn].reshape(B_, s.n_groups, s.state_dim)
    Cm = xbc1[..., d_in + gn :].reshape(B_, s.n_groups, s.state_dim)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])  # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A[None, :])  # [B,H]
    hg = H // s.n_groups
    b_h = jnp.repeat(Bm, hg, axis=1)  # [B,H,n]
    c_h = jnp.repeat(Cm, hg, axis=1)
    u = jnp.einsum("bhp,bhn,bh->bhpn", xin.astype(jnp.float32), b_h.astype(jnp.float32), dt1)
    h_new = cache["h"] * decay[:, :, None, None] + u
    y = jnp.einsum("bhpn,bhn->bhp", h_new, c_h.astype(jnp.float32)).astype(x.dtype)
    y = y + xin * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(B_, 1, d_in)
    y = rms_norm(y, p["norm"], cfg.rmsnorm_eps) * jax.nn.silu(z)
    out = apply_dense(p["out_proj"], y)
    new_cache = {
        "h": h_new,
        "conv": win[:, 1:, :].astype(cache["conv"].dtype),
    }
    return out, new_cache
