"""Mixture-of-experts layer: sort-based grouped matmul + optional LP routing.

Dispatch strategy (TPU-native, MaxText-style "dropping"): flatten the T*k
(token, expert) assignments, sort by expert, compute each assignment's rank
within its expert, and scatter into a dense [E, C, d] buffer (assignments
beyond capacity C are dropped).  Expert FFNs then run as one batched einsum
over the stacked [E, d, ff] weights — sharding E over the "model" axis gives
expert parallelism, and XLA inserts the all-to-alls at the scatter/gather
boundaries.

`router="lp"` routes with the paper's solver: token->expert assignment *is* a
regularized matching LP (tokens = sources under a top-k simplex constraint,
experts = destinations under capacity coupling constraints).  A few dual-
ascent iterations (eq. 3/4 with Jacobi-free unit coefficients) produce a
balanced fractional assignment, BASE-layers style — the §Arch-applicability
integration point of the paper's technique into the MoE pool members.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projections import project_simplex
from repro.models.layers import apply_dense, init_dense

__all__ = ["init_moe", "apply_moe", "lp_route"]


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    std = 0.02
    p = {
        "router": init_dense(ks[0], d, m.num_experts),
        "w_gate": jax.random.normal(ks[1], (m.num_experts, d, m.expert_ff)) * std,
        "w_up": jax.random.normal(ks[2], (m.num_experts, d, m.expert_ff)) * std,
        "w_down": jax.random.normal(ks[3], (m.num_experts, m.expert_ff, d)) * std,
    }
    if m.num_shared > 0:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(ks[4], d, m.num_shared * m.expert_ff)
    return p


def lp_route(
    probs: jax.Array,  # [T, E] router probabilities
    top_k: int,
    capacity: float,  # per-expert capacity (same units as sum of x)
    *,
    iters: int = 16,
    gamma: float = 0.1,
) -> jax.Array:
    """Balanced fractional assignment via the paper's regularized dual ascent.

    LP:  max_x sum_te probs_te x_te - (gamma/2)||x||^2
         s.t. sum_e x_te <= k (per token; simplex radius k),
              sum_t x_te <= capacity (per expert; coupling constraints).

    The coupling matrix is exactly a Def.-1 matching matrix with one family
    and unit coefficients; A^T lam is a broadcast and A x a column sum, so the
    dual-ascent iteration runs entirely on the [T, E] tile.  Returns the
    fractional assignment x (callers take top-k of x).
    """
    T, E = probs.shape
    probs = probs.astype(jnp.float32)
    mask = jnp.ones_like(probs)
    # analytic step size: sigma_max(A)^2 <= T (unit column sums over T tokens)
    eta = gamma / jnp.asarray(T, jnp.float32)
    b = jnp.asarray(capacity, jnp.float32)

    def body(lam, _):
        # x*(lam) = Pi_simplex_k( (probs - lam) / gamma ) ; cost c = -probs
        z = (probs - lam[None, :]) / gamma
        x = project_simplex(z, mask, radius=float(top_k))
        grad = jnp.sum(x, axis=0) - b  # A x - b  (per-expert load)
        lam_new = jnp.maximum(lam + eta * grad, 0.0)
        return lam_new, None

    lam0 = jnp.zeros((E,), jnp.float32)
    lam, _ = jax.lax.scan(body, lam0, None, length=iters)
    z = (probs - lam[None, :]) / gamma
    return project_simplex(z, mask, radius=float(top_k))


def apply_moe(p, cfg, x2d: jax.Array) -> jax.Array:
    """x2d: [T, d] -> [T, d].

    With `cfg.moe.groups > 0` the token set splits into that many groups and
    dispatch (argsort, rank, scatter) is vmapped per group: when groups align
    with the dp batch shard, dispatch runs shard-local with no collectives,
    and only the [G, E, C_g, d] <-> expert einsum boundary moves data (the
    canonical expert-parallel all-to-all).  groups=0 is the single global
    dispatch (baseline; see EXPERIMENTS.md §Perf for the delta).
    """
    m = cfg.moe
    T, d = x2d.shape
    G = m.groups
    if G > 1 and T % G == 0 and T // G >= m.top_k:
        xg = x2d.reshape(G, T // G, d)
        return jax.vmap(lambda xs: _moe_one_group(p, cfg, xs))(xg).reshape(T, d)
    return _moe_one_group(p, cfg, x2d)


def _moe_one_group(p, cfg, x2d: jax.Array) -> jax.Array:
    m = cfg.moe
    T, d = x2d.shape
    E, k = m.num_experts, m.top_k
    C = int(max(1, round(T * k / E * m.capacity_factor)))

    logits = apply_dense(p["router"], x2d).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if m.router == "lp":
        probs = lp_route(
            probs, k, capacity=C, iters=m.lp_iters, gamma=m.lp_gamma
        )
    weights, ids = jax.lax.top_k(probs, k)  # [T, k]
    weights = (weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )).astype(x2d.dtype)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = ids.reshape(-1)  # [T*k]
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - seg_start[sorted_e]
    keep = rank < C
    token_of = order // k
    dest = jnp.where(keep, sorted_e * C + rank, E * C)  # overflow -> scratch row
    buf = jnp.zeros((E * C + 1, d), x2d.dtype).at[dest].set(x2d[token_of])
    h = buf[: E * C].reshape(E, C, d)

    # ---- batched expert FFN (EP: E sharded over the tp axis) ----------------
    def ff(w):
        return w.astype(x2d.dtype)

    g = jnp.einsum("ecd,edf->ecf", h, ff(p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", h, ff(p["w_up"]))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, ff(p["w_down"]))

    # ---- combine -------------------------------------------------------------
    y_flat = jnp.concatenate([y.reshape(E * C, d), jnp.zeros((1, d), y.dtype)])
    contrib = y_flat[dest] * weights.reshape(-1)[order][:, None]
    out = jnp.zeros((T, d), x2d.dtype).at[token_of].add(contrib)

    if m.num_shared > 0:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(p["shared"], x2d)
    return out
