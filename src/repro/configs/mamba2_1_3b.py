"""Mamba2-1.3B [ssm]: SSD (state-space duality), attention-free
[arXiv:2405.21060].  48L d_model=2048, ssm_state=128, vocab=50280.
Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,  # attention-free; SSD heads come from SSMConfig
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    head_dim=1,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1, conv_width=4),
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=512,
    head_dim=1,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1, conv_width=4, chunk=32),
    subquadratic=True,
    remat=False,
)
