"""Architecture registry, input shapes, and dry-run input specs.

Each assigned architecture lives in its own module exposing CONFIG (the exact
published configuration) and REDUCED (a same-family small config for CPU smoke
tests).  `input_specs` builds ShapeDtypeStruct stand-ins for every model input
of an (arch x shape) cell — weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = [
    "ShapeSpec",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_reduced_config",
    "applicable_shapes",
    "skip_reason",
    "input_specs",
    "LP_INSTANCES",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS: tuple[str, ...] = (
    "internvl2-76b",
    "gemma-7b",
    "qwen3-8b",
    "qwen2-72b",
    "starcoder2-7b",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
    "seamless-m4t-medium",
    "zamba2-2.7b",
    "mamba2-1.3b",
)


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_")
    )


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """Why an (arch, shape) cell is skipped, or None if it runs.

    long_500k needs sub-quadratic sequence mixing: runs for SSM/hybrid,
    skipped for pure full-attention archs (noted in DESIGN.md).
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: 500k decode needs sub-quadratic mixing"
    return None


def applicable_shapes(cfg: ModelConfig) -> list[ShapeSpec]:
    return [s for s in SHAPES.values() if skip_reason(cfg, s) is None]


def input_specs(cfg: ModelConfig, shape: ShapeSpec, model=None) -> dict:
    """ShapeDtypeStructs for every input of this (arch, shape) cell."""
    from repro.models.model import Model

    model = model or Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.encdec:
            return {
                "embeds": sds((B, S, cfg.d_model), f32),  # frame stub
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
            }
        if cfg.frontend == "patch":
            P = cfg.frontend_len
            return {
                "embeds": sds((B, P, cfg.d_model), f32),  # patch stub
                "tokens": sds((B, S - P), i32),
                "labels": sds((B, S), i32),
            }
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.encdec:
            return {
                "embeds": sds((B, S, cfg.d_model), f32),
                "tokens": sds((B, 1), i32),
            }
        if cfg.frontend == "patch":
            P = cfg.frontend_len
            return {
                "embeds": sds((B, P, cfg.d_model), f32),
                "tokens": sds((B, S - P), i32),
            }
        return {"tokens": sds((B, S), i32)}
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "tokens": sds((B, 1), i32),
        "pos": sds((), i32),
        "cache": cache,
    }


# The paper's own workload configurations (Table 2/3 scales), expressed as
# generator specs.  Dry-runs use the analytic bucket layout; CPU benchmarks
# materialise the smaller ones.
LP_INSTANCES: dict[str, dict] = {
    # name: sources, destinations, avg_degree, families
    "s25M-d10K": dict(num_sources=25_000_000, num_destinations=10_000, avg_degree=10.0, num_families=1),
    "s50M-d10K": dict(num_sources=50_000_000, num_destinations=10_000, avg_degree=10.0, num_families=1),
    "s75M-d10K": dict(num_sources=75_000_000, num_destinations=10_000, avg_degree=10.0, num_families=1),
    "s100M-d10K": dict(num_sources=100_000_000, num_destinations=10_000, avg_degree=10.0, num_families=1),
}
