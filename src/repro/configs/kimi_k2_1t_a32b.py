"""Kimi-K2-1T-A32B [moe]: trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2, paper-table].  Assigned-table attention: 64H GQA kv=8.
First layer dense; 1 shared expert.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(num_experts=384, top_k=8, expert_ff=2048, num_shared=1),
    n_dense_layers=1,
    dense_ff=18432,
)

REDUCED = ModelConfig(
    name="kimi-k2-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=64, num_shared=1),
    n_dense_layers=1,
    dense_ff=128,
    remat=False,
)
