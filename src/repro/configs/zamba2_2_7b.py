"""Zamba2-2.7B [hybrid]: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  54 Mamba2 layers (d_model=2560, ssm_state=64) with one
*shared* attention+MLP block (32H, d_ff=10240) applied every 6 layers.
Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1, conv_width=4),
    attn_period=6,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1, conv_width=4, chunk=32),
    attn_period=2,
    subquadratic=True,
    remat=False,
)
