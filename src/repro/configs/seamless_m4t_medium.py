"""SeamlessM4T-medium [audio]: encoder-decoder, multimodal [arXiv:2308.11596].

12L encoder + 12L decoder, d_model=1024, 16H, d_ff=4096, vocab=256206.
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, S, d_model] as the encoder input.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,
    enc_layers=12,
    encdec=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="frame",
)

REDUCED = ModelConfig(
    name="seamless-m4t-smoke",
    family="encdec",
    num_layers=2,
    enc_layers=2,
    encdec=True,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    frontend="frame",
    remat=False,
)
