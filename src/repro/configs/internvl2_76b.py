"""InternVL2-76B [vlm]: InternViT frontend (stub) + InternLM2-76B backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821].
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings [B, 256, d_model]; only the LM backbone is modelled.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    frontend="patch",
    frontend_len=256,
)

REDUCED = ModelConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    frontend="patch",
    frontend_len=4,
    remat=False,
)
