"""DeepSeek-V2-236B [moe]: MLA (kv_lora=512), 2 shared + 160 routed top-6
[arXiv:2405.04434].  First layer dense (d_ff 12288), remaining 59 MoE.
`router="lp"` switches token->expert assignment to the paper's regularized
matching solver (see repro.models.moe.lp_route).
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,  # qk_nope 128 + qk_rope 64
    d_ff=1536,
    vocab_size=102400,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=160, top_k=6, expert_ff=1536, num_shared=2),
    n_dense_layers=1,
    dense_ff=12288,
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=48,
    d_ff=64,
    vocab_size=512,
    mla=MLAConfig(
        kv_lora_rank=32,
        q_lora_rank=48,
        qk_nope_head_dim=32,
        qk_rope_head_dim=16,
        v_head_dim=32,
    ),
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=64, num_shared=2),
    n_dense_layers=1,
    dense_ff=128,
    remat=False,
)
