"""Gemma-7B [dense]: GeGLU, head_dim=256, MQA on the 2b sibling [arXiv:2403.08295]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="geglu",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    mlp_type="geglu",
    tie_embeddings=True,
    remat=False,
)
