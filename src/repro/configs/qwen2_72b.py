"""Qwen2-72B [dense]: GQA kv=8, QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
)

REDUCED = ModelConfig(
    name="qwen2-72b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    remat=False,
)
