"""Analytic FLOP/byte accounting for every (arch x shape) cell.

Why analytic: XLA:CPU's `cost_analysis()` counts each while-loop *body* once,
not trip_count times, so for scan-over-layers programs the reported HLO_FLOPs
is a per-body figure.  Since we control the exact lowering (which ops run,
how many times), we derive the true totals analytically and *validate* the
model against cost_analysis using the body-once transform (see
tests/test_flops_model.py): predicted_hlo = extras + 1x(layer fwd body) +
1x(remat body) + 2x(layer bwd body) must match the measured per-device number.

Conventions: FLOPs are global (whole step, all chips); matmul = 2mnk; backward
= 2x forward matmul FLOPs; remat recomputes the block forward once (factor 4
on scanned blocks, factor 3 on non-rematted extras).  Attention in this
codebase computes *all* (q, kv) chunk pairs with masking, so causal attention
costs full S^2 (the 2x over the useful causal half shows up in the
MODEL_FLOPS / HLO_FLOPS ratio, exactly the redundancy the roofline section is
asked to surface).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs import ShapeSpec
from repro.models.config import ModelConfig

__all__ = ["CellCost", "cell_cost"]


@dataclasses.dataclass
class CellCost:
    flops: float  # global FLOPs per step (what our lowering executes)
    bytes: float  # global HBM bytes per step (params + activations + cache)
    layer_fwd_flops: float  # one scanned-block forward (for HLO validation)
    extra_flops: float  # non-scanned compute (embed/logits/loss/opt)
    notes: str = ""


def _attn_flops(cfg: ModelConfig, T: int, S_kv: int, full_pairs: bool = True) -> float:
    """Per-step attention FLOPs for T query tokens against S_kv keys."""
    H, Dh, d = cfg.num_heads, cfg.head_dim, cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = (
            2 * d * m.q_lora_rank
            + 2 * m.q_lora_rank * H * qk_dim
            + 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
            + 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            + 2 * H * m.v_head_dim * d
        )
        mix = 2 * H * S_kv * (qk_dim + m.v_head_dim)
    else:
        K = cfg.num_kv_heads
        proj = 2 * d * H * Dh + 2 * 2 * d * K * Dh + 2 * H * Dh * d
        mix = 2 * H * S_kv * (Dh + Dh)
    return T * (proj + mix)


def _mlp_flops(cfg: ModelConfig, T: int, ff: int) -> float:
    return T * 2 * 3 * cfg.d_model * ff


def _moe_flops(cfg: ModelConfig, T: int) -> float:
    m = cfg.moe
    d = cfg.d_model
    routed = T * 2 * 3 * d * m.expert_ff * m.top_k * m.capacity_factor
    shared = T * 2 * 3 * d * m.expert_ff * m.num_shared
    router = T * 2 * d * m.num_experts
    return routed + shared + router


def _mamba_flops(cfg: ModelConfig, T: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    H, P, N, Q = s.num_heads(d), s.head_dim, s.state_dim, s.chunk
    gn = s.n_groups * N
    proj = 2 * d * (2 * d_in + 2 * gn + H) + 2 * d_in * d
    conv = 2 * s.conv_width * (d_in + 2 * gn)
    # chunked SSD per token: intra-chunk L.x (2*Q*H*P) + CB (2*Q*gn) +
    # state in/out projections (4*H*P*N) + off-diag output (2*H*P*N)
    ssd = 2 * Q * H * P + 2 * Q * gn + 6 * H * P * N
    return T * (proj + conv + ssd)


def _layer_fwd_flops(cfg: ModelConfig, T: int, S_kv: int) -> float:
    """One scanned block, forward, T tokens."""
    if cfg.family in ("ssm", "hybrid"):
        f = _mamba_flops(cfg, T)
        if cfg.family == "hybrid" and cfg.attn_period:
            # shared attention block amortised over the period
            f += (_attn_flops(cfg, T, S_kv) + _mlp_flops(cfg, T, cfg.d_ff)) / cfg.attn_period
        return f
    f = _attn_flops(cfg, T, S_kv)
    if cfg.family == "moe":
        f += _moe_flops(cfg, T)
    else:
        f += _mlp_flops(cfg, T, cfg.d_ff)
    return f


def cell_cost(cfg: ModelConfig, shape: ShapeSpec) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    V, d = cfg.vocab_size, cfg.d_model
    n_scan = cfg.num_layers - cfg.n_dense_layers

    if shape.kind == "train":
        T = B * S
        lf = _layer_fwd_flops(cfg, T, S)
        prefix = sum(
            _attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.dense_ff or cfg.d_ff)
            for _ in range(cfg.n_dense_layers)
        )
        if cfg.encdec:
            # encoder (bidirectional) + decoder (self + cross) stacks
            enc = cfg.enc_layers * (_attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.d_ff))
            dec = n_scan * (
                2 * _attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.d_ff)
            )
            lf = (enc + dec) / max(cfg.enc_layers + n_scan, 1)
            body_total = enc + dec
        else:
            body_total = n_scan * lf
        logits = T * 2 * d * V
        extras = 3 * (logits + prefix) + T * 5 * V  # fwd+bwd (2x) + softmax
        total = 4 * body_total + extras  # fwd + remat + bwd(2x)
        # bytes: optimizer (7 fp32 accesses) + bf16 param reads x3 passes +
        # activation traffic (~8 B/token/layer/d: fwd write, bwd read, remat)
        from repro.models.model import Model

        N = Model(cfg).param_count()
        p_bytes = N * (7 * 4 + 3 * 2)
        act_bytes = 8.0 * T * d * (cfg.num_layers + (cfg.enc_layers if cfg.encdec else 0))
        logit_bytes = 4.0 * T * V  # fp32 logits r/w (sharded, still HBM traffic)
        return CellCost(total, p_bytes + act_bytes + logit_bytes, lf, extras)

    if shape.kind == "prefill":
        T = B * S
        lf = _layer_fwd_flops(cfg, T, S)
        if cfg.encdec:
            enc = cfg.enc_layers * (_attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.d_ff))
            dec1 = cfg.num_layers * (
                _attn_flops(cfg, B, 1) + _attn_flops(cfg, B, S) + _mlp_flops(cfg, B, cfg.d_ff)
            )
            body_total = enc + dec1
            lf = enc / max(cfg.enc_layers, 1)
        else:
            body_total = n_scan * lf + sum(
                _attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.dense_ff or cfg.d_ff)
                for _ in range(cfg.n_dense_layers)
            )
        logits = B * 2 * d * V  # last position only
        from repro.models.model import Model

        N = Model(cfg).param_count()
        cache_bytes = _cache_bytes(cfg, B, S)
        byts = N * 2 + 6.0 * T * d * cfg.num_layers + cache_bytes
        return CellCost(body_total + logits, byts, lf, logits)

    # decode: one token per sequence against an S-deep cache
    T = B
    lf = _layer_fwd_flops(cfg, T, S)
    body_total = n_scan * lf + sum(
        _attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.dense_ff or cfg.d_ff)
        for _ in range(cfg.n_dense_layers)
    )
    if cfg.encdec:
        body_total = cfg.num_layers * (
            2 * _attn_flops(cfg, T, S) + _mlp_flops(cfg, T, cfg.d_ff)
        )
        lf = body_total / cfg.num_layers
    logits = B * 2 * d * V
    from repro.models.model import Model

    N_active = Model(cfg).param_count(active_only=True)
    cache_bytes = _cache_bytes(cfg, B, S)
    byts = N_active * 2 + cache_bytes  # read all active params + full cache
    return CellCost(body_total + logits, byts, lf, logits)


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Bytes of the KV/state cache read once per decode step."""
    kvb = 1.125 if cfg.kv_cache_dtype == "int8" else 2.0  # int8 + bf16 scales/Dh
    if cfg.family == "ssm":
        s = cfg.ssm
        return 4.0 * cfg.num_layers * B * s.num_heads(cfg.d_model) * s.head_dim * s.state_dim
    if cfg.family == "hybrid":
        s = cfg.ssm
        state = 4.0 * cfg.num_layers * B * s.num_heads(cfg.d_model) * s.head_dim * s.state_dim
        n_attn = cfg.num_layers // max(cfg.attn_period, 1)
        kv = kvb * 2 * n_attn * B * S * cfg.num_kv_heads * cfg.head_dim
        return state + kv
    if cfg.mla is not None:
        r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return 2.0 * cfg.num_layers * B * S * r
    mult = 2 if not cfg.encdec else 4  # self + cross
    return kvb * mult * cfg.num_layers * B * S * cfg.num_kv_heads * cfg.head_dim
