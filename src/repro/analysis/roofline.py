"""Three-term roofline model from compiled dry-run artifacts (TPU v5e target).

    compute    = HLO_FLOPs_global   / (chips * peak_FLOP/s)
    memory     = HLO_bytes_global   / (chips * HBM_bw)
    collective = collective_bytes_global / (chips * link_bw)

cost_analysis() on a partitioned module reports *per-device* numbers, so
global = per_device * chips; the collective parser is also per-device.  The
dominant term is the bottleneck; roofline fraction = dominant / sum (how close
the dominant resource is to being the only cost, i.e. perfect overlap), and
MODEL_FLOPS/HLO_FLOPs catches remat/causal/dispatch redundancy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["HW", "V5E", "RooflineTerms", "roofline_from_stats"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # bytes/s per chip
    link_bw: float  # bytes/s per chip (ICI)


V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: Optional[float] = None  # 6*N*D (or 6*N_active*D)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops_per_device <= 0:
            return None
        return self.model_flops / (self.flops_per_device * self.chips)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Model-FLOPs utilisation if the dominant term were the runtime."""
        if self.model_flops is None or self.bound_s <= 0:
            return None
        hw_flops = self.flops_per_device * self.chips / max(self.compute_s, 1e-30)
        return self.model_flops / (self.bound_s * hw_flops)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline_from_stats(
    flops_per_device: float,
    bytes_per_device: float,
    coll_bytes_per_device: float,
    chips: int,
    hw: HW = V5E,
    model_flops: Optional[float] = None,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / hw.peak_flops,
        memory_s=bytes_per_device / hw.hbm_bw,
        collective_s=coll_bytes_per_device / hw.link_bw,
        chips=chips,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes_per_device,
        model_flops=model_flops,
    )
