"""Compiled-artifact analysis: HLO collective stats and roofline terms."""
from repro.analysis.hlo_stats import collective_stats, parse_shape_bytes
from repro.analysis.roofline import RooflineTerms, roofline_from_stats, V5E

__all__ = [
    "collective_stats",
    "parse_shape_bytes",
    "RooflineTerms",
    "roofline_from_stats",
    "V5E",
]
