"""Parse collective communication out of compiled (post-SPMD) HLO text.

cost_analysis() does not expose collective bytes, so we parse
compiled.as_text(): every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op contributes its *operand* bytes (the
payload entering the network on each device).  Shapes of named operands are
resolved from their defining lines; `-start` variants are counted once and
`-done` lines skipped.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Optional

__all__ = ["parse_shape_bytes", "collective_stats"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^)]*?\)?)\s+[\w\-]+\(")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\((.*?)\)",
)


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computations(hlo_text: str) -> dict[str, list[str]]:
    """Split HLO text into named computations -> list of body lines.

    Computation headers sit at column 0: `[ENTRY ]%name (args...) -> type {`
    (args may contain nested tuple parens, so match on position + `{`/`->`
    instead of balancing).
    """
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{") and "->" in line:
            head = line.split("(", 1)[0].strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            cur = head.lstrip("%").strip()
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count of a lax.scan/while: the constant in the LT compare."""
    consts = {}
    for line in cond_lines:
        m = re.match(r"\s*%?([\w\.\-]+)\s*=\s*\w+\[\]\s+constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if "compare(" in line and "direction=LT" in line:
            for name in re.findall(r"%([\w\.\-]+)", line.split("compare(")[1]):
                if name in consts:
                    return consts[name]
    # fall back: any constant in the condition
    return max(consts.values()) if consts else 1


def loop_multipliers(hlo_text: str) -> dict[str, int]:
    """Execution-count multiplier per computation (nested while loops).

    XLA's cost_analysis counts each computation once; collectives inside a
    lax.scan body execute trip_count times per step.  This walks while ops,
    reads trip counts from their condition computations, and propagates
    multipliers down the (acyclic) computation references.
    """
    comps = _computations(hlo_text)
    # while ops: (parent_comp, body, cond)
    whiles = []
    for parent, lines in comps.items():
        for line in lines:
            if " while(" in line or "while(" in line.lstrip()[:70]:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mb and mc:
                    whiles.append((parent, mb.group(1), mc.group(1)))
    mult: dict[str, int] = {name: 1 for name in comps}
    # iterate to fixpoint (nesting depth is small)
    for _ in range(8):
        changed = False
        for parent, body, cond in whiles:
            t = _trip_count(comps.get(cond, []))
            want = mult.get(parent, 1) * max(t, 1)
            if mult.get(body, 1) != want:
                mult[body] = want
                mult[cond] = want
                changed = True
        if not changed:
            break
    return mult


def collective_stats(hlo_text: str, loop_aware: bool = False) -> dict:
    """Per-kind collective op counts and payload bytes (per device).

    loop_aware=True multiplies collectives inside while/scan bodies by their
    trip counts (XLA statics count each body once).

    Returns {"counts": {kind: n}, "bytes": {kind: B}, "total_bytes": B,
             "ops": [(kind, bytes, result_shape)]}.
    """
    if loop_aware:
        return _collective_stats_loop_aware(hlo_text)
    # name -> result shape string (first token after '=')
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        head, _, rest = line.partition("=")
        name = head.strip().lstrip("%").replace("ROOT", "").strip()
        rest = rest.strip()
        # result shape = leading type expression
        m = re.match(r"(\([^)]*\)|[\w\[\],]+)", rest)
        if m and name:
            shapes[name] = m.group(1)

    counts: dict[str, int] = defaultdict(int)
    byts: dict[str, int] = defaultdict(int)
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        _, result_shape, kind, start, operands = m.groups()
        counts[kind] += 1
        # operand bytes: resolve %names; fall back to the result shape
        b = 0
        for op_name in re.findall(r"%([\w\.\-]+)", operands):
            b += parse_shape_bytes(shapes.get(op_name, ""))
        if b == 0:
            b = parse_shape_bytes(result_shape)
        byts[kind] += b
        ops.append((kind, b, result_shape.strip()))
    return {
        "counts": dict(counts),
        "bytes": dict(byts),
        "total_bytes": int(sum(byts.values())),
        "ops": ops,
    }


def _collective_stats_loop_aware(hlo_text: str) -> dict:
    comps = _computations(hlo_text)
    mult = loop_multipliers(hlo_text)
    # resolve result shapes globally (operand lookup)
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        head, _, rest = line.partition("=")
        name = head.strip().lstrip("%").replace("ROOT", "").strip()
        rest = rest.strip()
        m = re.match(r"(\([^)]*\)|[\w\[\],]+)", rest)
        if m and name:
            shapes[name] = m.group(1)

    counts: dict[str, int] = defaultdict(int)
    byts: dict[str, int] = defaultdict(int)
    ops = []
    for comp, lines in comps.items():
        k = mult.get(comp, 1)
        for line in lines:
            m = _COLL_RE.match(line)
            if not m:
                continue
            _, result_shape, kind, start, operands = m.groups()
            b = 0
            for op_name in re.findall(r"%([\w\.\-]+)", operands):
                b += parse_shape_bytes(shapes.get(op_name, ""))
            if b == 0:
                b = parse_shape_bytes(result_shape)
            counts[kind] += k
            byts[kind] += b * k
            ops.append((kind, b * k, result_shape.strip()))
    return {
        "counts": dict(counts),
        "bytes": dict(byts),
        "total_bytes": int(sum(byts.values())),
        "ops": ops,
    }
