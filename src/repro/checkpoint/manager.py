"""Sharded, atomic, async checkpointing with elastic restore.

Design (1000+-node ready, CPU-validated here):
  * every leaf of the state pytree is saved under its tree-path key in one
    .npz per checkpoint (multi-host deployments write one shard-file per host;
    the manifest and atomic-rename protocol are identical);
  * writes go to `step_XXXX.tmp/` then os.replace -> `step_XXXX/` — a crashed
    writer can never produce a half-checkpoint that restore would accept;
  * async mode: device->host copy happens synchronously (consistent snapshot),
    the file write on a background thread (training continues);
  * restore takes a *template* pytree (eval_shape of the state) and an
    optional sharding pytree: arrays are rebuilt host-side then device_put to
    the current mesh — restoring onto a different device count/topology
    (elastic rescale N -> M) is just a different sharding argument;
  * keep-K garbage collection + SIGTERM save hook (preemption safety);
  * template-free restore (`restore_flat`) + JSON `meta` in the manifest, for
    states whose shapes the restorer cannot know ahead of time — the
    recurring-solve service checkpoints its tenants' packed slabs this way
    (bucket shapes drift with the ingested deltas), then rebuilds sessions
    from the flat arrays + meta (`service.Scheduler.load_state`).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "latest_step"]

# How `_flatten` (tree_flatten_with_path + keystr) renders a FLAT dict's
# string key: exactly one DictKey, no nested path components.  `restore_flat`
# unwraps these so flat-dict states round-trip with their original keys.
_FLAT_DICT_KEY = re.compile(r"^\['([^]\[']*)'\]$")


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        async_write: bool = True,
        save_on_sigterm: bool = False,
    ):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_state_fn: Optional[Callable[[], tuple[int, Any]]] = None
        if save_on_sigterm:
            signal.signal(signal.SIGTERM, self._sigterm)

    # -- save -----------------------------------------------------------------

    def save(
        self, step: int, state, *, block: bool = False, meta: Optional[dict] = None
    ) -> None:
        """Snapshot (device->host now) and write (async unless block=True).

        ``meta`` (JSON-able) is stored in the manifest and returned by
        `read_meta` / `restore_flat` — construction parameters the restorer
        needs but that aren't arrays (e.g. the service's tenant specs).
        """
        self.wait()  # never two writers in flight (same-step collisions)
        host = _flatten(jax.device_get(state))
        if self.async_write and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(
        self, step: int, host: dict[str, np.ndarray], meta: Optional[dict] = None
    ) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + f".{os.getpid()}-{threading.get_ident()}.tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": sorted(host.keys()),
            "nbytes": int(sum(a.nbytes for a in host.values())),
        }
        if meta is not None:
            manifest["meta"] = meta
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "manifest.json"))
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    # -- restore ----------------------------------------------------------------

    def restore(
        self,
        step: int,
        template,
        shardings=None,
    ):
        """Rebuild `template`'s pytree from disk; device_put with `shardings`.

        `template` is any pytree of arrays/ShapeDtypeStructs with the target
        structure; `shardings` (same structure, or None) enables elastic
        restore onto the current mesh.
        """
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = jax.tree_util.keystr(p)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint leaf {key}: shape {arr.shape} != template {leaf.shape}"
                )
            leaves.append(arr.astype(leaf.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree

    def restore_flat(self, step: int) -> tuple[dict[str, np.ndarray], dict]:
        """Template-free restore: (flat key -> array, manifest meta).

        For states whose leaf shapes only the checkpoint knows (the service's
        packed slabs drift with ingested deltas); the caller reconstructs its
        objects from the arrays plus the JSON ``meta`` recorded at save time.
        States saved as a flat `{str: array}` dict round-trip with their
        original keys (the keystr wrapping `save` applies is undone here);
        nested-pytree keys come back keystr-rendered unchanged.
        """
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {}
        with np.load(os.path.join(path, "arrays.npz")) as data:
            for k in data.files:
                m = _FLAT_DICT_KEY.match(k)
                arrays[m.group(1) if m else k] = data[k].copy()
        return arrays, manifest.get("meta", {})

    def read_meta(self, step: int) -> dict:
        """The JSON ``meta`` recorded with `save` (empty dict when absent)."""
        path = os.path.join(self.directory, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f).get("meta", {})

    # -- preemption -------------------------------------------------------------

    def attach_state_provider(self, fn: Callable[[], tuple[int, Any]]) -> None:
        """fn() -> (step, state) used by the SIGTERM hook."""
        self._last_state_fn = fn

    def _sigterm(self, signum, frame):
        if self._last_state_fn is not None:
            step, state = self._last_state_fn()
            self.save(step, state, block=True)
        raise SystemExit(143)
