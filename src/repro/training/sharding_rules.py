"""Logical parameter/activation sharding rules (Megatron TP + optional FSDP).

Rules are keyed on the *owning* weight name in the param tree path (the
parent of the "w"/"b" leaf), classifying each 2D/3D weight as column-parallel
(output dim on the tp axis) or row-parallel (input dim on the tp axis); FSDP
additionally shards the complementary dim over the dp axes.  Stacked scan
params ([L, ...]) keep the leading layer dim unsharded.

Dims that do not divide the mesh axis size silently drop that axis
(`maybe_shard`) — e.g. starcoder2's 36 heads on a 16-way tp axis fall back to
sharding the flattened H*Dh projection dim, and mamba2's 50280-row vocab
stays replicated.  This keeps every spec legal for pjit while preserving as
much parallelism as the published dims allow.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.models.config import ModelConfig, ShardingProfile

__all__ = ["maybe_shard", "param_pspecs", "batch_pspecs", "cache_pspecs", "named"]

# column-parallel: output feature dim sharded on tp
_COL = {
    "wq", "wk", "wv", "w_gate", "w_up", "wq_a", "wq_b", "wkv_a", "wkv_b",
    "in_proj", "router",
}
# row-parallel: input feature dim sharded on tp
_ROW = {"wo", "w_down", "out_proj"}


def _axis_size(mesh: Mesh, axes: Union[str, tuple]) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def maybe_shard(dim: int, axes, mesh: Mesh):
    """axes if dim divides their product, else None (replicated dim)."""
    if axes is None:
        return None
    size = _axis_size(mesh, axes)
    return axes if dim % size == 0 else None


def _owner(path) -> str:
    """Owning weight name: parent key of a 'w'/'b' leaf, else the leaf key."""
    keys = [k.key for k in path if isinstance(k, DictKey)]
    if not keys:
        return ""
    if keys[-1] in ("w", "b") and len(keys) >= 2:
        return keys[-2]
    return keys[-1]


def _in_stack(path) -> bool:
    for k in path:
        if isinstance(k, DictKey) and k.key in ("blocks", "enc_blocks", "dec_blocks"):
            return True
    return False


def param_pspecs(
    params_shape,
    mesh: Mesh,
    profile: ShardingProfile,
) -> dict:
    """PartitionSpec pytree for a param tree (pass eval_shape output)."""
    tp = profile.tp_axis
    dp = tuple(profile.dp_axes) if profile.fsdp else None

    def rule(path, leaf):
        name = _owner(path)
        shape = leaf.shape
        off = 1 if _in_stack(path) else 0
        nd = len(shape) - off
        lead = (None,) * off
        if name == "embed":  # [V, d]
            return P(
                maybe_shard(shape[0], tp, mesh),
                maybe_shard(shape[1], dp, mesh) if dp else None,
            )
        if name == "lm_head":  # [d, V]
            return P(
                maybe_shard(shape[0], dp, mesh) if dp else None,
                maybe_shard(shape[1], tp, mesh),
            )
        if nd == 3 and name in ("w_gate", "w_up"):  # experts [E, d, f]
            return P(*lead,
                     maybe_shard(shape[off], tp, mesh),
                     maybe_shard(shape[off + 1], dp, mesh) if dp else None,
                     None)
        if nd == 3 and name == "w_down":  # experts [E, f, d]
            return P(*lead,
                     maybe_shard(shape[off], tp, mesh),
                     maybe_shard(shape[off + 1], dp, mesh) if dp else None,
                     None)
        if nd == 2 and name in _COL:
            return P(*lead,
                     maybe_shard(shape[off], dp, mesh) if dp else None,
                     maybe_shard(shape[off + 1], tp, mesh))
        if nd == 2 and name in _ROW:
            return P(*lead,
                     maybe_shard(shape[off], tp, mesh),
                     maybe_shard(shape[off + 1], dp, mesh) if dp else None)
        if nd == 2 and name == "conv_w":  # [W, C] depthwise conv
            return P(*lead, None, maybe_shard(shape[off + 1], tp, mesh))
        # norms, biases, scalars: replicated (beyond the stack dim)
        return P(*lead, *((None,) * nd))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_pspecs(batch_shape, profile: ShardingProfile, mesh: Mesh) -> dict:
    """Shard every batch input on its leading (batch) dim over the dp axes."""
    dp = tuple(profile.dp_axes)

    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        return P(maybe_shard(leaf.shape[0], dp, mesh), *((None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_pspecs(cache_shape, cfg: ModelConfig, profile: ShardingProfile, mesh: Mesh):
    """KV/state cache sharding for serving.

    Layout [L, B, S, K, Dh] (attention) / [L, B, ...] (ssm states): batch over
    dp; the cache *sequence* dim over tp (GQA kv-head counts rarely divide a
    16-way tp axis, and seq-sharding makes decode attention a distributed
    flash-decoding combine, which XLA emits automatically from the softmax).
    """
    tp = profile.tp_axis
    dp = tuple(profile.dp_axes)

    def rule(path, leaf):
        keys = [k.key for k in path if isinstance(k, DictKey)]
        name = keys[-1] if keys else ""
        sh = leaf.shape
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
                    "attn_k", "attn_v", "prefix_k", "prefix_v"):
            # [L, B, S, K, Dh]
            return P(None, maybe_shard(sh[1], dp, mesh),
                     maybe_shard(sh[2], tp, mesh), None, None)
        if name in ("latent", "prefix_latent"):  # [L, B, S, r]
            return P(None, maybe_shard(sh[1], dp, mesh),
                     maybe_shard(sh[2], tp, mesh), None)
        if name.endswith("_scale"):  # int8 cache scales [L, B, S, K]
            return P(None, maybe_shard(sh[1], dp, mesh),
                     maybe_shard(sh[2], tp, mesh), None)
        if name == "h":  # ssm state [L, B, H, P, N]
            return P(None, maybe_shard(sh[1], dp, mesh),
                     maybe_shard(sh[2], tp, mesh), None, None)
        if name == "conv":  # [L, B, W-1, conv_dim]
            return P(None, maybe_shard(sh[1], dp, mesh), None,
                     maybe_shard(sh[3], tp, mesh))
        return P(*((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def named(mesh: Mesh, tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
