"""Training substrate: optimizer, sharded train step, fault-tolerant loop."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training.sharding_rules import param_pspecs, batch_pspecs, maybe_shard
from repro.training.train_step import TrainState, make_train_step, init_train_state

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "lr_schedule",
    "param_pspecs",
    "batch_pspecs",
    "maybe_shard",
    "TrainState",
    "make_train_step",
    "init_train_state",
]
