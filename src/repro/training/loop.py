"""Fault-tolerant training loop: checkpoint/resume, bounded retry, preemption.

The loop composes the substrate pieces:
  * resume: restores the latest checkpoint and *skips ahead* in the
    deterministic data pipeline (batch k is a pure function of k);
  * periodic + final checkpoints via the atomic async CheckpointManager;
  * bounded retry around the step (transient-failure tolerance — on real
    fleets this wraps DCN flakes and preempted hosts; semantics identical);
  * SIGTERM -> synchronous save -> clean exit (preemption handling).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, latest_step
from repro.data.pipeline import SyntheticLMData
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.train")

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    save_every: int = 50
    keep: int = 3
    max_retries: int = 2
    log_every: int = 10


def train_loop(
    model: Model,
    data: SyntheticLMData,
    opt_cfg: AdamWConfig,
    loop_cfg: TrainLoopConfig,
    ckpt_dir: Optional[str] = None,
    *,
    mesh=None,
    profile=None,
    state: Optional[TrainState] = None,
    step_fn: Optional[Callable] = None,
    on_step: Optional[Callable[[int, dict], None]] = None,
) -> TrainState:
    if step_fn is None:
        if mesh is not None:
            step_fn, state_shardings, _ = make_train_step(
                model, opt_cfg, mesh, profile
            )
        else:
            def step_fn_(state, batch):
                import jax.numpy as jnp
                from repro.training.optimizer import adamw_update

                loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
                params, opt, metrics = adamw_update(
                    opt_cfg, grads, state.opt, state.params
                )
                return TrainState(params, opt, state.step + 1), dict(
                    metrics, loss=loss
                )

            step_fn = jax.jit(step_fn_, donate_argnums=(0,))
            state_shardings = None

    mgr = (
        CheckpointManager(ckpt_dir, keep=loop_cfg.keep, save_on_sigterm=True)
        if ckpt_dir
        else None
    )
    start = 0
    if state is None:
        state = init_train_state(model, jax.random.key(0))
    if mgr is not None:
        last = latest_step(ckpt_dir)
        if last is not None:
            template = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
            )
            state = mgr.restore(last, template, shardings=None)
            state = jax.tree.map(jax.numpy.asarray, state)
            start = last
            log.info("resumed from step %d", last)
        mgr.attach_state_provider(lambda: (int(state.step), state))

    t0 = time.time()
    for k in range(start, loop_cfg.total_steps):
        batch = data(k)
        for attempt in range(loop_cfg.max_retries + 1):
            try:
                state, metrics = step_fn(state, batch)
                break
            except Exception:  # bounded retry on transient failure
                if attempt == loop_cfg.max_retries:
                    if mgr:
                        mgr.save(k, state, block=True)
                    raise
                log.exception("step %d failed (attempt %d); retrying", k, attempt)
        if on_step is not None:
            on_step(k, metrics)
        if loop_cfg.log_every and (k + 1) % loop_cfg.log_every == 0:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            log.info("step %d loss %.4f (%.2fs)", k + 1, loss, dt)
        if mgr and (k + 1) % loop_cfg.save_every == 0:
            mgr.save(k + 1, state)
    if mgr:
        mgr.save(loop_cfg.total_steps, state, block=True)
        mgr.wait()
    return state
