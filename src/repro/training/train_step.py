"""Sharded train step: loss -> grad -> clip -> AdamW, with microbatching.

`make_train_step` builds the jit'd step with explicit in/out shardings from
the profile's rules; XLA GSPMD then propagates TP/FSDP through the model
(Megatron-style collectives fall out of the param shardings).  Gradient
accumulation scans over microbatches so the 256-sequence global batches fit
per-device memory with large models.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShardingProfile
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, OptState, adamw_init, adamw_update
from repro.training.sharding_rules import batch_pspecs, named, param_pspecs

__all__ = ["TrainState", "init_train_state", "make_train_step"]


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    step: jax.Array


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params, opt=adamw_init(params), step=jnp.zeros((), jnp.int32)
    )


def state_pspecs(model: Model, mesh: Mesh, profile: ShardingProfile) -> TrainState:
    pshape = jax.eval_shape(model.init, jax.random.key(0))
    pspec = param_pspecs(pshape, mesh, profile)
    return TrainState(
        params=pspec,
        opt=OptState(m=pspec, v=pspec, count=P()),
        step=P(),
    )


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    profile: ShardingProfile,
    *,
    microbatches: int = 1,
    donate: bool = True,
):
    """Returns (jit'd step fn, state_shardings, batch_sharding_fn)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step_fn(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                acc_loss, acc_g = carry
                return (
                    acc_loss + loss / microbatches,
                    jax.tree.map(lambda a, g: a + g / microbatches, acc_g, grads),
                ), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro
            )
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        params, opt, metrics = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    sspec = state_pspecs(model, mesh, profile)
    state_shardings = named(mesh, sspec)

    def batch_shardings(batch_shape):
        return named(mesh, batch_pspecs(batch_shape, profile, mesh))

    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_shardings, batch_shardings


def activation_sharding(
    cfg: ModelConfig, mesh: Mesh, profile: ShardingProfile, seq: int
):
    """Sequence-parallel residual-stream sharding (batch over dp, seq over tp
    when divisible) — caps the per-layer saved activations in scan."""
    from repro.training.sharding_rules import maybe_shard

    return NamedSharding(
        mesh,
        P(profile.dp_axes, maybe_shard(seq, profile.tp_axis, mesh), None),
    )


def lower_train_step(
    cfg: ModelConfig,
    batch_specs: dict,
    mesh: Mesh,
    profile: ShardingProfile,
    opt_cfg: Optional[AdamWConfig] = None,
    *,
    microbatches: int = 1,
):
    """Dry-run entry: .lower() the train step on ShapeDtypeStructs only."""
    model = Model(cfg)
    seq = (batch_specs.get("embeds") or batch_specs["tokens"]).shape[1] if cfg.encdec else batch_specs["labels"].shape[1]
    model.act_sharding = activation_sharding(cfg, mesh, profile, seq)
    opt_cfg = opt_cfg or AdamWConfig()

    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        params, opt, metrics = adamw_update(opt_cfg, grads, state.opt, state.params)
        return TrainState(params=params, opt=opt, step=state.step + 1), dict(
            metrics, loss=loss
        )

    sspec = state_pspecs(model, mesh, profile)
    state_shardings = named(mesh, sspec)
    bshard = named(mesh, batch_pspecs(batch_specs, profile, mesh))
    state_shape = jax.eval_shape(
        partial(init_train_state, model), jax.random.key(0)
    )
    return jax.jit(
        step_fn,
        in_shardings=(state_shardings, bshard),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    ).lower(state_shape, batch_specs)
