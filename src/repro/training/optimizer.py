"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX).

Optimizer state inherits the parameter sharding (see sharding_rules): with the
fsdp profile the fp32 masters and both moments are sharded over data x model —
ZeRO-3-style memory scaling without a separate partitioner.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    # NB: jnp.sum over the original dims, NOT vdot — vdot ravels to 1D, which
    # cannot represent a multi-axis sharding and forces XLA to all-gather the
    # full parameter (77 GB buffers on the 72B configs).
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step_ = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return p - step_, m, v

    # params trees contain only dict/list containers, so a tuple marks one
    # leaf-level (p, m, v) result
    out = jax.tree.map(upd, params, grads, state.m, state.v)
    is_result = lambda t: isinstance(t, tuple)
    pick = lambda i: jax.tree.map(lambda t: t[i], out, is_leaf=is_result)
    return (
        pick(0),
        OptState(m=pick(1), v=pick(2), count=count),
        {"grad_norm": gnorm, "lr": lr},
    )
