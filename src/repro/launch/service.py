"""Recurring-solve service demo: multi-tenant cadences end-to-end.

    PYTHONPATH=src python -m repro.launch.service \
        [--sources 2000] [--tenants 4] [--cadences 3] [--verify] \
        [--checkpoint-dir ckpts/service] [--resume] [--dry-run] \
        [--metrics-out m.jsonl] [--trace-out t.json] [--prom-out m.prom]

Simulates a production serving loop: N tenants share one eligibility topology
(so their packed shapes match and the scheduler batches them into ONE vmapped
solve), each cadence applies per-tenant deltas (cost updates, a few edge
inserts/deletes inside the padding headroom, budget jitter), and every solve
after the first warm-starts from the tenant's previous duals on a shortened
continuation schedule with convergence-based early stopping.  Slabs stay
device-resident across cadences: each solve reports its host→device upload —
one full O(nnz) transfer at bootstrap, then O(delta) scatter plans.

`--checkpoint-dir` persists every tenant session (duals, edge-space primal,
packed slabs + occupancy maps, continuation position) after each cadence via
`repro.checkpoint.CheckpointManager`; `--resume` restarts from the latest
checkpoint so every tenant's first solve after the restart is WARM, not cold.

`--dry-run` builds the fleet, ingests one delta per tenant and prints the
O(delta) scatter-plan sizes without solving — the CI docs job runs this to
prove the quickstart snippet stays executable.

`--verify` additionally cross-checks, for one tenant, the warm-started
delta-updated solve against a cold full-budget solve of the same mutated
instance (same final objective/violation, fewer iterations) and the batched
pool against sequential per-tenant solves.

Telemetry exports (see docs/observability.md):

  * `--metrics-out m.jsonl` appends schema-validated JSONL records (one
    `cadence` per scheduler cadence, one `solve_report` + `convergence` per
    tenant solve, one `ingest` per delta, a final `counters` snapshot) —
    validate with `python tools/check_metrics.py m.jsonl`;
  * `--trace-out t.json` writes a Chrome-trace-event file of the nested
    cadence→solve spans, loadable in Perfetto / chrome://tracing;
  * `--prom-out m.prom` writes a Prometheus text-exposition snapshot of the
    metrics registry.
"""
from __future__ import annotations

import argparse
import time


def _random_delta(edge_list, rng, *, frac_update=0.02, n_insert=3, n_delete=3,
                  rhs_jitter=0.02):
    import numpy as np

    from repro.instances import InstanceDelta

    spec = edge_list.spec
    m, J, I = spec.num_families, spec.num_destinations, spec.num_sources
    nnz = edge_list.nnz
    n_upd = max(1, int(frac_update * nnz))
    perm = rng.permutation(nnz)
    upd, dele = perm[:n_upd], perm[n_upd : n_upd + n_delete]
    existing = set((edge_list.src * J + edge_list.dst).tolist())
    ins_s, ins_d = [], []
    while len(ins_s) < n_insert:
        s, d = int(rng.integers(I)), int(rng.integers(J))
        if s * J + d not in existing:
            existing.add(s * J + d)
            ins_s.append(s)
            ins_d.append(d)
    return InstanceDelta(
        insert_src=ins_s,
        insert_dst=ins_d,
        insert_values=rng.uniform(0.1, 3.0, n_insert),
        insert_coeff=rng.uniform(0.1, 2.0, (m, n_insert)),
        delete_src=edge_list.src[dele],
        delete_dst=edge_list.dst[dele],
        update_src=edge_list.src[upd],
        update_dst=edge_list.dst[upd],
        update_values=edge_list.values[upd]
        * rng.uniform(0.9, 1.1, n_upd),
        rhs=np.asarray(edge_list.rhs)
        * rng.uniform(1 - rhs_jitter, 1 + rhs_jitter, m * J),
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sources", type=int, default=2000)
    ap.add_argument("--destinations", type=int, default=40)
    ap.add_argument("--families", type=int, default=1)
    ap.add_argument("--avg-degree", type=float, default=6.0)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--cadences", type=int, default=3)
    ap.add_argument("--iters-per-stage", type=int, default=150)
    ap.add_argument("--tol-grad", type=float, default=1e-4)
    ap.add_argument("--tol-viol", type=float, default=1e-4)
    ap.add_argument("--drift-sla", type=float, default=0.25)
    ap.add_argument("--row-headroom", type=int, default=8)
    ap.add_argument("--fused-oracle", action="store_true",
                    help="one-pass fused dual oracle inside every solve")
    ap.add_argument("--sigma-reuse-threshold", type=float, default=None,
                    help="warm cadences with ||dc|| at or below this skip "
                         "the power iteration (reuse previous sigma_sq)")
    ap.add_argument("--engine", default="agd",
                    choices=["agd", "pdhg", "auto"],
                    help="solver engine for every tenant, or 'auto' for the "
                         "per-tenant adaptive selector (docs/solvers.md); "
                         "the routed engine shows up in each solve_report")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="cross-check warm vs cold and batched vs sequential")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist all tenant sessions after each cadence")
    ap.add_argument("--resume", action="store_true",
                    help="restore from the latest checkpoint in "
                         "--checkpoint-dir; the first solve resumes warm")
    ap.add_argument("--dry-run", action="store_true",
                    help="build the fleet and ingest one delta per tenant "
                         "(print scatter-plan sizes) without solving")
    ap.add_argument("--metrics-out", default=None,
                    help="append telemetry JSONL records here "
                         "(schema: repro.telemetry.SCHEMA)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace-event (Perfetto) span file")
    ap.add_argument("--prom-out", default=None,
                    help="write a Prometheus text-exposition snapshot")
    args = ap.parse_args()

    import numpy as np

    from repro import telemetry
    from repro.core import MaximizerConfig
    from repro.instances import MatchingInstanceSpec, generate_matching_instance
    from repro.service import (
        BatchedSolvePool,
        Scheduler,
        ServiceConfig,
        compiled_solver,
        instance_nbytes,
        shape_signature,
        to_solve_result,
    )

    rng = np.random.default_rng(args.seed)
    spec = MatchingInstanceSpec(
        num_sources=args.sources,
        num_destinations=args.destinations,
        avg_degree=args.avg_degree,
        num_families=args.families,
        seed=args.seed,
    )
    base = generate_matching_instance(spec)
    print(f"base instance: {base.nnz} nnz, dual_dim={spec.num_families * args.destinations}")

    cfg = ServiceConfig(
        cold=MaximizerConfig(
            iters_per_stage=args.iters_per_stage,
            tol_grad=args.tol_grad,
            tol_viol=args.tol_viol,
        ),
        drift_sla_rel=args.drift_sla,
        row_headroom=args.row_headroom,
        fused_oracle=args.fused_oracle,
        sigma_reuse_dc_threshold=args.sigma_reuse_threshold,
        engine=args.engine,
    )
    sched = Scheduler(cfg)

    sink = telemetry.JsonlSink(args.metrics_out) if args.metrics_out else None

    def emit_ingest(name, rep):
        if sink is None or rep is None:
            return
        sink.emit("ingest", {
            "tenant": name,
            "in_place": rep.in_place,
            "n_insert": rep.n_insert,
            "n_delete": rep.n_delete,
            "n_update": rep.n_update,
            "rebucketized": rep.rebucketized,
            "plan_cells": None if rep.plan is None else rep.plan.num_cells,
            "plan_bytes": None if rep.plan is None else rep.plan.nbytes,
        })

    def emit_cadence(cadence, out, wall):
        if sink is None:
            return
        n = len(out.reports)
        n_batched = sum(len(g) for g in out.batched_groups)
        sink.emit("cadence", {
            "cadence": cadence,
            "tenants": n,
            "batched_fraction": (n_batched / n) if n else 0.0,
            "upload_bytes": sum(
                r["upload_bytes"] or 0 for r in out.reports.values()
            ),
            "overlapped": False,
            "wall_seconds": wall,
        })
        for name in sorted(out.reports):
            r = out.reports[name]
            sink.emit(
                "solve_report",
                {k: v for k, v in r.items() if k != "convergence"},
            )
            if r.get("convergence"):
                sink.emit("convergence", r["convergence"])
        for name, rep in out.ingest.items():
            emit_ingest(name, rep)

    def export_telemetry():
        if sink is not None:
            sink.emit_counters()
            sink.close()
            print(f"telemetry: metrics JSONL appended to {args.metrics_out}")
        if args.trace_out:
            telemetry.get_tracer().export_chrome_trace(args.trace_out)
            print(f"telemetry: chrome trace written to {args.trace_out}")
        if args.prom_out:
            telemetry.write_prometheus(args.prom_out)
            print(f"telemetry: prometheus snapshot written to {args.prom_out}")

    mgr = None
    start_cadence = 0
    if args.checkpoint_dir:
        from repro.checkpoint import CheckpointManager, latest_step

        mgr = CheckpointManager(args.checkpoint_dir, keep=3)
        last = latest_step(args.checkpoint_dir) if args.resume else None
        if last is not None:
            sched.restore_checkpoint(mgr, last)
            start_cadence = last + 1
            print(
                f"resumed {len(sched.sessions)} tenants from "
                f"{args.checkpoint_dir}/step_{last:08d} — first solve is WARM"
            )
    if not sched.sessions:
        for t in range(args.tenants):
            sched.add_tenant(f"tenant{t}", base)

    if args.dry_run:
        for name, sess in sched.sessions.items():
            with telemetry.span("dry_run_ingest", tenant=name):
                rep = sess.ingest(
                    _random_delta(sess.ingestor.to_edge_list(), rng)
                )
            emit_ingest(name, rep)
            plan = rep.plan
            print(
                f"  {name}: delta +{rep.n_insert}/-{rep.n_delete}/~{rep.n_update}"
                f" -> plan cells={plan.num_cells} bytes={plan.nbytes}"
                f" (full slab upload would be "
                f"{instance_nbytes(sess.instance())}B)"
                if plan is not None
                else f"  {name}: re-bucketize fallback ({rep.fallback_reason})"
            )
        export_telemetry()
        print("DRY-RUN OK (no solves executed)")
        return 0

    for cadence in range(start_cadence, start_cadence + args.cadences):
        deltas = {}
        if cadence > 0:  # day 0 is the cold bootstrap of the shared topology
            for name, sess in sched.sessions.items():
                deltas[name] = _random_delta(sess.ingestor.to_edge_list(), rng)
        t0 = time.time()
        out = sched.run_cadence(deltas)
        dt = time.time() - t0
        emit_cadence(cadence, out, dt)
        if mgr is not None:
            # async save: the write overlaps the next cadence; the final
            # mgr.wait() below keeps interpreter exit from killing the
            # daemon writer mid-checkpoint
            sched.save_checkpoint(mgr, cadence)
        n_batched = sum(len(g) for g in out.batched_groups)
        print(
            f"\ncadence {cadence}: {dt:.1f}s  "
            f"batched {n_batched}/{len(out.reports)} tenants "
            f"in {len(out.batched_groups)} vmapped call(s), "
            f"solo={out.solo_tenants}"
        )
        for name in sorted(out.reports):
            r = out.reports[name]
            ing = out.ingest.get(name)
            ing_s = (
                ""
                if ing is None
                else f"  delta[{'in-place' if ing.in_place else 'REPACK'}"
                f" +{ing.n_insert}/-{ing.n_delete}/~{ing.n_update}]"
            )
            drift = (
                "drift n/a"
                if r["drift_rel"] is None
                else f"drift_rel={r['drift_rel']:.3e} "
                f"(bound {r['drift_bound']:.2e}) sla_ok={r['sla_ok']}"
            )
            sigma_s = " sigma[reused]" if r.get("sigma_reused") else ""
            print(
                f"  {name}: {r['mode']:4s} [{r['engine']}] "
                f"iters {r['iters_used']}/{r['iter_budget']}"
                f" g={r['g']:.4f} viol={r['max_violation']:.2e} "
                f"up[{r['upload_mode']}:{r['upload_bytes']}B] {drift}{sigma_s}{ing_s}"
            )

    if mgr is not None:
        mgr.wait()  # flush the last async checkpoint before exiting

    export_telemetry()

    if args.verify:
        print("\n-- verify: warm+early-stop vs cold full budget ----------------")
        sess = sched.sessions["tenant0"]
        inst = sess.instance()
        # warm numbers from the last cadence report
        warm_r = sess.last_report
        full_cfg = MaximizerConfig(iters_per_stage=args.iters_per_stage)
        # Pin the cold reference to the engine that served the warm cadence:
        # under engine="auto" the selector's exploration may route
        # consecutive cadences to different engines, and the agd (smoothed
        # dual) and pdhg (exact LP) objectives differ by O(gamma) — the
        # same-quality check is only meaningful within one engine.
        verify_engine = warm_r["engine"]
        cold = to_solve_result(
            compiled_solver(full_cfg, cfg.normalize, engine=verify_engine)(
                inst, np.zeros(inst.dual_dim, np.float32)
            )
        )
        g_rel = abs(warm_r["g"] - float(cold.g)) / max(abs(float(cold.g)), 1e-9)
        print(
            f"  cold: [{verify_engine}] iters {full_cfg.total_iters} "
            f"g={float(cold.g):.4f} "
            f"viol={float(cold.stats[-1].max_violation[-1]):.2e}"
        )
        print(
            f"  warm: iters {warm_r['iters_used']} g={warm_r['g']:.4f} "
            f"viol={warm_r['max_violation']:.2e}  rel-dg={g_rel:.2e}"
        )
        ok_g = g_rel < 1e-3
        ok_iters = warm_r["iters_used"] < full_cfg.total_iters
        print(f"  same-quality={ok_g} fewer-iters={ok_iters}")

        print("-- verify: batched pool vs sequential -------------------------")
        insts = [s.instance() for s in sched.sessions.values()]
        sig = {shape_signature(i) for i in insts}
        pool_res = BatchedSolvePool(cfg.cold, normalize=cfg.normalize).solve(insts)
        seq_fn = compiled_solver(cfg.cold, cfg.normalize)
        max_rel = 0.0
        for i, inst_i in enumerate(insts):
            seq = to_solve_result(
                seq_fn(inst_i, np.zeros(inst_i.dual_dim, np.float32))
            )
            max_rel = max(
                max_rel,
                abs(float(pool_res[i].g) - float(seq.g))
                / max(abs(float(seq.g)), 1e-9),
            )
        print(
            f"  {len(insts)} tenants, {len(sig)} shape signature(s), "
            f"max rel objective diff batched-vs-seq: {max_rel:.2e}"
        )
        if not (ok_g and ok_iters and max_rel < 1e-3 and len(sig) == 1):
            print("VERIFY FAILED")
            return 1
        print("VERIFY OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
