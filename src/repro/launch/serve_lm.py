"""LM-demo serving CLI: batched request engine over a reduced arch config.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen3-8b \
        --requests 8 --max-new 24

The allocation-serving CLI (duals, not tokens) lives in
``repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models.model import Model
    from repro.serving.lm_demo.engine import Request, ServeEngine

    cfg = get_reduced_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(
        model, params, slots=args.slots,
        max_seq=args.prompt_len + args.max_new + 8,
    )
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"{args.requests} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
