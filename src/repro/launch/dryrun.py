import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: for the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, every
cell's step function must .lower().compile() under its production shardings.
The compiled artifact yields memory_analysis() (fits-in-HBM evidence) and
cost_analysis() + parsed collective bytes (the §Roofline inputs).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single_pod
  python -m repro.launch.dryrun --solver s100M-d10K --mesh multi_pod
  python -m repro.launch.dryrun --all --jobs 6 --out results/dryrun
(The XLA_FLAGS line above must run before any jax import; spawned --all
workers inherit it through this module.)
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp


def _mesh(mesh_name: str):
    from repro.launch.mesh import make_production_mesh

    return make_production_mesh(multi_pod=(mesh_name == "multi_pod"))


def _cost_analysis(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    return ca


def _oracle_partial_bytes(bucket, num_destinations: int, num_families: int) -> float:
    from repro.kernels.ops import oracle_hist_partial_bytes

    n, L = (int(s) for s in bucket.cost.shape)
    return float(oracle_hist_partial_bytes(n, L, num_families, num_destinations))


def run_arch_cell(arch: str, shape_name: str, mesh_name: str,
                  moe_groups: int = 0, kv_dtype: str = "") -> dict:
    import dataclasses as _dc

    from repro.analysis.hlo_stats import collective_stats
    from repro.configs import SHAPES, get_config, input_specs, skip_reason
    from repro.launch.mesh import default_profile
    from repro.models.model import Model
    from repro.serving.lm_demo.steps import lower_decode_step, lower_prefill
    from repro.training.train_step import lower_train_step

    cfg = get_config(arch)
    if moe_groups and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, groups=moe_groups))
    if kv_dtype:
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dtype)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"cell": f"{arch}/{shape_name}/{mesh_name}", "status": "skip",
                "reason": reason}
    mesh = _mesh(mesh_name)
    model = Model(cfg)
    specs = input_specs(cfg, shape, model)
    profile = default_profile(cfg, mesh)

    t0 = time.time()
    if shape.kind == "train":
        lowered = lower_train_step(cfg, specs, mesh, profile)
    elif shape.kind == "prefill":
        lowered = lower_prefill(cfg, specs, mesh, profile)
    else:
        lowered = lower_decode_step(cfg, specs, mesh, profile)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = _cost_analysis(compiled)
    print(compiled.memory_analysis())
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed", "transcendentals")})
    hlo = compiled.as_text()
    coll = collective_stats(hlo, loop_aware=True)
    coll_static = collective_stats(hlo)

    from repro.analysis.flops_model import cell_cost

    cost = cell_cost(cfg, shape)

    n = model.param_count()
    n_active = model.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence; 2*N per token + cache read
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    return {
        "cell": f"{arch}/{shape_name}/{mesh_name}",
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "chips": int(mesh.size),
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "params": n,
        "active_params": n_active,
        "model_flops": model_flops,
        "hlo_flops_per_device": float(ca.get("flops", 0.0)),
        "hlo_bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        # analytic totals (XLA statics count while bodies once; see
        # analysis/flops_model.py + tests/test_flops_model.py validation)
        "flops_global": cost.flops,
        "bytes_global": cost.bytes,
        "layer_fwd_flops": cost.layer_fwd_flops,
        "extra_flops": cost.extra_flops,
        "collectives": {"counts": coll["counts"], "bytes": coll["bytes"]},
        "coll_bytes_per_device": coll["total_bytes"],
        "coll_bytes_per_device_static": coll_static["total_bytes"],
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
    }


def run_solver_cell(inst_name: str, mesh_name: str, *, comm_mode="psum",
                    compress="none", iters: int = 100,
                    slab_dtype: str = "float32",
                    fused_kernel: bool = False,
                    fused_oracle: bool = False,
                    tol_grad: Optional[float] = None,
                    tol_viol: Optional[float] = None,
                    formulation: str = "matching",
                    engine: str = "agd") -> dict:
    from repro.analysis.hlo_stats import collective_stats
    from repro.configs import LP_INSTANCES
    from repro.core.maximizer import MaximizerConfig
    from repro.core.sharding import DistConfig, DistributedMaximizer
    from repro.kernels import ops as kops
    from repro.formulation import scenario_formulation
    from repro.instances.specs import solver_input_specs
    from repro.launch.mesh import solver_axes

    if formulation != "matching" and (fused_kernel or fused_oracle):
        raise ValueError("fused kernels implement the simplex feasible set; "
                         "only the matching formulation can use them")
    engine = "agd" if engine == "auto" else engine  # auto: service policy
    if engine == "pdhg":
        if formulation != "matching":
            raise ValueError("engine pdhg solves the simplex-constrained "
                             "matching LP; only formulation matching applies")
        if fused_kernel:
            raise ValueError("engine pdhg fuses its prox step through the "
                             "one-pass dual oracle; use fused_oracle")
    # The spec-shaped dry-run has no concrete instance to attach a spec to,
    # so lower the feasible set directly and hand the DistributedMaximizer
    # its projection (the supported zero-sharding-edits path).
    projection = scenario_formulation(formulation).shared_projection()
    mesh = _mesh(mesh_name)
    axes = solver_axes(mesh)
    n_shards = int(mesh.size)
    spec = LP_INSTANCES[inst_name]
    inst = solver_input_specs(
        spec["num_sources"], spec["num_destinations"], spec["num_families"],
        spec["avg_degree"], shard_multiple=n_shards,
        dtype=jnp.dtype(slab_dtype),
    )
    # tol_grad/tol_viol lower the early-stop (psum'd-predicate while_loop)
    # stage variant instead of the fixed-budget scan — same coherence proof,
    # different collective program.
    cfg = MaximizerConfig(iters_per_stage=iters, tol_grad=tol_grad,
                          tol_viol=tol_viol)
    dist = DistConfig(axes=axes, comm_mode=comm_mode, compress=compress,
                      fused_kernel=fused_kernel, fused_oracle=fused_oracle,
                      kernel_interpret=True,
                      slab_dtype=jnp.dtype(slab_dtype).name)
    t0 = time.time()
    if engine == "pdhg":
        from repro.engines.pdhg import lower_pdhg_sharded

        lowered = lower_pdhg_sharded(inst, mesh, cfg, dist,
                                     projection=projection)
    else:
        dm = DistributedMaximizer(inst, mesh, cfg, dist,
                                  projection=projection)
        lowered = dm.lower_stage()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = _cost_analysis(compiled)
    print(compiled.memory_analysis())
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    coll = collective_stats(hlo, loop_aware=True)
    nnz = sum(
        float(jnp.prod(jnp.asarray(b.cost.shape))) for b in inst.buckets
    )  # upper bound incl. padding
    # useful work per stage: 2 SpMVs (2 flops/nnz each) per iteration
    model_flops = 4.0 * nnz * iters
    return {
        "cell": f"solver-{inst_name}/{comm_mode}+{compress}/{mesh_name}"
                + ("" if formulation == "matching" else f"/{formulation}")
                + ("" if engine == "agd" else f"/{engine}"),
        "arch": f"solver-{inst_name}",
        "formulation": formulation,
        "engine": engine,
        "shape": f"stage{iters}",
        "kind": "solver",
        "mesh": mesh_name,
        "chips": n_shards,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "model_flops": model_flops,
        "hlo_flops_per_device": float(ca.get("flops", 0.0)),
        "hlo_bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        # solver analytic per-stage totals: gather+axpy (2 flops/nnz-slot) and
        # segment-sum (2) per SpMV, projection sort network ~log2(L)^2/2
        # compare-exchanges, x iters; bytes: slabs read 2x + lam traffic
        "flops_global": float(
            iters * sum(
                (8 + b.length.bit_length() ** 2)
                * float(jnp.prod(jnp.asarray(b.cost.shape)))
                for b in inst.buckets
            )
        ),
        # per slot per iteration: the fused oracle reads the slab exactly
        # once — kops.oracle_slab_slot_bytes (idx + m coeff families + cost +
        # mask at the storage width, x written at the primal-out width) plus
        # the O(grid*m*J) partial-histogram write+read tree-sum; the unfused
        # paths additionally pay the z write+read (unfused primal) and the
        # gradient half's slab re-read — idx + coeff + x for the segment-sum
        # plus cost + x for the objective scalars (same model as
        # benchmarks/table2_iteration_time._analytic_bytes)
        "bytes_global": float(
            iters * sum(
                (kops.oracle_slab_slot_bytes(
                    spec["num_families"], jnp.dtype(slab_dtype).name)
                 if fused_oracle
                 else 4 + 3 * jnp.dtype(slab_dtype).itemsize
                 + jnp.dtype(slab_dtype).itemsize
                 + (0 if fused_kernel else 8)
                 + 4 + 4 * jnp.dtype(slab_dtype).itemsize)
                * float(jnp.prod(jnp.asarray(b.cost.shape)))
                + (_oracle_partial_bytes(b, spec["num_destinations"],
                                         spec["num_families"])
                   if fused_oracle else 0)
                for b in inst.buckets
            )
        ),
        "collectives": {"counts": coll["counts"], "bytes": coll["bytes"]},
        "coll_bytes_per_device": coll["total_bytes"],
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
    }


def _all_cells() -> list[tuple[str, str, str]]:
    from repro.configs import ARCH_IDS, SHAPES

    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single_pod", "multi_pod"):
                cells.append((arch, shape, mesh))
    return cells


def _driver(out_dir: str, jobs: int, solver: bool) -> int:
    """Spawn one subprocess per cell (isolated compile, parallel workers)."""
    os.makedirs(out_dir, exist_ok=True)
    work = [("arch", a, s, m) for a, s, m in _all_cells()]
    if solver:
        from repro.configs import LP_INSTANCES

        for name in LP_INSTANCES:
            for mesh in ("single_pod", "multi_pod"):
                work.append(("solver", name, "", mesh))
    procs: list[tuple[subprocess.Popen, str]] = []
    failures = 0

    def launch(item):
        kind = item[0]
        if kind == "arch":
            _, a, s, m = item
            tag = f"{a}__{s}__{m}"
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                   "--shape", s, "--mesh", m, "--out", out_dir]
        else:
            _, name, _, m = item
            tag = f"solver-{name}__{m}"
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--solver",
                   name, "--mesh", m, "--out", out_dir]
        if os.path.exists(os.path.join(out_dir, tag + ".json")):
            print("cached:", tag)
            return None
        log = open(os.path.join(out_dir, tag + ".log"), "w")
        return (subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT), tag)

    queue = list(work)
    while queue or procs:
        while queue and len(procs) < jobs:
            p = launch(queue.pop(0))
            if p is not None:
                procs.append(p)
        if not procs:
            break
        time.sleep(2)
        still = []
        for p, tag in procs:
            if p.poll() is None:
                still.append((p, tag))
            else:
                ok = p.returncode == 0
                if not ok:
                    failures += 1
                print(("PASS " if ok else "FAIL ") + tag, flush=True)
        procs = still
    return failures


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--solver")
    ap.add_argument("--comm-mode", default="psum")
    ap.add_argument("--compress", default="none")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--kv-dtype", default="")
    ap.add_argument("--slab-dtype", default="float32")
    ap.add_argument("--fused-kernel", action="store_true")
    ap.add_argument("--fused-oracle", action="store_true")
    ap.add_argument("--tol-grad", type=float, default=None)
    ap.add_argument("--tol-viol", type=float, default=None)
    ap.add_argument("--engine", default="agd",
                    choices=["agd", "pdhg", "auto"],
                    help="solver engine lowered for the solver cell "
                         "(docs/solvers.md); auto falls back to agd")
    ap.add_argument("--formulation", default="matching",
                    choices=["matching", "capacity-cap", "fairness-floor",
                             "budget-pacing"],
                    help="scenario formulation; lowers to the projection "
                         "handed to the distributed stage (solver cells only)")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--with-solver", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    args = ap.parse_args(argv)

    if args.all:
        return _driver(args.out, args.jobs, args.with_solver)

    try:
        if args.solver:
            rec = run_solver_cell(args.solver, args.mesh,
                                  comm_mode=args.comm_mode,
                                  compress=args.compress,
                                  slab_dtype=args.slab_dtype,
                                  fused_kernel=args.fused_kernel,
                                  fused_oracle=args.fused_oracle,
                                  tol_grad=args.tol_grad,
                                  tol_viol=args.tol_viol,
                                  formulation=args.formulation,
                                  engine=args.engine)
            tag = f"solver-{args.solver}__{args.mesh}"
            if args.comm_mode != "psum" or args.compress != "none":
                tag += f"__{args.comm_mode}-{args.compress}"
            if args.fused_oracle:
                tag += "__fusedoracle"
            if args.slab_dtype != "float32":
                tag += f"__{args.slab_dtype}"
            if args.tol_grad is not None or args.tol_viol is not None:
                tag += "__earlystop"
            if args.formulation != "matching":
                tag += f"__{args.formulation}"
            if args.engine != "agd":
                tag += f"__{args.engine}"
            if args.tag:
                tag += "__" + args.tag
        else:
            rec = run_arch_cell(args.arch, args.shape, args.mesh,
                                moe_groups=args.moe_groups,
                                kv_dtype=args.kv_dtype)
            tag = f"{args.arch}__{args.shape}__{args.mesh}"
            if args.tag:
                tag += "__" + args.tag
    except Exception:
        traceback.print_exc()
        return 1
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    print(json.dumps({k: rec[k] for k in ("cell", "status") if k in rec}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
