"""Production meshes.

Single pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod" axis
is pure data parallelism across the DCI; the solver's column shard flattens
all axes into one logical wafer.

Defined as functions (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat
from repro.models.config import ModelConfig, ShardingProfile

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "default_profile",
    "solver_axes",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "data"):
    """Small CPU mesh over however many host devices exist (tests/benchmarks)."""
    n = n or len(jax.devices())
    return compat.make_mesh((n,), (axis,))


def default_profile(cfg: ModelConfig, mesh) -> ShardingProfile:
    """TP for <=10B-active archs; TP+FSDP for the >=70B ones."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    big = cfg.param_count() >= 3e10
    return ShardingProfile(tp_axis="model", dp_axes=dp, fsdp=big)


def solver_axes(mesh) -> tuple[str, ...]:
    """The paper's column shard uses every mesh axis as one flat wafer."""
    return tuple(mesh.axis_names)
