"""LM training CLI over the assigned architecture pool.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 100 --batch 8 --seq 128 [--ckpt-dir DIR]

--reduced uses the smoke-scale config (CPU-runnable); full configs are for
real pods (their distribution is proven by `repro.launch.dryrun`).
"""
from __future__ import annotations

import argparse
import logging


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    from repro.configs import get_config, get_reduced_config
    from repro.data.pipeline import SyntheticLMData
    from repro.models.model import Model
    from repro.training.loop import TrainLoopConfig, train_loop
    from repro.training.optimizer import AdamWConfig

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    print(f"{cfg.name}: {model.param_count():,} params")
    data = SyntheticLMData(cfg, batch=args.batch, seq=args.seq, seed=0)
    state = train_loop(
        model,
        data,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
        TrainLoopConfig(total_steps=args.steps, save_every=args.save_every),
        ckpt_dir=args.ckpt_dir or None,
    )
    print(f"done at step {int(state.step)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
