"""Production solve CLI: the paper's workload end-to-end.

    PYTHONPATH=src python -m repro.launch.solve --sources 100000 \
        [--shards 1] [--comm-mode psum] [--compress none] [--fused-kernel]
"""
from __future__ import annotations

import argparse
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sources", type=int, default=100_000)
    ap.add_argument("--destinations", type=int, default=1_000)
    ap.add_argument("--families", type=int, default=1)
    ap.add_argument("--avg-degree", type=float, default=8.0)
    ap.add_argument("--iters-per-stage", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=0, help="0 = all local devices")
    ap.add_argument("--comm-mode", default="psum", choices=["psum", "rank0"])
    ap.add_argument("--compress", default="none", choices=["none", "bf16", "bf16_ef"])
    ap.add_argument("--slab-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"],
                    help="slab storage dtype (coeff/cost/mask); duals and "
                         "all accumulation stay fp32.  bfloat16 halves and "
                         "int8 quarters the per-iteration slab HBM traffic "
                         "(int8 adds per-bucket symmetric scales)")
    ap.add_argument("--engine", default="agd", choices=["agd", "pdhg", "auto"],
                    help="solver engine (docs/solvers.md).  'auto' is the "
                         "service-level adaptive policy; a one-shot solve "
                         "has no per-tenant history, so it falls back to agd")
    ap.add_argument("--fused-kernel", action="store_true")
    ap.add_argument("--fused-oracle", action="store_true",
                    help="one-pass fused dual oracle (kernel Ax + objective "
                         "reduction; single slab read per iteration)")
    ap.add_argument("--tol-grad", type=float, default=None,
                    help="relative gradient-norm tolerance (enables early stop)")
    ap.add_argument("--tol-viol", type=float, default=None,
                    help="max-violation tolerance (enables early stop)")
    ap.add_argument("--formulation", default="matching",
                    choices=["matching", "capacity-cap", "fairness-floor",
                             "budget-pacing"],
                    help="scenario formulation compiled through "
                         "repro.formulation (docs/formulation.md)")
    ap.add_argument("--formulation-param", type=float, default=None,
                    help="primary scenario knob: simplex radius / cap / "
                         "floor / pace (scenario default when omitted)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.core import (
        DistConfig, DistributedMaximizer, Maximizer, MaximizerConfig,
        normalize_rows,
    )
    from repro.formulation import scenario_formulation
    from repro.instances import (
        MatchingInstanceSpec, bucketize, generate_matching_instance,
        unpack_primal,
    )

    if args.formulation != "matching" and (args.fused_kernel or args.fused_oracle):
        ap.error("--fused-kernel/--fused-oracle implement the simplex "
                 "feasible set; only --formulation matching can use them")
    engine = "agd" if args.engine == "auto" else args.engine
    if engine == "pdhg":
        if args.formulation != "matching":
            ap.error("--engine pdhg solves the simplex-constrained matching "
                     "LP; only --formulation matching is supported")
        if args.fused_kernel:
            ap.error("--engine pdhg fuses its prox step through the one-pass "
                     "dual oracle; use --fused-oracle, not --fused-kernel")

    n = args.shards or len(jax.devices())
    spec = MatchingInstanceSpec(
        num_sources=args.sources, num_destinations=args.destinations,
        avg_degree=args.avg_degree, num_families=args.families, seed=args.seed,
    )
    t0 = time.time()
    inst = generate_matching_instance(spec)
    packed = bucketize(inst, shard_multiple=n, dtype=args.slab_dtype)
    scaled, _ = normalize_rows(packed)
    comp = scenario_formulation(
        args.formulation, args.formulation_param
    ).compile(scaled)
    print(f"generated {inst.nnz} nnz in {time.time() - t0:.1f}s; shards={n}; "
          f"formulation={args.formulation}; slab_dtype={args.slab_dtype}")

    cfg = MaximizerConfig(iters_per_stage=args.iters_per_stage,
                          tol_grad=args.tol_grad, tol_viol=args.tol_viol)
    t0 = time.time()
    if engine == "pdhg":
        # Structured PDHG on the same bucketed instance: one driver for any
        # shard count (a 1-device mesh degenerates to the single-shard core).
        from repro.engines.pdhg import solve_pdhg_sharded

        mesh = compat.make_mesh((n,), ("data",))
        res = solve_pdhg_sharded(
            scaled, mesh, cfg,
            DistConfig(axes="data", fused_oracle=args.fused_oracle,
                       slab_dtype=args.slab_dtype),
        )
    elif n > 1:
        mesh = compat.make_mesh((n,), ("data",))
        dm = DistributedMaximizer(
            comp.sharded_instance(), mesh, cfg,
            DistConfig(axes="data", comm_mode=args.comm_mode,
                       compress=args.compress, fused_kernel=args.fused_kernel,
                       fused_oracle=args.fused_oracle,
                       slab_dtype=args.slab_dtype),
            projection=comp.projection,
        )
        dm.place()
        res = dm.solve()
    else:
        obj = comp.objective(fused_kernel=args.fused_kernel,
                             fused_oracle=args.fused_oracle)
        res = Maximizer(obj, cfg).solve()
    dt = time.time() - t0
    total_iters = res.total_iters_used or cfg.total_iters
    x = unpack_primal(packed, [np.asarray(s) for s in res.x_slabs])
    budget = cfg.total_iter_budget if cfg.early_stop else cfg.total_iters
    print(f"solved in {dt:.1f}s ({dt / max(total_iters, 1) * 1e3:.2f} ms/iter, "
          f"{total_iters}/{budget} iters, engine={engine})")
    print(f"g = {float(res.g):.6f}  value = {-float(np.dot(inst.cost, x)):.4f}  "
          f"viol = {float(res.stats[-1].max_violation[-1]):.3e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
