"""Production solve CLI: the paper's workload end-to-end.

    PYTHONPATH=src python -m repro.launch.solve --sources 100000 \
        [--shards 1] [--comm-mode psum] [--compress none] [--fused-kernel]
"""
from __future__ import annotations

import argparse
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sources", type=int, default=100_000)
    ap.add_argument("--destinations", type=int, default=1_000)
    ap.add_argument("--families", type=int, default=1)
    ap.add_argument("--avg-degree", type=float, default=8.0)
    ap.add_argument("--iters-per-stage", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=0, help="0 = all local devices")
    ap.add_argument("--comm-mode", default="psum", choices=["psum", "rank0"])
    ap.add_argument("--compress", default="none", choices=["none", "bf16", "bf16_ef"])
    ap.add_argument("--fused-kernel", action="store_true")
    ap.add_argument("--fused-oracle", action="store_true",
                    help="one-pass fused dual oracle (kernel Ax + objective "
                         "reduction; single slab read per iteration)")
    ap.add_argument("--tol-grad", type=float, default=None,
                    help="relative gradient-norm tolerance (enables early stop)")
    ap.add_argument("--tol-viol", type=float, default=None,
                    help="max-violation tolerance (enables early stop)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.core import (
        DistConfig, DistributedMaximizer, Maximizer, MaximizerConfig,
        MatchingObjective, normalize_rows,
    )
    from repro.instances import (
        MatchingInstanceSpec, bucketize, generate_matching_instance,
        unpack_primal,
    )

    n = args.shards or len(jax.devices())
    spec = MatchingInstanceSpec(
        num_sources=args.sources, num_destinations=args.destinations,
        avg_degree=args.avg_degree, num_families=args.families, seed=args.seed,
    )
    t0 = time.time()
    inst = generate_matching_instance(spec)
    packed = bucketize(inst, shard_multiple=n)
    scaled, _ = normalize_rows(packed)
    print(f"generated {inst.nnz} nnz in {time.time() - t0:.1f}s; shards={n}")

    cfg = MaximizerConfig(iters_per_stage=args.iters_per_stage,
                          tol_grad=args.tol_grad, tol_viol=args.tol_viol)
    t0 = time.time()
    if n > 1:
        mesh = compat.make_mesh((n,), ("data",))
        dm = DistributedMaximizer(
            scaled, mesh, cfg,
            DistConfig(axes="data", comm_mode=args.comm_mode,
                       compress=args.compress, fused_kernel=args.fused_kernel,
                       fused_oracle=args.fused_oracle),
        )
        dm.place()
        res = dm.solve()
    else:
        obj = MatchingObjective(scaled, fused_kernel=args.fused_kernel,
                                fused_oracle=args.fused_oracle)
        res = Maximizer(obj, cfg).solve()
    dt = time.time() - t0
    total_iters = res.total_iters_used or cfg.total_iters
    x = unpack_primal(packed, [np.asarray(s) for s in res.x_slabs])
    budget = cfg.total_iter_budget if cfg.early_stop else cfg.total_iters
    print(f"solved in {dt:.1f}s ({dt / max(total_iters, 1) * 1e3:.2f} ms/iter, "
          f"{total_iters}/{budget} iters)")
    print(f"g = {float(res.g):.6f}  value = {-float(np.dot(inst.cost, x)):.4f}  "
          f"viol = {float(res.stats[-1].max_violation[-1]):.3e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
