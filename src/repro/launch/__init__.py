"""Launch layer: production meshes, dry-run driver, train/solve/serve CLIs."""
