"""Allocation-serving demo: query the dual store while the fleet re-solves.

    PYTHONPATH=src python -m repro.launch.serve \
        [--sources 4000] [--tenants 2] [--cadences 3] [--batch 128] \
        [--hammer-threads 2] [--verify] \
        [--metrics-out m.jsonl] [--prom-out m.prom]

End-to-end demo of the request-time surface (docs/serving.md): a
`Scheduler` with an attached `DualStore` publishes every tenant's duals as
a generation-stamped snapshot after each cadence solve, while hammer
threads batch-query allocations the whole time — including mid-solve,
across the pipeline's snapshot swaps.  Each answered batch reports the
generation it was served from; the demo prints per-tenant p50/p99 batch
latency, users/second and the generations observed.

`--verify` replays every answered batch post-hoc against the retained
snapshot of the generation it reported and checks the served allocations
BIT-identical to the direct full-slab projection — the generation-fence
contract, checked at CLI volume.

Telemetry: `--metrics-out` appends one schema-validated ``serving_query``
JSONL record per batch plus a final ``counters`` snapshot (validate with
``python tools/check_metrics.py --require-kinds serving_query m.jsonl``);
`--prom-out` writes a Prometheus text-exposition snapshot (query counters,
latency histogram, publish/generation gauges).
"""
from __future__ import annotations

import argparse
import threading
import time


def _delta(edge_list, rng, frac=0.02):
    import numpy as np

    from repro.instances import InstanceDelta

    n = max(1, int(frac * edge_list.nnz))
    pick = rng.choice(edge_list.nnz, size=n, replace=False)
    return InstanceDelta(
        update_src=edge_list.src[pick],
        update_dst=edge_list.dst[pick],
        update_values=edge_list.values[pick] * rng.uniform(0.9, 1.1, n),
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sources", type=int, default=4000)
    ap.add_argument("--destinations", type=int, default=50)
    ap.add_argument("--avg-degree", type=float, default=6.0)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--cadences", type=int, default=3)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--hammer-threads", type=int, default=2)
    ap.add_argument("--iters-per-stage", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="replay every batch against the snapshot of the "
                         "generation it reported; check bit-identical")
    ap.add_argument("--metrics-out", default=None,
                    help="append serving_query JSONL records here")
    ap.add_argument("--prom-out", default=None,
                    help="write a Prometheus text-exposition snapshot")
    args = ap.parse_args()

    import numpy as np

    from repro import telemetry
    from repro.core import MaximizerConfig
    from repro.instances import (
        MatchingInstanceSpec,
        generate_matching_instance,
    )
    from repro.service import Scheduler, ServiceConfig
    from repro.serving import DualStore, direct_allocations

    rng = np.random.default_rng(args.seed)
    cfg = ServiceConfig(
        cold=MaximizerConfig(
            iters_per_stage=args.iters_per_stage,
            tol_grad=1e-4, tol_viol=1e-4,
        ),
        row_headroom=4,
    )
    store = DualStore(history=args.cadences + 2)
    sched = Scheduler(cfg, dual_store=store)
    bases = {}
    for i in range(args.tenants):
        name = f"t{i}"
        bases[name] = generate_matching_instance(MatchingInstanceSpec(
            num_sources=args.sources,
            num_destinations=args.destinations,
            avg_degree=args.avg_degree,
            seed=args.seed + i,
        ))
        sched.add_tenant(name, bases[name])
    print(f"{args.tenants} tenant(s), {bases['t0'].nnz} nnz each; "
          f"initial cold cadence ...")
    sched.run_cadence()
    for name in store.tenants():
        snap = store.snapshot(name)
        print(f"  {name}: published generation {snap.generation} "
              f"({snap.num_users} users, gamma={snap.gamma})")

    sink = telemetry.JsonlSink(args.metrics_out) if args.metrics_out else None
    live = {
        name: np.flatnonzero(store.snapshot(name).deg > 0)
        for name in store.tenants()
    }
    results: list = []
    lock = threading.Lock()
    stop = threading.Event()

    def hammer(worker_seed):
        qrng = np.random.default_rng(worker_seed)
        names = sorted(live)
        while not stop.is_set():
            name = names[int(qrng.integers(len(names)))]
            users = live[name]
            batch = users[qrng.integers(0, users.size, size=args.batch)]
            r = store.query(name, batch)
            with lock:
                results.append(r)
                if sink is not None:
                    sink.emit("serving_query", {
                        "tenant": r.tenant,
                        "generation": r.generation,
                        "users": int(r.num_users),
                        "latency_seconds": r.latency_seconds,
                    })

    threads = [
        threading.Thread(target=hammer, args=(args.seed + 100 + i,),
                         daemon=True)
        for i in range(args.hammer_threads)
    ]
    deltas = [
        {name: _delta(bases[name], rng) for name in bases}
        for _ in range(args.cadences)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    try:
        outs = sched.run_pipeline(deltas)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    wall = time.perf_counter() - t0
    for t, out in enumerate(outs):
        gens = {n: out.reports[n]["published_generation"] for n in out.reports}
        print(f"cadence {t}: published generations {gens}")
        if out.ingest_errors:
            print(f"  ingest errors: {out.ingest_errors}")

    by_tenant: dict = {}
    for r in results:
        by_tenant.setdefault(r.tenant, []).append(r)
    total_users = sum(r.num_users for r in results)
    print(f"\nserved {len(results)} batches / {total_users} users in "
          f"{wall:.2f}s while {args.cadences} pipelined cadences solved "
          f"({total_users / max(wall, 1e-9):.0f} users/s)")
    for name in sorted(by_tenant):
        rs = by_tenant[name]
        lats = np.asarray([r.latency_seconds for r in rs]) * 1e3
        gens = sorted({r.generation for r in rs})
        print(f"  {name}: {len(rs)} batches, p50={np.percentile(lats, 50):.2f}ms "
              f"p99={np.percentile(lats, 99):.2f}ms, generations observed "
              f"{gens}")

    failures = 0
    if args.verify:
        directs: dict = {}
        for r in results:
            key = (r.tenant, r.generation)
            if key not in directs:
                directs[key] = direct_allocations(
                    store.get(r.tenant, r.generation)
                )
            xs = directs[key]
            for ba in r.slabs:
                if not np.array_equal(
                    ba.x, np.asarray(xs[ba.bucket])[ba.rows]
                ):
                    failures += 1
        print(f"verify: {len(results)} batches replayed against their "
              f"reported generations — "
              + ("all bit-identical" if failures == 0
                 else f"{failures} MISMATCHED batches"))

    if sink is not None:
        sink.emit_counters()
        sink.close()
        print(f"metrics written to {args.metrics_out}")
    if args.prom_out:
        telemetry.write_prometheus(args.prom_out)
        print(f"prometheus snapshot written to {args.prom_out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
