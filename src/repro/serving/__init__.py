"""Serving substrate: prefill/decode steps and a batched request engine."""
from repro.serving.steps import lower_decode_step, lower_prefill, make_serve_fns
from repro.serving.engine import ServeEngine, Request

__all__ = [
    "lower_decode_step",
    "lower_prefill",
    "make_serve_fns",
    "ServeEngine",
    "Request",
]
