"""Allocation serving from device-resident duals (the request-time surface).

``repro.serving`` owns the LP serving API: a `DualStore` of generation-
stamped per-tenant `DualSnapshot`s, published atomically by the service
layer after each cadence solve, and queried with a shape-keyed jitted
kernel that projects only the requested users' rows — O(degree) per user,
bit-identical to a direct projection against the reported generation.
See docs/serving.md.

The seed's LM-demo scaffolding (token serving, unrelated to LP work)
lives in ``repro.serving.lm_demo`` and is deliberately not imported here —
it pulls in the model/training stack.
"""
from repro.serving.duals import (
    BucketAllocations,
    DualSnapshot,
    DualStore,
    QueryResult,
    compute_lam_eff,
    direct_allocations,
)

__all__ = [
    "BucketAllocations",
    "DualSnapshot",
    "DualStore",
    "QueryResult",
    "compute_lam_eff",
    "direct_allocations",
]
