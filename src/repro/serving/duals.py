"""Low-latency allocation serving from device-resident duals.

The production workload the paper targets is request-driven: once a cadence
solve has produced optimal item duals ``lam``, a single user's allocation

    x_u = Pi_C( -(A_u^T lam + c_u) / gamma )

is local and O(degree) — no solve at request time.  This module is the
serving surface over that fact:

  * `DualSnapshot` — one immutable, generation-stamped publication: the
    descaled duals, the device-resident raw slabs they were solved over, and
    the dispatch-time occupancy maps (user -> bucket/row).
  * `DualStore` — the per-tenant slot the service publishes into.  A publish
    swaps the slot reference under a lock; a query reads the slot ONCE and
    answers the whole batch against that snapshot.  Snapshots are never
    mutated, so a torn read is structurally impossible — this is the
    generation fence, and every `QueryResult` reports which generation it
    was served from.
  * a tiny shape-keyed jitted query kernel that gathers only the requested
    rows of each bucket and mirrors `MatchingObjective.primal_candidate`
    op-for-op (same gather/einsum/scale grouping, same host-level ``==1.0``
    scale branches, same per-bucket `ProjectionMap` lowering), so a served
    batch is bit-identical to a post-hoc direct projection against the same
    snapshot — including capacity-cap / fairness-floor / budget-pacing
    tenants, whose `FormulationSpec` rides the snapshot instance.

Scaled-dual subtlety: the service solves with device-side Jacobi
normalization (A' = D A), so the solver's duals live in the scaled space and
``lam_original = D lam'``.  Rather than descaling the coefficients per query,
`compute_lam_eff` descales the duals ONCE per publish — then
``A'^T lam' = A^T (D lam')`` lets the query kernel run a plain gather over
the raw slabs.

See docs/serving.md for the lifecycle and the latency methodology.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.objective import binned_segment_sum
from repro.core.projections import ProjectionMap, UnitSimplexProjection
from repro.formulation.spec import lower_spec
from repro.instances.buckets import BucketedInstance

__all__ = [
    "BucketAllocations",
    "DualSnapshot",
    "DualStore",
    "QueryResult",
    "compute_lam_eff",
    "direct_allocations",
]


# -- publish-side math --------------------------------------------------------


@jax.jit
def _descale_duals(inst: BucketedInstance, lam: jax.Array) -> jax.Array:
    """D lam' over the RAW slabs — the inverse of `normalize_rows_traced`.

    Recomputes the same per-row norms (same `binned_segment_sum` math, same
    eps) the normalized solve applied device-side, so the returned duals are
    exactly the original-space duals of the solve that produced ``lam``.
    """
    m, J = inst.num_families, inst.num_destinations
    norms_sq = jnp.zeros((m, J), jnp.float32)
    for b in inst.buckets:
        contrib = (b.coeff**2) * b.mask[None]
        norms_sq = norms_sq + binned_segment_sum(b.idx, contrib, J)
    norms = jnp.sqrt(norms_sq)
    d2 = jnp.where(norms > 1e-30, 1.0 / jnp.maximum(norms, 1e-30), 1.0)
    return lam * d2.reshape(-1)


def compute_lam_eff(
    instance: BucketedInstance, lam: jax.Array, *, normalize: bool
) -> jax.Array:
    """The duals the query kernel gathers raw slabs against.

    ``normalize=True`` (the service default) maps the solver's scaled-space
    duals back to the original space on device; ``normalize=False`` solves
    were already in the original space.
    """
    if not normalize:
        return jnp.asarray(lam)
    return _descale_duals(instance, lam)


def _lowered(inst: BucketedInstance):
    """(per-bucket projections, cost_scale, ridge_weight) of an instance.

    Same resolution as `MatchingObjective.__post_init__`: a spec-free
    instance is the legacy simplex matching formulation.
    """
    spec = getattr(inst, "formulation", None)
    if spec is None:
        return (UnitSimplexProjection(),) * len(inst.buckets), 1.0, 1.0
    low = lower_spec(spec, inst)
    return low.projections, low.cost_scale, low.ridge_weight


# -- the query kernel ---------------------------------------------------------

# One jitted kernel per (projection, term scales, dual-grid dims); within
# each, XLA re-keys executables on the bucket/request shapes.  Request counts
# are padded to the next power of two before dispatch so the cache holds
# O(log max_batch) executables per bucket shape instead of one per count.
_QUERY: dict[tuple, Any] = {}


def _query_kernel(
    proj: ProjectionMap, cost_scale: float, ridge_weight: float, m: int, J: int
):
    key = (proj, cost_scale, ridge_weight, m, J)
    fn = _QUERY.get(key)
    if fn is None:
        # Mirrors primal_candidate's op grouping exactly (gather of the raw
        # idx/coeff/cost/mask rows, take -> einsum -> -(e + c)/gamma ->
        # projection, host-level ==1.0 scale branches), restricted to the
        # requested rows — so the result is bit-identical to the full-slab
        # direct projection at O(q * L) work.
        def q(idx, coeff, cost, mask, rows, lam, gamma):
            lam2 = lam.reshape(m, J)
            idx_r = jnp.take(idx, rows, axis=0)  # [q, L]
            mask_r = jnp.take(mask, rows, axis=0)
            gathered = jnp.take(lam2, idx_r, axis=1)  # [m, q, L]
            e = jnp.einsum(
                "mql,mql->ql", jnp.take(coeff, rows, axis=1), gathered
            )
            c = jnp.take(cost, rows, axis=0)
            if cost_scale != 1.0:
                c = cost_scale * c
            gamma_eff = gamma if ridge_weight == 1.0 else ridge_weight * gamma
            z = -(e + c) / gamma_eff
            return proj(z, mask_r), idx_r, mask_r

        fn = jax.jit(q)
        _QUERY[key] = fn
    return fn


def _dispatch_kernel(fn, bucket, rows_padded, lam, gamma):
    """Run one bucket's kernel with compile-cache accounting."""
    reg = telemetry.get_registry()
    try:
        before = fn._cache_size()
    except AttributeError:
        before = None
    out = fn(
        bucket.idx, bucket.coeff, bucket.cost, bucket.mask,
        rows_padded, lam, gamma,
    )
    try:
        after = fn._cache_size()
    except AttributeError:
        after = None
    if before is not None and after is not None and after > before:
        reg.inc("serving_kernel_compiles_total", 1)
    else:
        reg.inc("serving_kernel_cache_hits_total", 1)
    return out


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


@jax.jit
def _direct_primal(inst: BucketedInstance, lam: jax.Array, gamma: jax.Array):
    from repro.core.objective import MatchingObjective

    return MatchingObjective(inst).primal_candidate(lam, gamma)


def direct_allocations(snap: "DualSnapshot") -> tuple[jax.Array, ...]:
    """Post-hoc direct projection against one snapshot — full slabs.

    The reference the serving kernel is bit-compared against: the unfused
    `MatchingObjective.primal_candidate` over the snapshot's raw device
    instance and published (descaled) duals, at the snapshot's gamma floor.
    Jitted like the query kernel, so XLA applies the same algebraic rewrites
    (e.g. the divide -> reciprocal-multiply canonicalisation) to both sides
    of the bit-identity contract.
    """
    return _direct_primal(snap.instance, snap.lam_eff, jnp.float32(snap.gamma))


# -- snapshots and results ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DualSnapshot:
    """One immutable publication: duals + the instance they were solved over.

    ``instance`` is the dispatch-time device-resident RAW instance (the
    in-flight solve's input, never the host slabs — the overlapped pipeline
    keeps mutating those), so slabs, maps and duals are mutually consistent
    at ``generation``.  ``lam_eff`` is already descaled (`compute_lam_eff`).
    """

    tenant: str
    generation: int  # ingestor generation the instance reflects
    cadence: int  # session cadence that produced the duals
    gamma: float  # gamma floor the solve converged at
    lam_eff: jax.Array  # [dual_dim] original-space duals, device-resident
    instance: BucketedInstance  # raw device slabs (+ FormulationSpec, if any)
    bucket_of: np.ndarray  # [I] user -> bucket (-1: no edges)
    row_of: np.ndarray  # [I] user -> slab row
    deg: np.ndarray  # [I] user degree

    @property
    def num_users(self) -> int:
        return int(self.bucket_of.shape[0])


@dataclasses.dataclass(frozen=True)
class BucketAllocations:
    """Allocations of the queried users living in one bucket."""

    bucket: int
    users: np.ndarray  # [q] user ids, in query order within the bucket
    rows: np.ndarray  # [q] slab rows they were served from
    x: np.ndarray  # [q, L] allocations (padding slots are exact zeros)
    idx: np.ndarray  # [q, L] destination ids per slot
    mask: np.ndarray  # [q, L] slot validity


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One served batch — answered entirely against ``generation``."""

    tenant: str
    generation: int
    cadence: int
    gamma: float
    users: np.ndarray
    slabs: tuple[BucketAllocations, ...]
    unmatched: np.ndarray  # queried users with no edges at this generation
    latency_seconds: float

    @property
    def num_users(self) -> int:
        return int(self.users.size)

    def allocation(self, user: int) -> tuple[np.ndarray, np.ndarray]:
        """(destination ids, allocation values) of one queried user."""
        for ba in self.slabs:
            pos = np.flatnonzero(ba.users == user)
            if pos.size:
                p = int(pos[0])
                sel = ba.mask[p].astype(bool)
                return ba.idx[p][sel].astype(np.int64), ba.x[p][sel]
        return np.zeros(0, np.int64), np.zeros(0, np.float32)


# -- the store ----------------------------------------------------------------


class DualStore:
    """Per-tenant slots of the latest published duals (atomic swap on publish).

    Thread-safety contract: `publish` replaces a slot reference under the
    store lock; `query` reads the slot once and then works exclusively off
    that immutable `DualSnapshot`.  A publish landing mid-query therefore
    never mixes generations within a batch — late batches simply observe the
    new slot on their next read.  ``history > 0`` additionally retains the
    last N snapshots per tenant (`get`), which is what the benchmark's
    post-hoc bit-identity verification replays queries against.
    """

    def __init__(self, *, history: int = 0):
        self._lock = threading.Lock()
        self._latest: dict[str, DualSnapshot] = {}
        self._history: dict[str, deque] = {}
        self.history = int(history)

    # -- publish side --------------------------------------------------------

    def publish(self, snap: DualSnapshot) -> DualSnapshot:
        """Swap in a new snapshot for its tenant (the generation fence)."""
        with self._lock:
            self._latest[snap.tenant] = snap
            if self.history:
                self._history.setdefault(
                    snap.tenant, deque(maxlen=self.history)
                ).append(snap)
        reg = telemetry.get_registry()
        reg.inc("serving_publishes_total", 1, tenant=snap.tenant)
        reg.set_gauge("serving_generation", snap.generation, tenant=snap.tenant)
        return snap

    def publish_result(
        self,
        tenant: str,
        instance: BucketedInstance,
        lam: jax.Array,
        *,
        generation: int,
        gamma: float,
        bucket_of: np.ndarray,
        row_of: np.ndarray,
        deg: np.ndarray,
        cadence: int = 0,
        normalize: bool = True,
    ) -> DualSnapshot:
        """Build + publish a snapshot from an engine-level solve.

        The session/scheduler path publishes automatically out of
        `SolveSession.absorb`; this helper serves callers that drive
        `compiled_solver` directly (benchmarks, tests, offline fits).
        ``instance`` must be the RAW (unnormalized) instance the solve ran
        on; ``normalize`` says whether the solve scaled it device-side, i.e.
        whether ``lam`` needs descaling.
        """
        snap = DualSnapshot(
            tenant=tenant,
            generation=int(generation),
            cadence=int(cadence),
            gamma=float(gamma),
            lam_eff=compute_lam_eff(instance, lam, normalize=normalize),
            instance=instance,
            bucket_of=np.asarray(bucket_of, np.int64).copy(),
            row_of=np.asarray(row_of, np.int64).copy(),
            deg=np.asarray(deg, np.int64).copy(),
        )
        return self.publish(snap)

    # -- read side -----------------------------------------------------------

    def tenants(self) -> list[str]:
        with self._lock:
            return sorted(self._latest)

    def snapshot(self, tenant: str) -> DualSnapshot:
        """The tenant's current snapshot (the single fenced read)."""
        with self._lock:
            try:
                return self._latest[tenant]
            except KeyError:
                raise KeyError(
                    f"no duals published for tenant {tenant!r} yet"
                ) from None

    def generations(self, tenant: str) -> list[int]:
        """Generations currently answerable via `get` (history + latest)."""
        with self._lock:
            gens = {s.generation for s in self._history.get(tenant, ())}
            if tenant in self._latest:
                gens.add(self._latest[tenant].generation)
        return sorted(gens)

    def get(self, tenant: str, generation: int) -> DualSnapshot:
        """A retained snapshot by generation (requires ``history > 0``)."""
        with self._lock:
            latest = self._latest.get(tenant)
            if latest is not None and latest.generation == generation:
                return latest
            for s in self._history.get(tenant, ()):
                if s.generation == generation:
                    return s
        raise KeyError(
            f"generation {generation} of tenant {tenant!r} is not retained "
            f"(history={self.history})"
        )

    def query(
        self, tenant: str, users: Sequence[int], *, block: bool = True
    ) -> QueryResult:
        """Answer one batch of allocation requests from the current snapshot.

        The snapshot reference is read exactly once, so the whole batch —
        across all buckets its users map to — is served against a single
        generation, reported in the result.  Users with no edges at that
        generation come back in ``unmatched`` with zero allocations.
        ``block=False`` skips the device fence (the arrays are still
        correct on host conversion; latency then excludes device time).
        """
        t0 = time.perf_counter()
        snap = self.snapshot(tenant)
        return self.query_snapshot(snap, users, block=block, t0=t0)

    def query_snapshot(
        self,
        snap: DualSnapshot,
        users: Sequence[int],
        *,
        block: bool = True,
        t0: Optional[float] = None,
    ) -> QueryResult:
        """Serve a batch against an explicit snapshot (post-hoc replays)."""
        if t0 is None:
            t0 = time.perf_counter()
        users = np.asarray(users, np.int64).reshape(-1)
        if users.size and (
            users.min() < 0 or users.max() >= snap.num_users
        ):
            raise ValueError(
                f"user ids must be in [0, {snap.num_users}); got range "
                f"[{users.min()}, {users.max()}]"
            )
        b_of = snap.bucket_of[users]
        served = (b_of >= 0) & (snap.deg[users] > 0)
        unmatched = users[~served]
        inst = snap.instance
        projections, cost_scale, ridge_weight = _lowered(inst)
        gamma = jnp.float32(snap.gamma)
        launched = []
        for t in np.unique(b_of[served]):
            pick = served & (b_of == t)
            u = users[pick]
            rows = snap.row_of[users[pick]]
            rows_padded = np.zeros(_next_pow2(rows.size), np.int64)
            rows_padded[: rows.size] = rows
            fn = _query_kernel(
                projections[int(t)],
                cost_scale,
                ridge_weight,
                inst.num_families,
                inst.num_destinations,
            )
            out = _dispatch_kernel(
                fn, inst.buckets[int(t)], jnp.asarray(rows_padded),
                snap.lam_eff, gamma,
            )
            launched.append((int(t), u, rows, out))
        if block and launched:
            jax.block_until_ready([out for *_, out in launched])
        slabs = []
        for t, u, rows, (x, idx_r, mask_r) in launched:
            q = u.size
            slabs.append(
                BucketAllocations(
                    bucket=t,
                    users=u,
                    rows=rows,
                    x=np.asarray(x)[:q],
                    idx=np.asarray(idx_r)[:q],
                    mask=np.asarray(mask_r)[:q],
                )
            )
        dt = time.perf_counter() - t0
        reg = telemetry.get_registry()
        reg.inc("serving_queries_total", 1, tenant=snap.tenant)
        reg.inc("serving_users_total", int(users.size), tenant=snap.tenant)
        if unmatched.size:
            reg.inc(
                "serving_unmatched_total", int(unmatched.size),
                tenant=snap.tenant,
            )
        reg.observe("serving_query_seconds", dt, tenant=snap.tenant)
        return QueryResult(
            tenant=snap.tenant,
            generation=snap.generation,
            cadence=snap.cadence,
            gamma=snap.gamma,
            users=users,
            slabs=tuple(slabs),
            unmatched=unmatched,
            latency_seconds=dt,
        )
