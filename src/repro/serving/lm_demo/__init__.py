"""Seed LM-demo serving scaffolding (token generation, not LP allocation).

Kept apart from the dual-serving API that owns ``repro.serving``: this
sub-package serves *tokens* from a reduced LM architecture, while the
parent package serves *allocations* from device-resident duals.
"""
from repro.serving.lm_demo.steps import (
    lower_decode_step,
    lower_prefill,
    make_serve_fns,
)
from repro.serving.lm_demo.engine import ServeEngine, Request

__all__ = [
    "lower_decode_step",
    "lower_prefill",
    "make_serve_fns",
    "ServeEngine",
    "Request",
]
