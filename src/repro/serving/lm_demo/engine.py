"""Batched request engine (continuous batching, CPU demo-grade).

A fixed pool of decode slots; incoming requests are prefilled into a free
slot and decoded step-by-step alongside the other active slots.  Greedy
sampling; slots retire on EOS or max_new_tokens.  This is the serving-loop
substrate for `examples/serve_lm.py`; per-slot prefill keeps the demo simple
(production would batch prefill separately).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stop early
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        slots: int = 4,
        max_seq: int = 256,
    ):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, dtype=np.int32)
        self.cache = model.init_cache(slots, max_seq)
        self._decode = jax.jit(model.decode_step, donate_argnums=(3,))
        self._single_prefill = jax.jit(self._prefill_one)

    def _prefill_one(self, params, tokens):
        """Prefill one prompt [1, S] by teacher-forced decode steps."""
        cache1 = self.model.init_cache(1, self.max_seq)

        def body(carry, t):
            cache, _ = carry
            logits, cache = self.model.decode_step(
                params, t[None, None], carry[1], cache
            )
            return (cache, carry[1] + 1), logits[0, -1]

        (cache1, _), logits = jax.lax.scan(
            body, (cache1, jnp.asarray(0, jnp.int32)), tokens
        )
        return cache1, logits[-1]

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                cache1, last_logits = self._single_prefill(
                    self.params, jnp.asarray(req.prompt, jnp.int32)
                )
                # splice the slot-local cache into the batch cache
                def put(batch_leaf, one_leaf):
                    return batch_leaf.at[:, s : s + 1].set(one_leaf)

                self.cache = jax.tree.map(put, self.cache, cache1)
                nxt = int(jnp.argmax(last_logits))
                req.out_tokens.append(nxt)
                self.active[s] = req
                self.pos[s] = len(req.prompt)

    def step(self) -> int:
        """One engine step: admit + one batched decode. Returns #active."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        tokens = np.zeros((self.slots, 1), dtype=np.int32)
        for s, r in enumerate(self.active):
            if r is not None and r.out_tokens:
                tokens[s, 0] = r.out_tokens[-1]
        pos = int(max(self.pos[s] for s, r in enumerate(self.active) if r))
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), jnp.asarray(pos, jnp.int32), self.cache
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        n_active = 0
        for s, r in enumerate(self.active):
            if r is None:
                continue
            r.out_tokens.append(int(nxt[s]))
            self.pos[s] += 1
            if (
                len(r.out_tokens) >= r.max_new_tokens
                or int(nxt[s]) == r.eos_id
                or self.pos[s] >= self.max_seq - 1
            ):
                r.done = True
                self.active[s] = None
            else:
                n_active += 1
        return n_active

    def run(self) -> None:
        while self.queue or any(r is not None for r in self.active):
            self.step()
