"""Sharded serve steps: prefill and single-token decode.

decode_* / long_* shapes lower `serve_step` — one new token against a
seq_len-deep cache — NOT train_step.  The cache is sequence-sharded over the
tp axis (GQA kv-head counts generally don't divide a 16-way axis), so XLA
emits the flash-decoding-style distributed softmax combine automatically.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShardingProfile
from repro.models.model import Model
from repro.training.sharding_rules import (
    batch_pspecs,
    cache_pspecs,
    named,
    param_pspecs,
)

__all__ = ["make_serve_fns", "lower_decode_step", "lower_prefill"]


def _param_shardings(model: Model, mesh: Mesh, profile: ShardingProfile):
    pshape = jax.eval_shape(model.init, jax.random.key(0))
    return named(mesh, param_pspecs(pshape, mesh, profile))


def make_serve_fns(model: Model, mesh: Mesh, profile: ShardingProfile):
    """(prefill_fn, decode_fn) jit'd with explicit shardings."""
    pshard = _param_shardings(model, mesh, profile)

    prefill = jax.jit(model.prefill, in_shardings=(pshard, None))
    decode = jax.jit(
        model.decode_step,
        in_shardings=(pshard, None, None, None),
        donate_argnums=(3,),
    )
    return prefill, decode


def lower_decode_step(
    cfg: ModelConfig,
    specs: dict,  # {"tokens", "pos", "cache"} ShapeDtypeStructs
    mesh: Mesh,
    profile: ShardingProfile,
):
    """Dry-run entry for decode_* / long_* cells."""
    model = Model(cfg)
    pshard = _param_shardings(model, mesh, profile)
    cshard = named(mesh, cache_pspecs(specs["cache"], cfg, profile, mesh))
    tshard = NamedSharding(
        mesh,
        P(("pod", "data") if "pod" in mesh.shape and specs["tokens"].shape[0] % (mesh.shape["pod"] * mesh.shape["data"]) == 0
          else ("data",) if specs["tokens"].shape[0] % mesh.shape["data"] == 0 else None,
          None),
    )
    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    return jax.jit(
        model.decode_step,
        in_shardings=(pshard, tshard, NamedSharding(mesh, P()), cshard),
        out_shardings=(None, cshard),
        donate_argnums=(3,),
    ).lower(params_shape, specs["tokens"], specs["pos"], specs["cache"])


def lower_prefill(
    cfg: ModelConfig,
    specs: dict,  # {"tokens"(, "embeds")} ShapeDtypeStructs
    mesh: Mesh,
    profile: ShardingProfile,
):
    """Dry-run entry for prefill_* cells."""
    from repro.training.train_step import activation_sharding

    model = Model(cfg)
    seq = (specs.get("embeds") or specs["tokens"]).shape[1]
    model.act_sharding = activation_sharding(cfg, mesh, profile, seq)
    pshard = _param_shardings(model, mesh, profile)
    bshard = named(mesh, batch_pspecs(specs, profile, mesh))
    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    return jax.jit(
        model.prefill,
        in_shardings=(pshard, bshard),
    ).lower(params_shape, specs)
