"""Stability control for recurring solves (paper contribution 2).

The ridge term makes the primal map Lipschitz in the problem data: since
x*_gamma(lam) = Pi_C(-(A^T lam + c)/gamma) and projections onto convex sets
are nonexpansive,

    || x*(lam1; c1) - x*(lam2; c2) ||_2
        <= (1/gamma) * ( ||A^T (lam1 - lam2)||_2 + ||c1 - c2||_2 )
        <= (1/gamma) * ( sigma_max(A) ||lam1 - lam2||_2 + ||c1 - c2||_2 ).

Exposing gamma therefore *provably bounds run-to-run primal drift* — the
control the paper says no existing GPU LP solver offers.  This module provides
the bound, an empirical drift meter, and a warm-started recurring-solve driver
(prior-day duals as lam0), which is the production cadence the paper targets.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maximizer import Maximizer, MaximizerConfig, SolveResult
from repro.core.objective import MatchingObjective
from repro.instances.buckets import BucketedInstance

__all__ = ["drift_bound", "primal_drift", "RecurringSolver"]


def drift_bound(
    gamma: float,
    dc_norm: float,
    dlam_norm: float = 0.0,
    sigma_max: float = 1.0,
) -> float:
    """Upper bound on ||x1 - x2||_2 under data perturbation (see module doc)."""
    return (sigma_max * dlam_norm + dc_norm) / gamma


def primal_drift(
    x1: Sequence[jax.Array], x2: Sequence[jax.Array]
) -> jax.Array:
    """||x1 - x2||_2 across bucket slabs (same packing required)."""
    sq = sum(jnp.vdot(a - b, a - b) for a, b in zip(x1, x2))
    return jnp.sqrt(sq)


@dataclasses.dataclass
class RecurringSolver:
    """Recurring-cadence driver: warm-start each solve from yesterday's duals.

    Holds the last dual iterate; each `solve(instance)` warm-starts from it
    (paper §6: stages warm-start; production solves warm-start across days).
    The `gamma` floor of the continuation schedule is the stability knob.
    """

    config: MaximizerConfig = dataclasses.field(default_factory=MaximizerConfig)
    lam_prev: Optional[jax.Array] = None
    x_prev: Optional[tuple[jax.Array, ...]] = None

    def solve(self, inst: BucketedInstance) -> tuple[SolveResult, dict]:
        obj = MatchingObjective(inst)
        lam0 = self.lam_prev
        cold_start_reason = None
        if lam0 is not None and lam0.shape != (obj.dual_dim,):
            # Shape drift: a resized instance (different destination/family
            # count) makes yesterday's duals meaningless, and passing them
            # into the jitted stage function would crash at trace time.
            # Fall back to a cold start and say so.
            lam0 = None
            self.x_prev = None
            cold_start_reason = "dual_dim_drift"
        res = Maximizer(obj, self.config).solve(lam0=lam0)
        report = {}
        if cold_start_reason is not None:
            report["cold_start_reason"] = cold_start_reason
        slabs_comparable = self.x_prev is not None and [
            x.shape for x in self.x_prev
        ] == [x.shape for x in res.x_slabs]
        if slabs_comparable:
            drift = float(primal_drift(res.x_slabs, self.x_prev))
            x_norm = float(
                jnp.sqrt(sum(jnp.vdot(x, x) for x in res.x_slabs))
            )
            report.update(
                drift_l2=drift,
                drift_rel=drift / max(x_norm, 1e-12),
                gamma_floor=self.config.gammas[-1],
            )
        self.lam_prev = res.lam
        self.x_prev = res.x_slabs
        return res, report
