"""ObjectiveFunction — encodes (A, b, c) and the dual oracle (paper Table 1, §3.2).

`MatchingObjective.calculate(lam, gamma)` returns (g(lam), grad g(lam), x*(lam))
for the ridge-regularized matching LP:

    x*_gamma(lam) = Pi_C( -(A^T lam + c) / gamma )          (eq. 3)
    grad g(lam)   = A x*_gamma(lam) - b                      (eq. 4)
    g(lam)        = c'x* + (gamma/2)||x*||^2 + lam'(A x* - b)

over the bucketed-ELL layout of Def. 1 coupling matrices:

    A^T lam  — per-bucket vectorized *gather*  lam[k*J + idx] * coeff[k]
    A x      — per-bucket *segment-sum* (scatter-add) of coeff[k] * x into J bins

Both SpMVs touch only real nonzeros (padding is masked to exact zeros), so the
cost matches the paper's CSC complexity while staying dense-slab shaped for the
VPU/MXU.  All methods are pure functions of jax arrays — safe under jit,
shard_map and grad.

`fused_oracle=True` routes the whole of `calculate` through the one-pass
fused dual-oracle kernel (kernels/dual_oracle.py): one launch per bucket
emits the primal slab plus this bucket's A x histogram and (c'x, ||x||^2)
partials from a single slab read, instead of the ~3 passes the unfused
composition pays (docs/architecture.md "one-pass dual oracle").

`MatchingObjective` is a thin shim over the operator-centric formulation
layer (repro.formulation, docs/formulation.md): when the instance carries a
compiled `FormulationSpec` (a static pytree field), `__post_init__` resolves
it into per-bucket projections and the lowered term scales, so any
composition of feasible-set/term/coupling primitives dispatches through this
same oracle — and through every solver/service layer built on it — without
solve-loop changes.  A spec-free instance with default parameters is the
legacy ridge-regularized matching formulation, bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.projections import ProjectionMap, UnitSimplexProjection
from repro.instances.buckets import (
    Bucket,
    BucketedInstance,
    _quantize_sym,
    dequantize_bucket,
)

__all__ = [
    "DualEval",
    "MatchingObjective",
    "binned_segment_sum",
    "normalize_rows",
    "normalize_rows_traced",
]


class DualEval(NamedTuple):
    g: jax.Array  # scalar dual objective g(lam)
    grad: jax.Array  # [m*J] gradient of g
    x_slabs: tuple[jax.Array, ...]  # per-bucket primal slabs
    # decomposition useful for logging / distributed reduction:
    primal_linear: jax.Array  # c'x
    primal_ridge: jax.Array  # (gamma/2)||x||^2
    ax: jax.Array  # [m*J] A x


def _acc32(x: jax.Array) -> jax.Array:
    """Widen narrow primal slabs to fp32 before self-reductions (host-level
    dtype branch: identity object, identical jaxpr, for fp32 inputs)."""
    return x if x.dtype == jnp.float32 else x.astype(jnp.float32)


def _gather_at_lam(bucket: Bucket, lam2: jax.Array) -> jax.Array:
    """(A^T lam) restricted to this bucket: [n, L]."""
    # lam2: [m, J]; bucket.idx: [n, L] -> [m, n, L] gather, contract over m.
    gathered = jnp.take(lam2, bucket.idx, axis=1)  # [m, n, L]
    return jnp.einsum("mnl,mnl->nl", bucket.coeff, gathered)


def binned_segment_sum(idx: jax.Array, contrib: jax.Array, J: int) -> jax.Array:
    """Scatter-add [m, ...] contributions into [m, J] bins keyed by `idx`.

    One `segment_sum` over family-offset indices (`idx + k*J`, flattened
    once) replaces the previous per-family vmap'd `.at[].add` plus the
    materialised [m, n, L] broadcast of the index tensor — XLA lowers the
    single flat segment-sum without the batched-scatter loop.
    """
    m = contrib.shape[0]
    flat_idx = idx.reshape(-1).astype(jnp.int32)
    offs = (jnp.arange(m, dtype=jnp.int32) * J)[:, None]  # [m, 1]
    seg = (flat_idx[None, :] + offs).reshape(-1)
    out = jax.ops.segment_sum(contrib.reshape(-1), seg, num_segments=m * J)
    return out.reshape(m, J)


def _segment_sum_ax(bucket: Bucket, x: jax.Array, J: int) -> jax.Array:
    """This bucket's contribution to A x: [m, J]."""
    contrib = bucket.coeff * (x * bucket.mask)[None]  # [m, n, L]
    return binned_segment_sum(bucket.idx, contrib, J)


@dataclasses.dataclass
class MatchingObjective:
    """ObjectiveFunction over a (possibly device-local shard of a) BucketedInstance.

    In distributed execution each shard holds its local rows of every bucket
    (column shard of A, paper §4.4); `calculate` then returns the *local*
    contributions, and `repro.core.sharding` performs the single |lam|-sized
    reduction.  `rhs_in_local=True` (default) subtracts b and adds -lam'b here,
    which is correct for single-shard use; the sharded driver sets it False and
    applies b once after the psum.
    """

    instance: BucketedInstance
    projection: ProjectionMap = dataclasses.field(
        default_factory=UnitSimplexProjection
    )
    include_rhs: bool = True
    # Route the primal step through the fused Pallas dual-primal kernel
    # (gather + axpy + scale + projection in one kernel; see kernels/).
    # Only valid for UnitSimplexProjection feasible sets.
    fused_kernel: bool = False
    # Route the ENTIRE oracle through the one-pass fused dual-oracle kernel:
    # the same one-pass-over-VMEM-tiles launch that computes x also emits
    # per-grid-step partial A x histograms and (c'x, ||x||^2) partials, so
    # `calculate` reads each slab once per iteration instead of ~3x (see
    # kernels/dual_oracle.py and docs/architecture.md "one-pass dual
    # oracle").  Subsumes fused_kernel; simplex feasible sets only.
    fused_oracle: bool = False
    kernel_interpret: bool | None = None
    # Lowered objective-term scales (repro.formulation.terms):
    #   g = cost_scale * c'x + ridge_weight * (gamma/2)||x||^2 + lam'(Ax - b)
    #   x* = Pi_C( -(A^T lam + cost_scale * c) / (ridge_weight * gamma) )
    # Defaults reproduce the legacy matching objective bit-for-bit (the
    # scale-application branches below are host-level, so the jaxpr is
    # unchanged when both scales are exactly 1.0).
    cost_scale: float = 1.0
    ridge_weight: float = 1.0

    def __post_init__(self):
        # Formulation shim: a compiled FormulationSpec riding the instance's
        # static `formulation` field carries the per-bucket feasible sets and
        # term scales; resolve them here (trace-time host logic only), so
        # every caller that constructs a MatchingObjective from the instance
        # — Maximizer, core.sharding, the whole service engine — dispatches
        # compiled formulations with zero changes.
        self._projections: Optional[tuple[ProjectionMap, ...]] = None
        spec = getattr(self.instance, "formulation", None)
        if spec is None:
            return
        from repro.formulation.spec import lower_spec

        lowered = lower_spec(spec, self.instance)
        self.cost_scale = self.cost_scale * lowered.cost_scale
        self.ridge_weight = self.ridge_weight * lowered.ridge_weight
        # An explicitly passed non-default projection (e.g. the distributed
        # layer's `projection=` argument) wins over the spec's lowering.
        if self.projection == UnitSimplexProjection():
            self._projections = lowered.projections
            if len(set(lowered.projections)) == 1:
                self.projection = lowered.projections[0]

    @property
    def dual_dim(self) -> int:
        return self.instance.dual_dim

    @property
    def _buckets(self) -> tuple[Bucket, ...]:
        """fp32 compute views of the buckets for the unfused (pure-jnp) paths.

        For fp32 storage this returns the instance's own bucket objects — a
        host-level no-op keeping the default path's jaxpr bit-identical.
        Narrow storage builds the widening converts (+ int8 scale multiplies)
        at the call site, inside the consumer's trace: XLA fuses the convert
        into the consuming op, so HBM reads stay at the storage width and no
        fp32 slab copy is ever materialized.  The fused kernel paths bypass
        this view and take the raw storage arrays (+ scales), dequantizing
        in VMEM.
        """
        return tuple(dequantize_bucket(b) for b in self.instance.buckets)

    def _proj(self, i: int) -> ProjectionMap:
        return self._projections[i] if self._projections else self.projection

    def _scaled_cost(self, b: Bucket) -> jax.Array:
        return b.cost if self.cost_scale == 1.0 else self.cost_scale * b.cost

    def _scaled_gamma(self, gamma):
        return gamma if self.ridge_weight == 1.0 else self.ridge_weight * gamma

    def _assert_fused_ok(self, kind: str) -> UnitSimplexProjection:
        assert self.cost_scale == 1.0 and self.ridge_weight == 1.0, (
            f"{kind} implements unit term scales; lower non-unit "
            "LinearCost/RidgeSmoothing through the unfused oracle"
        )
        projs = {self._proj(i) for i in range(len(self.instance.buckets))}
        assert len(projs) == 1 and isinstance(
            next(iter(projs)), UnitSimplexProjection
        ), f"{kind} implements the simplex feasible set"
        return next(iter(projs))

    def primal_candidate(self, lam: jax.Array, gamma) -> tuple[jax.Array, ...]:
        """x*_gamma(lam) per bucket (eq. 3)."""
        inst = self.instance
        if self.fused_kernel:
            from repro.kernels import ops as kops

            proj = self._assert_fused_ok("fused dual-primal kernel")
            gamma = jnp.asarray(gamma, jnp.float32)
            return tuple(
                kops.fused_dual_primal(
                    b.idx, b.coeff, b.cost, b.mask, lam, gamma,
                    num_destinations=inst.num_destinations,
                    radius=proj.radius,
                    inequality=proj.inequality,
                    interpret=self.kernel_interpret,
                    coeff_scale=b.coeff_scale,
                    cost_scale=b.cost_scale,
                )
                for b in inst.buckets
            )
        lam2 = lam.reshape(inst.num_families, inst.num_destinations)
        gamma_eff = self._scaled_gamma(gamma)
        slabs = []
        for i, b in enumerate(self._buckets):
            z = -(_gather_at_lam(b, lam2) + self._scaled_cost(b)) / gamma_eff
            slabs.append(self._proj(i)(z, b.mask))
        return tuple(slabs)

    def apply_A(self, x_slabs: Sequence[jax.Array]) -> jax.Array:
        """A x as a [m*J] vector (accumulated at >= fp32 for narrow slabs)."""
        inst = self.instance
        ax = jnp.zeros(
            (inst.num_families, inst.num_destinations),
            jnp.promote_types(x_slabs[0].dtype, jnp.float32),
        )
        for b, x in zip(self._buckets, x_slabs):
            ax = ax + _segment_sum_ax(b, x, inst.num_destinations)
        return ax.reshape(-1)

    def apply_AT(self, lam: jax.Array) -> tuple[jax.Array, ...]:
        """A^T lam per bucket (for power iteration / diagnostics)."""
        inst = self.instance
        lam2 = lam.reshape(inst.num_families, inst.num_destinations)
        return tuple(_gather_at_lam(b, lam2) * b.mask for b in self._buckets)

    def calculate(self, lam: jax.Array, gamma) -> DualEval:
        """(g, grad g, x*) — the paper's ObjectiveFunction.calculate (Table 1)."""
        if self.fused_oracle:
            return self._calculate_fused(lam, gamma)
        inst = self.instance
        gamma = jnp.asarray(gamma, lam.dtype)
        x_slabs = self.primal_candidate(lam, gamma)
        ax = self.apply_A(x_slabs)
        lin = sum(
            jnp.vdot(self._scaled_cost(b), x)
            for b, x in zip(self._buckets, x_slabs)
        )
        ridge = (
            0.5 * self._scaled_gamma(gamma)
            * sum(jnp.vdot(_acc32(x), _acc32(x)) for x in x_slabs)
        )
        return self._finish_eval(lam, ax, lin, ridge, x_slabs)

    def _finish_eval(
        self, lam, ax, lin, ridge, x_slabs: tuple[jax.Array, ...]
    ) -> DualEval:
        """Shared tail of both oracle paths: grad/g from the reduced pieces.

        `include_rhs=False` is the sharded-local mode: b is applied once
        globally after the psum, so grad/g here are pre-reduction
        contributions (see core.sharding._make_calculate).
        """
        if self.include_rhs:
            grad = ax - self.instance.rhs
            g = lin + ridge + jnp.vdot(lam, grad)
        else:
            grad = ax
            g = lin + ridge + jnp.vdot(lam, ax)
        return DualEval(
            g=g, grad=grad, x_slabs=x_slabs, primal_linear=lin,
            primal_ridge=ridge, ax=ax,
        )

    def _calculate_fused(self, lam: jax.Array, gamma) -> DualEval:
        """One-pass oracle: per bucket, ONE fused launch emits the primal slab
        plus partial A x histograms and the objective scalars; `calculate`
        finishes with the O(m*J) tree-sums.  Same DualEval as the unfused
        path (including the `include_rhs=False` sharded-local mode, where
        the returned ax/lin/ridge are this shard's pre-psum contributions).
        """
        from repro.kernels import ops as kops

        inst = self.instance
        proj = self._assert_fused_ok("fused dual-oracle kernel")
        gamma = jnp.asarray(gamma, jnp.float32)
        ax2 = jnp.zeros(
            (inst.num_families, inst.num_destinations), jnp.float32
        )
        lin = jnp.float32(0.0)
        sq = jnp.float32(0.0)
        x_slabs = []
        for b in inst.buckets:
            x, hist, b_lin, b_sq = kops.fused_dual_oracle(
                b.idx, b.coeff, b.cost, b.mask, lam, gamma,
                num_destinations=inst.num_destinations,
                radius=proj.radius,
                inequality=proj.inequality,
                interpret=self.kernel_interpret,
                coeff_scale=b.coeff_scale,
                cost_scale=b.cost_scale,
            )
            x_slabs.append(x)
            ax2 = ax2 + hist
            lin = lin + b_lin
            sq = sq + b_sq
        return self._finish_eval(
            lam, ax2.reshape(-1), lin, 0.5 * gamma * sq, tuple(x_slabs)
        )

    # -- diagnostics --------------------------------------------------------

    def primal_objective(self, x_slabs: Sequence[jax.Array], gamma) -> jax.Array:
        lin = sum(
            jnp.vdot(self._scaled_cost(b), x)
            for b, x in zip(self._buckets, x_slabs)
        )
        ridge = (
            0.5 * self._scaled_gamma(gamma)
            * sum(jnp.vdot(_acc32(x), _acc32(x)) for x in x_slabs)
        )
        return lin + ridge

    def max_violation(self, x_slabs: Sequence[jax.Array]) -> jax.Array:
        """max(0, Ax - b) infinity-norm — the paper's Table-4 'slack'."""
        return jnp.max(jnp.maximum(self.apply_A(x_slabs) - self.instance.rhs, 0.0))

    def power_iteration(
        self, key: jax.Array, iters: int = 30
    ) -> jax.Array:
        """sigma_max(A)^2 estimate via power iteration on A A^T.

        Drives the analytic AGD step size 1/L, L = sigma_max^2 / gamma
        (paper §3.1: 'a fixed step size derived analytically from A and gamma').
        """
        u0 = jax.random.normal(key, (self.dual_dim,), jnp.float32)

        def body(u, _):
            atl = self.apply_AT(u / jnp.linalg.norm(u))
            au = self.apply_A(atl)
            return au, jnp.linalg.norm(au)

        _, norms = jax.lax.scan(body, u0, None, length=iters)
        return norms[-1]  # ~ sigma_max^2


def normalize_rows_traced(
    inst: BucketedInstance, eps: float = 1e-30
) -> tuple[BucketedInstance, jax.Array]:
    """Jacobi row normalization as a traced (device-side) transform.

    Same math as `normalize_rows` (A' = D A, b' = D b, D_r = 1/||A_r||_2)
    but expressed in jnp so it can run *inside* a compiled solve.  The
    recurring-solve service needs this: delta ingestion mutates the raw
    slabs in place, and re-running the host-side O(nnz) normalization every
    cadence would defeat the O(delta) update path.  One extra segment-sum +
    gather per solve is amortised over hundreds of AGD iterations.

    The costs `c` and the feasible set are untouched, so the primal solution
    is that of the original problem; returned duals live in the scaled space
    (lam_original = D lam'), which is consistent cadence-over-cadence as long
    as every solve applies the same transform.
    """
    m, J = inst.num_families, inst.num_destinations
    # Narrow slab dtypes: norms and the Jacobi scaling run on fp32 compute
    # views; float storage casts the scaled coeff back to the storage dtype
    # (keeping the slab HBM width through the solve), while quantized (int8)
    # slabs stay dequantized-fp32 for the remainder of the traced solve —
    # in-trace requantization would need data-dependent scales.  fp32
    # storage takes the exact pre-slab_dtype expressions (host branch).
    compute = tuple(dequantize_bucket(b) for b in inst.buckets)
    norms_sq = jnp.zeros((m, J), jnp.float32)
    for b in compute:
        contrib = (b.coeff**2) * b.mask[None]  # [m, n, L]
        norms_sq = norms_sq + binned_segment_sum(b.idx, contrib, J)
    norms = jnp.sqrt(norms_sq)
    d2 = jnp.where(norms > eps, 1.0 / jnp.maximum(norms, eps), 1.0)  # [m, J]

    def _scaled_bucket(b: Bucket, cb: Bucket) -> Bucket:
        coeff = cb.coeff * jnp.take(d2, b.idx, axis=1)
        if b.coeff_scale is None and coeff.dtype != b.coeff.dtype:
            coeff = coeff.astype(b.coeff.dtype)  # bf16 storage: cast back
        if b.coeff_scale is None:
            return Bucket(
                idx=b.idx, coeff=coeff, cost=b.cost, mask=b.mask,
                length=b.length,
            )
        return Bucket(
            idx=b.idx, coeff=coeff, cost=cb.cost, mask=cb.mask,
            length=b.length,
        )

    buckets = tuple(
        _scaled_bucket(b, cb) for b, cb in zip(inst.buckets, compute)
    )
    # dataclasses.replace keeps the static fields — including an attached
    # FormulationSpec, so compiled formulations survive the device-side
    # normalization inside the service engine's solves
    scaled = dataclasses.replace(
        inst, buckets=buckets, rhs=jnp.asarray(inst.rhs) * d2.reshape(-1)
    )
    return scaled, d2.reshape(-1)


def normalize_rows(
    inst: BucketedInstance, eps: float = 1e-30
) -> tuple[BucketedInstance, np.ndarray]:
    """Jacobi preconditioning / row normalization (paper §6, Appendix B.2).

    Returns (scaled instance with A' = D A, b' = D b) and the diagonal D as a
    [m*J] vector, D_r = 1/||A_r||_2 (rows with zero norm keep D_r = 1).  The
    feasible set is unchanged; duals map back as lam_original = D lam'.
    Host-side transform: runs once at instance build time, before sharding.
    """
    m, J = inst.num_families, inst.num_destinations
    norms = np.sqrt(inst.row_norms_sq())
    d = np.where(norms > eps, 1.0 / np.maximum(norms, eps), 1.0)
    d2 = d.reshape(m, J)
    buckets = []
    for b in inst.buckets:
        idx = np.asarray(b.idx)
        scale = d2[:, idx]  # [m, n, L]
        if b.coeff_scale is not None:
            # quantized (int8) slabs: dequantize, apply the Jacobi scaling in
            # fp32, requantize with fresh symmetric per-family scales
            coeff_f32 = np.asarray(b.coeff, np.float32) * np.asarray(
                b.coeff_scale, np.float32
            )
            q, new_scale = _quantize_sym(
                (coeff_f32 * scale).astype(np.float32), axes=(1, 2)
            )
            buckets.append(
                dataclasses.replace(
                    b, idx=idx, coeff=q, coeff_scale=new_scale,
                    cost=np.asarray(b.cost), mask=np.asarray(b.mask),
                )
            )
            continue
        buckets.append(
            Bucket(
                idx=idx,
                coeff=(np.asarray(b.coeff) * scale).astype(b.coeff.dtype),
                cost=np.asarray(b.cost),
                mask=np.asarray(b.mask),
                length=b.length,
            )
        )
    scaled = dataclasses.replace(
        inst,
        buckets=tuple(buckets),
        rhs=(np.asarray(inst.rhs) * d).astype(inst.rhs.dtype),
    )
    return scaled, d
