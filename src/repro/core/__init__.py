"""The paper's primary contribution: ridge-regularized matching LP solver.

Operator-centric programming model (paper Table 1 / §5):
  ObjectiveFunction -> `MatchingObjective`      (objective.py)
  ProjectionMap     -> `UnitSimplexProjection`, `BoxProjection`,
                       `BoxCutProjection`       (projections.py)
  Maximizer         -> `Maximizer` (single device, maximizer.py) and
                       `DistributedMaximizer` (column-sharded, sharding.py)

Plus: gamma-stability control (stability.py), the unstructured PDHG
baseline the paper compares against (pdhg.py), and convergence-based early
stopping in the Maximizer (tol_grad/tol_viol) used by the recurring-solve
service (repro.service).
"""
from repro.core.objective import (
    MatchingObjective,
    DualEval,
    normalize_rows,
    normalize_rows_traced,
)
from repro.core.projections import (
    ProjectionMap,
    UnitSimplexProjection,
    BoxProjection,
    BoxCutProjection,
    project_simplex,
    project_box,
    project_box_cut,
)
from repro.core.maximizer import (
    Maximizer,
    MaximizerConfig,
    SolveResult,
    StageStats,
    PAPER_GAMMA_SCHEDULE,
)
from repro.core.sharding import (
    DistConfig,
    DistributedMaximizer,
    shard_instance,
    instance_pspecs,
)
from repro.core.stability import drift_bound, primal_drift, RecurringSolver
from repro.core.pdhg import COOLP, PDHGConfig, solve_pdhg, from_edge_list

__all__ = [
    "MatchingObjective",
    "DualEval",
    "normalize_rows",
    "normalize_rows_traced",
    "ProjectionMap",
    "UnitSimplexProjection",
    "BoxProjection",
    "BoxCutProjection",
    "project_simplex",
    "project_box",
    "project_box_cut",
    "Maximizer",
    "MaximizerConfig",
    "SolveResult",
    "StageStats",
    "PAPER_GAMMA_SCHEDULE",
    "DistConfig",
    "DistributedMaximizer",
    "shard_instance",
    "instance_pspecs",
    "drift_bound",
    "primal_drift",
    "RecurringSolver",
    "COOLP",
    "PDHGConfig",
    "solve_pdhg",
    "from_edge_list",
]
