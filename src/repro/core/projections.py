"""ProjectionMap — blockwise projection operators (paper §3.3, §4.2, Table 1).

Each operator projects every row of a padded slab `v [*, L]` (one row per
source) onto its feasible polytope, honouring a {0,1} mask of real entries.
Padded entries are guaranteed to come out exactly zero and never influence the
projection of real entries.

These are the *reference* (pure-jnp, multi-op) implementations — the paper's
"PyTorch eager" baseline.  The fused Pallas kernel in `repro.kernels` replaces
`UnitSimplexProjection` in the inner loop; `repro/kernels/ref.py` re-exports
these as the kernel oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

__all__ = [
    "ProjectionMap",
    "UnitSimplexProjection",
    "BoxProjection",
    "BoxCutProjection",
    "project_simplex",
    "project_simplex_cmp",
    "project_box",
    "project_box_cut",
]

_NEG = -1.0e30  # finite stand-in for -inf; fp32-safe under cumsum


def _masked(v: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.where(mask > 0, v, _NEG)


def project_simplex(
    v: jax.Array,
    mask: jax.Array,
    radius: Union[float, jax.Array] = 1.0,
    *,
    inequality: bool = True,
    tol: float = 0.0,
) -> jax.Array:
    """Duchi et al. (2008) projection of each row onto the unit simplex.

    inequality=True  : project onto {w >= 0, sum(w) <= radius}
    inequality=False : project onto {w >= 0, sum(w) == radius}

    The pipeline is the paper's §4.3 reference: sort, prefix sums, cutoff
    rho via the monotone Duchi condition, threshold theta, subtract-and-clamp,
    plus the inequality-variant early exit (already-feasible rows returned
    unchanged up to the nonnegativity clamp).

    Differentiable via an analytic custom JVP (the projection Jacobian is
    P = diag(a) - a a^T / |a| on the active set a = {w > 0} for tight rows,
    identity-on-positives for feasible rows) — both exact a.e. and far
    cheaper than differentiating through the sort network.
    """
    if inequality:
        return _project_simplex_ineq(v, mask, jnp.asarray(radius, v.dtype))
    return _project_simplex_eq(v, mask, jnp.asarray(radius, v.dtype))


def _simplex_fwd(v, mask, z, inequality):
    if z.ndim == 1:
        z = z[:, None]
    L = v.shape[-1]
    vm = _masked(v, mask)
    u = jnp.flip(jnp.sort(vm, axis=-1), axis=-1)  # descending
    css = jnp.cumsum(u, axis=-1)
    j = jnp.arange(1, L + 1, dtype=v.dtype)
    cond = u * j > css - z  # u_j - (css_j - z)/j > 0
    rho = jnp.sum(cond, axis=-1, keepdims=True).astype(v.dtype)
    rho = jnp.maximum(rho, 1.0)
    css_rho = jnp.sum(jnp.where(j == rho, css, 0.0), axis=-1, keepdims=True)
    theta = (css_rho - z) / rho
    w_eq = jnp.maximum(vm - theta, 0.0) * mask
    if not inequality:
        return w_eq, jnp.zeros_like(theta, bool)
    w0 = jnp.maximum(v, 0.0) * mask
    feasible = jnp.sum(w0, axis=-1, keepdims=True) <= z
    return jnp.where(feasible, w0, w_eq), feasible


@jax.custom_jvp
def _project_simplex_ineq(v, mask, z):
    return _simplex_fwd(v, mask, z, True)[0]


@_project_simplex_ineq.defjvp
def _project_simplex_ineq_jvp(primals, tangents):
    v, mask, z = primals
    dv, _, _ = tangents
    w, feasible = _simplex_fwd(v, mask, z, True)
    act = (w > 0).astype(v.dtype) * mask
    rho = jnp.maximum(jnp.sum(act, axis=-1, keepdims=True), 1.0)
    davg = jnp.sum(act * dv, axis=-1, keepdims=True) / rho
    d_eq = act * (dv - davg)
    d_feas = (v > 0).astype(v.dtype) * mask * dv
    return w, jnp.where(feasible, d_feas, d_eq)


@jax.custom_jvp
def _project_simplex_eq(v, mask, z):
    return _simplex_fwd(v, mask, z, False)[0]


@_project_simplex_eq.defjvp
def _project_simplex_eq_jvp(primals, tangents):
    v, mask, z = primals
    dv, _, _ = tangents
    w, _ = _simplex_fwd(v, mask, z, False)
    act = (w > 0).astype(v.dtype) * mask
    rho = jnp.maximum(jnp.sum(act, axis=-1, keepdims=True), 1.0)
    davg = jnp.sum(act * dv, axis=-1, keepdims=True) / rho
    return w, act * (dv - davg)


def project_simplex_cmp(
    v: jax.Array,
    mask: jax.Array,
    radius: Union[float, jax.Array] = 1.0,
    *,
    inequality: bool = True,
) -> jax.Array:
    """Sort-free simplex projection via pairwise comparisons, O(L^2) work.

    Same polytope and same result as `project_simplex` (exact up to fp
    rounding), lowered very differently: the rank of each entry and the
    prefix sum over everything that outranks it come from an L x L
    comparison matrix (two packed row reductions), and the Duchi threshold
    collapses to a single max,

        theta* = max_i (S_i - z) / k_i,
        k_i = #{j : v_j outranks v_i},  S_i = sum of those v_j,

    using that `(css_j - z)/j` increases up to the cutoff rho and decreases
    after it.  Feasibility for the inequality variant folds in as
    `theta = max(theta*, 0)` (a row is feasible iff theta* <= 0), so the
    whole projection is one comparison fusion, two reductions and an
    elementwise epilogue — no sort, no cumsum, no branch.

    The sorted pipeline moves O(L log L) values but costs a sort + cumsum +
    three masked reductions as separate XLA thunks; inside a
    dispatch-bound solver loop (small shards on CPU, one program per
    PDHG iteration) this O(L^2) form is ~3x faster end to end.  Prefer
    `project_simplex` when L is large or the call is not loop-critical.
    """
    z = jnp.asarray(radius, v.dtype)
    if inequality:
        return _project_simplex_cmp_ineq(v, mask, z)
    return _project_simplex_cmp_eq(v, mask, z)


def _simplex_cmp_fwd(v, mask, z, inequality):
    if z.ndim == 1:
        z = z[:, None]
    L = v.shape[-1]
    vm = _masked(v, mask)
    i = jnp.arange(L)
    # "j outranks i": strictly greater, ties broken by index so every entry
    # has a unique 1-based rank k_i (duplicates land on consecutive ranks,
    # exactly as a stable descending sort would place them).
    ge = (
        (vm[..., None, :] > vm[..., :, None])
        | ((vm[..., None, :] == vm[..., :, None]) & (i <= i[:, None]))
    ).astype(v.dtype)
    # packed reduction: rank k_i and outranking prefix sum S_i in one kernel
    kS = jnp.sum(jnp.stack([ge, ge * vm[..., None, :]], -1), axis=-2)
    t = (kS[..., 1] - z) / jnp.maximum(kS[..., 0], 1.0)
    theta = jnp.max(jnp.where(mask > 0, t, _NEG), axis=-1, keepdims=True)
    feasible = theta <= 0
    if inequality:
        theta = jnp.maximum(theta, 0.0)
    return jnp.maximum(vm - theta, 0.0) * mask, feasible


@jax.custom_jvp
def _project_simplex_cmp_ineq(v, mask, z):
    return _simplex_cmp_fwd(v, mask, z, True)[0]


@_project_simplex_cmp_ineq.defjvp
def _project_simplex_cmp_ineq_jvp(primals, tangents):
    v, mask, z = primals
    dv, _, _ = tangents
    w, feasible = _simplex_cmp_fwd(v, mask, z, True)
    act = (w > 0).astype(v.dtype) * mask
    rho = jnp.maximum(jnp.sum(act, axis=-1, keepdims=True), 1.0)
    davg = jnp.sum(act * dv, axis=-1, keepdims=True) / rho
    d_eq = act * (dv - davg)
    d_feas = (v > 0).astype(v.dtype) * mask * dv
    return w, jnp.where(feasible, d_feas, d_eq)


@jax.custom_jvp
def _project_simplex_cmp_eq(v, mask, z):
    return _simplex_cmp_fwd(v, mask, z, False)[0]


@_project_simplex_cmp_eq.defjvp
def _project_simplex_cmp_eq_jvp(primals, tangents):
    v, mask, z = primals
    dv, _, _ = tangents
    w, _ = _simplex_cmp_fwd(v, mask, z, False)
    act = (w > 0).astype(v.dtype) * mask
    rho = jnp.maximum(jnp.sum(act, axis=-1, keepdims=True), 1.0)
    davg = jnp.sum(act * dv, axis=-1, keepdims=True) / rho
    return w, act * (dv - davg)


def project_box(
    v: jax.Array,
    mask: jax.Array,
    lo: Union[float, jax.Array] = 0.0,
    hi: Union[float, jax.Array] = 1.0,
) -> jax.Array:
    """Elementwise projection onto [lo, hi] (padded entries -> 0)."""
    return jnp.clip(v, lo, hi) * mask


def project_box_cut(
    v: jax.Array,
    mask: jax.Array,
    lo: Union[float, jax.Array] = 0.0,
    hi: Union[float, jax.Array] = 1.0,
    radius: Union[float, jax.Array] = 1.0,
    *,
    iters: int = 64,
) -> jax.Array:
    """Projection onto {lo <= w <= hi} ∩ {sum(w) <= radius} ("box-cut").

    w(theta) = clip(v - theta, lo, hi) with theta >= 0 chosen by bisection so
    that sum(w(theta)) = radius when the plain box projection is infeasible.
    Requires lo >= 0 entries to guarantee sum monotonicity (matching the
    DuaLip BoxCut operator, where lo = 0).
    """
    z = jnp.asarray(radius, v.dtype)
    if z.ndim == 1:
        z = z[:, None]
    w_box = jnp.clip(v, lo, hi) * mask
    s_box = jnp.sum(w_box, axis=-1, keepdims=True)
    feasible = s_box <= z

    def w_of(theta):
        return jnp.clip(v - theta, lo, hi) * mask

    # theta in [0, max(v - lo)]: at theta_hi every entry is at its lower bound.
    theta_hi = jnp.maximum(
        jnp.max(jnp.where(mask > 0, v, 0.0), axis=-1, keepdims=True) - lo, 1.0
    )
    theta_lo = jnp.zeros_like(theta_hi)

    def body(_, carry):
        tlo, thi = carry
        mid = 0.5 * (tlo + thi)
        s = jnp.sum(w_of(mid), axis=-1, keepdims=True)
        too_big = s > z
        return jnp.where(too_big, mid, tlo), jnp.where(too_big, thi, mid)

    theta_lo, theta_hi = jax.lax.fori_loop(0, iters, body, (theta_lo, theta_hi))
    w_cut = w_of(0.5 * (theta_lo + theta_hi))
    return jnp.where(feasible, w_box, w_cut)


# ---------------------------------------------------------------------------
# Operator-centric primitives (paper Table 1).  Frozen dataclasses are
# hashable, so they can be closed over / passed as static args under jit.
# ---------------------------------------------------------------------------


class ProjectionMap:
    """Blockwise projection operator Pi_C (paper Table 1).

    Subclasses implement `__call__(z_slab, mask) -> x_slab` for one padded
    bucket slab.  New constraint families implement only this; batching,
    execution and the solve loop are reused (paper §5).
    """

    def __call__(self, v: jax.Array, mask: jax.Array) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UnitSimplexProjection(ProjectionMap):
    radius: float = 1.0
    inequality: bool = True
    use_kernel: bool = False  # route through the fused Pallas kernel (§4.3)
    interpret: bool = True  # Pallas interpret mode (CPU validation)

    def __call__(self, v, mask):
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.fused_project_simplex(
                v,
                mask,
                radius=self.radius,
                inequality=self.inequality,
                interpret=self.interpret,
            )
        return project_simplex(
            v, mask, radius=self.radius, inequality=self.inequality
        )


@dataclasses.dataclass(frozen=True)
class BoxProjection(ProjectionMap):
    lo: float = 0.0
    hi: float = 1.0

    def __call__(self, v, mask):
        return project_box(v, mask, self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class BoxCutProjection(ProjectionMap):
    lo: float = 0.0
    hi: float = 1.0
    radius: float = 1.0
    iters: int = 64

    def __call__(self, v, mask):
        return project_box_cut(
            v, mask, self.lo, self.hi, self.radius, iters=self.iters
        )
