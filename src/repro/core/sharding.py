"""Column-sharded distributed execution (paper §4.4), TPU-native.

The paper launches one process per GPU and, per iteration, performs a
reduce-to-rank-0 of the |lam|-sized gradient, a serialized AGD update on rank
0, and a broadcast of the new duals.  The TPU-native schedule here is a single
`psum` inside `shard_map` followed by a *replicated* dual update on every
shard — mathematically identical, one collective instead of two, and no
serialized rank.  Both schedules are implemented (`comm_mode`):

  "psum"  (default) one all-reduce of [m*J (+2 packed scalars)] per iteration
  "rank0" paper-faithful: reduce + rank-0 update + broadcast (2 collectives)

Either way, per-iteration communication volume depends only on the dual
dimension m*J — never on sources, nonzeros, or shard count — which is the
paper's central scaling property.  Beyond the paper, `compress="bf16_ef"`
halves the reduce payload with per-shard error-feedback accumulators.

Sharding layout (the paper's balanced column split):
  bucket.idx/cost/mask [n, L]   -> P(axes, None)       n is the source axis
  bucket.coeff       [m, n, L]  -> P(None, axes, None)
  rhs                  [m*J]    -> P()                  replicated
  lam                  [m*J]    -> P()                  replicated

Buckets are padded to a row-multiple of the shard count at pack time
(`bucketize(shard_multiple=...)`), so every shard sees identical shapes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat, telemetry
from repro.core.maximizer import (
    MaximizerConfig,
    SolveResult,
    StageStats,
    _stage_scan,
    _stage_scan_early,
    step_size,
)
from repro.core.objective import DualEval, MatchingObjective
from repro.core.projections import ProjectionMap, UnitSimplexProjection
from repro.instances.buckets import Bucket, BucketedInstance

__all__ = [
    "DistConfig",
    "instance_pspecs",
    "shard_instance",
    "DistributedMaximizer",
    "num_shards",
]


@dataclasses.dataclass(frozen=True)
class DistConfig:
    axes: Union[str, tuple[str, ...]] = "data"  # mesh axes carrying the column shard
    comm_mode: str = "psum"  # "psum" | "rank0"
    compress: str = "none"  # "none" | "bf16" | "bf16_ef"
    fused_kernel: bool = False
    # One-pass fused dual oracle (kernels/dual_oracle.py): each shard's local
    # calculate() reads its slab rows once per iteration and emits the
    # pre-psum (ax, c'x, ||x||^2) contributions directly from the kernel's
    # partial histograms (include_rhs=False local mode, b applied after the
    # reduction as before).  Subsumes fused_kernel when set.
    fused_oracle: bool = False
    kernel_interpret: Optional[bool] = None
    # Slab storage dtype ("float32" | "bfloat16" | "int8"); the launch layer
    # bucketizes with it and the per-shard oracles load the narrow slabs with
    # fp32 accumulation (kernels/).  Dual space (lam, rhs, the psum payload)
    # stays fp32 regardless — wire compression is the separate `compress`.
    slab_dtype: str = "float32"

    def __post_init__(self):
        from repro.instances.buckets import SLAB_DTYPES

        if self.slab_dtype not in SLAB_DTYPES:
            raise ValueError(
                f"DistConfig.slab_dtype={self.slab_dtype!r}; "
                f"choose from {SLAB_DTYPES}"
            )

    @property
    def axes_tuple(self) -> tuple[str, ...]:
        return (self.axes,) if isinstance(self.axes, str) else tuple(self.axes)


def num_shards(mesh: Mesh, dist: DistConfig) -> int:
    return int(np.prod([mesh.shape[a] for a in dist.axes_tuple]))


def instance_pspecs(
    inst: BucketedInstance, axes: Union[str, tuple[str, ...]]
) -> BucketedInstance:
    """Pytree of PartitionSpecs matching a BucketedInstance."""
    row = P(axes, None)
    # int8 dequant scales (when present) are tiny [m,1,1]/[1,1] arrays and
    # ride along fully replicated; None mirrors None so treedefs match.
    buckets = tuple(
        Bucket(idx=row, coeff=P(None, axes, None), cost=row, mask=row,
               length=b.length,
               coeff_scale=None if b.coeff_scale is None else P(),
               cost_scale=None if b.cost_scale is None else P())
        for b in inst.buckets
    )
    return BucketedInstance(
        buckets=buckets,
        rhs=P(),
        num_sources=inst.num_sources,
        num_destinations=inst.num_destinations,
        num_families=inst.num_families,
    )


def shard_instance(
    inst: BucketedInstance, mesh: Mesh, dist: DistConfig
) -> BucketedInstance:
    """Place instance arrays on the mesh with the column-shard layout.

    Each host materialises only its local rows in a real multi-host deployment
    (the paper's 'reads the shared instance directly from the network
    filesystem'); here jax.device_put performs the equivalent placement.
    """
    specs = instance_pspecs(inst, dist.axes)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), inst, specs
    )


# ---------------------------------------------------------------------------


def _make_calculate(local_obj: MatchingObjective, dist: DistConfig, rhs):
    """Distributed ObjectiveFunction.calculate: local work + one reduction.

    Packs the two scalar reductions (objective decomposition) into the same
    all-reduce payload as the gradient vector, so `psum` mode issues exactly
    one collective per iteration.
    """
    axes = dist.axes_tuple

    def calculate(lam, gamma, comm):
        ev = local_obj.calculate(lam, gamma)  # include_rhs=False: local parts
        contrib = jnp.concatenate(
            [ev.ax, jnp.stack([ev.primal_linear, ev.primal_ridge])]
        )
        if dist.compress in ("bf16", "bf16_ef"):
            if dist.compress == "bf16_ef":
                contrib = contrib + comm  # add carried quantization error
            sent = contrib.astype(jnp.bfloat16)  # the wire payload IS bf16
            if dist.compress == "bf16_ef":
                comm = contrib - sent.astype(jnp.float32)
            contrib = sent
        if dist.comm_mode == "rank0":
            # paper-faithful: reduce to rank 0, update there, broadcast back.
            # In SPMD both hops are all-reduces; the second one broadcasts the
            # rank-0 update by summing a one-hot-masked copy.
            total = jax.lax.psum(contrib, axes)  # 'reduce' hop
            rank = _linear_rank(axes)
            masked = jnp.where(rank == 0, total, jnp.zeros_like(total))
            total = jax.lax.psum(masked, axes)  # 'broadcast' hop
        else:
            total = jax.lax.psum(contrib, axes)
        total = total.astype(jnp.float32)
        ax, lin, ridge = total[:-2], total[-2], total[-1]
        grad = ax - rhs
        g = lin + ridge + jnp.vdot(lam, grad)
        return (
            DualEval(g=g, grad=grad, x_slabs=ev.x_slabs,
                     primal_linear=lin, primal_ridge=ridge, ax=ax),
            comm,
        )

    return calculate


def _linear_rank(axes: tuple[str, ...]) -> jax.Array:
    rank = jnp.int32(0)
    for a in axes:
        rank = rank * compat.axis_size(a) + jax.lax.axis_index(a)
    return rank


class DistributedMaximizer:
    """Maximizer over a column-sharded instance (paper §4.4).

    The continuation driver and AGD stage logic are *shared* with the
    single-device Maximizer (`_stage_scan` / `_stage_scan_early`); this class
    contributes only the sharded `calculate`, the shard_map plumbing, and —
    when `config.tol_grad`/`tol_viol` are set — a psum'd convergence
    predicate so the chunked early-stop stage variant can run collectively:
    every shard votes on the stop decision and the stage exits only on a
    unanimous vote, keeping all shards at the same while_loop trip count.
    Up to the stop iteration the trajectory is bit-for-bit the fixed-budget
    one (same AGD body, same chunked scan).
    """

    def __init__(
        self,
        inst: BucketedInstance,  # host or already-sharded arrays
        mesh: Mesh,
        config: MaximizerConfig = MaximizerConfig(),
        dist: DistConfig = DistConfig(),
        projection: Optional[ProjectionMap] = None,
    ):
        self.mesh = mesh
        self.config = config
        self.dist = dist
        self.projection = projection or UnitSimplexProjection()
        self.inst = inst
        self._specs = instance_pspecs(inst, dist.axes)
        self._rhs_host = inst.rhs

        axes = dist.axes_tuple
        cfg = config

        def local_objective(inst_local: BucketedInstance) -> MatchingObjective:
            return MatchingObjective(
                inst_local,
                projection=self.projection,
                include_rhs=False,
                fused_kernel=dist.fused_kernel,
                fused_oracle=dist.fused_oracle,
                kernel_interpret=dist.kernel_interpret,
            )

        # ---- stage function (jit once; gamma/eta are traced scalars) -------
        slab_specs = tuple(P(axes, None) for _ in inst.buckets)
        n_shards = num_shards(mesh, dist)

        def psum_all_converged(done):
            """Collective stop predicate: every shard must vote converged.

            The per-shard predicate is computed from the psum'd global
            gradient, so the votes agree mathematically; reducing them with
            one more psum makes the agreement *structural* — the while_loop
            trip count is identical on every shard by construction, which is
            what keeps the collectives inside the loop body from deadlocking.
            """
            votes = jax.lax.psum(done.astype(jnp.int32), axes)
            return votes == n_shards

        @partial(
            shard_map,
            mesh=mesh,
            in_specs=(P(), P(), P(), self._specs),
            out_specs=(P(), StageStats(P(), P(), P()), P()),
            check_rep=False,
        )
        def stage_fn(lam0, gamma, eta, inst_local):
            obj = local_objective(inst_local)
            calculate = _make_calculate(obj, dist, inst_local.rhs)
            comm0 = (
                jnp.zeros((obj.dual_dim + 2,), jnp.float32)
                if dist.compress == "bf16_ef"
                else None
            )
            if cfg.early_stop:
                lam, stats, _, iters_used = _stage_scan_early(
                    calculate,
                    lam0,
                    gamma,
                    eta,
                    cfg.iters_per_stage,
                    acceleration=cfg.acceleration,
                    adaptive_restart=cfg.adaptive_restart,
                    tol_grad=cfg.tol_grad,
                    tol_viol=cfg.tol_viol,
                    check_every=cfg.check_every,
                    comm0=comm0,
                    stop_reduce=psum_all_converged,
                )
                return lam, stats, iters_used
            lam, stats, _ = _stage_scan(
                calculate,
                lam0,
                gamma,
                eta,
                cfg.iters_per_stage,
                acceleration=cfg.acceleration,
                adaptive_restart=cfg.adaptive_restart,
                comm0=comm0,
            )
            return lam, stats, jnp.asarray(cfg.iters_per_stage, jnp.int32)

        self._stage_fn = jax.jit(stage_fn)

        # ---- one-time sigma_max^2 power iteration (sharded) ----------------
        @partial(
            shard_map,
            mesh=mesh,
            in_specs=(P(), self._specs),
            out_specs=P(),
            check_rep=False,
        )
        def power_fn(u0, inst_local):
            obj = local_objective(inst_local)

            def body(u, _):
                atl = obj.apply_AT(u / jnp.linalg.norm(u))
                au = jax.lax.psum(obj.apply_A(atl), axes)
                return au, jnp.linalg.norm(au)

            _, norms = jax.lax.scan(body, u0, None, length=cfg.power_iters)
            return norms[-1]

        self._power_fn = jax.jit(power_fn)

        # ---- final primal recovery ------------------------------------------
        @partial(
            shard_map,
            mesh=mesh,
            in_specs=(P(), P(), self._specs),
            out_specs=(slab_specs, P()),
            check_rep=False,
        )
        def final_fn(lam, gamma, inst_local):
            obj = local_objective(inst_local)
            calculate = _make_calculate(obj, dist, inst_local.rhs)
            ev, _ = calculate(lam, gamma, jnp.zeros((obj.dual_dim + 2,), jnp.float32)
                              if dist.compress == "bf16_ef" else None)
            return ev.x_slabs, ev.g

        self._final_fn = jax.jit(final_fn)

    def place(self) -> None:
        """Device-put the instance with the column-shard layout."""
        self.inst = shard_instance(self.inst, self.mesh, self.dist)

    def solve(self, lam0: Optional[jax.Array] = None) -> SolveResult:
        cfg = self.config
        shards = num_shards(self.mesh, self.dist)
        dual_dim = self.inst.dual_dim
        lam = jnp.zeros((dual_dim,), jnp.float32) if lam0 is None else lam0
        u0 = jax.random.normal(jax.random.key(cfg.seed), (dual_dim,), jnp.float32)
        with telemetry.span(
            "dist_solve", shards=shards, comm_mode=self.dist.comm_mode
        ), compat.set_mesh(self.mesh):
            with telemetry.span("power_iteration"):
                sigma_sq = self._power_fn(u0, self.inst)
            stats, steps, used_stages = [], [], []
            for k, gamma in enumerate(cfg.gammas):
                eta = step_size(cfg, sigma_sq, gamma)
                with telemetry.span("stage", stage=k, gamma=float(gamma)):
                    lam, st, used = self._stage_fn(
                        lam, jnp.float32(gamma), eta.astype(jnp.float32),
                        self.inst,
                    )
                stats.append(st)
                steps.append(float(eta))
                used_stages.append(used)
            x_slabs, g = self._final_fn(
                lam, jnp.float32(cfg.gammas[-1]), self.inst
            )
        # host-convert the per-stage counts only after every stage has been
        # dispatched — int() blocks on the stage's device result, and the
        # fixed-budget path should keep its dispatch pipelining
        iters_used = (
            tuple(int(u) for u in used_stages) if cfg.early_stop else None
        )
        reg = telemetry.get_registry()
        reg.inc("dist_solves_total", 1, shards=shards)
        if iters_used is not None:
            # every shard votes once per check_every-chunk actually executed,
            # and budget minus iters_used is the work early stopping skipped
            checks = sum(-(-u // cfg.check_every) for u in iters_used)
            reg.inc("dist_early_stop_checks_total", checks * shards)
            reg.inc(
                "dist_iters_saved_total",
                sum(cfg.iters_per_stage - u for u in iters_used),
            )
        return SolveResult(
            lam=lam, x_slabs=x_slabs, g=g, stats=tuple(stats),
            sigma_sq=sigma_sq, steps=tuple(steps),
            iters_used=iters_used,
        )

    # -- dry-run hooks (launch/dryrun.py) ------------------------------------

    def lower_stage(self):
        """jax.jit(...).lower() of one continuation stage on abstract inputs."""
        sds = self.inst.shape_dtype_structs()
        lam = jax.ShapeDtypeStruct((self.inst.dual_dim,), jnp.float32)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        with compat.set_mesh(self.mesh):
            return self._stage_fn.lower(lam, scalar, scalar, sds)
