"""Restarted PDHG baseline (cuPDLP / D-PDLP family) — paper §7.2's comparator.

The paper compares against D-PDLP, which treats the LP as *unstructured*:
generic sparse K, two synchronous all-reduces per iteration under 2D
partitioning.  This module implements the same algorithmic family in JAX —
primal-dual hybrid gradient (Chambolle–Pock) with ergodic-average restarts, the
core of PDLP/cuPDLP — operating on an unstructured COO matrix that stacks the
coupling rows AND the per-source simplex rows (exactly the reformulation a
generic LP solver is forced into, which is the structural disadvantage the
paper exploits).

    min c'x   s.t.  K x <= q,  0 <= x <= u
    x+ = clip(x - tau (c + K'y), 0, u)
    y+ = max(0, y + sigma (K (2 x+ - x) - q))        tau sigma ||K||^2 < 1

Termination mirrors D-PDLP: relative primal residual, relative dual residual,
and relative gap all below `tol` (paper uses 1e-4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maximizer import StageStats
from repro.instances.generator import EdgeListInstance

__all__ = ["COOLP", "PDHGConfig", "PDHGResult", "from_edge_list", "solve_pdhg"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class COOLP:
    """Unstructured LP in COO form: min c'x s.t. Kx <= q, 0 <= x <= u."""

    rows: jax.Array  # [nnz] int32
    cols: jax.Array  # [nnz] int32
    vals: jax.Array  # [nnz] f32
    c: jax.Array  # [n]
    q: jax.Array  # [R]
    u: jax.Array  # [n] upper bounds
    num_rows: int = dataclasses.field(metadata=dict(static=True))
    num_cols: int = dataclasses.field(metadata=dict(static=True))

    def K(self, x: jax.Array) -> jax.Array:
        return jnp.zeros((self.num_rows,), x.dtype).at[self.rows].add(
            self.vals * x[self.cols]
        )

    def KT(self, y: jax.Array) -> jax.Array:
        return jnp.zeros((self.num_cols,), y.dtype).at[self.cols].add(
            self.vals * y[self.rows]
        )


def from_edge_list(inst: EdgeListInstance, dtype=jnp.float32) -> COOLP:
    """Stack coupling rows and per-source simplex rows into one generic K.

    Variables are the eligible edges (one x_e per (i,j) in E).  This is the
    'treat the system as unstructured' formulation that D-PDLP sees.
    """
    spec = inst.spec
    I, J, m = spec.num_sources, spec.num_destinations, spec.num_families
    nnz = inst.nnz
    e = np.arange(nnz, dtype=np.int64)
    rows = [k * J + inst.dst for k in range(m)] + [m * J + inst.src]
    cols = [e] * (m + 1)
    vals = [inst.coeff[k] for k in range(m)] + [np.ones(nnz)]
    # compress row space to active simplex rows? keep full I rows: fine.
    return COOLP(
        rows=jnp.asarray(np.concatenate(rows), jnp.int32),
        cols=jnp.asarray(np.concatenate(cols), jnp.int32),
        vals=jnp.asarray(np.concatenate(vals), dtype),
        c=jnp.asarray(inst.cost, dtype),
        q=jnp.asarray(np.concatenate([inst.rhs, np.ones(I)]), dtype),
        u=jnp.ones((nnz,), dtype),
        num_rows=m * J + I,
        num_cols=nnz,
    )


@dataclasses.dataclass(frozen=True)
class PDHGConfig:
    max_iters: int = 20000
    tol: float = 1e-4  # D-PDLP's relative tolerance
    restart_every: int = 200  # restart to the ergodic average (PDLP-style)
    check_every: int = 50
    power_iters: int = 50
    step_ratio: float = 1.0  # tau/sigma balance
    seed: int = 0


class PDHGResult(NamedTuple):
    x: jax.Array
    y: jax.Array
    iters: jax.Array
    primal_obj: jax.Array
    dual_obj: jax.Array
    rel_gap: jax.Array
    primal_res: jax.Array
    dual_res: jax.Array
    converged: jax.Array
    # Convergence-telemetry parity with core.maximizer.SolveResult: `stats` is
    # a 1-tuple of StageStats at check_every resolution (g=primal objective,
    # grad_norm=dual residual, max_violation=primal residual; entries past the
    # last check backfilled with the final residuals), `iters_used` a 1-tuple
    # of the iterations actually executed.  Both feed
    # telemetry.ConvergenceTrace.from_result(engine="pdhg",
    # trace_stride=check_every) unchanged.
    stats: tuple = ()
    iters_used: Optional[tuple[int, ...]] = None


def _residuals(lp: COOLP, x, y):
    kx = lp.K(x)
    primal_res = jnp.linalg.norm(jnp.maximum(kx - lp.q, 0.0)) / (
        1.0 + jnp.linalg.norm(lp.q)
    )
    r = lp.c + lp.KT(y)  # reduced costs
    # dual objective for 0 <= x <= u: -q'y + sum_i min(0, r_i) * u_i
    dual_obj = -jnp.vdot(lp.q, y) + jnp.vdot(jnp.minimum(r, 0.0), lp.u)
    primal_obj = jnp.vdot(lp.c, x)
    # dual residual: violation of r >= 0 where x can still increase is captured
    # by the gap; use projected-gradient norm as the dual residual proxy
    dual_res = jnp.linalg.norm(x - jnp.clip(x - r, 0.0, lp.u)) / (
        1.0 + jnp.linalg.norm(lp.c)
    )
    rel_gap = jnp.abs(primal_obj - dual_obj) / (
        1.0 + jnp.abs(primal_obj) + jnp.abs(dual_obj)
    )
    return primal_obj, dual_obj, rel_gap, primal_res, dual_res


@partial(jax.jit, static_argnames=("config",))
def _solve_pdhg_jit(lp: COOLP, config: PDHGConfig) -> PDHGResult:
    cfg = config
    n, R = lp.num_cols, lp.num_rows
    n_checks = max(1, -(-cfg.max_iters // cfg.check_every))

    # ||K||_2 by power iteration
    v0 = jax.random.normal(jax.random.key(cfg.seed), (n,), jnp.float32)

    def pw(v, _):
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-20)
        w = lp.KT(lp.K(v))
        return w, jnp.linalg.norm(w)

    _, ns = jax.lax.scan(pw, v0, None, length=cfg.power_iters)
    sigma_max = jnp.sqrt(ns[-1])
    tau = cfg.step_ratio / jnp.maximum(sigma_max, 1e-20) * 0.9
    sig = 1.0 / (cfg.step_ratio * jnp.maximum(sigma_max, 1e-20)) * 0.9

    class S(NamedTuple):
        x: jax.Array
        y: jax.Array
        x_sum: jax.Array
        y_sum: jax.Array
        k_in_window: jax.Array
        it: jax.Array
        done: jax.Array
        stats: tuple
        bufs: tuple  # check-resolution (primal_obj, dual_res, primal_res)

    def cond(s: S):
        return jnp.logical_and(s.it < cfg.max_iters, jnp.logical_not(s.done))

    def body(s: S):
        x, y = s.x, s.y
        x1 = jnp.clip(x - tau * (lp.c + lp.KT(y)), 0.0, lp.u)
        y1 = jnp.maximum(y + sig * (lp.K(2.0 * x1 - x) - lp.q), 0.0)
        x_sum, y_sum = s.x_sum + x1, s.y_sum + y1
        k = s.k_in_window + 1
        # PDLP-style fixed-frequency restart to the ergodic average
        do_restart = (s.it + 1) % cfg.restart_every == 0
        x2 = jnp.where(do_restart, x_sum / k, x1)
        y2 = jnp.where(do_restart, y_sum / k, y1)
        x_sum = jnp.where(do_restart, jnp.zeros_like(x_sum), x_sum)
        y_sum = jnp.where(do_restart, jnp.zeros_like(y_sum), y_sum)
        k = jnp.where(do_restart, 0, k)
        check = (s.it + 1) % cfg.check_every == 0
        po, do_, gap, pr, dr = jax.lax.cond(
            check,
            lambda: _residuals(lp, x2, y2),
            lambda: s.stats,
        )
        done = jnp.logical_and(
            check,
            jnp.logical_and(gap < cfg.tol, jnp.logical_and(pr < cfg.tol, dr < cfg.tol)),
        )
        # check-resolution trace buffers (AGD stats parity); idx addresses
        # the check that iteration it+1 completes, clipped so the non-check
        # branch's self-write is a no-op
        idx = jnp.clip((s.it + 1) // cfg.check_every - 1, 0, n_checks - 1)
        bg, bdr, bpr = s.bufs
        bg = bg.at[idx].set(jnp.where(check, po, bg[idx]))
        bdr = bdr.at[idx].set(jnp.where(check, dr, bdr[idx]))
        bpr = bpr.at[idx].set(jnp.where(check, pr, bpr[idx]))
        return S(
            x2, y2, x_sum, y_sum, k, s.it + 1, done,
            (po, do_, gap, pr, dr), (bg, bdr, bpr),
        )

    zero_stats = tuple(jnp.asarray(jnp.inf, jnp.float32) for _ in range(5))
    init = S(
        x=jnp.zeros((n,), jnp.float32),
        y=jnp.zeros((R,), jnp.float32),
        x_sum=jnp.zeros((n,), jnp.float32),
        y_sum=jnp.zeros((R,), jnp.float32),
        k_in_window=jnp.asarray(0, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
        done=jnp.asarray(False),
        stats=zero_stats,
        bufs=tuple(jnp.zeros((n_checks,), jnp.float32) for _ in range(3)),
    )
    s = jax.lax.while_loop(cond, body, init)
    po, do_, gap, pr, dr = _residuals(lp, s.x, s.y)
    # backfill check slots the loop never reached with the final residuals —
    # the same convention _stage_scan_early uses, so trace tails stay
    # meaningful after an early exit
    checks_done = s.it // cfg.check_every
    pos = jnp.arange(n_checks)
    bg, bdr, bpr = s.bufs
    stats = StageStats(
        g=jnp.where(pos < checks_done, bg, po),
        grad_norm=jnp.where(pos < checks_done, bdr, dr),
        max_violation=jnp.where(pos < checks_done, bpr, pr),
    )
    return PDHGResult(
        x=s.x, y=s.y, iters=s.it, primal_obj=po, dual_obj=do_,
        rel_gap=gap, primal_res=pr, dual_res=dr,
        converged=jnp.logical_and(gap < cfg.tol, jnp.logical_and(pr < cfg.tol, dr < cfg.tol)),
        stats=(stats,),
        iters_used=None,
    )


def solve_pdhg(lp: COOLP, config: PDHGConfig = PDHGConfig()) -> PDHGResult:
    """Solve the COO LP; the host wrapper fills in `iters_used` (one scalar
    host read after the solve completes — no per-iteration syncs)."""
    res = _solve_pdhg_jit(lp, config)
    return res._replace(iters_used=(int(res.iters),))
