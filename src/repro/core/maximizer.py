"""Maximizer — accelerated dual ascent with gamma-continuation (paper Table 1, §6).

Runs Nesterov-accelerated projected gradient ascent on the smoothed dual
g(lam) over lam >= 0, with:

  * analytic step size  eta_s = gamma_s / sigma_max(A)^2  per continuation
    stage (the Lipschitz constant of grad g is ||A||^2 / gamma; paper §3.1),
    clipped to the paper's AGD step-size range [1e-5, 1e-1] and rescaled
    proportionally with the gamma decay (paper §B.2);
  * the paper's six-stage geometric continuation schedule
    gamma in {1e3, 1e2, 10, 1, 1e-1, 1e-2}, each stage warm-started from the
    previous dual iterate (paper §6/§7.2);
  * O'Donoghue–Candès adaptive restart (momentum reset when the dual
    objective decreases), which replaces the instance-specific AGD tuning the
    paper reports for the Scala system;
  * Jacobi preconditioning is an instance transform (`normalize_rows` in
    objective.py) applied before the Maximizer sees the problem.

The stage loop is a single `lax.scan` (jit-compiled once and reused across
stages, since stage hyperparameters enter as traced scalars).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core.objective import DualEval, MatchingObjective

__all__ = [
    "MaximizerConfig",
    "StageStats",
    "SolveResult",
    "Maximizer",
    "PAPER_GAMMA_SCHEDULE",
    "step_size",
]

# `_stage_scan` / `_stage_scan_early` are the shared stage primitives: the
# distributed layer (core/sharding) and the recurring-solve service
# (repro/service) both build their own drivers around them.

# Paper §7.2: six-stage geometric schedule.
PAPER_GAMMA_SCHEDULE: tuple[float, ...] = (1e3, 1e2, 10.0, 1.0, 1e-1, 1e-2)


def step_size(
    cfg: "MaximizerConfig", sigma_sq: jax.Array, gamma: float
) -> jax.Array:
    """Analytic AGD step eta = step_scale * gamma / sigma_max(A)^2, clipped
    to the paper's range.  Single source of truth — Maximizer and the
    recurring-solve service engine must agree for warm/batched solves to be
    equivalent to one-shot solves."""
    eta = cfg.step_scale * gamma / jnp.maximum(sigma_sq, 1e-20)
    return jnp.clip(eta, cfg.min_step, cfg.max_step)


@dataclasses.dataclass(frozen=True)
class MaximizerConfig:
    gammas: tuple[float, ...] = PAPER_GAMMA_SCHEDULE
    iters_per_stage: int = 200
    step_scale: float = 1.0
    min_step: float = 1e-5  # paper §7.2 AGD step-size range
    max_step: float = 1e-1
    acceleration: bool = True
    adaptive_restart: bool = True
    power_iters: int = 30
    record_every: int = 1
    seed: int = 0
    # Convergence-based early stopping (recurring-solve service): a stage exits
    # once ||grad g|| <= tol_grad * max(1, |g|) and max(0, Ax-b) <= tol_viol,
    # checked every `check_every` iterations inside a lax.while_loop of scanned
    # chunks.  None disables the corresponding criterion; both None keeps the
    # original fixed-budget single-scan stage (bitwise-identical trajectories).
    tol_grad: Optional[float] = None
    tol_viol: Optional[float] = None
    check_every: int = 25

    @property
    def total_iters(self) -> int:
        return self.iters_per_stage * len(self.gammas)

    @property
    def early_stop(self) -> bool:
        return self.tol_grad is not None or self.tol_viol is not None

    @property
    def stage_iter_budget(self) -> int:
        """Worst-case iterations per stage (chunking rounds the budget up)."""
        if not self.early_stop:
            return self.iters_per_stage
        chunk = max(1, min(self.check_every, self.iters_per_stage))
        return -(-self.iters_per_stage // chunk) * chunk

    @property
    def total_iter_budget(self) -> int:
        return self.stage_iter_budget * len(self.gammas)


class StageStats(NamedTuple):
    g: jax.Array  # [T] dual objective trace
    grad_norm: jax.Array  # [T] ||grad g||
    max_violation: jax.Array  # [T] max(0, Ax - b) (grad is exactly Ax - b)


class SolveResult(NamedTuple):
    lam: jax.Array
    x_slabs: tuple[jax.Array, ...]
    g: jax.Array  # final dual objective
    stats: tuple[StageStats, ...]  # one per continuation stage
    sigma_sq: jax.Array  # power-iteration estimate of sigma_max(A)^2
    steps: tuple[float, ...]  # per-stage step sizes actually used
    # per-stage iterations actually executed (< iters_per_stage when the
    # early-stop criterion fires); None when early stopping is disabled
    iters_used: Optional[tuple[int, ...]] = None
    # explicit solver restarts taken (PDHG anchor/average restarts); None for
    # engines that don't count them (AGD's in-scan momentum resets)
    restarts: Optional[int] = None

    @property
    def total_iters_used(self) -> Optional[int]:
        return None if self.iters_used is None else int(sum(self.iters_used))


class _Carry(NamedTuple):
    lam_prev: jax.Array
    lam: jax.Array
    tk: jax.Array  # momentum counter (float)
    g_prev: jax.Array
    comm: object  # opaque per-shard communication state (e.g. error feedback)


def _agd_body(
    calculate: Callable,
    gamma: jax.Array,
    eta: jax.Array,
    *,
    acceleration: bool,
    adaptive_restart: bool,
) -> Callable:
    """Scan body of one accelerated projected dual-ascent iteration."""

    def body(carry: _Carry, _):
        beta = (carry.tk - 1.0) / (carry.tk + 2.0) if acceleration else 0.0
        mu = carry.lam + beta * (carry.lam - carry.lam_prev)
        mu = jnp.maximum(mu, 0.0)
        ev, comm = calculate(mu, gamma, carry.comm)
        lam_next = jnp.maximum(mu + eta * ev.grad, 0.0)
        if adaptive_restart:
            restart = ev.g < carry.g_prev
            tk_next = jnp.where(restart, 1.0, carry.tk + 1.0)
        else:
            tk_next = carry.tk + 1.0
        gn = jnp.linalg.norm(ev.grad)
        viol = jnp.max(jnp.maximum(ev.grad, 0.0))
        new = _Carry(
            lam_prev=carry.lam, lam=lam_next, tk=tk_next, g_prev=ev.g, comm=comm
        )
        return new, (ev.g, gn, viol)

    return body


def _init_carry(lam0: jax.Array, comm0: object) -> _Carry:
    return _Carry(
        lam_prev=lam0,
        lam=lam0,
        tk=jnp.asarray(1.0, lam0.dtype),
        g_prev=jnp.asarray(-jnp.inf, lam0.dtype),
        comm=comm0,
    )


def _stage_scan(
    calculate: Callable,  # (lam, gamma, comm_state) -> (DualEval, comm_state)
    lam0: jax.Array,
    gamma: jax.Array,
    eta: jax.Array,
    iters: int,
    *,
    acceleration: bool,
    adaptive_restart: bool,
    comm0: object = None,
) -> tuple[jax.Array, StageStats, object]:
    """One continuation stage of accelerated projected dual ascent.

    `calculate` threads an opaque communication state through the loop — the
    distributed layer uses it for gradient-compression error feedback; the
    single-shard path passes None straight through.
    """
    body = _agd_body(
        calculate, gamma, eta,
        acceleration=acceleration, adaptive_restart=adaptive_restart,
    )
    init = _init_carry(lam0, comm0)
    final, (gs, gns, viols) = jax.lax.scan(body, init, None, length=iters)
    return final.lam, StageStats(g=gs, grad_norm=gns, max_violation=viols), final.comm


def _chunked_early_scan(
    body: Callable,
    carry0,
    iters: int,
    *,
    check_every: int,
    trace_dtype,
    num_traces: int,
    stop_predicate: Callable,
    stop_reduce: Optional[Callable] = None,
):
    """Generic chunked-scan-inside-while_loop early-stop machinery.

    Engine-agnostic core shared by the AGD stage loop (`_stage_scan_early`)
    and the structured PDHG engine (`repro.engines.pdhg`): runs `body` — any
    `lax.scan` body emitting a tuple of `num_traces` scalar traces per step —
    in chunks of `check_every` steps inside a `lax.while_loop`.  After each
    chunk, `stop_predicate(chunk_traces)` (a boolean of the just-scanned
    trace chunk) decides convergence, optionally reduced collectively by
    `stop_reduce` (e.g. the psum'd all-shards-agree vote in
    `repro.core.sharding` — it must return the same value on every
    participant, or shards exit at different trip counts and the collectives
    inside `body` deadlock).

    Returns `(final_carry, trace_bufs, steps_used)`.  Trace buffers are
    preallocated at the padded budget (`ceil(iters/chunk) * chunk`); entries
    past `steps_used` are backfilled with the last computed value, so
    `buf[-1]` stays meaningful after an early exit.  Under `vmap` the batch
    runs lockstep until every element has converged.
    """
    chunk = max(1, min(int(check_every), int(iters)))
    n_chunks = -(-int(iters) // chunk)  # ceil
    total = n_chunks * chunk
    bufs0 = tuple(jnp.zeros((total,), trace_dtype) for _ in range(num_traces))
    state0 = (
        carry0,
        jnp.asarray(0, jnp.int32),  # chunks completed
        jnp.asarray(False),  # converged
        bufs0,
    )

    def cond(state):
        _, k, done, _ = state
        return jnp.logical_and(k < n_chunks, jnp.logical_not(done))

    def step(state):
        carry, k, _, bufs = state
        carry, traces = jax.lax.scan(body, carry, None, length=chunk)
        off = k * chunk
        bufs = tuple(
            jax.lax.dynamic_update_slice(b, t, (off,))
            for b, t in zip(bufs, traces)
        )
        done = stop_predicate(traces)
        if stop_reduce is not None:
            done = stop_reduce(done)
        return carry, k + 1, done, bufs

    final, k, _, bufs = jax.lax.while_loop(cond, step, state0)
    steps_used = (k * chunk).astype(jnp.int32)
    last = jnp.maximum(steps_used - 1, 0)
    pos = jnp.arange(total)
    bufs = tuple(jnp.where(pos < steps_used, b, b[last]) for b in bufs)
    return final, bufs, steps_used


def _stage_scan_early(
    calculate: Callable,
    lam0: jax.Array,
    gamma: jax.Array,
    eta: jax.Array,
    iters: int,
    *,
    acceleration: bool,
    adaptive_restart: bool,
    tol_grad: Optional[float],
    tol_viol: Optional[float],
    check_every: int,
    comm0: object = None,
    stop_reduce: Optional[Callable] = None,
) -> tuple[jax.Array, StageStats, object, jax.Array]:
    """Early-stopping variant of `_stage_scan` (recurring-solve service).

    Runs the same AGD body in chunks of `check_every` iterations inside a
    `lax.while_loop` (`_chunked_early_scan`); after each chunk the criterion
    ``||grad|| <= tol_grad * max(1, |g|)  and  max(0, Ax-b) <= tol_viol``
    is evaluated and the loop exits once met.  Warm-started solves therefore
    pay only as many iterations as they need instead of the full fixed budget.

    `stop_reduce` makes the stop decision *collective* (see
    `_chunked_early_scan`); None keeps the local predicate — correct for
    single-device and vmapped use.

    Returns `(lam, stats, comm, iters_used)`.  Stats traces are preallocated at
    the padded budget; entries past `iters_used` are backfilled with the last
    computed value, so `stats.g[-1]` etc. stay meaningful.
    """
    body = _agd_body(
        calculate, gamma, eta,
        acceleration=acceleration, adaptive_restart=adaptive_restart,
    )

    def stop_predicate(traces):
        gs, gns, viols = traces
        done = jnp.asarray(True)
        if tol_grad is not None:
            scale = jnp.maximum(1.0, jnp.abs(gs[-1]))
            done = jnp.logical_and(done, gns[-1] <= tol_grad * scale)
        if tol_viol is not None:
            done = jnp.logical_and(done, viols[-1] <= tol_viol)
        return done

    final, (bg, bgn, bv), iters_used = _chunked_early_scan(
        body,
        _init_carry(lam0, comm0),
        iters,
        check_every=check_every,
        trace_dtype=lam0.dtype,
        num_traces=3,
        stop_predicate=stop_predicate,
        stop_reduce=stop_reduce,
    )
    stats = StageStats(g=bg, grad_norm=bgn, max_violation=bv)
    return final.lam, stats, final.comm, iters_used


class Maximizer:
    """Dual-ascent driver (paper Table 1 'Maximizer').

    Hides acceleration, continuation and conditioning behind one `solve()`;
    distributed execution wraps the same stage function inside `shard_map`
    (see `repro.core.sharding`), leaving this class unchanged — that boundary
    is the paper's §5 operator-centric claim.
    """

    def __init__(
        self,
        objective: MatchingObjective,
        config: MaximizerConfig = MaximizerConfig(),
    ):
        self.objective = objective
        self.config = config

        def calc(lam, gamma, comm):
            return objective.calculate(lam, gamma), comm

        if config.early_stop:
            self._stage_fn = jax.jit(
                partial(
                    _stage_scan_early,
                    calc,
                    iters=config.iters_per_stage,
                    acceleration=config.acceleration,
                    adaptive_restart=config.adaptive_restart,
                    tol_grad=config.tol_grad,
                    tol_viol=config.tol_viol,
                    check_every=config.check_every,
                )
            )
        else:
            self._stage_fn = jax.jit(
                partial(
                    _stage_scan,
                    calc,
                    iters=config.iters_per_stage,
                    acceleration=config.acceleration,
                    adaptive_restart=config.adaptive_restart,
                )
            )

    def step_size(self, sigma_sq: jax.Array, gamma: float) -> jax.Array:
        return step_size(self.config, sigma_sq, gamma)

    def solve(self, lam0: Optional[jax.Array] = None) -> SolveResult:
        cfg = self.config
        obj = self.objective
        lam = (
            jnp.zeros((obj.dual_dim,), jnp.float32) if lam0 is None else lam0
        )
        with telemetry.span("power_iteration"):
            sigma_sq = jax.jit(partial(obj.power_iteration, iters=cfg.power_iters))(
                jax.random.key(cfg.seed)
            )
        stats: list[StageStats] = []
        steps: list[float] = []
        iters_used: list[int] = []
        for k, gamma in enumerate(cfg.gammas):
            eta = self.step_size(sigma_sq, gamma)
            with telemetry.span("stage", stage=k, gamma=float(gamma)):
                if cfg.early_stop:
                    lam, st, _, used = self._stage_fn(
                        lam, jnp.asarray(gamma, lam.dtype), eta.astype(lam.dtype)
                    )
                    iters_used.append(int(used))
                else:
                    lam, st, _ = self._stage_fn(
                        lam, jnp.asarray(gamma, lam.dtype), eta.astype(lam.dtype)
                    )
            stats.append(st)
            steps.append(float(eta))
        final = jax.jit(obj.calculate)(lam, jnp.asarray(cfg.gammas[-1], lam.dtype))
        return SolveResult(
            lam=lam,
            x_slabs=final.x_slabs,
            g=final.g,
            stats=tuple(stats),
            sigma_sq=sigma_sq,
            steps=tuple(steps),
            iters_used=tuple(iters_used) if cfg.early_stop else None,
        )
