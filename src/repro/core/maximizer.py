"""Maximizer — accelerated dual ascent with gamma-continuation (paper Table 1, §6).

Runs Nesterov-accelerated projected gradient ascent on the smoothed dual
g(lam) over lam >= 0, with:

  * analytic step size  eta_s = gamma_s / sigma_max(A)^2  per continuation
    stage (the Lipschitz constant of grad g is ||A||^2 / gamma; paper §3.1),
    clipped to the paper's AGD step-size range [1e-5, 1e-1] and rescaled
    proportionally with the gamma decay (paper §B.2);
  * the paper's six-stage geometric continuation schedule
    gamma in {1e3, 1e2, 10, 1, 1e-1, 1e-2}, each stage warm-started from the
    previous dual iterate (paper §6/§7.2);
  * O'Donoghue–Candès adaptive restart (momentum reset when the dual
    objective decreases), which replaces the instance-specific AGD tuning the
    paper reports for the Scala system;
  * Jacobi preconditioning is an instance transform (`normalize_rows` in
    objective.py) applied before the Maximizer sees the problem.

The stage loop is a single `lax.scan` (jit-compiled once and reused across
stages, since stage hyperparameters enter as traced scalars).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.objective import DualEval, MatchingObjective

__all__ = [
    "MaximizerConfig",
    "StageStats",
    "SolveResult",
    "Maximizer",
    "PAPER_GAMMA_SCHEDULE",
]

# Paper §7.2: six-stage geometric schedule.
PAPER_GAMMA_SCHEDULE: tuple[float, ...] = (1e3, 1e2, 10.0, 1.0, 1e-1, 1e-2)


@dataclasses.dataclass(frozen=True)
class MaximizerConfig:
    gammas: tuple[float, ...] = PAPER_GAMMA_SCHEDULE
    iters_per_stage: int = 200
    step_scale: float = 1.0
    min_step: float = 1e-5  # paper §7.2 AGD step-size range
    max_step: float = 1e-1
    acceleration: bool = True
    adaptive_restart: bool = True
    power_iters: int = 30
    record_every: int = 1
    seed: int = 0

    @property
    def total_iters(self) -> int:
        return self.iters_per_stage * len(self.gammas)


class StageStats(NamedTuple):
    g: jax.Array  # [T] dual objective trace
    grad_norm: jax.Array  # [T] ||grad g||
    max_violation: jax.Array  # [T] max(0, Ax - b) (grad is exactly Ax - b)


class SolveResult(NamedTuple):
    lam: jax.Array
    x_slabs: tuple[jax.Array, ...]
    g: jax.Array  # final dual objective
    stats: tuple[StageStats, ...]  # one per continuation stage
    sigma_sq: jax.Array  # power-iteration estimate of sigma_max(A)^2
    steps: tuple[float, ...]  # per-stage step sizes actually used


class _Carry(NamedTuple):
    lam_prev: jax.Array
    lam: jax.Array
    tk: jax.Array  # momentum counter (float)
    g_prev: jax.Array
    comm: object  # opaque per-shard communication state (e.g. error feedback)


def _stage_scan(
    calculate: Callable,  # (lam, gamma, comm_state) -> (DualEval, comm_state)
    lam0: jax.Array,
    gamma: jax.Array,
    eta: jax.Array,
    iters: int,
    *,
    acceleration: bool,
    adaptive_restart: bool,
    comm0: object = None,
) -> tuple[jax.Array, StageStats, object]:
    """One continuation stage of accelerated projected dual ascent.

    `calculate` threads an opaque communication state through the loop — the
    distributed layer uses it for gradient-compression error feedback; the
    single-shard path passes None straight through.
    """

    def body(carry: _Carry, _):
        beta = (carry.tk - 1.0) / (carry.tk + 2.0) if acceleration else 0.0
        mu = carry.lam + beta * (carry.lam - carry.lam_prev)
        mu = jnp.maximum(mu, 0.0)
        ev, comm = calculate(mu, gamma, carry.comm)
        lam_next = jnp.maximum(mu + eta * ev.grad, 0.0)
        if adaptive_restart:
            restart = ev.g < carry.g_prev
            tk_next = jnp.where(restart, 1.0, carry.tk + 1.0)
        else:
            tk_next = carry.tk + 1.0
        gn = jnp.linalg.norm(ev.grad)
        viol = jnp.max(jnp.maximum(ev.grad, 0.0))
        new = _Carry(
            lam_prev=carry.lam, lam=lam_next, tk=tk_next, g_prev=ev.g, comm=comm
        )
        return new, (ev.g, gn, viol)

    init = _Carry(
        lam_prev=lam0,
        lam=lam0,
        tk=jnp.asarray(1.0, lam0.dtype),
        g_prev=jnp.asarray(-jnp.inf, lam0.dtype),
        comm=comm0,
    )
    final, (gs, gns, viols) = jax.lax.scan(body, init, None, length=iters)
    return final.lam, StageStats(g=gs, grad_norm=gns, max_violation=viols), final.comm


class Maximizer:
    """Dual-ascent driver (paper Table 1 'Maximizer').

    Hides acceleration, continuation and conditioning behind one `solve()`;
    distributed execution wraps the same stage function inside `shard_map`
    (see `repro.core.sharding`), leaving this class unchanged — that boundary
    is the paper's §5 operator-centric claim.
    """

    def __init__(
        self,
        objective: MatchingObjective,
        config: MaximizerConfig = MaximizerConfig(),
    ):
        self.objective = objective
        self.config = config

        def calc(lam, gamma, comm):
            return objective.calculate(lam, gamma), comm

        self._stage_fn = jax.jit(
            partial(
                _stage_scan,
                calc,
                iters=config.iters_per_stage,
                acceleration=config.acceleration,
                adaptive_restart=config.adaptive_restart,
            )
        )

    def step_size(self, sigma_sq: jax.Array, gamma: float) -> jax.Array:
        cfg = self.config
        eta = cfg.step_scale * gamma / jnp.maximum(sigma_sq, 1e-20)
        return jnp.clip(eta, cfg.min_step, cfg.max_step)

    def solve(self, lam0: Optional[jax.Array] = None) -> SolveResult:
        cfg = self.config
        obj = self.objective
        lam = (
            jnp.zeros((obj.dual_dim,), jnp.float32) if lam0 is None else lam0
        )
        sigma_sq = jax.jit(partial(obj.power_iteration, iters=cfg.power_iters))(
            jax.random.key(cfg.seed)
        )
        stats: list[StageStats] = []
        steps: list[float] = []
        for gamma in cfg.gammas:
            eta = self.step_size(sigma_sq, gamma)
            lam, st, _ = self._stage_fn(
                lam, jnp.asarray(gamma, lam.dtype), eta.astype(lam.dtype)
            )
            stats.append(st)
            steps.append(float(eta))
        final = jax.jit(obj.calculate)(lam, jnp.asarray(cfg.gammas[-1], lam.dtype))
        return SolveResult(
            lam=lam,
            x_slabs=final.x_slabs,
            g=final.g,
            stats=tuple(stats),
            sigma_sq=sigma_sq,
            steps=tuple(steps),
        )
