"""LM-demo serving: engine generation, prefill/decode consistency, int8 cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.model import Model
from repro.serving.lm_demo.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced_config("qwen3-8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_generates(small_model):
    cfg, model, params = small_model
    engine = ServeEngine(model, params, slots=2, max_seq=48)
    rng = np.random.default_rng(0)
    for rid in range(4):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=6,
        ))
    reqs = list(engine.queue)
    engine.run()
    for r in reqs:
        assert r.done
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_engine_deterministic(small_model):
    cfg, model, params = small_model
    outs = []
    for _ in range(2):
        engine = ServeEngine(model, params, slots=2, max_seq=48)
        prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
        req = Request(rid=0, prompt=prompt, max_new_tokens=5)
        engine.submit(req)
        engine.run()
        outs.append(tuple(req.out_tokens))
    assert outs[0] == outs[1]


def test_prefill_then_decode_matches_decode_only(small_model):
    """prefill(cache) + decode == teacher-forced decode from empty cache."""
    cfg, model, params = small_model
    B, S = 2, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_pf, cache_pf = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq=S + 4)
    )(params, {"tokens": toks})
    # decode-only path
    cache = model.init_cache(B, S + 4)
    dec = jax.jit(model.decode_step)
    for t in range(S):
        lg, cache = dec(params, toks[:, t:t+1], jnp.asarray(t, jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(logits_pf[:, -1], np.float32),
        np.asarray(lg[:, -1], np.float32), atol=0.05, rtol=0.05,
    )
    # continue one step from both caches: same next logits
    nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    lg_a, _ = dec(params, nxt, jnp.asarray(S, jnp.int32), cache_pf)
    lg_b, _ = dec(params, nxt, jnp.asarray(S, jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(lg_a, np.float32), np.asarray(lg_b, np.float32),
        atol=0.05, rtol=0.05,
    )


@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-2.7b"])
@pytest.mark.slow
def test_int8_cache_parity(arch):
    cfg = get_reduced_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model, model8 = Model(cfg), Model(cfg8)
    params = model.init(jax.random.key(2))
    B, S = 2, 16
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    c, c8 = model.init_cache(B, S), model8.init_cache(B, S)
    assert c8["attn_k" if cfg.family == "hybrid" else "k"].dtype == jnp.int8
    dec, dec8 = jax.jit(model.decode_step), jax.jit(model8.decode_step)
    for t in range(S):
        lg, c = dec(params, toks[:, t:t+1], jnp.asarray(t, jnp.int32), c)
        lg8, c8 = dec8(params, toks[:, t:t+1], jnp.asarray(t, jnp.int32), c8)
    a = np.asarray(lg.astype(jnp.float32))
    b = np.asarray(lg8.astype(jnp.float32))
    assert np.argmax(a[:, -1], -1).tolist() == np.argmax(b[:, -1], -1).tolist()
    np.testing.assert_allclose(a, b, atol=0.05)
