"""MoE layer + LP router: dispatch correctness and balance properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced_config
from repro.models.moe import apply_moe, init_moe, lp_route


def test_moe_dense_equivalence():
    """With capacity >= T*k (no drops), sorted dispatch == naive per-token loop."""
    cfg = get_reduced_config("kimi-k2-1t-a32b")
    m = dataclasses.replace(cfg.moe, capacity_factor=8.0, num_shared=0)
    cfg = dataclasses.replace(cfg, moe=m)
    p = init_moe(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(24, cfg.d_model)).astype(np.float32))
    out = apply_moe(p, cfg, x)

    # naive reference
    logits = x @ p["router"]["w"].astype(x.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / w.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(x))
    for t in range(x.shape[0]):
        for j in range(m.top_k):
            e = int(ids[t, j])
            g = jax.nn.silu(x[t] @ p["w_gate"][e].astype(x.dtype))
            u = x[t] @ p["w_up"][e].astype(x.dtype)
            y = (g * u) @ p["w_down"][e].astype(x.dtype)
            ref[t] += float(w[t, j]) * np.asarray(y)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)


def test_moe_capacity_drops():
    """Over-capacity assignments are dropped, not mis-routed."""
    cfg = get_reduced_config("deepseek-v2-236b")
    m = dataclasses.replace(cfg.moe, capacity_factor=0.1, num_shared=0)
    cfg = dataclasses.replace(cfg, moe=m)
    p = init_moe(jax.random.key(1), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64, cfg.d_model)), jnp.float32)
    out = apply_moe(p, cfg, x)
    assert jnp.all(jnp.isfinite(out))
    # with tiny capacity most tokens get zero contribution
    zero_rows = float((jnp.abs(out).sum(-1) < 1e-9).mean())
    assert zero_rows > 0.3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), T=st.sampled_from([64, 256]), E=st.sampled_from([4, 8]))
def test_lp_route_properties(seed, T, E):
    rng = np.random.default_rng(seed)
    k = 2
    probs = jax.nn.softmax(jnp.asarray(rng.normal(size=(T, E)) * 2), -1)
    cap = T * k / E * 1.1
    x = lp_route(probs, k, capacity=cap, iters=64, gamma=0.05)
    x = np.asarray(x)
    assert (x >= -1e-5).all()
    assert (x.sum(1) <= k + 1e-3).all()  # per-token simplex radius k
    # per-expert capacity approximately respected (finite-iteration dual
    # ascent: small residual violation decays with iters)
    assert x.sum(0).max() <= cap * 1.25


def test_lp_route_balances_hot_experts():
    rng = np.random.default_rng(2)
    T, E, k = 1024, 8, 2
    hot = np.zeros(E); hot[0] = 3.0  # one very hot expert
    probs = jax.nn.softmax(jnp.asarray(rng.normal(size=(T, E)) + hot[None]), -1)
    C = T * k / E * 1.25
    _, id_top = jax.lax.top_k(probs, k)
    x = lp_route(probs, k, capacity=C, iters=64, gamma=0.05)
    _, id_lp = jax.lax.top_k(x, k)
    load = lambda ids: np.bincount(np.asarray(ids).reshape(-1), minlength=E).max()
    # fractional x respects capacity; hardening via top-k re-concentrates a
    # little, so compare against the unbalanced router and a loose cap bound
    assert load(id_lp) < 0.6 * load(id_top)
    assert load(id_lp) <= C * 1.5


def test_lp_router_in_model_trains():
    cfg = get_reduced_config("deepseek-v2-236b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router="lp", lp_iters=8)
    )
    from repro.models.model import Model

    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(
        params, {"tokens": toks, "labels": toks}
    )
    assert jnp.isfinite(loss)
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads))
