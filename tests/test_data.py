"""Data pipeline: determinism, resume skip-ahead, frontend stubs."""
import numpy as np

from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLMData


def test_deterministic_per_step():
    cfg = get_reduced_config("qwen3-8b")
    d1 = SyntheticLMData(cfg, batch=4, seq=32, seed=7)
    d2 = SyntheticLMData(cfg, batch=4, seq=32, seed=7)
    for k in (0, 3, 100):
        a, b = d1(k), d2(k)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ_and_seeds_differ():
    cfg = get_reduced_config("qwen3-8b")
    d = SyntheticLMData(cfg, batch=4, seq=32, seed=7)
    assert not np.array_equal(d(0)["tokens"], d(1)["tokens"])
    d2 = SyntheticLMData(cfg, batch=4, seq=32, seed=8)
    assert not np.array_equal(d(0)["tokens"], d2(0)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_reduced_config("qwen3-8b")
    d = SyntheticLMData(cfg, batch=2, seq=16, seed=0)
    b = d(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


def test_learnable_signal():
    """The bigram structure makes next-token partially predictable."""
    cfg = get_reduced_config("qwen3-8b")
    d = SyntheticLMData(cfg, batch=8, seq=64, seed=1)
    b = d(0)
    hits = (d._shift[b["tokens"][:, :-1]] == b["tokens"][:, 1:]).mean()
    assert hits > 0.3  # ~50% by construction


def test_frontend_stubs():
    vlm = get_reduced_config("internvl2-76b")
    b = SyntheticLMData(vlm, batch=2, seq=32, seed=0)(0)
    P = vlm.frontend_len
    assert b["embeds"].shape == (2, P, vlm.d_model)
    assert b["tokens"].shape == (2, 32 - P)
    assert b["labels"].shape == (2, 32)
    assert (b["labels"][:, :P] == -100).all()

    enc = get_reduced_config("seamless-m4t-medium")
    b = SyntheticLMData(enc, batch=2, seq=32, seed=0)(0)
    assert b["embeds"].shape == (2, 32, enc.d_model)
    assert b["tokens"].shape == (2, 32)
