"""Telemetry subsystem: registry, spans, convergence traces, exporters."""
import json
import threading

import numpy as np
import pytest

from repro import telemetry
from repro.core import Maximizer, MaximizerConfig, MatchingObjective
from repro.instances import (
    DeltaIngestor,
    InstanceDelta,
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.service import Scheduler, ServiceConfig, compiled_solver
from repro.telemetry import (
    SCHEMA,
    ConvergenceTrace,
    JsonlSink,
    MetricsRegistry,
    StallDetector,
    Tracer,
    prometheus_text,
    validate_jsonl,
)

SPEC = MatchingInstanceSpec(
    num_sources=120, num_destinations=10, avg_degree=4.0, seed=21
)
BASE = generate_matching_instance(SPEC)

COLD = MaximizerConfig(iters_per_stage=120, tol_grad=1e-4, tol_viol=1e-4)
SERVICE = ServiceConfig(
    cold=COLD, warm_gammas=(0.1, 0.01), drift_sla_rel=0.5, row_headroom=4
)


@pytest.fixture(autouse=True)
def fresh_telemetry():
    """Isolate every test behind its own registry + tracer."""
    prev_reg = telemetry.set_registry(MetricsRegistry())
    prev_tr = telemetry.set_tracer(Tracer())
    yield
    telemetry.set_registry(prev_reg)
    telemetry.set_tracer(prev_tr)


def _perturb_delta(edge_list, rng, frac=0.1):
    n = max(1, int(frac * edge_list.nnz))
    idx = rng.permutation(edge_list.nnz)[:n]
    return InstanceDelta(
        update_src=edge_list.src[idx],
        update_dst=edge_list.dst[idx],
        update_values=edge_list.values[idx] * rng.uniform(0.9, 1.1, n),
    )


# -- JSONL schema stability (golden keys) -------------------------------------


def test_jsonl_schema_golden_keys():
    """The exported record schema is a contract with downstream tooling
    (tools/check_metrics.py, the bench-history artifact, dashboards).
    Removing or renaming a required key is a schema break: update BOTH this
    golden set and docs/observability.md in the same change."""
    golden = {
        "solve_report": {
            "tenant", "cadence", "mode", "engine", "iters_used",
            "iter_budget", "g", "max_violation", "dc_norm", "upload_mode",
            "upload_bytes", "drift_rel", "drift_bound", "sla_ok",
        },
        "convergence": {
            "tenant", "cadence", "engine", "iters_used", "stage_budgets",
            "total_iters_used", "total_budget", "stalled", "g_final",
            "max_violation_final",
        },
        "cadence": {
            "cadence", "tenants", "batched_fraction", "upload_bytes",
            "overlapped", "wall_seconds",
        },
        "ingest": {"tenant", "in_place", "n_insert", "n_delete", "n_update"},
        "counters": {"counters", "gauges", "histograms"},
        "bench": {"suite", "quick", "results"},
        "serving_query": {"tenant", "generation", "users", "latency_seconds"},
    }
    assert set(SCHEMA) == set(golden)
    for kind, keys in golden.items():
        assert set(SCHEMA[kind]) == keys, f"schema drift in kind {kind!r}"


def test_jsonl_sink_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as sink:
        sink.emit("ingest", {
            "tenant": "t0", "in_place": True,
            "n_insert": 1, "n_delete": 0, "n_update": np.int64(3),
        })
        sink.emit_counters()
    n, errors = validate_jsonl(path)
    assert (n, errors) == (2, [])
    records = [json.loads(l) for l in open(path)]
    assert [r["kind"] for r in records] == ["ingest", "counters"]
    assert records[0]["payload"]["n_update"] == 3  # numpy scalar serialized
    with JsonlSink(path) as sink:  # append mode: prior records survive
        sink.emit("ingest", {
            "tenant": "t1", "in_place": False,
            "n_insert": 0, "n_delete": 0, "n_update": 0,
        })
    assert validate_jsonl(path)[0] == 3

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"ts": 1.0, "kind": "ingest", "payload": {"tenant": "x"}}\n')
    n, errors = validate_jsonl(str(bad))
    assert n == 1 and len(errors) == 4  # four missing required keys

    with pytest.raises(ValueError):
        JsonlSink(str(tmp_path / "x.jsonl")).emit("nope", {})


# -- registry ------------------------------------------------------------------


def test_registry_labels_and_snapshot():
    reg = telemetry.get_registry()
    reg.inc("solves_total", 2, tenant="a", mode="cold")
    reg.inc("solves_total", 3, tenant="b", mode="warm")
    reg.set_gauge("queue_depth", 7)
    reg.observe("batch_size", 4)
    reg.observe("batch_size", 4)
    assert reg.counter_value("solves_total", tenant="a", mode="cold") == 2
    assert reg.counter_total("solves_total") == 5
    snap = reg.snapshot()
    assert snap["counters"]["solves_total{mode=cold,tenant=a}"] == 2
    assert snap["gauges"]["queue_depth"] == 7
    h = snap["histograms"]["batch_size"]
    assert h["count"] == 2 and h["sum"] == 8 and h["min"] == h["max"] == 4


def test_registry_thread_safety_under_hammer():
    """N writer threads + a concurrent snapshot reader: totals must be exact
    (no lost updates) and snapshots must never crash mid-mutation."""
    reg = telemetry.get_registry()
    threads, iters = 8, 500
    stop = threading.Event()
    snaps = []

    def writer(t):
        for i in range(iters):
            reg.inc("hammer_total", 1, thread=t % 2)
            reg.observe("hammer_obs", i)
            reg.set_gauge("hammer_gauge", i, thread=t)

    def reader():
        while not stop.is_set():
            snaps.append(reg.snapshot())

    r = threading.Thread(target=reader)
    r.start()
    ws = [threading.Thread(target=writer, args=(t,)) for t in range(threads)]
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    r.join()
    assert reg.counter_total("hammer_total") == threads * iters
    snap = reg.snapshot()
    assert snap["histograms"]["hammer_obs"]["count"] == threads * iters
    assert snaps  # the reader actually raced the writers


def test_registry_counter_state_roundtrip():
    reg = MetricsRegistry()
    reg.inc("a_total", 5, tenant="x")
    reg.inc("b_total", 2.5)
    reg.set_gauge("g", 1)  # gauges intentionally NOT checkpointed
    state = json.loads(json.dumps(reg.state_dict()))  # must be JSON-able
    fresh = MetricsRegistry()
    fresh.load_state(state)
    assert fresh.counter_value("a_total", tenant="x") == 5
    assert fresh.counter_value("b_total") == 2.5
    assert fresh.gauge_value("g") is None


# -- spans / chrome trace ------------------------------------------------------


def test_span_nesting_and_chrome_trace(tmp_path):
    tr = telemetry.get_tracer()
    with telemetry.span("cadence", index=0):
        with telemetry.span("solve", tenant="t0"):
            pass
        with telemetry.span("solve", tenant="t1"):
            pass
    events = tr.events()
    assert [e["name"] for e in events] == ["solve", "solve", "cadence"]
    cad = events[2]
    for child in events[:2]:
        assert cad["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= cad["ts"] + cad["dur"] + 1e-6
    path = str(tmp_path / "t.json")
    tr.export_chrome_trace(path)
    doc = json.loads(open(path).read())
    assert {e["name"] for e in doc["traceEvents"]} == {"cadence", "solve"}
    for e in doc["traceEvents"]:  # Perfetto-required complete-event fields
        assert e["ph"] == "X"
        assert {"name", "ts", "dur", "pid", "tid", "args"} <= set(e)
    assert doc["traceEvents"][0]["args"] == {"tenant": "t0"}


def test_span_buffer_bound():
    tr = telemetry.set_tracer(Tracer(max_events=3))
    try:
        for i in range(5):
            with telemetry.span("s", i=i):
                pass
        got = telemetry.get_tracer()
        assert len(got.events()) == 3
        assert got.dropped == 2
    finally:
        telemetry.set_tracer(tr)


# -- convergence traces + stall detection --------------------------------------


def _packed_objective():
    return MatchingObjective(bucketize(BASE))


def test_convergence_trace_from_solve():
    cfg = MaximizerConfig(iters_per_stage=120, tol_grad=1e-4, tol_viol=1e-4)
    res = Maximizer(_packed_objective(), cfg).solve()
    trace = ConvergenceTrace.from_result(res, tenant="t0", engine="agd")
    s = trace.summary()
    assert s["iters_used"] == list(res.iters_used)
    assert s["total_iters_used"] == sum(res.iters_used)
    assert len(trace.stages) == len(cfg.gammas)
    for st, used in zip(trace.stages, res.iters_used):
        assert st.g.shape == (used,)
        assert st.budget == cfg.stage_iter_budget
    # JSONL-exportable and schema-complete
    assert set(SCHEMA["convergence"]) <= set(s)
    trace.record()
    reg = telemetry.get_registry()
    assert reg.counter_value(
        "convergence_solves_total", tenant="t0", engine="agd", mode="oneshot"
    ) == 1
    assert reg.counter_total("convergence_iters_total") == sum(res.iters_used)


def test_stall_detector_flags_budget_exhaustion():
    """An impossible tolerance on a tiny budget exhausts every stage: the
    gamma-floor stage never converges -> the solve is stalled and the tenant
    is flagged; a healthy solve then clears the flag."""
    stalled_cfg = MaximizerConfig(
        gammas=(1.0, 0.01), iters_per_stage=10, check_every=5,
        tol_grad=1e-12, tol_viol=1e-12,
    )
    res = Maximizer(_packed_objective(), stalled_cfg).solve()
    trace = ConvergenceTrace.from_result(res, tenant="t0")
    assert res.iters_used == (10, 10)  # budget exhausted everywhere
    assert not trace.stages[-1].converged
    assert trace.stalled

    det = StallDetector()
    assert det.observe(trace) is True
    assert det.flagged == {"t0"}
    reg = telemetry.get_registry()
    assert reg.counter_value(
        "convergence_stalled_solves_total", tenant="t0"
    ) == 1

    ok_cfg = MaximizerConfig(
        gammas=(1.0,), iters_per_stage=300, tol_grad=1e-3, tol_viol=1e-3
    )
    ok_res = Maximizer(_packed_objective(), ok_cfg).solve()
    ok_trace = ConvergenceTrace.from_result(ok_res, tenant="t0")
    assert not ok_trace.stalled
    assert det.observe(ok_trace) is False
    assert det.flagged == set()


def test_pdhg_stats_parity():
    """PDHG emits the same stats/iters_used shape as AGD, so one
    ConvergenceTrace covers both engines."""
    from repro.core.pdhg import PDHGConfig, from_edge_list, solve_pdhg

    cfg = PDHGConfig(max_iters=400, check_every=50, tol=1e-3)
    res = solve_pdhg(from_edge_list(BASE), cfg)
    assert len(res.stats) == 1
    n_checks = cfg.max_iters // cfg.check_every
    assert res.stats[0].g.shape == (n_checks,)
    assert res.iters_used == (int(res.iters),)
    trace = ConvergenceTrace.from_result(
        res, engine="pdhg", trace_stride=cfg.check_every,
        stage_budget=cfg.max_iters,
    )
    st = trace.stages[0]
    assert st.iters_used == int(res.iters)
    assert st.budget == cfg.max_iters
    assert st.trace_stride == cfg.check_every
    assert st.g.shape == (-(-st.iters_used // cfg.check_every),)
    assert st.converged == bool(res.converged)
    assert set(SCHEMA["convergence"]) <= set(trace.summary())


def test_pdhg_stall_on_budget_exhaustion():
    from repro.core.pdhg import PDHGConfig, from_edge_list, solve_pdhg

    cfg = PDHGConfig(max_iters=100, check_every=50, tol=1e-12)
    res = solve_pdhg(from_edge_list(BASE), cfg)
    assert not bool(res.converged)
    trace = ConvergenceTrace.from_result(
        res, engine="pdhg", trace_stride=cfg.check_every,
        stage_budget=cfg.max_iters,
    )
    assert trace.stalled


# -- service instrumentation ---------------------------------------------------


def _fresh_sched(n=3):
    sched = Scheduler(SERVICE)
    for t in range(n):
        sched.add_tenant(f"t{t}", BASE)
    return sched


def _cadence_deltas(n_tenants=3, cadences=2, seed=43):
    out = [None]
    for c in range(cadences):
        rng = np.random.default_rng(seed + c)
        out.append(
            {f"t{t}": _perturb_delta(BASE, rng) for t in range(n_tenants)}
        )
    return out


def test_pipelined_scheduler_records_consistent_metrics():
    """A pipelined two-cadence run (ingest thread overlapping the in-flight
    solve) must leave exact counter totals, and concurrent snapshots taken
    WHILE it runs must stay internally consistent."""
    sched = _fresh_sched()
    reg = telemetry.get_registry()
    snaps, stop = [], threading.Event()

    def reader():
        while not stop.is_set():
            snaps.append(reg.snapshot())

    r = threading.Thread(target=reader)
    r.start()
    try:
        outs = sched.run_pipeline(_cadence_deltas())
    finally:
        stop.set()
        r.join()

    n_solves = sum(len(o.reports) for o in outs)
    assert reg.counter_total("service_solves_total") == n_solves
    assert reg.counter_value("scheduler_cadences_total") == len(outs)
    assert reg.counter_total("deltas_applied_total") == 6  # 3 tenants x 2
    assert reg.counter_value("convergence_solves_total",
                             tenant="t0", engine="agd", mode="cold") == 1
    assert reg.counter_value("convergence_solves_total",
                             tenant="t0", engine="agd", mode="warm") == 2
    # iters totals agree with the per-solve reports
    want_iters = sum(r_["iters_used"] for o in outs for r_ in o.reports.values())
    assert reg.counter_total("service_iters_total") == want_iters
    # overlap accounting exists for the overlapped cadences
    assert reg.counter_value("scheduler_overlap_ingest_seconds_total") > 0
    for snap in snaps:  # every concurrent snapshot was a consistent view
        assert set(snap) == {"counters", "gauges", "histograms"}
    # spans: cadence spans with nested dispatch/absorb
    names = [e["name"] for e in telemetry.get_tracer().events()]
    assert names.count("cadence") == len(outs)
    assert "dispatch" in names and "tenant_absorb" in names


def test_solve_reports_carry_convergence_and_stall_fields():
    sched = _fresh_sched(n=2)
    out = sched.run_cadence(None)
    for name, rep in out.reports.items():
        conv = rep["convergence"]
        assert set(SCHEMA["convergence"]) <= set(conv)
        assert conv["tenant"] == name
        assert rep["stalled"] == conv["stalled"]
        assert isinstance(rep["stall_flagged"], bool)


def test_scheduler_checkpoint_preserves_counters(tmp_path):
    """Cumulative counters ride Scheduler.save_checkpoint: after a restore
    into a fresh process-state, totals continue instead of resetting."""
    from repro.checkpoint import CheckpointManager

    sched = _fresh_sched(n=2)
    sched.run_cadence(None)
    reg = telemetry.get_registry()
    before = reg.counter_total("service_solves_total")
    assert before == 2
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    sched.save_checkpoint(mgr, 0)
    mgr.wait()

    # simulated restart: fresh registry, fresh scheduler
    telemetry.set_registry(MetricsRegistry())
    sched2 = Scheduler(SERVICE)
    sched2.restore_checkpoint(mgr, 0)
    reg2 = telemetry.get_registry()
    assert reg2.counter_total("service_solves_total") == before
    rng = np.random.default_rng(7)
    sched2.run_cadence({n: _perturb_delta(BASE, rng) for n in sched2.sessions})
    assert reg2.counter_total("service_solves_total") == before + 2


def test_engine_compile_cache_metrics():
    reg = telemetry.get_registry()
    cfg = MaximizerConfig(gammas=(0.1,), iters_per_stage=10)
    inst = bucketize(BASE)
    fn = compiled_solver(cfg)
    lam0 = np.zeros(inst.dual_dim, np.float32)
    base = reg.counter_value("engine_compiles_total", entry="single")
    fn(inst, lam0)  # first call on this shape key: compile
    assert reg.counter_value("engine_compiles_total", entry="single") == base + 1
    assert reg.counter_total("engine_compile_seconds_total") > 0
    hits = reg.counter_value("engine_cache_hits_total", entry="single")
    fn(inst, lam0)  # same shapes: cache hit
    assert reg.counter_value("engine_cache_hits_total", entry="single") == hits + 1
    assert reg.counter_value("engine_compiles_total", entry="single") == base + 1


def test_delta_ingest_metrics_and_rejections():
    reg = telemetry.get_registry()
    ing = DeltaIngestor(BASE, row_headroom=4)
    ing.telemetry_tenant = "t9"
    rng = np.random.default_rng(3)
    rep = ing.apply(_perturb_delta(BASE, rng))
    assert rep.in_place
    assert reg.counter_value(
        "deltas_applied_total", tenant="t9", path="in_place"
    ) == 1
    assert reg.counter_value("delta_edits_total", op="update") == rep.n_update
    assert reg.counter_value(
        "scatter_bytes_total", tenant="t9"
    ) == rep.plan.nbytes
    assert reg.counter_value(
        "scatter_cells_total", tenant="t9"
    ) == rep.plan.num_cells
    with pytest.raises(ValueError):
        ing.apply(
            InstanceDelta(delete_src=[SPEC.num_sources + 1], delete_dst=[0])
        )
    assert reg.counter_value("delta_rejections_total", tenant="t9") == 1
    # the rejected delta must not have advanced any applied counters
    assert reg.counter_value(
        "deltas_applied_total", tenant="t9", path="in_place"
    ) == 1


# -- prometheus exposition -----------------------------------------------------


def test_prometheus_text_exposition():
    reg = telemetry.get_registry()
    reg.inc("solves_total", 3, tenant="a")
    reg.set_gauge("depth", 2)
    reg.observe("lat_seconds", 0.2)
    text = prometheus_text(reg)
    assert '# TYPE solves_total counter' in text
    assert 'solves_total{tenant="a"} 3' in text
    assert '# TYPE depth gauge' in text
    assert '# TYPE lat_seconds histogram' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert 'lat_seconds_count 1' in text
    # cumulative bucket semantics: counts never decrease with rising le
    counts = [
        int(l.rsplit(" ", 1)[1])
        for l in text.splitlines()
        if l.startswith("lat_seconds_bucket")
    ]
    assert counts == sorted(counts)


def test_write_prometheus_atomic(tmp_path):
    reg = telemetry.get_registry()
    reg.inc("x_total", 1)
    path = str(tmp_path / "m.prom")
    telemetry.write_prometheus(path, reg)
    assert "x_total 1" in open(path).read()
    assert list(tmp_path.iterdir()) == [tmp_path / "m.prom"]  # no tmp litter
