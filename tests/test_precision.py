"""Mixed-precision slab storage: quality drift, parity, and fp32 pins.

The slab_dtype axis stores the bucketed-ELL slabs (coeff/cost/mask) in
bfloat16 or int8 (symmetric per-bucket scales) while every accumulation —
the Ax histogram, c'x, ||x||^2, duals, gamma/continuation math — stays
fp32.  These tests pin the contract:

  * fp32 default is bit-identical to the pre-slab_dtype pipeline (the
    dtype plumbing is a host-level branch that adds nothing to the jaxpr);
  * bf16/int8 end-to-end solves drift within table4-style tolerances;
  * O(delta) ScatterPlan replay stays bit-for-bit at narrow dtypes;
  * int8 is rejected on the service path (frozen per-bucket scales are
    unsound under in-place slab surgery);
  * the warm-escalation knob adapts the warm gamma tail from drift;
  * the batched fixed-sigma pool matches the recompute pool.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Maximizer, MaximizerConfig, MatchingObjective, normalize_rows
from repro.instances import (
    DeltaIngestor,
    InstanceDelta,
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.instances.buckets import dequantize_bucket, rhs_dtype
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.service import ServiceConfig, SolveSession

SPEC = MatchingInstanceSpec(
    num_sources=400, num_destinations=30, avg_degree=5.0,
    num_families=2, seed=17,
)
BASE = generate_matching_instance(SPEC)

# short continuation solve for the drift regressions (same shape as the
# table2 sweep's quality metric)
CFG = MaximizerConfig(gammas=(0.1, 0.01), iters_per_stage=60)

# quality-drift tolerances per storage dtype, calibrated like table4's
# quality bars: duals rel-L2 vs the fp32 solve + normalized objective gap.
# bf16 is a rounding cast (~3 decimal digits); int8 quantizes A itself, so
# its drift is inherent to the quantization, not the pipeline (the
# dequantized-fp32 solve of the SAME quantized problem is bit-identical).
DRIFT_TOL = {"bfloat16": 3e-2, "int8": 1.5e-1}
GAP_TOL = {"bfloat16": 1e-2, "int8": 1e-1}


def _solve(dtype: str):
    packed = bucketize(BASE, dtype=dtype)
    scaled, _ = normalize_rows(packed)
    return Maximizer(MatchingObjective(scaled), CFG).solve()


# -- quality drift regressions ------------------------------------------------


@pytest.mark.parametrize("dt", ["bfloat16", "int8"])
def test_narrow_storage_quality_drift(dt):
    ref = _solve("float32")
    res = _solve(dt)
    drift = float(
        jnp.linalg.norm(res.lam - ref.lam)
        / jnp.maximum(jnp.linalg.norm(ref.lam), 1e-12)
    )
    gap = abs(float(res.g) - float(ref.g)) / (1.0 + abs(float(ref.g)))
    assert drift <= DRIFT_TOL[dt], (dt, drift)
    assert gap <= GAP_TOL[dt], (dt, gap)


def test_int8_pipeline_exact_vs_dequantized_solve():
    """int8's drift is inherent to quantizing A, not the narrow pipeline:
    solving the dequantized-to-fp32 copy of the SAME quantized instance
    must land on bit-identical duals and objective."""
    packed = bucketize(BASE, dtype="int8")
    wide = dataclasses.replace(
        packed,
        buckets=tuple(dequantize_bucket(b) for b in packed.buckets),
        rhs=jnp.asarray(packed.rhs, jnp.float32),
    )
    # no row normalization: its host-side scale folding rounds in a
    # different order on quantized vs dequantized storage; the pin is about
    # the solve pipeline, which dequantizes with the exact same converts
    r8 = Maximizer(MatchingObjective(packed), CFG).solve()
    r32 = Maximizer(MatchingObjective(wide), CFG).solve()
    np.testing.assert_array_equal(np.asarray(r8.lam), np.asarray(r32.lam))
    assert float(r8.g) == float(r32.g)


# -- fp32 default: bitwise pin ------------------------------------------------


def test_fp32_default_adds_nothing():
    """The dtype plumbing is a host-level branch: fp32 buckets pass through
    dequantize_bucket and the objective's _buckets view by IDENTITY (no
    copies, no converts in the jaxpr), and the dispatched oracle equals the
    plain reference bit-for-bit."""
    packed = bucketize(BASE)  # default dtype
    for b in packed.buckets:
        assert b.slab_dtype == "float32" and b.coeff_scale is None
        assert dequantize_bucket(b) is b
    obj = MatchingObjective(packed, fused_oracle=True)
    for view, own in zip(obj._buckets, packed.buckets):
        assert view is own
    # no narrow dtypes anywhere in the fp32 fused-oracle jaxpr
    lam = jnp.zeros((packed.dual_dim,), jnp.float32)
    jaxpr = str(jax.make_jaxpr(
        lambda l: obj.calculate(l, jnp.float32(1.0))
    )(lam))
    assert "bf16" not in jaxpr and "i8" not in jaxpr
    # dispatch path (off-TPU -> reference) == calling the reference directly
    b = packed.buckets[0]
    lam_r = jnp.asarray(
        np.random.default_rng(0).random(packed.dual_dim).astype(np.float32)
    )
    got = kops.fused_dual_oracle(
        b.idx, b.coeff, b.cost, b.mask, lam_r, jnp.float32(1.0),
        num_destinations=packed.num_destinations,
    )
    want = kref.dual_oracle_ref(
        b.idx, b.coeff, b.cost, b.mask, lam_r, 1.0, packed.num_destinations
    )
    for a, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(w))


def test_storage_layout_per_dtype():
    """bf16: narrow slabs, no scales, fp32 rhs.  int8: scale tensors with
    the documented shapes; mask keeps its exact 0/1 pattern."""
    b16 = bucketize(BASE, dtype="bfloat16")
    assert all(b.slab_dtype == "bfloat16" for b in b16.buckets)
    assert all(b.coeff_scale is None for b in b16.buckets)
    assert np.dtype(rhs_dtype("bfloat16")) == np.float32
    assert np.asarray(b16.rhs).dtype == np.float32
    i8 = bucketize(BASE, dtype="int8")
    for b in i8.buckets:
        assert b.slab_dtype == "int8"
        m = b.coeff.shape[0]
        assert b.coeff_scale.shape == (m, 1, 1)
        assert b.cost_scale.shape == (1, 1)
        assert set(np.unique(np.asarray(b.mask))) <= {0, 1}
        # padding invariant survives quantization: mask-zero slots hold 0
        pad = np.asarray(b.mask) == 0
        assert not np.asarray(b.cost)[pad].any()
        assert not np.asarray(b.coeff)[:, pad].any()


# -- O(delta) scatter replay at narrow dtypes ---------------------------------


def _perturb_delta(edge_list, rng, frac=0.1):
    n = max(1, int(frac * edge_list.nnz))
    idx = rng.permutation(edge_list.nnz)[:n]
    return InstanceDelta(
        update_src=edge_list.src[idx],
        update_dst=edge_list.dst[idx],
        update_values=edge_list.values[idx] * rng.uniform(0.9, 1.1, n),
        update_coeff=rng.uniform(0.1, 2.0, (SPEC.num_families, n)),
    )


def test_scatter_plan_replay_bit_for_bit_bf16():
    """Device .at[].set replay == mutated host slabs, exactly, when the
    slabs are stored in bfloat16 (delta payloads are cast to the storage
    dtype before the scatter, so host and device round identically)."""
    from repro.service import apply_scatter_plan, device_put_instance

    rng = np.random.default_rng(5)
    ing = DeltaIngestor(BASE, row_headroom=4, dtype="bfloat16")
    dev = device_put_instance(ing.instance())
    for _ in range(3):
        rep = ing.apply(_perturb_delta(ing.to_edge_list(), rng))
        assert rep.plan is not None and rep.in_place
        dev = apply_scatter_plan(dev, rep.plan)
        host = ing.instance()
        for db, hb in zip(dev.buckets, host.buckets):
            assert np.asarray(db.coeff).dtype == np.asarray(hb.coeff).dtype
            np.testing.assert_array_equal(np.asarray(db.idx), hb.idx)
            np.testing.assert_array_equal(
                np.asarray(db.cost).view(np.uint16),
                np.asarray(hb.cost).view(np.uint16),
            )
            np.testing.assert_array_equal(
                np.asarray(db.coeff).view(np.uint16),
                np.asarray(hb.coeff).view(np.uint16),
            )
            np.testing.assert_array_equal(
                np.asarray(db.mask).view(np.uint16),
                np.asarray(hb.mask).view(np.uint16),
            )
        np.testing.assert_array_equal(np.asarray(dev.rhs), np.asarray(host.rhs))


def test_int8_rejected_on_service_path():
    """In-place slab surgery under frozen per-bucket scales is unsound, so
    both the ingestor and the service config refuse int8 up front."""
    with pytest.raises(ValueError, match="int8"):
        DeltaIngestor(BASE, dtype="int8")
    with pytest.raises(ValueError, match="int8"):
        ServiceConfig(slab_dtype="int8")
    with pytest.raises(ValueError):
        ServiceConfig(slab_dtype="float64")
    # the supported service dtypes construct fine
    ServiceConfig(slab_dtype="float32")
    ServiceConfig(slab_dtype="bfloat16")


def test_session_checkpoint_roundtrip_preserves_dtype():
    """state_dict/from_state round-trips the slab dtype tag: the restored
    ingestor re-packs at the configured width, bit-for-bit."""
    cfg = ServiceConfig(slab_dtype="bfloat16", row_headroom=4)
    sess = SolveSession("t0", BASE, cfg)
    sess.solve()
    arrays, meta = sess.state_dict()
    back = SolveSession.from_state(cfg, arrays, meta)
    assert back.ingestor.dtype == sess.ingestor.dtype
    for a, b in zip(back.instance().buckets, sess.instance().buckets):
        assert np.asarray(a.coeff).dtype == np.asarray(b.coeff).dtype
        np.testing.assert_array_equal(
            np.asarray(a.coeff).view(np.uint16),
            np.asarray(b.coeff).view(np.uint16),
        )


# -- warm escalation ----------------------------------------------------------


def test_escalated_warm_gammas_schedule():
    """Level e prepends the e smallest cold gammas above the warm head,
    descending, saturating at the full cold run-up."""
    cfg = ServiceConfig(
        cold=MaximizerConfig(gammas=(10.0, 1.0, 0.3, 0.1, 0.01)),
        warm_gammas=(0.1, 0.01),
        warm_escalation=(1e-4, 1e-2),
    )
    assert cfg.escalated_warm_gammas(0) == (0.1, 0.01)
    assert cfg.escalated_warm_gammas(1) == (0.3, 0.1, 0.01)
    assert cfg.escalated_warm_gammas(2) == (1.0, 0.3, 0.1, 0.01)
    assert cfg.escalated_warm_gammas(3) == (10.0, 1.0, 0.3, 0.1, 0.01)
    # saturates: no more cold gammas to prepend
    assert cfg.escalated_warm_gammas(99) == (10.0, 1.0, 0.3, 0.1, 0.01)
    assert cfg.warm_for(0).gammas == cfg.warm_gammas
    assert cfg.warm_for(2).gammas == (1.0, 0.3, 0.1, 0.01)
    # the warm iters-per-stage knob still applies at every level
    cfg2 = dataclasses.replace(cfg, warm_iters_per_stage=7)
    assert cfg2.warm_for(2).iters_per_stage == 7


def test_warm_escalation_tracks_observed_drift():
    """A quiet cadence stays at level 0; a violent one escalates the next
    warm solve's schedule (reported in the solve record) and a following
    quiet cadence de-escalates — the level is recomputed fresh, not
    ratcheted."""
    cfg = ServiceConfig(
        warm_gammas=(0.1, 0.01),
        warm_escalation=(1e-4, 1e-2),
        row_headroom=4,
    )
    rng = np.random.default_rng(23)
    sess = SolveSession("t0", BASE, cfg)
    _, rep0 = sess.solve()
    assert rep0["warm_level"] == 0  # cold solves report level 0
    sess.ingest(_perturb_delta(sess.ingestor.to_edge_list(), rng, frac=0.02))
    _, rep1 = sess.solve()
    assert rep1["mode"] == "warm"
    assert rep1["warm_level"] == 0
    assert rep1["warm_schedule"] == [0.1, 0.01]
    # violent cost shock -> drift above both thresholds -> escalation
    cur = sess.ingestor.to_edge_list()
    sess.ingest(InstanceDelta(
        update_src=cur.src, update_dst=cur.dst,
        update_values=cur.values * rng.uniform(3.0, 6.0, cur.nnz),
    ))
    _, rep2 = sess.solve()
    assert rep2["mode"] == "warm"
    if rep2["drift_rel"] > 1e-2:
        _, rep3 = sess.solve()  # zero-delta cadence runs the escalated tail
        assert rep3["warm_level"] >= 2
        assert len(rep3["warm_schedule"]) > len(rep1["warm_schedule"])
        assert rep3["warm_schedule"][-2:] == [0.1, 0.01]
        # quiet again -> recomputed level drops back
        _, rep4 = sess.solve()
        assert rep4["warm_level"] <= rep3["warm_level"]


def test_warm_escalation_disabled_by_default():
    sess = SolveSession("t0", BASE, ServiceConfig(row_headroom=4))
    sess.solve()
    sess.ingest(_perturb_delta(
        sess.ingestor.to_edge_list(), np.random.default_rng(3)
    ))
    _, rep = sess.solve()
    assert rep["warm_level"] == 0
    assert rep["warm_schedule"] == list(sess.config.warm_gammas)


# -- batched fixed-sigma pool -------------------------------------------------


def test_batched_fixed_sigma_matches_recompute():
    """The vmapped fixed-sigma solver fed the recompute pool's own sigma
    estimates reproduces its duals exactly (the power iteration is the only
    thing skipped)."""
    from repro.service import (
        compiled_batch_solver,
        compiled_batch_solver_fixed_sigma,
        stack_instances,
    )

    cfg = MaximizerConfig(gammas=(0.1, 0.01), iters_per_stage=40)
    packed = bucketize(BASE)
    stacked = stack_instances([packed, packed])
    lam0 = jnp.zeros((2, packed.dual_dim), jnp.float32)
    raw = compiled_batch_solver(cfg, True)(stacked, lam0)
    sig = compiled_batch_solver_fixed_sigma(cfg, True)(
        stacked, lam0, jnp.asarray(raw.sigma_sq, jnp.float32)
    )
    np.testing.assert_array_equal(np.asarray(sig.lam), np.asarray(raw.lam))
    np.testing.assert_array_equal(
        np.asarray(sig.sigma_sq), np.asarray(raw.sigma_sq)
    )
