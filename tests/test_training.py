"""Training substrate: optimizer semantics, microbatch equivalence, loss goes down."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLMData
from repro.models.model import Model
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    lr_schedule,
)
from repro.training.train_step import TrainState, init_train_state


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4, rel=1e-3)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(clip_norm=1.0, lr=1.0, weight_decay=0.0, warmup_steps=0)
    _, _, metrics = adamw_update(cfg, grads, adamw_init(params), params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_global_norm_no_ravel():
    """global_norm must not use vdot/ravel (sharding-destroying; see DESIGN)."""
    import inspect

    src = inspect.getsource(global_norm)
    code = "\n".join(
        l.split("#")[0] for l in src.splitlines() if not l.strip().startswith("#")
    )
    assert "vdot(" not in code and "ravel(" not in code


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    rng = np.random.default_rng(0)
    p = {"a": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))}
    g = {"a": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))}
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                      clip_norm=1e9, warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    p2, st, _ = adamw_update(cfg, g, adamw_init(p), p)
    gn = np.asarray(g["a"])
    m = 0.1 * gn
    v = 0.05 * gn ** 2
    mh, vh = m / 0.1, v / 0.05
    want = np.asarray(p["a"]) - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(p["a"]))
    np.testing.assert_allclose(np.asarray(p2["a"]), want, atol=1e-6)


def test_loss_decreases():
    cfg = get_reduced_config("qwen3-8b")
    model = Model(cfg)
    data = SyntheticLMData(cfg, batch=8, seq=32, seed=0)
    state = init_train_state(model, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)

    @jax.jit
    def step(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        p, o, met = adamw_update(opt_cfg, grads, state.opt, state.params)
        return TrainState(p, o, state.step + 1), loss

    losses = []
    for k in range(40):
        state, loss = step(state, data(k))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + losses[-3:]


@pytest.mark.slow
def test_microbatch_equivalence():
    """1 macro step == mean of microbatch grads (accumulation correctness)."""
    cfg = get_reduced_config("gemma-7b")
    model = Model(cfg)
    data = SyntheticLMData(cfg, batch=8, seq=16, seed=1)
    batch = jax.tree.map(jnp.asarray, data(0))
    params = model.init(jax.random.key(0))
    g_full = jax.grad(model.loss)(params, batch)
    micro = jax.tree.map(
        lambda x: x.reshape((4, 2) + x.shape[1:]), batch
    )
    g_acc = jax.tree.map(jnp.zeros_like, params)
    for i in range(4):
        mb = jax.tree.map(lambda x: x[i], micro)
        g = jax.grad(model.loss)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b / 4, g_acc, g)
    # token-weighted vs uniform microbatch weighting agree here because every
    # microbatch has the same number of valid labels
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)
