"""Checkpoint manager: roundtrip, atomicity, keep-K, restart parity, elastic."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, latest_step
from repro.configs import get_reduced_config
from repro.data.pipeline import SyntheticLMData
from repro.models.model import Model
from repro.training.loop import TrainLoopConfig, train_loop
from repro.training.optimizer import AdamWConfig


def _state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)},
        "nested": [jnp.zeros((4,), jnp.int32), {"x": jnp.float32(3.5)}],
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = _state()
    mgr.save(7, state)
    assert latest_step(str(tmp_path)) == 7
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    back = mgr.restore(7, template)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, _state())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state())
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_half_written_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(5, _state())
    # simulate a crashed writer: tmp dir + final dir without manifest
    os.makedirs(tmp_path / "step_00000009.tmp")
    os.makedirs(tmp_path / "step_00000010")
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


@pytest.mark.slow
def test_restart_parity(tmp_path):
    """Train 12 steps straight == train 6, 'crash', resume 6 (same data skip)."""
    cfg = get_reduced_config("gemma-7b")
    model = Model(cfg)
    data = SyntheticLMData(cfg, batch=4, seq=16, seed=3)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    straight = train_loop(
        model, data, opt, TrainLoopConfig(total_steps=12, save_every=100, log_every=0),
        ckpt_dir=None,
    )
    d1 = str(tmp_path / "run")
    train_loop(model, data, opt,
               TrainLoopConfig(total_steps=6, save_every=6, log_every=0), ckpt_dir=d1)
    resumed = train_loop(model, data, opt,
                         TrainLoopConfig(total_steps=12, save_every=6, log_every=0),
                         ckpt_dir=d1)
    for a, b in zip(jax.tree.leaves(straight.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.slow
def test_elastic_reshard_subprocess(tmp_path):
    """Save on 4 devices, restore on 8 (different sharding) — values identical."""
    from conftest import run_with_devices

    script = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh
from repro.checkpoint.manager import CheckpointManager

mesh4 = make_mesh((4,), ("d",), devices=jax.devices()[:4])
x = jnp.arange(32.0).reshape(8, 4)
xs = jax.device_put(x, NamedSharding(mesh4, P("d", None)))
mgr = CheckpointManager(r"{tmp_path}", async_write=False)
mgr.save(1, {{"x": xs}})
mesh8 = make_mesh((8,), ("d",))
tpl = {{"x": jax.ShapeDtypeStruct((8, 4), jnp.float32)}}
back = mgr.restore(1, tpl, shardings={{"x": NamedSharding(mesh8, P("d", None))}})
assert len(back["x"].sharding.device_set) == 8
np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))
print("ELASTIC_OK")
"""
    out = run_with_devices(script, 8)
    assert "ELASTIC_OK" in out
