"""Generator + packing invariants (paper Appendix A, §4.1/§4.2)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    pack_single_slab,
    unpack_primal,
)


@settings(max_examples=15, deadline=None)
@given(
    I=st.integers(5, 300),
    J=st.integers(2, 40),
    deg=st.floats(1.0, 8.0),
    m=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_generator_invariants(I, J, deg, m, seed):
    spec = MatchingInstanceSpec(
        num_sources=I, num_destinations=J, avg_degree=deg, num_families=m, seed=seed
    )
    inst = generate_matching_instance(spec)
    assert inst.nnz > 0
    assert (inst.src >= 0).all() and (inst.src < I).all()
    assert (inst.dst >= 0).all() and (inst.dst < J).all()
    # sorted by (src, dst), unique edges
    eid = inst.src * J + inst.dst
    assert (np.diff(eid) > 0).all()
    assert (inst.values >= 0).all() and (inst.values <= spec.c_max + 1e-9).all()
    assert inst.coeff.shape == (m, inst.nnz)
    assert (inst.coeff >= 0).all()
    assert (inst.rhs > 0).all()
    # cost is negated value (minimisation convention)
    np.testing.assert_allclose(inst.cost, -inst.values)


def test_rhs_makes_some_constraints_bind():
    spec = MatchingInstanceSpec(num_sources=500, num_destinations=20, avg_degree=5.0, seed=1)
    inst = generate_matching_instance(spec)
    # greedy load with rho in [0.5, 1] must leave b below the max greedy load
    # for at least some resources (otherwise nothing would ever bind)
    assert inst.rhs.min() < inst.coeff[0].max() * spec.num_sources


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), mult=st.sampled_from([1, 4, 8]))
def test_pack_roundtrip(seed, mult):
    spec = MatchingInstanceSpec(num_sources=80, num_destinations=9, avg_degree=3.0, seed=seed)
    inst = generate_matching_instance(spec)
    packed = bucketize(inst, shard_multiple=mult)
    # shapes padded to shard multiple
    for b in packed.buckets:
        assert b.rows % mult == 0
        assert b.idx.shape == (b.rows, b.length)
        assert b.coeff.shape == (spec.num_families, b.rows, b.length)
    assert packed.nnz == inst.nnz
    # roundtrip: pack values, unpack, compare to edge order
    slabs = [b.cost for b in packed.buckets]
    back = unpack_primal(packed, slabs)
    np.testing.assert_allclose(back, inst.cost, rtol=1e-6)


def test_bucket_padding_bound():
    """Geometric bucketing wastes at most 2x per bucket (paper §4.2)."""
    spec = MatchingInstanceSpec(num_sources=400, num_destinations=16, avg_degree=6.0, seed=2)
    inst = generate_matching_instance(spec)
    packed = bucketize(inst)
    deg = inst.degrees()
    for b in packed.buckets:
        n_real = int((np.asarray(b.mask).sum(axis=1) > 0).sum())
        if n_real == 0:
            continue
        real = np.asarray(b.mask).sum()
        slots = n_real * b.length
        assert slots <= 2 * real + b.length, (b.length, real, slots)


def test_single_slab_equivalence():
    """batching=False baseline encodes the same instance (paper Fig. 2)."""
    spec = MatchingInstanceSpec(num_sources=60, num_destinations=8, avg_degree=4.0, seed=3)
    inst = generate_matching_instance(spec)
    a = bucketize(inst)
    b = pack_single_slab(inst)
    assert len(b.buckets) == 1
    assert a.nnz == b.nnz == inst.nnz
    assert b.buckets[0].length >= max(inst.degrees().max(), 1)


def test_row_norms_match_dense():
    spec = MatchingInstanceSpec(num_sources=40, num_destinations=6, avg_degree=3.0, num_families=2, seed=4)
    inst = generate_matching_instance(spec)
    packed = bucketize(inst)
    A, b, c = inst.to_dense()
    np.testing.assert_allclose(
        packed.row_norms_sq(), (A ** 2).sum(axis=1), rtol=1e-5
    )


def test_to_dense_structure():
    """Def. 1: diagonal blocks — A[k*J+j, i*J+j'] = 0 unless j == j'."""
    spec = MatchingInstanceSpec(num_sources=12, num_destinations=5, avg_degree=2.5, num_families=2, seed=5)
    inst = generate_matching_instance(spec)
    A, _, _ = inst.to_dense()
    J, I, m = 5, 12, 2
    for k in range(m):
        for i in range(I):
            blk = A[k * J:(k + 1) * J, i * J:(i + 1) * J]
            off_diag = blk - np.diag(np.diag(blk))
            assert np.abs(off_diag).max() == 0
