"""Cross-cadence checkpointing: a restarted service resumes warm, not cold.

The contract (ISSUE 2 / ROADMAP "cross-cadence checkpointing"): persisting a
`SolveSession` (duals, edge-space primal, ingestor maps + slabs, continuation
position) and restoring it must reproduce the uninterrupted session's next
solve — same mode (warm), same objective — while a cold restart of the same
instance burns the full continuation budget.
"""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.core import MaximizerConfig
from repro.instances import (
    InstanceDelta,
    MatchingInstanceSpec,
    generate_matching_instance,
)
from repro.service import Scheduler, ServiceConfig, SolveSession

SPEC = MatchingInstanceSpec(
    num_sources=120, num_destinations=10, avg_degree=4.0, seed=51
)
BASE = generate_matching_instance(SPEC)
SERVICE = ServiceConfig(
    cold=MaximizerConfig(iters_per_stage=120, tol_grad=1e-4, tol_viol=1e-4),
    warm_gammas=(0.1, 0.01),
    drift_sla_rel=0.5,
    row_headroom=4,
)


def _delta(edge_list, rng, frac=0.1):
    n = max(1, int(frac * edge_list.nnz))
    idx = rng.permutation(edge_list.nnz)[:n]
    return InstanceDelta(
        update_src=edge_list.src[idx],
        update_dst=edge_list.dst[idx],
        update_values=edge_list.values[idx] * rng.uniform(0.9, 1.1, n),
    )


def test_session_restore_matches_uninterrupted_and_beats_cold():
    rng = np.random.default_rng(1)
    sess = SolveSession("t0", BASE, SERVICE)
    sess.solve()
    sess.ingest(_delta(BASE, rng))
    sess.solve()

    arrays, meta = sess.state_dict()
    restored = SolveSession.from_state(SERVICE, arrays, meta)

    delta2 = _delta(BASE, np.random.default_rng(2))
    sess.ingest(delta2)
    restored.ingest(delta2)
    _, rep_live = sess.solve()
    _, rep_back = restored.solve()

    # warm resume, not a cold start
    assert rep_back["mode"] == "warm" and rep_back["cold_reason"] is None
    # acceptance: restored matches uninterrupted to <= 1e-6 rel objective
    rel = abs(rep_back["g"] - rep_live["g"]) / max(abs(rep_live["g"]), 1e-9)
    assert rel <= 1e-6, (rep_back["g"], rep_live["g"])
    assert rep_back["iters_used"] == rep_live["iters_used"]
    # drift metering survived the restart (prev_primal was persisted)
    assert rep_back["drift_rel"] is not None
    np.testing.assert_allclose(rep_back["drift_rel"], rep_live["drift_rel"])
    # ...and uses fewer iterations than a cold start of the same instance
    cold = SolveSession("cold", restored.ingestor.to_edge_list(), SERVICE)
    _, rep_cold = cold.solve()
    assert rep_cold["mode"] == "cold"
    assert rep_back["iters_used"] < rep_cold["iters_used"]


def test_scheduler_checkpoint_roundtrip_via_manager(tmp_path):
    """save_checkpoint -> restore_checkpoint through CheckpointManager files."""
    rng = np.random.default_rng(3)
    sched = Scheduler(SERVICE)
    for t in range(3):
        sched.add_tenant(f"t{t}", BASE)
    sched.run_cadence()
    deltas = {n: _delta(BASE, rng) for n in sched.sessions}
    sched.run_cadence(deltas)

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    sched.save_checkpoint(mgr, step=1)
    assert latest_step(str(tmp_path)) == 1

    sched2 = Scheduler(SERVICE)
    sched2.restore_checkpoint(mgr, 1)
    assert sorted(sched2.sessions) == sorted(sched.sessions)
    for name in sched.sessions:
        assert sched2.sessions[name].cadence == sched.sessions[name].cadence

    deltas2 = {n: _delta(BASE, np.random.default_rng(4)) for n in sched.sessions}
    out_live = sched.run_cadence(deltas2)
    out_back = sched2.run_cadence(deltas2)
    for name in out_live.reports:
        a, b = out_live.reports[name], out_back.reports[name]
        assert b["mode"] == "warm"
        rel = abs(a["g"] - b["g"]) / max(abs(a["g"]), 1e-9)
        assert rel <= 1e-6
        assert a["iters_used"] == b["iters_used"]


def test_restore_flat_and_meta_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = {"a": np.arange(6.0).reshape(2, 3), "b/nested.key": np.ones(3)}
    mgr.save(2, state, meta={"tenants": ["x"], "k": 1})
    arrays, meta = mgr.restore_flat(2)
    assert meta == {"tenants": ["x"], "k": 1}
    assert mgr.read_meta(2) == meta
    # flat-dict states round-trip with their ORIGINAL keys
    assert sorted(arrays) == sorted(state)
    for k in state:
        np.testing.assert_array_equal(arrays[k], state[k])


def test_checkpoint_survives_fallback_shapes(tmp_path):
    """Sessions whose ingestor re-bucketized (new shapes) still roundtrip."""
    sess = SolveSession("t0", BASE, SERVICE)
    sess.solve()
    # force the overflow fallback: give source s an edge to every destination
    J = SPEC.num_destinations
    s = int(BASE.src[0])
    have = set(BASE.dst[BASE.src == s].tolist())
    new_d = [d for d in range(J) if d not in have]
    rep = sess.ingest(
        InstanceDelta(
            insert_src=[s] * len(new_d),
            insert_dst=new_d,
            insert_values=np.ones(len(new_d)),
            insert_coeff=np.ones((1, len(new_d))),
        )
    )
    if not rep.rebucketized:
        pytest.skip("headroom absorbed the insert burst at this seed")
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    arrays, meta = sess.state_dict()
    mgr.save(0, arrays, meta=meta)
    flat, meta_back = mgr.restore_flat(0)
    restored = SolveSession.from_state(SERVICE, flat, meta_back)
    _, rep_live = sess.solve()
    _, rep_back = restored.solve()
    rel = abs(rep_back["g"] - rep_live["g"]) / max(abs(rep_live["g"]), 1e-9)
    assert rel <= 1e-6
