"""Property tests for the ProjectionMap operators (paper §4.2/§4.3 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.projections import (
    project_box,
    project_box_cut,
    project_simplex,
)

ATOL = 1e-5


def _rand(rng, n, L, scale=3.0):
    v = rng.normal(size=(n, L)).astype(np.float32) * scale
    mask = (rng.random((n, L)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0  # at least one real entry per row
    return jnp.asarray(v), jnp.asarray(mask)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 7),
    L=st.integers(1, 33),
    seed=st.integers(0, 2**31 - 1),
    z=st.floats(0.1, 5.0),
)
def test_simplex_feasibility(n, L, seed, z):
    rng = np.random.default_rng(seed)
    v, mask = _rand(rng, n, L)
    w = project_simplex(v, mask, z)
    w = np.asarray(w)
    assert (w >= -ATOL).all()
    assert (w.sum(-1) <= z + 1e-4 * max(1, z)).all()
    assert (np.abs(w * (1 - np.asarray(mask))) == 0).all(), "pad leaked"


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 5), L=st.integers(1, 17), seed=st.integers(0, 2**31 - 1))
def test_simplex_idempotent(n, L, seed):
    rng = np.random.default_rng(seed)
    v, mask = _rand(rng, n, L)
    w1 = project_simplex(v, mask, 1.0)
    w2 = project_simplex(w1, mask, 1.0)
    np.testing.assert_allclose(w1, w2, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 4), L=st.integers(2, 16), seed=st.integers(0, 2**31 - 1))
def test_simplex_nonexpansive(n, L, seed):
    rng = np.random.default_rng(seed)
    v1, mask = _rand(rng, n, L)
    v2 = v1 + jnp.asarray(rng.normal(size=v1.shape).astype(np.float32)) * mask
    w1 = project_simplex(v1, mask, 1.0)
    w2 = project_simplex(v2, mask, 1.0)
    d_in = np.linalg.norm(np.asarray((v1 - v2) * mask))
    d_out = np.linalg.norm(np.asarray(w1 - w2))
    assert d_out <= d_in + 1e-4


def test_simplex_matches_exact_qp():
    """KKT check vs a brute-force water-filling solution."""
    rng = np.random.default_rng(3)
    v = rng.normal(size=(50, 8)).astype(np.float32)
    mask = np.ones_like(v)
    w = np.asarray(project_simplex(jnp.asarray(v), jnp.asarray(mask), 1.0))
    for i in range(v.shape[0]):
        # exact: minimize ||w - v||^2 s.t. w>=0, sum<=1 by scanning thresholds
        vv = np.sort(v[i])[::-1]
        best = np.maximum(v[i], 0)
        if best.sum() > 1:
            css = np.cumsum(vv)
            rho = max(
                j + 1 for j in range(len(vv)) if vv[j] * (j + 1) > css[j] - 1.0
            )
            theta = (css[rho - 1] - 1.0) / rho
            best = np.maximum(v[i] - theta, 0)
        np.testing.assert_allclose(w[i], best, atol=2e-5)


def test_equality_variant_sums_to_radius():
    rng = np.random.default_rng(0)
    v, mask = _rand(rng, 20, 12)
    w = np.asarray(project_simplex(v, mask, 1.0, inequality=False))
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-4)


def test_box_projection():
    rng = np.random.default_rng(1)
    v, mask = _rand(rng, 10, 6)
    w = np.asarray(project_box(v, mask, 0.0, 1.0))
    assert (w >= 0).all() and (w <= 1).all()
    inside = (np.asarray(v) >= 0) & (np.asarray(v) <= 1) & (np.asarray(mask) > 0)
    np.testing.assert_allclose(w[inside], np.asarray(v)[inside])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), z=st.floats(0.5, 4.0))
def test_box_cut(seed, z):
    rng = np.random.default_rng(seed)
    v, mask = _rand(rng, 8, 10, scale=2.0)
    w = np.asarray(project_box_cut(v, mask, 0.0, 1.0, z))
    assert (w >= -ATOL).all() and (w <= 1 + ATOL).all()
    assert (w.sum(-1) <= z + 1e-3).all()
    # when box projection already feasible it is returned exactly
    wb = np.clip(np.asarray(v), 0, 1) * np.asarray(mask)
    feas = wb.sum(-1) <= z
    np.testing.assert_allclose(w[feas], wb[feas], atol=1e-5)
