"""Allocation serving: query-kernel parity, the generation fence, store API.

The contract under test (see docs/serving.md): a served batch is
bit-identical to a post-hoc direct projection against the generation the
`QueryResult` reports — across all formulation presets, and even while the
scheduler's double-buffered pipeline is swapping snapshots mid-batch.
"""
import dataclasses
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MaximizerConfig
from repro.formulation import scenario_formulation
from repro.instances import (
    DeltaIngestor,
    InstanceDelta,
    MatchingInstanceSpec,
    generate_matching_instance,
)
from repro.service import (
    Scheduler,
    ServiceConfig,
    SolveSession,
    compiled_solver,
    device_put_instance,
    to_solve_result,
)
from repro.serving import DualStore, direct_allocations

SPEC = MatchingInstanceSpec(
    num_sources=120, num_destinations=10, avg_degree=4.0, seed=21
)
BASE = generate_matching_instance(SPEC)
COLD = MaximizerConfig(iters_per_stage=120, tol_grad=1e-4, tol_viol=1e-4)
SERVICE = ServiceConfig(
    cold=COLD, warm_gammas=(0.1, 0.01), drift_sla_rel=0.5, row_headroom=4
)
PRESETS = ("matching", "capacity-cap", "fairness-floor", "budget-pacing")


def _perturb_delta(edge_list, rng, frac=0.1):
    n = max(1, int(frac * edge_list.src.size))
    pick = rng.choice(edge_list.src.size, size=n, replace=False)
    return InstanceDelta(
        update_src=edge_list.src[pick],
        update_dst=edge_list.dst[pick],
        update_values=edge_list.values[pick] * rng.uniform(0.9, 1.1, n),
    )


def _published_preset(name: str, store: DualStore):
    """Solve one preset with the normalized engine solver and publish it."""
    ing = DeltaIngestor(BASE, row_headroom=4)
    comp = scenario_formulation(name).compile(ing.instance())
    dev = device_put_instance(comp.instance)
    lam0 = jnp.zeros((dev.dual_dim,), jnp.float32)
    res = to_solve_result(compiled_solver(COLD, True)(dev, lam0))
    return store.publish_result(
        name, dev, res.lam,
        generation=ing.generation, gamma=COLD.gammas[-1],
        bucket_of=ing.bucket_of, row_of=ing.row_of, deg=ing.deg,
        normalize=True,
    )


def _assert_result_matches_snapshot(result, snap):
    """Every served row bit-identical to the direct projection of `snap`."""
    xs = direct_allocations(snap)
    for ba in result.slabs:
        ref = np.asarray(xs[ba.bucket])[ba.rows]
        assert np.array_equal(ba.x, ref), (
            f"bucket {ba.bucket}: served rows differ from direct projection "
            f"(max abs diff {np.abs(ba.x - ref).max()})"
        )


# -- query kernel vs direct projection, all presets ---------------------------


@pytest.mark.parametrize("preset", PRESETS)
def test_query_matches_direct_projection_bitwise(preset):
    store = DualStore()
    snap = _published_preset(preset, store)
    users = np.flatnonzero(snap.deg > 0)
    result = store.query(preset, users)
    assert result.generation == snap.generation
    assert result.unmatched.size == 0
    _assert_result_matches_snapshot(result, snap)
    # the acceptance criterion is rel-L2 <= 1e-6; bit-identity implies it,
    # assert it explicitly so a future tolerance relaxation stays honest
    xs = direct_allocations(snap)
    for ba in result.slabs:
        ref = np.asarray(xs[ba.bucket])[ba.rows]
        rel = np.linalg.norm(ba.x - ref) / max(np.linalg.norm(ref), 1e-12)
        assert rel <= 1e-6


@pytest.mark.parametrize("preset", ("matching", "capacity-cap"))
def test_query_subset_and_repeat_batches(preset):
    """Different batch sizes (different pad shapes) all serve correctly."""
    store = DualStore()
    snap = _published_preset(preset, store)
    users = np.flatnonzero(snap.deg > 0)
    rng = np.random.default_rng(3)
    for size in (1, 2, 7, 33, users.size):
        batch = rng.choice(users, size=min(size, users.size), replace=False)
        _assert_result_matches_snapshot(store.query(preset, batch), snap)


def test_unmatched_users_and_range_validation():
    store = DualStore()
    snap = _published_preset("matching", store)
    dead = np.flatnonzero(snap.deg == 0)
    live = np.flatnonzero(snap.deg > 0)[:4]
    if dead.size:
        result = store.query("matching", np.concatenate([live, dead[:2]]))
        assert set(result.unmatched) == set(dead[:2])
        ids, x = result.allocation(int(dead[0]))
        assert ids.size == 0 and x.size == 0
    with pytest.raises(ValueError):
        store.query("matching", [snap.num_users])
    with pytest.raises(KeyError):
        store.query("no-such-tenant", [0])


def test_allocation_accessor_is_feasible():
    """Simplex tenants (inequality radius 1): each served user's allocation
    is nonnegative with mass <= 1 over its destinations, padding slots
    exactly zero."""
    store = DualStore()
    snap = _published_preset("matching", store)
    users = np.flatnonzero(snap.deg > 0)[:16]
    result = store.query("matching", users)
    for u in users:
        ids, x = result.allocation(int(u))
        assert ids.size == int(snap.deg[u])
        assert np.all(x >= 0.0) and float(x.sum()) <= 1.0 + 1e-5
    for ba in result.slabs:
        pad = ~ba.mask.astype(bool)
        assert np.all(ba.x[pad] == 0.0)


# -- session / scheduler integration ------------------------------------------


def test_session_publishes_and_generation_advances():
    rng = np.random.default_rng(5)
    store = DualStore(history=4)
    sess = SolveSession("t0", BASE, SERVICE)
    sess.dual_store = store
    _, rep0 = sess.solve()
    assert rep0["published_generation"] == 0
    snap0 = store.snapshot("t0")
    assert snap0.generation == 0 and snap0.cadence == 0
    users = np.flatnonzero(snap0.deg > 0)
    _assert_result_matches_snapshot(store.query("t0", users), snap0)
    # an A-touching cadence bumps the ingestor generation; the new snapshot
    # must report it and the old one stays answerable through history
    sess.ingest(_perturb_delta(BASE, rng))
    _, rep1 = sess.solve()
    assert rep1["published_generation"] == sess.ingestor.generation > 0
    snap1 = store.snapshot("t0")
    assert snap1.generation == rep1["published_generation"]
    _assert_result_matches_snapshot(store.query("t0", users), snap1)
    _assert_result_matches_snapshot(
        store.query_snapshot(store.get("t0", 0), users), snap0
    )


def test_session_without_store_reports_no_publication():
    sess = SolveSession("t0", BASE, SERVICE)
    _, rep = sess.solve()
    assert rep["published_generation"] is None


def test_scheduler_wires_store_into_sessions():
    store = DualStore()
    sched = Scheduler(SERVICE, dual_store=store)
    sched.add_tenant("t0", BASE)
    sched.add_tenant("t1", generate_matching_instance(
        dataclasses.replace(SPEC, seed=22)
    ))
    out = sched.run_cadence()
    assert sorted(store.tenants()) == ["t0", "t1"]
    for name in ("t0", "t1"):
        assert out.reports[name]["published_generation"] == 0
        snap = store.snapshot(name)
        users = np.flatnonzero(snap.deg > 0)[:8]
        _assert_result_matches_snapshot(store.query(name, users), snap)


def test_scheduler_restore_rewires_store():
    store = DualStore()
    sched = Scheduler(SERVICE, dual_store=store)
    sched.add_tenant("t0", BASE)
    sched.run_cadence()
    arrays, meta = sched.state_dict()
    sched2 = Scheduler(SERVICE, dual_store=store)
    sched2.load_state(arrays, meta)
    assert sched2.sessions["t0"].dual_store is store


# -- the generation fence under the pipeline ----------------------------------


def test_generation_fence_under_pipeline():
    """Queries hammering the store while run_pipeline swaps snapshots: every
    batch is answered entirely against ONE retained generation and is
    bit-identical to the direct projection of that generation's snapshot."""
    rng = np.random.default_rng(9)
    store = DualStore(history=16)
    sched = Scheduler(SERVICE, dual_store=store)
    base2 = generate_matching_instance(dataclasses.replace(SPEC, seed=22))
    sched.add_tenant("t0", BASE)
    sched.add_tenant("t1", base2)
    sched.run_cadence()  # initial publication (cold, generation 0)
    deltas = [
        {"t0": _perturb_delta(BASE, rng), "t1": _perturb_delta(base2, rng)}
        for _ in range(4)
    ]
    snap0 = store.snapshot("t0")
    users_all = np.flatnonzero(snap0.deg > 0)
    results = []
    stop = threading.Event()

    def hammer():
        qrng = np.random.default_rng(11)
        while not stop.is_set():
            batch = qrng.choice(users_all, size=24, replace=False)
            results.append(store.query("t0", batch))

    worker = threading.Thread(target=hammer, daemon=True)
    worker.start()
    try:
        outs = sched.run_pipeline(deltas)
    finally:
        stop.set()
        worker.join(timeout=30)
    assert not worker.is_alive()
    assert len(outs) == 4 and all(not o.ingest_errors for o in outs)
    gens = {r.generation for r in results}
    assert len(gens) >= 2, "hammer should observe a mid-pipeline swap"
    # every batch verifies against the snapshot of the generation it reports
    retained = set(store.generations("t0"))
    assert gens <= retained
    for r in results:
        _assert_result_matches_snapshot(r, store.get("t0", r.generation))


# -- namespace split ----------------------------------------------------------


def test_lm_demo_namespace_is_separate():
    """The seed's token-serving demo moved under repro.serving.lm_demo and
    the allocation API owns the package root."""
    import repro.serving as serving
    import repro.serving.lm_demo as lm_demo

    assert hasattr(serving, "DualStore")
    assert not hasattr(serving, "ServeEngine")
    assert hasattr(lm_demo, "ServeEngine")
    assert hasattr(lm_demo, "lower_decode_step")
