"""One-pass fused dual oracle: kernel/reference/objective/solve parity.

The acceptance bar for the fused oracle is <= 1e-6 relative L2 against the
unfused path on `grad` and `g` (interpret mode); the sweeps here also pin the
exact-zero padding guarantee, the fallback widths, and full-solve trajectory
parity.  Distributed 1/2/8-shard parity lives in tests/test_distributed.py
(slow, subprocess).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Maximizer, MaximizerConfig
from repro.core.objective import MatchingObjective, binned_segment_sum
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _random_bucket(rng, n, L, m, J, *, padded_rows=0):
    idx = jnp.asarray(rng.integers(0, J, size=(n, L)), jnp.int32)
    coeff = jnp.asarray(rng.random((m, n, L)).astype(np.float32))
    cost = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32))
    mask = jnp.asarray((rng.random((n, L)) < 0.8).astype(np.float32))
    if padded_rows:
        mask = mask.at[:padded_rows].set(0.0)
    # padding invariant the packer guarantees: mask-zero slots hold zeros
    coeff = coeff * mask[None]
    cost = cost * mask
    idx = idx * mask.astype(jnp.int32)
    return idx, coeff, cost, mask


def _assert_oracle_close(got, want, msg=""):
    for a, b, name in zip(got, want, ["x", "hist", "lin", "sq"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=3e-5, rtol=1e-5,
            err_msg=f"{name} {msg}",
        )


@pytest.mark.parametrize("L", [1, 4, 64, 512])
@pytest.mark.parametrize("m", [1, 3])
@pytest.mark.slow
def test_dual_oracle_kernel_sweep(L, m):
    J = 64
    n = 29
    rng = np.random.default_rng(L + m)
    idx, coeff, cost, mask = _random_bucket(rng, n, L, m, J, padded_rows=5)
    lam = jnp.asarray(rng.random(m * J).astype(np.float32))
    for gamma in [0.01, 1.0, 100.0]:
        got = kops.fused_dual_oracle(
            idx, coeff, cost, mask, lam, jnp.float32(gamma),
            num_destinations=J, interpret=True,
        )
        want = kref.dual_oracle_ref(idx, coeff, cost, mask, lam, gamma, J)
        _assert_oracle_close(got, want, f"L={L} m={m} gamma={gamma}")


def test_dual_oracle_kernel_basic():
    """Tier-1 pin of the kernel path (one shape, vs the one-pass reference)."""
    J, n, L, m = 100, 37, 32, 2
    rng = np.random.default_rng(0)
    idx, coeff, cost, mask = _random_bucket(rng, n, L, m, J, padded_rows=7)
    lam = jnp.asarray(rng.random(m * J).astype(np.float32))
    got = kops.fused_dual_oracle(
        idx, coeff, cost, mask, lam, jnp.float32(0.5),
        num_destinations=J, interpret=True,
    )
    want = kref.dual_oracle_ref(idx, coeff, cost, mask, lam, 0.5, J)
    _assert_oracle_close(got, want)
    # mask-zero (padded) rows contribute exact zeros everywhere
    x, hist, lin, sq = got
    assert float(jnp.abs(x[:7]).max()) == 0.0
    only_pad = kops.fused_dual_oracle(
        idx, coeff * 0, cost * 0, mask * 0, lam, jnp.float32(0.5),
        num_destinations=J, interpret=True,
    )
    assert float(jnp.abs(only_pad[1]).max()) == 0.0
    assert float(only_pad[2]) == 0.0 and float(only_pad[3]) == 0.0


@pytest.mark.parametrize("dt", ["bfloat16", "int8"])
def test_dual_oracle_kernel_dtype_parity(dt):
    """Narrow-storage kernel parity: the interpret-mode kernel consuming a
    bf16/int8 slab (with per-bucket scales for int8) matches the
    dtype-faithful reference fed the SAME narrow inputs — both widen on
    load and accumulate in fp32, so they must agree to fp32 noise."""
    from repro.instances.buckets import Bucket, convert_bucket

    J, n, L, m = 64, 24, 32, 2
    rng = np.random.default_rng(11)
    idx, coeff, cost, mask = _random_bucket(rng, n, L, m, J, padded_rows=4)
    bd = convert_bucket(
        Bucket(idx=idx, coeff=coeff, cost=cost, mask=mask, length=L), dt
    )
    assert bd.slab_dtype == dt
    assert (bd.coeff_scale is not None) == (dt == "int8")
    lam = jnp.asarray(rng.random(m * J).astype(np.float32))
    for gamma in [0.05, 1.0]:
        got = kops.fused_dual_oracle(
            bd.idx, bd.coeff, bd.cost, bd.mask, lam, jnp.float32(gamma),
            num_destinations=J, interpret=True,
            coeff_scale=bd.coeff_scale, cost_scale=bd.cost_scale,
        )
        want = kref.dual_oracle_ref(
            bd.idx, bd.coeff, bd.cost, bd.mask, lam, gamma, J,
            coeff_scale=bd.coeff_scale, cost_scale=bd.cost_scale,
        )
        _assert_oracle_close(got, want, f"dtype={dt} gamma={gamma}")
        # partials accumulate in fp32 regardless of storage width; the
        # primal slab is written at the storage width for float slabs
        x, hist, lin, sq = got
        assert hist.dtype == jnp.float32
        assert x.dtype == (jnp.bfloat16 if dt == "bfloat16" else jnp.float32)
        # mask-zero (padded) rows still contribute exact zeros
        assert float(jnp.abs(x[:4].astype(jnp.float32)).max()) == 0.0


def test_dual_oracle_fallback_widths():
    """Non-pow2 and > MAX_FUSED_LENGTH widths take the reference path."""
    J, m = 16, 1
    rng = np.random.default_rng(3)
    for n, L in [(9, 48), (2, 16384)]:
        idx, coeff, cost, mask = _random_bucket(rng, n, L, m, J)
        lam = jnp.asarray(rng.random(m * J).astype(np.float32))
        got = kops.fused_dual_oracle(
            idx, coeff, cost, mask, lam, jnp.float32(1.0),
            num_destinations=J, interpret=True,
        )
        want = kref.dual_oracle_ref(idx, coeff, cost, mask, lam, 1.0, J)
        _assert_oracle_close(got, want, f"L={L}")


def test_dual_oracle_onehot_vmem_gate():
    """L * J beyond the one-hot tile budget must dispatch to the reference:
    even a one-row chunk's [L, J] tile would exceed the kernel's VMEM
    working set (the dispatch gates on fits_onehot_budget, not just L)."""
    from repro.kernels.dual_oracle import _ONEHOT_TILE_ELEMS, fits_onehot_budget

    L, J, m, n = 512, 2048, 1, 6  # pow2, <= MAX_FUSED_LENGTH, L*J = 2x budget
    assert L * J > _ONEHOT_TILE_ELEMS and not fits_onehot_budget(L, J)
    rng = np.random.default_rng(9)
    idx, coeff, cost, mask = _random_bucket(rng, n, L, m, J)
    lam = jnp.asarray(rng.random(m * J).astype(np.float32))
    # interpret=True would take the kernel path if the gate were L-only;
    # with the L*J gate this must route to — and therefore match — the ref
    got = kops.fused_dual_oracle(
        idx, coeff, cost, mask, lam, jnp.float32(1.0),
        num_destinations=J, interpret=True,
    )
    want = kref.dual_oracle_ref(idx, coeff, cost, mask, lam, 1.0, J)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_binned_segment_sum_matches_scatter():
    """The satellite segment-sum rewrite == the naive per-family scatter."""
    rng = np.random.default_rng(1)
    m, n, L, J = 3, 17, 8, 23
    idx = jnp.asarray(rng.integers(0, J, size=(n, L)), jnp.int32)
    contrib = jnp.asarray(rng.normal(size=(m, n, L)).astype(np.float32))
    got = binned_segment_sum(idx, contrib, J)
    want = np.zeros((m, J), np.float32)
    for k in range(m):
        np.add.at(want, (k, np.asarray(idx).ravel()),
                  np.asarray(contrib[k]).ravel())
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


@pytest.fixture(scope="module")
def small_packed():
    spec = MatchingInstanceSpec(
        num_sources=300, num_destinations=40, avg_degree=5.0,
        num_families=2, seed=7,
    )
    return bucketize(generate_matching_instance(spec))


@pytest.mark.parametrize("interpret", [True, None])
@pytest.mark.parametrize("include_rhs", [True, False])
def test_fused_oracle_calculate_parity(small_packed, interpret, include_rhs):
    """Acceptance: fused-oracle calculate matches unfused to <= 1e-6 rel-L2
    on grad and g — kernel path (interpret=True) and dispatch path alike."""
    packed = small_packed
    lam = jnp.asarray(
        np.random.default_rng(0).random(packed.dual_dim).astype(np.float32)
    )
    for gamma in [0.05, 1.0, 50.0]:
        ref = MatchingObjective(packed, include_rhs=include_rhs).calculate(
            lam, gamma
        )
        fo = MatchingObjective(
            packed, include_rhs=include_rhs,
            fused_oracle=True, kernel_interpret=interpret,
        ).calculate(lam, gamma)
        rel_g = abs(float(ref.g - fo.g)) / max(abs(float(ref.g)), 1e-12)
        rel_grad = float(
            jnp.linalg.norm(ref.grad - fo.grad)
            / jnp.maximum(jnp.linalg.norm(ref.grad), 1e-12)
        )
        assert rel_g <= 1e-6, (gamma, rel_g)
        assert rel_grad <= 1e-6, (gamma, rel_grad)
        for xr, xf in zip(ref.x_slabs, fo.x_slabs):
            np.testing.assert_allclose(
                np.asarray(xf), np.asarray(xr), atol=3e-5
            )


def test_fused_oracle_full_solve_trajectory(small_packed):
    """Full continuation solve: fused-oracle trajectories track the unfused
    solver (identical off-TPU, <= fp32 noise with the kernel engaged)."""
    cfg = MaximizerConfig(iters_per_stage=40)
    ref = Maximizer(MatchingObjective(small_packed), cfg).solve()
    fo = Maximizer(
        MatchingObjective(small_packed, fused_oracle=True), cfg
    ).solve()
    for st_r, st_f in zip(ref.stats, fo.stats):
        tr_r, tr_f = np.asarray(st_r.g), np.asarray(st_f.g)
        dev = np.max(np.abs(tr_f - tr_r) / (np.abs(tr_r) + 1e-9))
        assert dev <= 1e-5, dev
    rel = float(
        jnp.linalg.norm(fo.lam - ref.lam)
        / jnp.maximum(jnp.linalg.norm(ref.lam), 1e-12)
    )
    assert rel <= 1e-5, rel


@pytest.mark.slow
def test_fused_oracle_kernel_full_solve(small_packed):
    """Same trajectory check with the Pallas kernel body (interpret mode)."""
    cfg = MaximizerConfig(gammas=(10.0, 1.0), iters_per_stage=30)
    ref = Maximizer(MatchingObjective(small_packed), cfg).solve()
    fo = Maximizer(
        MatchingObjective(
            small_packed, fused_oracle=True, kernel_interpret=True
        ),
        cfg,
    ).solve()
    rel = float(
        jnp.linalg.norm(fo.lam - ref.lam)
        / jnp.maximum(jnp.linalg.norm(ref.lam), 1e-12)
    )
    assert rel <= 1e-4, rel
    assert abs(float(fo.g - ref.g)) / max(abs(float(ref.g)), 1e-12) <= 1e-5
