"""Stability control (paper contribution 2): drift bound holds empirically."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    RecurringSolver,
    drift_bound,
    primal_drift,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)


def _perturbed_pair(scale=0.02, seed=31):
    spec = MatchingInstanceSpec(
        num_sources=200, num_destinations=12, avg_degree=4.0, seed=seed
    )
    a = generate_matching_instance(spec)
    b = dataclasses.replace(a)
    rng = np.random.default_rng(seed + 1)
    noise = 1.0 + scale * rng.standard_normal(a.nnz)
    b.values = a.values * noise
    b.coeff = a.coeff * noise
    return a, b


@pytest.mark.parametrize("gamma", [0.05, 0.5])
def test_drift_bound_holds(gamma):
    """||x*(lam1;c1) - x*(lam2;c2)|| <= (sigma||dlam|| + ||dc||)/gamma."""
    a, b = _perturbed_pair()
    pa, pb = bucketize(a), bucketize(b)
    cfg = MaximizerConfig(gammas=(gamma,), iters_per_stage=400)
    ra = Maximizer(MatchingObjective(pa), cfg).solve()
    rb = Maximizer(MatchingObjective(pb), cfg).solve(lam0=ra.lam)
    drift = float(primal_drift(ra.x_slabs, rb.x_slabs))
    dc = float(np.sqrt(sum(
        np.sum((np.asarray(x.cost) - np.asarray(y.cost)) ** 2)
        for x, y in zip(pa.buckets, pb.buckets)
    )))
    # sigma_max of the raw instances (not normalized here)
    sig = float(np.sqrt(max(ra.sigma_sq, rb.sigma_sq)))
    dlam = float(np.linalg.norm(np.asarray(ra.lam) - np.asarray(rb.lam)))
    # the A^T(dlam) term also carries the dA perturbation; grant 10% slack
    bound = drift_bound(gamma, dc_norm=dc * 1.5, dlam_norm=dlam, sigma_max=sig)
    assert drift <= bound * 1.1, (drift, bound)


def test_larger_gamma_less_drift():
    a, b = _perturbed_pair()
    pa, pb = bucketize(a), bucketize(b)
    drifts = {}
    for gamma in (0.05, 1.0):
        cfg = MaximizerConfig(gammas=(gamma,), iters_per_stage=300)
        ra = Maximizer(MatchingObjective(pa), cfg).solve()
        rb = Maximizer(MatchingObjective(pb), cfg).solve(lam0=ra.lam)
        drifts[gamma] = float(primal_drift(ra.x_slabs, rb.x_slabs))
    assert drifts[1.0] <= drifts[0.05] + 1e-6, drifts


def test_recurring_solver_reports_drift():
    a, b = _perturbed_pair()
    rs = RecurringSolver(MaximizerConfig(iters_per_stage=100))
    _, rep0 = rs.solve(bucketize(a))
    assert rep0 == {}
    _, rep1 = rs.solve(bucketize(b))
    assert rep1["drift_l2"] >= 0
    assert rep1["gamma_floor"] == 0.01
