"""Property suite for every `FeasibleSet.lower()` projection.

For each set in the catalog the lowered `ProjectionMap` must satisfy the
three properties that make the dual oracle sound (paper §4.2):

  idempotence        P(P(z)) == P(z)          (P lands *on* the set)
  non-expansiveness  ||P(a)-P(b)|| <= ||a-b|| (AGD step-size analysis)
  membership         P(z) in C                (via `FeasibleSet.contains`,
                                               incl. pads-stay-zero)

Runs under hypothesis when available; falls back to a fixed sample grid
otherwise (the pattern from tests/test_deltas.py), so the suite is never
silently skipped.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.formulation import (
    Box,
    BudgetPacedBox,
    CappedSimplex,
    FairnessFloor,
    Simplex,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep; the fixed-sample fallback below runs
    HAVE_HYPOTHESIS = False

ATOL = 2e-5

# Feasibility-safe parameters: rows have at most L_MAX real entries, and
# every set below is non-empty at that degree (FairnessFloor needs
# floor * L_MAX <= radius: 0.05 * 16 = 0.8 <= 1.0).
L_MAX = 16
CATALOG = [
    Box(lo=0.0, hi=0.7),
    Box(lo=-0.5, hi=0.5),
    Simplex(),
    Simplex(radius=2.5),
    Simplex(radius=1.0, inequality=False),
    CappedSimplex(cap=0.4),
    CappedSimplex(cap=0.15, radius=0.8),
    FairnessFloor(floor=0.05, hi=1.0, radius=1.0),
    BudgetPacedBox(pace=0.3, budget=1.5),
]
IDS = [
    "box", "box-neg", "simplex", "simplex-r2.5", "simplex-eq",
    "cap-0.4", "cap-0.15", "floor-0.05", "pace-0.3",
]


def _sample(rng, n, L, scale=3.0):
    v = rng.normal(size=(n, L)).astype(np.float32) * scale
    mask = (rng.random((n, L)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0  # at least one real entry per row
    return jnp.asarray(v), jnp.asarray(mask)


def _check_properties(fs, seed, n, L):
    rng = np.random.default_rng(seed)
    proj = fs.lower()
    v, mask = _sample(rng, n, L)

    w = proj(v, mask)
    # membership (includes pads-stay-zero)
    assert fs.contains(w, mask), (
        f"{fs} projection output left the set:\n{np.asarray(w)}"
    )
    # idempotence
    w2 = proj(w, mask)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=ATOL)
    # non-expansiveness
    v2 = v + jnp.asarray(rng.normal(size=v.shape).astype(np.float32)) * mask
    w_b = proj(v2, mask)
    d_in = np.linalg.norm(np.asarray((v - v2) * mask))
    d_out = np.linalg.norm(np.asarray(w - w_b))
    assert d_out <= d_in + 1e-4, f"{fs} projection expanded: {d_out} > {d_in}"


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("fs", CATALOG, ids=IDS)
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(1, 6),
        L=st.integers(1, L_MAX),
    )
    def test_projection_properties(fs, seed, n, L):
        _check_properties(fs, seed, n, L)

else:

    @pytest.mark.parametrize("fs", CATALOG, ids=IDS)
    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("shape", [(1, 1), (2, 5), (3, 8), (4, L_MAX)])
    def test_projection_properties(fs, seed, shape):
        _check_properties(fs, seed, *shape)


@pytest.mark.parametrize("fs", CATALOG, ids=IDS)
def test_feasible_point_is_fixed(fs):
    """A point already in C must be (nearly) fixed by the projection."""
    rng = np.random.default_rng(0)
    proj = fs.lower()
    v, mask = _sample(rng, 4, 8)
    w = proj(v, mask)  # in C by membership above
    w2 = proj(w, mask)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=ATOL)


def test_contains_rejects_out_of_set_points():
    """The membership predicates themselves must not be vacuous."""
    mask = np.ones((1, 4), np.float32)
    assert not Box(lo=0.0, hi=0.5).contains([[0.9, 0, 0, 0]], mask)
    assert not Simplex().contains([[0.9, 0.9, 0, 0]], mask)
    assert not Simplex(inequality=False).contains([[0.2, 0.2, 0, 0]], mask)
    assert not CappedSimplex(cap=0.3).contains([[0.5, 0, 0, 0]], mask)
    assert not FairnessFloor(floor=0.1).contains([[0.01, 0.2, 0.2, 0.2]], mask)
    assert not BudgetPacedBox(pace=0.2, budget=1.0).contains(
        [[0.4, 0, 0, 0]], mask
    )
    # pad leak: masked-out entries must be exactly zero
    assert not Simplex().contains(
        [[0.5, 0.0, 0.0, 0.1]], [[1.0, 1.0, 1.0, 0.0]]
    )


def test_equality_simplex_lands_on_boundary():
    rng = np.random.default_rng(1)
    v, mask = _sample(rng, 5, 6)
    w = np.asarray(Simplex(radius=1.0, inequality=False).lower()(v, mask))
    sums = (w * np.asarray(mask)).sum(-1)
    np.testing.assert_allclose(sums, 1.0, atol=1e-4)
