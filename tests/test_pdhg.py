"""PDHG baseline (cuPDLP/D-PDLP family) correctness."""
import numpy as np
import pytest
from scipy.optimize import linprog

from repro.core import PDHGConfig, from_edge_list, solve_pdhg
from repro.instances import MatchingInstanceSpec, generate_matching_instance


@pytest.mark.parametrize("seed", [5, 6])
def test_pdhg_matches_linprog(seed):
    spec = MatchingInstanceSpec(
        num_sources=60, num_destinations=10, avg_degree=4.0, seed=seed
    )
    inst = generate_matching_instance(spec)
    res = solve_pdhg(from_edge_list(inst), PDHGConfig(max_iters=40_000))
    assert bool(res.converged)
    A, b, c = inst.to_dense()
    J = spec.num_destinations
    cols = inst.src * J + inst.dst
    S = np.zeros((spec.num_sources, inst.nnz))
    S[inst.src, np.arange(inst.nnz)] = 1.0
    r = linprog(
        c[cols], A_ub=np.vstack([A[:, cols], S]),
        b_ub=np.concatenate([b, np.ones(spec.num_sources)]),
        bounds=(0, 1), method="highs",
    )
    rel = abs(float(res.primal_obj) - r.fun) / abs(r.fun)
    assert rel < 5e-3, (float(res.primal_obj), r.fun)


def test_pdhg_feasibility():
    spec = MatchingInstanceSpec(num_sources=80, num_destinations=8, avg_degree=3.0, seed=7)
    inst = generate_matching_instance(spec)
    lp = from_edge_list(inst)
    res = solve_pdhg(lp, PDHGConfig(max_iters=30_000))
    x = np.asarray(res.x)
    assert (x >= -1e-6).all() and (x <= 1 + 1e-6).all()
    kx = np.asarray(lp.K(res.x))
    q = np.asarray(lp.q)
    assert np.maximum(kx - q, 0).max() / (1 + np.abs(q).max()) < 1e-3


def test_explicit_row_blowup():
    """The unstructured formulation carries (m+1)x the nnz — the structural
    cost that the paper's bucketed formulation avoids (Table 3 narrative)."""
    spec = MatchingInstanceSpec(
        num_sources=50, num_destinations=8, avg_degree=3.0, num_families=2, seed=8
    )
    inst = generate_matching_instance(spec)
    lp = from_edge_list(inst)
    assert lp.rows.shape[0] == 3 * inst.nnz
