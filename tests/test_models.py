"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models.model import Model


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.encdec:
        return {
            "embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    if cfg.frontend == "patch":
        P = cfg.frontend_len
        return {
            "embeds": jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - P)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_smoke_forward_and_step(arch):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch
    p2, opt, metrics = adamw_update(
        AdamWConfig(), grads, adamw_init(params), params
    )
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_smoke_prefill_decode(arch):
    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = _batch(cfg, B=B, S=S)
    pf = {k: v for k, v in batch.items() if k != "labels"}
    if cfg.encdec:
        pf["tokens"] = pf["tokens"][:, :1]
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_seq=S + 8))(
        params, pf
    )
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.asarray(1 if cfg.encdec else (S if cfg.frontend != "patch" else S), jnp.int32)
    lg, cache = jax.jit(model.decode_step)(params, tok, pos, cache)
    assert jnp.all(jnp.isfinite(lg.astype(jnp.float32))), arch


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v2-236b", "mamba2-1.3b", "zamba2-2.7b"])
@pytest.mark.slow
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits (cache correctness)."""
    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 16
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    # full forward logits
    h = model.hidden_states(model._lowp(params), toks)
    from repro.models import layers as L

    h = L.rms_norm(h, params["final_norm"], cfg.rmsnorm_eps)
    full_logits = np.asarray(model.logits(model._lowp(params), h), np.float32)
    # step-by-step decode from an empty cache
    cache = model.init_cache(B, S)
    dec = jax.jit(model.decode_step)
    for t in range(S):
        lg, cache = dec(params, toks[:, t : t + 1], jnp.asarray(t, jnp.int32), cache)
        got = np.asarray(lg[:, 0], np.float32)
        want = full_logits[:, t]
        # bf16 compute: compare argmax + loose numeric agreement
        np.testing.assert_allclose(got, want, atol=0.05, rtol=0.05)


def test_full_configs_match_table():
    """Exact published dims for every assigned architecture."""
    table = {
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
    }
    for arch, (L_, d, H, K, ff, V) in table.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L_, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == H, arch
        assert cfg.num_kv_heads == K, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    # flavour details
    assert get_config("gemma-7b").head_dim == 256
    assert get_config("gemma-7b").mlp_type == "geglu"
    assert get_config("qwen3-8b").qk_norm
    assert get_config("qwen2-72b").qkv_bias
    assert get_config("deepseek-v2-236b").mla.kv_lora_rank == 512
    assert get_config("deepseek-v2-236b").moe.num_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    assert get_config("kimi-k2-1t-a32b").moe.num_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_config("zamba2-2.7b").ssm.state_dim == 64
    assert get_config("mamba2-1.3b").ssm.state_dim == 128


def test_param_counts_plausible():
    expected = {
        "internvl2-76b": 70e9, "gemma-7b": 8.5e9, "qwen3-8b": 8e9,
        "qwen2-72b": 72e9, "starcoder2-7b": 10e9, "deepseek-v2-236b": 236e9,
        "kimi-k2-1t-a32b": 1.03e12, "seamless-m4t-medium": 1e9,
        "zamba2-2.7b": 2.4e9, "mamba2-1.3b": 1.4e9,
    }
    for arch, n in expected.items():
        got = Model(get_config(arch)).param_count()
        assert 0.75 * n < got < 1.3 * n, (arch, got, n)
