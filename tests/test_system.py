"""End-to-end system behaviour: the paper's full pipeline + the LM substrate."""
import numpy as np
import pytest
from scipy.optimize import linprog

from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    normalize_rows,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    unpack_primal,
)


def test_end_to_end_solve_quality():
    """generate -> pack -> normalize -> continuation solve -> verify vs HiGHS."""
    spec = MatchingInstanceSpec(
        num_sources=120, num_destinations=10, avg_degree=4.0, num_families=2, seed=42
    )
    inst = generate_matching_instance(spec)
    packed = bucketize(inst)
    scaled, _ = normalize_rows(packed)
    res = Maximizer(
        MatchingObjective(scaled), MaximizerConfig(iters_per_stage=400)
    ).solve()
    x = unpack_primal(packed, res.x_slabs)

    A, b, c = inst.to_dense()
    J = spec.num_destinations
    cols = inst.src * J + inst.dst
    S = np.zeros((spec.num_sources, inst.nnz))
    S[inst.src, np.arange(inst.nnz)] = 1.0
    truth = linprog(
        c[cols], A_ub=np.vstack([A[:, cols], S]),
        b_ub=np.concatenate([b, np.ones(spec.num_sources)]),
        bounds=(0, None), method="highs",
    )
    rel = abs(float(np.dot(inst.cost, x)) - truth.fun) / abs(truth.fun)
    assert rel < 2e-3
    # simple constraints hold exactly (projection): per-source mass <= 1
    mass = np.zeros(spec.num_sources)
    np.add.at(mass, inst.src, x)
    assert mass.max() <= 1.0 + 1e-5
    assert x.min() >= -1e-7


def test_end_to_end_fused_kernel_solve():
    """Same pipeline with the fused Pallas dual-primal kernel in the loop."""
    spec = MatchingInstanceSpec(num_sources=80, num_destinations=8, avg_degree=3.0, seed=43)
    packed, _ = normalize_rows(bucketize(generate_matching_instance(spec)))
    cfg = MaximizerConfig(iters_per_stage=150)
    g_ref = float(Maximizer(MatchingObjective(packed), cfg).solve().g)
    g_kern = float(
        Maximizer(
            MatchingObjective(packed, fused_kernel=True, kernel_interpret=True),
            cfg,
        ).solve().g
    )
    assert abs(g_ref - g_kern) / abs(g_ref) < 1e-4


@pytest.mark.slow
def test_end_to_end_train_and_serve():
    """Train a tiny LM with the fault-tolerant loop, then serve it."""
    from repro.configs import get_reduced_config
    from repro.data.pipeline import SyntheticLMData
    from repro.models.model import Model
    from repro.serving.lm_demo.engine import Request, ServeEngine
    from repro.training.loop import TrainLoopConfig, train_loop
    from repro.training.optimizer import AdamWConfig

    cfg = get_reduced_config("gemma-7b")
    model = Model(cfg)
    data = SyntheticLMData(cfg, batch=4, seq=32, seed=5)
    state = train_loop(
        model, data, AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=15),
        TrainLoopConfig(total_steps=15, save_every=100, log_every=0),
    )
    engine = ServeEngine(model, state.params, slots=2, max_seq=48)
    req = Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new_tokens=4)
    engine.submit(req)
    engine.run()
    assert len(req.out_tokens) == 4
