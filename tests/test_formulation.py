"""Formulation layer: primitives lower onto the untouched oracle stack.

Parity pins: matching-expressed-as-primitives must reproduce the legacy
`MatchingObjective` (duals rel-L2 <= 1e-6, identical per-stage iters_used)
on both the fallback and fused-oracle paths.  Scenario pins: capacity caps,
fairness floors and budget pacing solve end-to-end through
`Formulation.compile` — including the untouched service engine and the
distributed layer over every local device.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat, telemetry
from repro.core import (
    DistConfig,
    DistributedMaximizer,
    MatchingObjective,
    Maximizer,
    MaximizerConfig,
    normalize_rows,
)
from repro.core.projections import BoxCutProjection, UnitSimplexProjection
from repro.formulation import (
    Box,
    CappedSimplex,
    FairnessFloor,
    Formulation,
    FormulationSpec,
    LinearCost,
    PackedCoupling,
    RidgeSmoothing,
    Simplex,
    budget_pacing_formulation,
    capacity_cap_formulation,
    fairness_floor_formulation,
    lower_spec,
    matching_formulation,
    scenario_formulation,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.service import compiled_solver


def _scaled(seed=7, I=400, J=23, m=2, shard_multiple=1):
    spec = MatchingInstanceSpec(
        num_sources=I, num_destinations=J, avg_degree=4.0,
        num_families=m, seed=seed,
    )
    packed = bucketize(generate_matching_instance(spec),
                       shard_multiple=shard_multiple)
    scaled, _ = normalize_rows(packed)
    return scaled


def _rel_l2(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12))


EARLY_CFG = MaximizerConfig(iters_per_stage=60, tol_grad=1e-3, tol_viol=1e-3)


# ---------------------------------------------------------------------------
# parity: matching-as-primitives == legacy MatchingObjective
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused_oracle", [False, True])
def test_matching_primitives_parity(fused_oracle):
    scaled = _scaled()
    legacy = Maximizer(
        MatchingObjective(scaled, fused_oracle=fused_oracle), EARLY_CFG
    ).solve()
    comp = matching_formulation().compile(scaled)
    prim = comp.solve(EARLY_CFG, fused_oracle=fused_oracle)
    assert _rel_l2(prim.lam, legacy.lam) <= 1e-6
    assert prim.iters_used == legacy.iters_used
    assert np.isclose(float(prim.g), float(legacy.g), rtol=1e-6)


def test_matching_primitives_parity_is_bitwise():
    """The default composition must not even perturb the jaxpr: same
    projection object, unit scales, untouched rhs -> identical arrays."""
    scaled = _scaled(seed=3)
    cfg = MaximizerConfig(iters_per_stage=30)
    legacy = Maximizer(MatchingObjective(scaled), cfg).solve()
    prim = matching_formulation().compile(scaled).solve(cfg)
    assert np.array_equal(np.asarray(prim.lam), np.asarray(legacy.lam))


def test_formulation_objective_matches_dense_scales():
    """Non-unit term scales lower into the oracle: g uses scaled c and gamma."""
    scaled = _scaled(seed=11, I=80, J=9, m=1)
    form = Formulation(
        terms=(LinearCost(scale=2.0), RidgeSmoothing(weight=0.5)),
        name="scaled_terms",
    )
    obj = form.compile(scaled).objective()
    base = MatchingObjective(scaled)
    lam = jnp.asarray(
        np.random.default_rng(0).random(base.dual_dim).astype(np.float32)
    )
    ev = obj.calculate(lam, 1.0)
    # same point evaluated through the unscaled oracle at the equivalent
    # (cost*2, gamma*0.5) parameters
    ref = MatchingObjective(
        dataclasses.replace(
            scaled,
            buckets=tuple(
                dataclasses.replace(b, cost=2.0 * b.cost)
                for b in scaled.buckets
            ),
        )
    ).calculate(lam, 0.5)
    assert _rel_l2(ev.grad, ref.grad) <= 1e-6
    assert np.isclose(float(ev.g), float(ref.g), rtol=1e-5)


# ---------------------------------------------------------------------------
# scenarios end-to-end (zero edits to maximizer/sharding/service)
# ---------------------------------------------------------------------------


def test_capacity_cap_end_to_end():
    scaled = _scaled(seed=5)
    comp = capacity_cap_formulation(cap=0.4).compile(scaled)
    res = comp.solve(MaximizerConfig(iters_per_stage=40))
    for s, b in zip(res.x_slabs, comp.instance.buckets):
        x = np.asarray(s)
        assert x.max() <= 0.4 + 1e-5
        assert x.min() >= -1e-6
        rows = (x * np.asarray(b.mask)).sum(-1)
        assert rows.max() <= 1.0 + 1e-4
    assert np.isfinite(float(res.g))


def test_fairness_floor_end_to_end():
    scaled = _scaled(seed=6)
    comp = fairness_floor_formulation(floor=0.05).compile(scaled)
    res = comp.solve(MaximizerConfig(iters_per_stage=40))
    for s, b in zip(res.x_slabs, comp.instance.buckets):
        x, mask = np.asarray(s), np.asarray(b.mask)
        real = x[mask > 0]
        if real.size:
            assert real.min() >= 0.05 - 1e-5
        assert (np.abs(x[mask == 0]) == 0).all(), "pad leaked"


def test_budget_pacing_end_to_end():
    scaled = _scaled(seed=8)
    comp = budget_pacing_formulation(pace=0.3, budget=1.5).compile(scaled)
    res = comp.solve(MaximizerConfig(iters_per_stage=40))
    for s, b in zip(res.x_slabs, comp.instance.buckets):
        x = np.asarray(s)
        assert x.max() <= 0.3 + 1e-5
        rows = (x * np.asarray(b.mask)).sum(-1)
        assert rows.max() <= 1.5 + 1e-4


def test_rhs_scale_coupling_lowered_once():
    scaled = _scaled(seed=9, I=60, J=7, m=1)
    comp = capacity_cap_formulation(cap=0.9, rhs_scale=0.5).compile(scaled)
    np.testing.assert_allclose(
        np.asarray(comp.instance.rhs), 0.5 * np.asarray(scaled.rhs), rtol=1e-6
    )
    # the oracle's gradient uses the transformed rhs
    obj = comp.objective()
    ev = obj.calculate(jnp.zeros(obj.dual_dim), 1.0)
    np.testing.assert_allclose(
        np.asarray(ev.grad),
        np.asarray(ev.ax) - 0.5 * np.asarray(scaled.rhs).reshape(-1),
        atol=1e-6,
    )


# ---------------------------------------------------------------------------
# service-engine dispatch: the spec rides the instance treedef
# ---------------------------------------------------------------------------


def test_engine_dispatches_formulation_without_service_edits():
    scaled = _scaled(seed=12, I=200, J=11, m=1)
    cfg = MaximizerConfig(iters_per_stage=30)
    solver = compiled_solver(cfg)
    lam0 = jnp.zeros(scaled.dual_dim)

    legacy_raw = solver(scaled, lam0)
    match_comp = matching_formulation().compile(scaled)
    match_raw = solver(match_comp.instance, lam0)
    assert _rel_l2(match_raw.lam, legacy_raw.lam) <= 1e-6

    cap_comp = capacity_cap_formulation(cap=0.4).compile(scaled)
    cap_raw = solver(cap_comp.instance, lam0)
    for s in cap_raw.x_slabs:
        assert np.asarray(s).max() <= 0.4 + 1e-5
    # distinct formulations must not share an executable: the spec is part
    # of the treedef, so the shape-keyed cache re-keys automatically and the
    # capped solve genuinely differs from the legacy one.
    assert not np.array_equal(
        np.asarray(cap_raw.lam), np.asarray(legacy_raw.lam)
    )

    # direct CompiledFormulation.solve agrees with the engine path
    direct = cap_comp.solve(cfg)
    assert _rel_l2(cap_raw.lam, direct.lam) <= 1e-6


def test_normalize_preserves_formulation_spec():
    packed = bucketize(generate_matching_instance(MatchingInstanceSpec(
        num_sources=50, num_destinations=5, avg_degree=3.0,
        num_families=1, seed=0,
    )))
    comp = capacity_cap_formulation(cap=0.3).compile(packed)
    renorm, _ = normalize_rows(comp.instance)
    assert renorm.formulation == comp.spec


# ---------------------------------------------------------------------------
# distributed parity over every local device (CI runs this file under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 -> shard count > 1)
# ---------------------------------------------------------------------------


def test_distributed_formulation_parity():
    n = len(jax.devices())
    scaled = _scaled(seed=13, shard_multiple=n)
    cfg = MaximizerConfig(iters_per_stage=40)
    comp = capacity_cap_formulation(cap=0.4).compile(scaled)

    single = comp.solve(cfg)
    mesh = compat.make_mesh((n,), ("data",))
    dm = DistributedMaximizer(
        comp.sharded_instance(), mesh, cfg, DistConfig(axes="data"),
        projection=comp.projection,
    )
    dm.place()
    dist = dm.solve()
    assert _rel_l2(dist.lam, single.lam) <= 1e-5
    for s in dist.x_slabs:
        assert np.asarray(s).max() <= 0.4 + 1e-5


def test_distributed_matching_primitives_parity():
    """Primitives vs legacy on the *same* distributed path (same psum
    reduction order), so any difference is the formulation layer's."""
    n = len(jax.devices())
    scaled = _scaled(seed=14, shard_multiple=n)
    comp = matching_formulation().compile(scaled)
    mesh = compat.make_mesh((n,), ("data",))

    def run(inst, **kw):
        dm = DistributedMaximizer(
            inst, mesh, EARLY_CFG, DistConfig(axes="data"), **kw
        )
        dm.place()
        return dm.solve()

    legacy = run(scaled)
    prim = run(comp.sharded_instance(), projection=comp.projection)
    assert _rel_l2(prim.lam, legacy.lam) <= 1e-6
    assert prim.iters_used == legacy.iters_used


# ---------------------------------------------------------------------------
# compile telemetry + validation
# ---------------------------------------------------------------------------


def test_compile_emits_telemetry():
    scaled = _scaled(seed=15, I=40, J=5, m=1)
    reg = telemetry.get_registry()

    def counter(name):
        return sum(
            v for k, v in reg.snapshot()["counters"].items()
            if k.startswith(name)
        )

    before = counter("formulation_compiles_total")
    capacity_cap_formulation(cap=0.5).compile(scaled)
    after = reg.snapshot()["counters"]
    assert counter("formulation_compiles_total") == before + 1
    assert any(
        k.startswith("formulation_compiles_total")
        and "capacity_cap" in k
        for k in after
    )
    assert any(
        k.startswith("formulation_primitives_total") for k in after
    )


def test_lowering_table():
    assert Simplex().lower() == UnitSimplexProjection()
    assert CappedSimplex(cap=0.4).lower() == BoxCutProjection(
        lo=0.0, hi=0.4, radius=1.0
    )
    assert isinstance(FairnessFloor(floor=0.02).lower(), BoxCutProjection)


def test_validation_errors():
    scaled = _scaled(seed=16, I=40, J=5, m=1)
    bad_count = len(scaled.buckets) + 2  # never 1 (shared) nor per-bucket
    with pytest.raises(ValueError, match="feasible sets"):
        spec = FormulationSpec(feasible=(Simplex(),) * bad_count)
        lower_spec(spec, scaled)
    with pytest.raises(ValueError):
        Formulation(terms=(LinearCost(), LinearCost())).compile(scaled)
    with pytest.raises(ValueError):
        Formulation(couplings=()).compile(scaled)
    with pytest.raises(ValueError):
        Formulation(
            couplings=(PackedCoupling(sense="ge"),)
        ).compile(scaled)
    with pytest.raises(ValueError):
        scenario_formulation("nope")
    with pytest.raises(ValueError):
        CappedSimplex(cap=-0.1).validate()
    with pytest.raises(ValueError):
        Box(lo=1.0, hi=0.0).validate()
    with pytest.raises(ValueError):
        Formulation(
            feasible_sets=(Simplex(), CappedSimplex())
        ).shared_projection()


def test_fused_paths_reject_non_simplex_formulations():
    scaled = _scaled(seed=17, I=40, J=5, m=1)
    comp = capacity_cap_formulation(cap=0.5).compile(scaled)
    obj = comp.objective(fused_oracle=True)
    with pytest.raises(AssertionError, match="simplex"):
        obj.calculate(jnp.zeros(obj.dual_dim), 1.0)
