"""HLO parsing + roofline math + analytic FLOPs-model validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_stats import (
    collective_stats,
    loop_multipliers,
    parse_shape_bytes,
)
from repro.analysis.roofline import V5E, roofline_from_stats

SAMPLE = """
HloModule jit_f

%region_body.10 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,16]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%ar, %ar)
}

%region_cond.11 (arg: (s32[], f32[8,16])) -> pred[] {
  %pc = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%pc), index=0
  %c = s32[] constant(48)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.20 (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8,16]) while(%init), condition=%region_cond.11, body=%region_body.10
  ROOT %r = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[8,16]") == 512
    assert parse_shape_bytes("bf16[2,3,4]") == 48
    assert parse_shape_bytes("(f32[4], s32[2])") == 24
    assert parse_shape_bytes("pred[]") == 1


def test_loop_multipliers():
    m = loop_multipliers(SAMPLE)
    assert m["region_body.10"] == 48
    assert m["main.20"] == 1


def test_collective_stats_static_vs_loop_aware():
    st = collective_stats(SAMPLE)
    la = collective_stats(SAMPLE, loop_aware=True)
    assert st["counts"]["all-reduce"] == 1
    assert la["counts"]["all-reduce"] == 48
    assert la["bytes"]["all-reduce"] == 48 * 512
    assert st["counts"]["all-gather"] == la["counts"]["all-gather"] == 1
    # all-gather payload = operand bytes (the shard entering the network)
    assert la["bytes"]["all-gather"] == 512


def test_roofline_terms():
    t = roofline_from_stats(
        flops_per_device=197e12, bytes_per_device=819e9,
        coll_bytes_per_device=25e9, chips=256, model_flops=197e12 * 256 / 2,
    )
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.dominant in ("compute", "memory")
    assert t.useful_flops_ratio == pytest.approx(0.5)


def test_flops_model_validates_against_hlo():
    """Body-once transform: extras + 4x(one layer fwd) ~ measured HLO flops.

    Run on the REDUCED config with a small shape so compile stays fast; the
    same relation justifies the analytic totals at full scale.
    """
    from repro.analysis.flops_model import cell_cost
    from repro.configs import ShapeSpec, get_reduced_config
    from repro.models.model import Model
    from repro.training.optimizer import AdamWConfig, adamw_update
    from repro.training.train_step import TrainState, init_train_state

    cfg = get_reduced_config("qwen3-8b")
    shape = ShapeSpec("tiny_train", "train", 64, 4)
    cost = cell_cost(cfg, shape)

    model = Model(cfg)
    opt_cfg = AdamWConfig()

    def step(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        p, o, _ = adamw_update(opt_cfg, grads, state.opt, state.params)
        return TrainState(p, o, state.step + 1)

    state = jax.eval_shape(lambda: init_train_state(model, jax.random.key(0)))
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32),
    }
    ca = jax.jit(step).lower(state, batch).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    measured = ca["flops"]
    # body-once: fwd body (1x) + bwd body (remat fwd + 2x bwd = 3x) + extras
    predicted = 4 * cost.layer_fwd_flops + cost.extra_flops
    assert 0.4 < measured / predicted < 2.5, (measured, predicted)
    # and the full analytic total uses trip counts
    assert cost.flops == pytest.approx(
        4 * cost.layer_fwd_flops * cfg.num_layers + cost.extra_flops, rel=0.01
    )
