"""Solver correctness: vs scipy.linprog ground truth + algorithmic properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.optimize import linprog

from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    normalize_rows,
)
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    unpack_primal,
)


def _lp_ground_truth(inst):
    """HiGHS solution of the unregularized LP restricted to eligible pairs."""
    spec = inst.spec
    I, J = spec.num_sources, spec.num_destinations
    A, b, c = inst.to_dense()
    cols = inst.src * J + inst.dst
    S = np.zeros((I, inst.nnz))
    S[inst.src, np.arange(inst.nnz)] = 1.0
    r = linprog(
        c[cols],
        A_ub=np.vstack([A[:, cols], S]),
        b_ub=np.concatenate([b, np.ones(I)]),
        bounds=(0, None),
        method="highs",
    )
    assert r.status == 0
    return r


@pytest.mark.parametrize("m", [1, 2])
def test_solver_matches_linprog(m):
    spec = MatchingInstanceSpec(
        num_sources=50, num_destinations=8, avg_degree=3.0, num_families=m, seed=11
    )
    inst = generate_matching_instance(spec)
    packed = bucketize(inst)
    scaled, d = normalize_rows(packed)
    res = Maximizer(
        MatchingObjective(scaled), MaximizerConfig(iters_per_stage=400)
    ).solve()
    truth = _lp_ground_truth(inst)
    x = unpack_primal(packed, res.x_slabs)
    ours = float(np.dot(inst.cost, x))
    rel = abs(ours - truth.fun) / abs(truth.fun)
    assert rel < 1e-3, (ours, truth.fun)
    # feasibility in the ORIGINAL (unscaled) problem
    A, b, _ = inst.to_dense()
    cols = inst.src * spec.num_destinations + inst.dst
    viol = np.maximum(A[:, cols] @ x - b, 0).max()
    assert viol < 1e-3 * max(1.0, np.abs(b).max())


def test_continuation_beats_fixed_small_gamma():
    """Paper Fig. 5: gamma decay converges faster than fixed small gamma."""
    spec = MatchingInstanceSpec(num_sources=120, num_destinations=10, avg_degree=4.0, seed=12)
    packed, _ = normalize_rows(bucketize(generate_matching_instance(spec)))
    obj = MatchingObjective(packed)
    total = 240
    cont = Maximizer(
        obj, MaximizerConfig(gammas=(1.0, 0.1, 0.01), iters_per_stage=total // 3)
    ).solve()
    fixed = Maximizer(
        obj, MaximizerConfig(gammas=(0.01,), iters_per_stage=total)
    ).solve()
    # evaluate both final duals at the target gamma
    g_cont = float(obj.calculate(cont.lam, 0.01).g)
    g_fixed = float(obj.calculate(fixed.lam, 0.01).g)
    assert g_cont >= g_fixed - 1e-3 * abs(g_fixed)


def test_jacobi_preconditioning_tightens_spectrum():
    """Lemma B.1: row normalization drives sigma_max(A')^2 toward ~1."""
    spec = MatchingInstanceSpec(
        num_sources=150, num_destinations=12, avg_degree=4.0, scale_sigma=1.5, seed=13
    )
    packed = bucketize(generate_matching_instance(spec))
    scaled, _ = normalize_rows(packed)
    key = jax.random.key(0)
    s_raw = float(MatchingObjective(packed).power_iteration(key, 40))
    s_scaled = float(MatchingObjective(scaled).power_iteration(key, 40))
    # normalized spectrum is much tighter and O(1)
    assert s_scaled < s_raw
    assert s_scaled < 10.0


def test_warm_start_helps():
    spec = MatchingInstanceSpec(num_sources=80, num_destinations=8, avg_degree=3.0, seed=14)
    packed, _ = normalize_rows(bucketize(generate_matching_instance(spec)))
    obj = MatchingObjective(packed)
    cfg = MaximizerConfig(gammas=(0.01,), iters_per_stage=50)
    cold = Maximizer(obj, cfg).solve()
    warm = Maximizer(obj, cfg).solve(lam0=cold.lam)
    assert float(warm.stats[0].grad_norm[-1]) <= float(cold.stats[0].grad_norm[0])


def test_dual_gradient_is_exact():
    """eq. 4 gradient == autodiff gradient of g (Danskin's theorem)."""
    spec = MatchingInstanceSpec(num_sources=30, num_destinations=6, avg_degree=3.0, seed=15)
    packed, _ = normalize_rows(bucketize(generate_matching_instance(spec)))
    obj = MatchingObjective(packed)
    lam = jnp.asarray(np.random.default_rng(0).random(6).astype(np.float32))

    def g_of(lam_):
        return obj.calculate(lam_, 0.5).g

    auto = jax.grad(g_of)(lam)
    analytic = obj.calculate(lam, 0.5).grad
    np.testing.assert_allclose(np.asarray(auto), np.asarray(analytic), atol=1e-4)


def test_step_size_single_source_of_truth():
    """`Maximizer.step_size`, the module-level `step_size` helper, and the
    service engine's compiled solves must all produce the same step — the
    formula exists exactly once (warm/batched solves would silently diverge
    from one-shot solves if a copy drifted)."""
    from repro.core.maximizer import step_size
    from repro.service import compiled_solver

    spec = MatchingInstanceSpec(
        num_sources=40, num_destinations=6, avg_degree=3.0, seed=17
    )
    packed, _ = normalize_rows(bucketize(generate_matching_instance(spec)))
    cfg = MaximizerConfig(step_scale=0.7, iters_per_stage=10)
    m = Maximizer(MatchingObjective(packed), cfg)
    # the method IS the helper, across clipped and unclipped regimes
    for sigma_sq in (1e-8, 0.3, 4.0, 1e7):
        for gamma in cfg.gammas:
            np.testing.assert_array_equal(
                np.asarray(m.step_size(jnp.float32(sigma_sq), gamma)),
                np.asarray(step_size(cfg, jnp.float32(sigma_sq), gamma)),
            )
    # the service engine reports exactly the helper's steps for its sigma_sq
    raw = compiled_solver(cfg, False)(
        packed, jnp.zeros((packed.dual_dim,), jnp.float32)
    )
    expect = [
        float(step_size(cfg, raw.sigma_sq, g).astype(jnp.float32))
        for g in cfg.gammas
    ]
    np.testing.assert_allclose(np.asarray(raw.etas), expect, rtol=1e-7)
    # and Maximizer.solve's recorded steps agree with the helper too
    res = m.solve()
    for eta, gamma in zip(res.steps, cfg.gammas):
        np.testing.assert_allclose(
            eta, float(step_size(cfg, res.sigma_sq, gamma)), rtol=1e-7
        )


def test_adaptive_restart_no_worse():
    spec = MatchingInstanceSpec(num_sources=100, num_destinations=10, avg_degree=4.0, seed=16)
    packed, _ = normalize_rows(bucketize(generate_matching_instance(spec)))
    obj = MatchingObjective(packed)
    base = MaximizerConfig(gammas=(0.1,), iters_per_stage=150, adaptive_restart=False)
    rst = MaximizerConfig(gammas=(0.1,), iters_per_stage=150, adaptive_restart=True)
    g0 = float(Maximizer(obj, base).solve().g)
    g1 = float(Maximizer(obj, rst).solve().g)
    assert g1 >= g0 - 1e-4 * abs(g0)
