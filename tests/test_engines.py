"""Solver-engine subsystem: PDHG engine, dense fast path, selector, routing.

Covers the engine-subsystem acceptance criteria:

  * structured PDHG (bucketed and dense fast path) agrees with the seed COO
    path and with itself across fusion / density / restart variants;
  * the sort-free comparison-matrix simplex projection is exact against the
    sort-based reference;
  * warm-started cadences use fewer iterations than cold ones;
  * `EngineSelector` explores deterministically, routes to the cheaper
    engine, penalizes non-convergence, and survives a checkpoint round-trip;
  * `Scheduler` in ``engine="auto"`` mode routes at least one tenant to each
    engine on a mixed workload;
  * (slow) the sharded PDHG solve is shard-count invariant.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MaximizerConfig,
    PDHGConfig,
    from_edge_list,
    solve_pdhg,
)
from repro.core.projections import project_simplex, project_simplex_cmp
from repro.engines.base import ENGINES, resolve_engine
from repro.engines.pdhg import PDHGEngineConfig, _use_dense, pdhg_raw_solve
from repro.engines.selector import EngineSelector
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.service import Scheduler, ServiceConfig

from conftest import run_with_devices

SPEC = MatchingInstanceSpec(
    num_sources=60, num_destinations=10, avg_degree=4.0, seed=5
)
INST = generate_matching_instance(SPEC)
PACKED = bucketize(INST)
LAM0 = jnp.zeros(PACKED.dual_dim, jnp.float32)


def _solve(restart="none", dense="auto", fused=True, iters=20_000,
           lam0=None, sigma_sq=None, tol=1e-4):
    cfg = MaximizerConfig(
        gammas=(0.01,), iters_per_stage=iters, tol_grad=tol, check_every=50
    )
    pcfg = PDHGEngineConfig(restart=restart, dense=dense)
    return pdhg_raw_solve(
        PACKED, LAM0 if lam0 is None else lam0, cfg, normalize=False,
        fused_oracle=fused, sigma_sq=sigma_sq, pcfg=pcfg,
    )


# -- parity across engine variants -------------------------------------------


def test_dense_matches_bucketed():
    """The dense fast path is the same algorithm on a coalesced layout."""
    a = _solve(dense="off")
    b = _solve(dense="on")
    np.testing.assert_allclose(float(a.g), float(b.g), rtol=1e-5)
    rel = float(
        jnp.linalg.norm(a.lam - b.lam) / (1e-9 + jnp.linalg.norm(a.lam))
    )
    assert rel < 1e-4, rel
    # per-bucket slab shapes are preserved by the merge/split round trip
    assert tuple(x.shape for x in a.x_slabs) == tuple(
        x.shape for x in b.x_slabs
    )
    for xa, xb in zip(a.x_slabs, b.x_slabs):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb), atol=1e-4)


def test_fused_matches_unfused():
    a = _solve(dense="off", fused=False)
    b = _solve(dense="off", fused=True)
    np.testing.assert_allclose(float(a.g), float(b.g), rtol=1e-5)


def test_structured_matches_coo_seed():
    """Engine and seed COO path solve the same LP to the same objective."""
    coo = solve_pdhg(
        from_edge_list(INST), PDHGConfig(max_iters=40_000, tol=1e-5)
    )
    assert bool(coo.converged)
    eng = _solve(dense="auto", tol=1e-5)
    rel = abs(float(eng.g) - float(coo.primal_obj)) / abs(
        float(coo.primal_obj)
    )
    assert rel < 1e-3, (float(eng.g), float(coo.primal_obj))


@pytest.mark.parametrize("restart", ["ergodic", "adaptive", "halpern"])
def test_restart_schemes_converge(restart):
    plain = _solve(restart="none")
    res = _solve(restart=restart)
    np.testing.assert_allclose(float(res.g), float(plain.g), rtol=1e-3)
    assert int(res.restarts) > 0
    # restarts are why the schemes exist: adaptive must beat no-restart
    if restart == "adaptive":
        assert int(res.iters[0]) < int(plain.iters[0])


def test_warm_start_uses_fewer_iters():
    cold = _solve(restart="adaptive")
    warm = _solve(restart="adaptive", lam0=cold.lam, sigma_sq=cold.sigma_sq)
    assert int(warm.iters[0]) < int(cold.iters[0]), (
        int(warm.iters[0]), int(cold.iters[0]),
    )


# -- dense-path gating --------------------------------------------------------


def test_dense_gate_respects_config():
    buckets = PACKED.buckets
    J = SPEC.num_destinations
    assert _use_dense(buckets, J, PDHGEngineConfig(dense="on"))
    assert not _use_dense(buckets, J, PDHGEngineConfig(dense="off"))
    # the standard instance is far under the auto-mode cell budget
    assert _use_dense(buckets, J, PDHGEngineConfig(dense="auto"))
    # a tiny cell budget pushes auto back to the bucketed path
    assert not _use_dense(
        buckets, J, PDHGEngineConfig(dense="auto", dense_max_cells=8)
    )


def test_dense_config_validation():
    with pytest.raises(ValueError):
        PDHGEngineConfig(dense="sometimes")


# -- sort-free comparison-matrix projection -----------------------------------


@pytest.mark.parametrize("inequality", [True, False])
def test_project_simplex_cmp_matches_sort(rng, inequality):
    v = jnp.asarray(rng.normal(size=(40, 8)), jnp.float32)
    mask = jnp.asarray(rng.random((40, 8)) > 0.25, jnp.float32)
    mask = mask.at[:, 0].set(1.0)  # no empty rows
    ref = project_simplex(v, mask, inequality=inequality)
    got = project_simplex_cmp(v, mask, inequality=inequality)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-6)


def test_project_simplex_cmp_masked_and_feasible(rng):
    v = jnp.asarray(rng.normal(size=(16, 6)) - 2.0, jnp.float32)  # feasible
    mask = jnp.ones((16, 6), jnp.float32)
    out = project_simplex_cmp(v, mask)
    # strictly-interior points are fixed points of the inequality projection
    np.testing.assert_allclose(
        np.asarray(out), np.maximum(np.asarray(v), 0.0), atol=1e-6
    )
    # masked slots never receive mass
    mask = mask.at[:, 3:].set(0.0)
    out = project_simplex_cmp(
        jnp.asarray(rng.normal(size=(16, 6)) + 5.0, jnp.float32), mask
    )
    assert float(jnp.abs(out[:, 3:]).max()) == 0.0
    np.testing.assert_allclose(
        np.asarray(out.sum(-1)), 1.0, atol=1e-5
    )


def test_project_simplex_cmp_grad_matches_sort(rng):
    v = jnp.asarray(rng.normal(size=(12, 5)), jnp.float32)
    mask = jnp.ones((12, 5), jnp.float32)

    def loss_ref(u):
        return jnp.sum(project_simplex(u, mask) ** 2)

    def loss_cmp(u):
        return jnp.sum(project_simplex_cmp(u, mask) ** 2)

    g_ref = jax.grad(loss_ref)(v)
    g_cmp = jax.grad(loss_cmp)(v)
    np.testing.assert_allclose(
        np.asarray(g_cmp), np.asarray(g_ref), atol=1e-5
    )


# -- engine selector ----------------------------------------------------------


def test_selector_exploration_is_deterministic_rotation():
    sel = EngineSelector()
    orders = {t: sel.exploration_order(t) for t in ("a", "b", "c", "d")}
    for t, order in orders.items():
        assert sorted(order) == sorted(ENGINES)
        assert sel.exploration_order(t) == order  # stable
    # crc32 rotation spreads tenants across starting engines
    starts = {order[0] for order in orders.values()}
    assert starts == set(ENGINES)


def test_selector_routes_to_cheaper_engine():
    sel = EngineSelector(explore_cadences=1)
    t = "tenant"
    first, second = sel.exploration_order(t)
    assert sel.choose(t) == first
    sel.observe(t, first, iters=900, converged=True)
    assert sel.choose(t) == second  # still exploring
    sel.observe(t, second, iters=200, converged=True)
    assert sel.choose(t) == second  # cheaper engine wins
    # drift: the cheap engine degrades, routing migrates after decay
    for _ in range(8):
        sel.observe(t, second, iters=5000, converged=True)
    assert sel.choose(t) == first


def test_selector_penalizes_non_convergence():
    sel = EngineSelector(explore_cadences=1, penalty=2.0)
    t = "x"
    e0, e1 = sel.exploration_order(t)
    sel.observe(t, e0, iters=1000, converged=False)  # scores 2000
    sel.observe(t, e1, iters=1500, converged=True)  # scores 1500
    assert sel.choose(t) == e1


def test_selector_checkpoint_round_trip():
    sel = EngineSelector(decay=0.5, explore_cadences=2, penalty=3.0)
    for t in ("a", "b"):
        for e in ENGINES:
            sel.observe(t, e, iters=100 if e == "agd" else 400,
                        converged=True)
    clone = EngineSelector()
    clone.load_state(sel.state_dict())
    assert clone.state_dict() == sel.state_dict()
    for t in ("a", "b", "never-seen"):
        assert clone.choose(t) == sel.choose(t)


def test_selector_rejects_unknown_engine():
    sel = EngineSelector()
    with pytest.raises(ValueError):
        sel.observe("t", "simplex", iters=10, converged=True)
    with pytest.raises(ValueError):
        EngineSelector(decay=1.0)


# -- engine registry ----------------------------------------------------------


def test_resolve_engine_registry():
    for name in ENGINES:
        assert resolve_engine(name).name == name
    with pytest.raises(ValueError):
        resolve_engine("auto")  # a policy, not an engine


# -- scheduler auto routing ---------------------------------------------------


def test_scheduler_auto_routes_to_both_engines():
    """Mixed workload in auto mode exercises both engines from cadence 0."""
    cfg = ServiceConfig(
        cold=MaximizerConfig(
            iters_per_stage=400, tol_grad=1e-3, tol_viol=1e-3, check_every=50
        ),
        engine="auto",
    )
    sched = Scheduler(cfg)
    # pick tenant names whose crc32 rotations start on different engines
    names, starts = [], set()
    i = 0
    while len(names) < 4 and i < 64:
        name = f"tenant-{i}"
        start = sched.engine_selector.exploration_order(name)[0]
        if len(names) < 2 or start not in starts or len(starts) == 2:
            names.append(name)
            starts.add(start)
        i += 1
    assert starts == set(ENGINES)
    for name in names:
        sched.add_tenant(name, INST)
    out = sched.run_cadence()
    routed = {out.reports[name]["engine"] for name in names}
    assert routed == set(ENGINES), routed
    # observations landed: the selector now has a score per routed engine
    state = sched.state_dict()[1]["engine_selector"]
    assert all(len(state["counts"][name]) >= 1 for name in names)


def test_scheduler_selector_survives_checkpoint():
    cfg = ServiceConfig(
        cold=MaximizerConfig(
            iters_per_stage=400, tol_grad=1e-3, tol_viol=1e-3, check_every=50
        ),
        engine="auto",
    )
    sched = Scheduler(cfg)
    sched.add_tenant("t0", INST)
    sched.run_cadence()
    arrays, meta = sched.state_dict()
    assert "engine_selector" in meta

    restored = Scheduler(cfg)
    restored.add_tenant("t0", INST)
    restored.load_state(arrays, meta)
    assert (
        restored.engine_selector.state_dict()
        == sched.engine_selector.state_dict()
    )


# -- distributed parity (slow tier) ------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("shards", [1, 2, 8])
def test_pdhg_sharded_matches_single_device(shards):
    out = run_with_devices(
        f"""
import jax, jax.numpy as jnp
from repro import compat
from repro.core import MaximizerConfig
from repro.engines.pdhg import PDHGEngineConfig, pdhg_raw_solve, solve_pdhg_sharded
from repro.instances import MatchingInstanceSpec, bucketize, generate_matching_instance

spec = MatchingInstanceSpec(num_sources=60, num_destinations=10, avg_degree=4.0, seed=5)
inst = generate_matching_instance(spec)
packed = bucketize(inst, shard_multiple={shards})
cfg = MaximizerConfig(gammas=(0.01,), iters_per_stage=4000, tol_grad=1e-4, check_every=50)
pcfg = PDHGEngineConfig(restart="adaptive")
lam0 = jnp.zeros(packed.dual_dim, jnp.float32)
single = pdhg_raw_solve(packed, lam0, cfg, normalize=False, fused_oracle=True, pcfg=pcfg)
mesh = compat.make_mesh(({shards},), ("data",), devices=jax.devices()[:{shards}])
res = solve_pdhg_sharded(packed, mesh, cfg, pcfg=pcfg, lam0=lam0)
print(float(single.g), float(res.g))
""",
        n_devices=8,
    )
    g_single, g_sharded = map(float, out.split())
    np.testing.assert_allclose(g_sharded, g_single, rtol=1e-3)
