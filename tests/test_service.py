"""Recurring-solve service: early stop, warm starts, batching, shape guards."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Maximizer,
    MaximizerConfig,
    MatchingObjective,
    RecurringSolver,
    normalize_rows,
)
from repro.instances import (
    DeltaIngestor,
    InstanceDelta,
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
)
from repro.service import (
    BatchedSolvePool,
    Scheduler,
    ServiceConfig,
    SolveSession,
    compiled_solver,
    shape_signature,
    stack_instances,
    to_solve_result,
)

SPEC = MatchingInstanceSpec(
    num_sources=120, num_destinations=10, avg_degree=4.0, seed=21
)
BASE = generate_matching_instance(SPEC)

COLD = MaximizerConfig(iters_per_stage=120, tol_grad=1e-4, tol_viol=1e-4)
SERVICE = ServiceConfig(
    cold=COLD, warm_gammas=(0.1, 0.01), drift_sla_rel=0.5, row_headroom=4
)


def _perturb_delta(edge_list, rng, frac=0.1):
    n = max(1, int(frac * edge_list.nnz))
    idx = rng.permutation(edge_list.nnz)[:n]
    return InstanceDelta(
        update_src=edge_list.src[idx],
        update_dst=edge_list.dst[idx],
        update_values=edge_list.values[idx] * rng.uniform(0.9, 1.1, n),
    )


# -- early stopping ----------------------------------------------------------


def test_early_stop_matches_full_budget():
    packed, _ = normalize_rows(bucketize(BASE))
    obj = MatchingObjective(packed)
    full = Maximizer(obj, MaximizerConfig(iters_per_stage=120)).solve()
    es_cfg = MaximizerConfig(
        iters_per_stage=120, tol_grad=1e-4, tol_viol=1e-4, check_every=20
    )
    es = Maximizer(obj, es_cfg).solve()
    assert es.iters_used is not None
    assert es.total_iters_used <= es_cfg.total_iter_budget
    # stopped solve reaches the full-budget solution quality
    np.testing.assert_allclose(float(es.g), float(full.g), rtol=1e-4)
    assert float(es.stats[-1].max_violation[-1]) <= max(
        2 * float(full.stats[-1].max_violation[-1]), 2e-4
    )


def test_early_stop_saves_iterations_when_warm():
    packed, _ = normalize_rows(bucketize(BASE))
    obj = MatchingObjective(packed)
    cfg = MaximizerConfig(
        gammas=(0.1, 0.01), iters_per_stage=200, tol_grad=1e-4, tol_viol=1e-4
    )
    cold = Maximizer(obj, MaximizerConfig(iters_per_stage=200)).solve()
    warm = Maximizer(obj, cfg).solve(lam0=cold.lam)
    assert warm.total_iters_used < cfg.total_iter_budget
    np.testing.assert_allclose(float(warm.g), float(cold.g), rtol=1e-4)


# -- sessions: warm starts + drift reports ------------------------------------


def test_session_warm_start_fewer_iters_same_quality():
    rng = np.random.default_rng(3)
    sess = SolveSession("t0", BASE, SERVICE)
    _, rep0 = sess.solve()
    assert rep0["mode"] == "cold" and rep0["cold_reason"] == "first_solve"
    sess.ingest(_perturb_delta(BASE, rng))
    res1, rep1 = sess.solve()
    assert rep1["mode"] == "warm"
    assert rep1["drift_rel"] is not None and rep1["drift_bound"] is not None
    assert rep1["sla_ok"] is not None
    # reference: cold full-budget solve of the SAME mutated instance
    z = np.zeros(sess.instance().dual_dim, np.float32)
    ref = to_solve_result(
        compiled_solver(MaximizerConfig(iters_per_stage=120), True)(
            sess.instance(), z
        )
    )
    rel = abs(rep1["g"] - float(ref.g)) / max(abs(float(ref.g)), 1e-9)
    assert rel < 1e-3, (rep1["g"], float(ref.g))
    assert rep1["iters_used"] < 6 * 120  # fewer than the cold budget


def test_drift_sla_bound_monotone_and_zero_delta_zero_drift():
    """Drift-SLA regression: the analytic gamma bound reported by
    `SolveSession` is monotone in the observed `dc_norm`, and a zero-delta
    cadence reports zero cost drift (and ~zero primal churn)."""
    from repro.core import drift_bound

    # analytic monotonicity of the bound itself, all else fixed
    bounds = [
        drift_bound(0.01, dc, dlam_norm=0.3, sigma_max=2.0)
        for dc in (0.0, 0.1, 1.0, 5.0)
    ]
    assert all(b1 > b0 for b0, b1 in zip(bounds, bounds[1:])), bounds

    sess = SolveSession("t0", BASE, SERVICE)
    sess.solve()

    # zero-delta cadence: nothing ingested, so no cost drift and the primal
    # churn is solver noise only (the warm solve re-runs from converged duals)
    _, rep0 = sess.solve()
    assert rep0["dc_norm"] == 0.0
    assert rep0["drift_rel"] is not None and rep0["drift_rel"] <= 1e-4
    assert rep0["drift_bound"] is not None

    # cadences with the same update set at growing perturbation scales:
    # dc_norm must grow, and the reported analytic bound must track it
    rng = np.random.default_rng(11)
    edge = sess.ingestor.to_edge_list()
    n = max(1, edge.nnz // 10)
    idx = rng.permutation(edge.nnz)[:n]
    reports = []
    for scale in (0.01, 2.0):
        # update-only deltas leave the topology unchanged, so `idx` stays a
        # valid edge selection across cadences
        cur = sess.ingestor.to_edge_list()
        sess.ingest(
            InstanceDelta(
                update_src=cur.src[idx],
                update_dst=cur.dst[idx],
                update_values=cur.values[idx]
                * (1.0 + scale * rng.uniform(0.5, 1.0, idx.size)),
            )
        )
        _, rep = sess.solve()
        reports.append(rep)
    assert reports[0]["dc_norm"] < reports[1]["dc_norm"], reports
    assert rep0["dc_norm"] < reports[0]["dc_norm"]
    assert reports[0]["drift_bound"] < reports[1]["drift_bound"], reports


def test_session_shape_drift_guard():
    sess = SolveSession("t0", BASE, SERVICE)
    sess.solve()
    # corrupt the cached duals as if the instance had been resized
    sess.lam_prev = jnp.zeros((sess.instance().dual_dim + 3,), jnp.float32)
    _, rep = sess.solve()
    assert rep["mode"] == "cold"
    assert rep["cold_reason"] == "dual_dim_drift"


def test_recurring_solver_shape_drift_guard():
    cfg = MaximizerConfig(gammas=(0.1,), iters_per_stage=30)
    rs = RecurringSolver(cfg)
    rs.solve(bucketize(BASE))
    other = generate_matching_instance(
        dataclasses.replace(SPEC, num_destinations=14, seed=22)
    )
    res, rep = rs.solve(bucketize(other))  # must not crash on stale duals
    assert rep["cold_start_reason"] == "dual_dim_drift"
    assert res.lam.shape == (14,)


# -- batched pool -------------------------------------------------------------


def _tenant_instances(n=4):
    rng = np.random.default_rng(7)
    out = []
    for _ in range(n):
        ing = DeltaIngestor(BASE, row_headroom=4)
        ing.apply(_perturb_delta(BASE, rng))
        out.append(ing.instance())
    return out


def test_batched_pool_matches_sequential():
    insts = _tenant_instances(4)
    assert len({shape_signature(i) for i in insts}) == 1
    pool = BatchedSolvePool(COLD, normalize=True)
    batched = pool.solve(insts)
    seq_fn = compiled_solver(COLD, True)
    z = np.zeros(insts[0].dual_dim, np.float32)
    for inst, b in zip(insts, batched):
        s = to_solve_result(seq_fn(inst, z))
        rel = abs(float(b.g) - float(s.g)) / max(abs(float(s.g)), 1e-9)
        assert rel < 1e-3, (float(b.g), float(s.g))
        np.testing.assert_allclose(
            np.asarray(b.lam), np.asarray(s.lam), atol=5e-2
        )


def test_stack_instances_rejects_mismatched_shapes():
    insts = _tenant_instances(2)
    other = bucketize(
        generate_matching_instance(dataclasses.replace(SPEC, seed=33))
    )
    if shape_signature(other) == shape_signature(insts[0]):
        pytest.skip("seeds produced identical shapes")
    with pytest.raises(ValueError):
        stack_instances([insts[0], other])


# -- scheduler ----------------------------------------------------------------


def test_scheduler_batches_and_reports():
    rng = np.random.default_rng(11)
    sched = Scheduler(SERVICE)
    for t in range(4):
        sched.add_tenant(f"t{t}", BASE)
    out0 = sched.run_cadence()
    assert sorted(sum(out0.batched_groups, [])) == ["t0", "t1", "t2", "t3"]
    assert all(r["mode"] == "cold" for r in out0.reports.values())
    for cadence in (1, 2):
        deltas = {
            name: _perturb_delta(s.ingestor.to_edge_list(), rng)
            for name, s in sched.sessions.items()
        }
        out = sched.run_cadence(deltas)
        assert len(out.batched_groups) == 1  # shapes stayed identical
        for r in out.reports.values():
            assert r["mode"] == "warm"
            assert r["batched"]
            assert r["drift_rel"] is not None
            assert r["iters_used"] <= SERVICE.warm.total_iter_budget
    # warm cadences must use fewer iterations than the cold bootstrap budget
    assert all(
        r["iters_used"] < SERVICE.cold.total_iter_budget
        for r in out.reports.values()
    )


# -- device residency ---------------------------------------------------------


def test_device_resident_transfer_is_o_delta():
    """First solve uploads O(nnz); delta cadences upload only the plan bytes."""
    rng = np.random.default_rng(17)
    sess = SolveSession("t0", BASE, SERVICE)
    _, rep0 = sess.solve()
    assert rep0["upload_mode"] == "full"
    sess.ingest(_perturb_delta(BASE, rng, frac=0.05))
    _, rep1 = sess.solve()
    assert rep1["upload_mode"] == "scatter"
    assert rep1["upload_bytes"] < rep0["upload_bytes"] / 5
    # no delta -> no transfer at all
    _, rep2 = sess.solve()
    assert rep2["upload_mode"] == "none" and rep2["upload_bytes"] == 0


def test_device_copy_resyncs_after_external_mutation():
    """Host mutations that bypass the session force a full re-upload, not stale reuse."""
    rng = np.random.default_rng(19)
    sess = SolveSession("t0", BASE, SERVICE)
    sess.solve()
    # mutate the host ingestor directly (no plan queued on the session)
    sess.ingestor.apply(_perturb_delta(BASE, rng))
    dev = sess.device_instance()
    assert sess.last_transfer["mode"] == "full"
    host = sess.instance()
    for db, hb in zip(dev.buckets, host.buckets):
        np.testing.assert_array_equal(np.asarray(db.cost), hb.cost)


# -- pipelined cadences -------------------------------------------------------


def _fresh_sched(n=4):
    sched = Scheduler(SERVICE)
    for t in range(n):
        sched.add_tenant(f"t{t}", BASE)
    return sched


def _cadence_deltas(n_tenants=4, cadences=2, seed=43, frac=0.1):
    # update-only deltas against the shared BASE topology: applying the same
    # dicts to two schedulers leaves both in identical states
    out = [None]
    for c in range(cadences):
        rng = np.random.default_rng(seed + c)
        out.append(
            {f"t{t}": _perturb_delta(BASE, rng, frac) for t in range(n_tenants)}
        )
    return out


def test_pipeline_matches_sequential_cadences():
    """Double-buffered run_pipeline == run_cadence loop, report for report."""
    deltas = _cadence_deltas()
    outs_p = _fresh_sched().run_pipeline(deltas)
    sched_s = _fresh_sched()
    outs_s = [sched_s.run_cadence(d) for d in deltas]
    assert len(outs_p) == len(outs_s) == 3
    assert outs_p[1].overlapped and outs_p[2].overlapped
    for op, os_ in zip(outs_p, outs_s):
        assert not op.ingest_errors
        assert sorted(sum(op.batched_groups, [])) == sorted(
            sum(os_.batched_groups, [])
        )
        for name in op.reports:
            assert op.reports[name]["g"] == os_.reports[name]["g"]
            assert op.reports[name]["mode"] == os_.reports[name]["mode"]
            assert (
                op.reports[name]["iters_used"]
                == os_.reports[name]["iters_used"]
            )
            # drift accounting must not leak across the overlap: the cost
            # drift ingested for cadence t+1 belongs to t+1's report
            assert op.reports[name]["dc_norm"] == os_.reports[name]["dc_norm"]
            assert (
                op.reports[name]["drift_bound"]
                == os_.reports[name]["drift_bound"]
            )


def _structural_delta(seed, n=3):
    """Inserts + deletes against the BASE topology (moves slab rows)."""
    J = BASE.spec.num_destinations
    r = np.random.default_rng(seed)
    dele = r.permutation(BASE.nnz)[:n]
    existing = set((BASE.src * J + BASE.dst).tolist())
    ins_s, ins_d = [], []
    while len(ins_s) < n:
        s, d = int(r.integers(BASE.spec.num_sources)), int(r.integers(J))
        if s * J + d not in existing:
            existing.add(s * J + d)
            ins_s.append(s)
            ins_d.append(d)
    return InstanceDelta(
        insert_src=ins_s,
        insert_dst=ins_d,
        insert_values=r.uniform(0.1, 2.0, n),
        insert_coeff=r.uniform(0.1, 2.0, (1, n)),
        delete_src=BASE.src[dele],
        delete_dst=BASE.dst[dele],
    )


def test_pipeline_structural_overlap_drift_parity():
    """Overlapped ingest of row-moving deltas must not corrupt drift metering.

    Cadence 2's inserts/deletes mutate the occupancy maps WHILE cadence 1's
    results are still in flight; cadence 1's drift must be metered with the
    maps its solve was dispatched under, identical to the sequential driver.
    """
    deltas = [
        None,
        _cadence_deltas(cadences=1, seed=61)[1],
        {f"t{t}": _structural_delta(73 + t) for t in range(4)},
    ]
    outs_p = _fresh_sched().run_pipeline(deltas)
    sched_s = _fresh_sched()
    outs_s = [sched_s.run_cadence(d) for d in deltas]
    for op, os_ in zip(outs_p, outs_s):
        assert not op.ingest_errors
        for name in op.reports:
            for k in ("g", "dc_norm", "drift_l2", "drift_rel", "drift_bound"):
                assert op.reports[name][k] == os_.reports[name][k], (name, k)


def test_rejected_delta_mid_overlap_leaks_nothing():
    """A delta rejected during the overlap leaves zero partial state behind.

    The poisoned tenant must solve cadence 1 on its UNCHANGED instance —
    identical (bitwise) to a run that never submitted the bad delta — while
    healthy tenants' deltas still apply.
    """
    J = BASE.spec.num_destinations
    s0 = int(BASE.src[0])
    missing = next(
        d for d in range(J) if d not in set(BASE.dst[BASE.src == s0].tolist())
    )
    # valid updates for t1..t3 + a delete of a nonexistent edge for t0,
    # sequenced AFTER a valid delete so partial application would be visible
    good = _cadence_deltas(seed=47)[1]
    bad = InstanceDelta(
        delete_src=[int(BASE.src[1]), s0],
        delete_dst=[int(BASE.dst[1]), missing],
    )
    deltas = [None, {**good, "t0": bad}]
    sched = _fresh_sched()
    outs = sched.run_pipeline(deltas)
    assert "t0" in outs[1].ingest_errors
    assert "not present" in outs[1].ingest_errors["t0"]
    assert sched.sessions["t0"].ingestor.generation == 0  # nothing applied
    # reference: same run with t0 simply submitting no delta
    ref = _fresh_sched()
    ref_outs = ref.run_pipeline([None, {k: v for k, v in good.items() if k != "t0"}])
    assert outs[1].reports["t0"]["g"] == ref_outs[1].reports["t0"]["g"]
    # healthy tenants were not blocked by t0's rejection
    for t in ("t1", "t2", "t3"):
        assert t in outs[1].ingest
        assert outs[1].reports[t]["g"] == ref_outs[1].reports[t]["g"]


# -- power-iteration reuse + fused oracle ------------------------------------


def test_sigma_reuse_on_quiet_warm_cadence():
    """Sub-threshold drift: the warm solve reuses yesterday's sigma_sq
    (skipping the power iteration) with the same solution quality; large
    drift and re-bucketizes recompute."""
    rng = np.random.default_rng(7)
    cfg = dataclasses.replace(SERVICE, sigma_reuse_dc_threshold=1.0)
    sess = SolveSession("t0", BASE, cfg)
    res0, rep0 = sess.solve()
    assert rep0["sigma_reused"] is False  # cold always recomputes
    # tiny cost perturbation -> dc below threshold -> reuse
    sess.ingest(_perturb_delta(BASE, rng, frac=0.02))
    assert sess.last_ingest.in_place
    res1, rep1 = sess.solve()
    assert rep1["mode"] == "warm" and rep1["dc_norm"] <= 1.0
    assert rep1["sigma_reused"] is True
    assert float(res1.sigma_sq) == float(res0.sigma_sq)  # echoed, not recomputed
    # the reused-sigma solve still reaches the non-reuse solution
    twin = SolveSession("twin", BASE, SERVICE)
    twin.solve()
    twin.ingest(_perturb_delta(generate_matching_instance(SPEC),
                               np.random.default_rng(7), frac=0.02))
    res_ref, rep_ref = twin.solve()
    assert rep_ref["sigma_reused"] is False
    rel = abs(rep1["g"] - rep_ref["g"]) / max(abs(rep_ref["g"]), 1e-9)
    assert rel < 1e-3, (rep1["g"], rep_ref["g"])
    # large drift -> recompute
    big = InstanceDelta(
        update_src=BASE.src[:1], update_dst=BASE.dst[:1],
        update_values=[float(BASE.values[0]) + 100.0],
    )
    sess.ingest(big)
    _, rep2 = sess.solve()
    assert rep2["sigma_reused"] is False and rep2["dc_norm"] > 1.0
    # next quiet cadence reuses again (sigma refreshed by the recompute)
    sess.ingest(_perturb_delta(sess.ingestor.to_edge_list(), rng, frac=0.02))
    if sess.last_ingest.in_place:
        _, rep3 = sess.solve()
        assert rep3["sigma_reused"] is True


def test_sigma_reuse_disabled_without_threshold():
    rng = np.random.default_rng(11)
    sess = SolveSession("t0", BASE, SERVICE)  # threshold None
    sess.solve()
    sess.ingest(_perturb_delta(BASE, rng, frac=0.02))
    _, rep = sess.solve()
    assert rep["mode"] == "warm" and rep["sigma_reused"] is False


def test_sigma_reuse_survives_checkpoint_roundtrip():
    rng = np.random.default_rng(13)
    cfg = dataclasses.replace(SERVICE, sigma_reuse_dc_threshold=1.0)
    sess = SolveSession("t0", BASE, cfg)
    sess.solve()
    arrays, meta = sess.state_dict()
    back = SolveSession.from_state(cfg, arrays, meta)
    back.ingest(_perturb_delta(BASE, rng, frac=0.02))
    _, rep = back.solve()
    assert rep["mode"] == "warm"
    assert rep["sigma_reused"] is True  # sigma cache restored with the session


def test_session_fused_oracle_matches_unfused():
    """ServiceConfig.fused_oracle: same cadence trajectory as the unfused
    engine (identical off-TPU, where the oracle dispatches to the fused
    reference path)."""
    rng = np.random.default_rng(17)
    a = SolveSession("a", BASE, SERVICE)
    b = SolveSession(
        "b", BASE, dataclasses.replace(SERVICE, fused_oracle=True)
    )
    _, rep_a0 = a.solve()
    _, rep_b0 = b.solve()
    assert rep_a0["g"] == rep_b0["g"]
    delta = _perturb_delta(BASE, rng)
    a.ingest(delta)
    b.ingest(delta)
    _, rep_a1 = a.solve()
    _, rep_b1 = b.solve()
    assert rep_a1["mode"] == rep_b1["mode"] == "warm"
    assert rep_a1["g"] == rep_b1["g"]
    assert rep_a1["iters_used"] == rep_b1["iters_used"]


def test_batched_pool_fused_oracle_matches_sequential():
    """vmapped fused-oracle pool == per-tenant unfused solves."""
    insts = _tenant_instances(3)
    cfg = MaximizerConfig(iters_per_stage=60)
    pool = BatchedSolvePool(cfg, fused_oracle=True)
    batch = pool.solve(insts)
    z = np.zeros(insts[0].dual_dim, np.float32)
    for inst, rb in zip(insts, batch):
        solo = to_solve_result(compiled_solver(cfg)(inst, z))
        rel = abs(float(rb.g) - float(solo.g)) / max(abs(float(solo.g)), 1e-9)
        assert rel < 1e-3, (float(rb.g), float(solo.g))
        np.testing.assert_allclose(
            np.asarray(rb.lam), np.asarray(solo.lam), atol=5e-2
        )


def test_sigma_reuse_invalidated_by_coeff_and_structural_edits():
    """Coefficient updates meter no cost drift but DO change A: they (and
    inserts/deletes) must invalidate the sigma cache even at dc_norm ~ 0."""
    cfg = dataclasses.replace(SERVICE, sigma_reuse_dc_threshold=1e6)
    sess = SolveSession("t0", BASE, cfg)
    sess.solve()
    # coefficient-only update: dc_norm contribution is zero
    sess.ingest(InstanceDelta(
        update_src=BASE.src[:1], update_dst=BASE.dst[:1],
        update_coeff=np.asarray([[7.5]]),
    ))
    assert sess.last_ingest.in_place
    _, rep = sess.solve()
    assert rep["mode"] == "warm"
    assert rep["sigma_reused"] is False  # A changed -> recompute
    # cost-only update afterwards: cache fresh again -> reuse
    sess.ingest(InstanceDelta(
        update_src=BASE.src[:1], update_dst=BASE.dst[:1],
        update_values=[float(BASE.values[0]) + 0.01],
    ))
    _, rep2 = sess.solve()
    assert rep2["sigma_reused"] is True


def test_scheduler_solo_path_reuses_sigma():
    """The scheduler's non-batched dispatch honors sigma_reuse_dc_threshold."""
    rng = np.random.default_rng(19)
    cfg = dataclasses.replace(SERVICE, sigma_reuse_dc_threshold=1e6)
    sched = Scheduler(cfg)
    sched.add_tenant("t0", BASE)  # single tenant -> always solo
    out0 = sched.run_cadence()
    assert out0.reports["t0"]["sigma_reused"] is False  # cold
    out1 = sched.run_cadence({"t0": _perturb_delta(BASE, rng, frac=0.05)})
    assert out1.solo_tenants == ["t0"]
    assert out1.reports["t0"]["mode"] == "warm"
    assert out1.reports["t0"]["sigma_reused"] is True


# -- PR 8 bugfix regressions -------------------------------------------------


def test_drift_sla_reports_resize_unbounded():
    """BUGFIX: a dual-dim resize used to contribute dlam = 0 to the drift
    bound, making the one cadence guaranteed to churn every allocation look
    like the quietest of the day.  The resized cadence must flag itself and
    report an unbounded drift bound instead of a bogus finite one."""
    rng = np.random.default_rng(23)
    sess = SolveSession("t0", BASE, SERVICE)
    _, rep0 = sess.solve()
    assert rep0["dual_resized"] is False
    sess.ingest(_perturb_delta(BASE, rng, frac=0.05))
    # simulate a checkpoint from a different packing (resized dual space)
    sess.lam_prev = jnp.zeros((sess.instance().dual_dim + 3,), jnp.float32)
    _, rep = sess.solve()
    assert rep["mode"] == "cold" and rep["cold_reason"] == "dual_dim_drift"
    assert rep["dual_resized"] is True
    assert rep["drift_bound"] == float("inf")  # NOT a finite dlam=0 bound
    # the measured drift is still reported; only the analytic bound is void
    assert rep["drift_rel"] is not None and np.isfinite(rep["drift_rel"])


def test_sigma_cache_dirtied_on_offline_mutated_restore():
    """BUGFIX: `from_state` used to trust the checkpointed sigma-clean flag
    blindly, so an instance mutated out-of-band (an offline job restores the
    ingestor, applies an A-touching delta and writes the arrays back without
    touching the session meta) restored with a sigma estimate for a matrix
    that no longer exists.  The restore must prove the saved generation
    matches the restored ingestor's before reusing sigma."""
    rng = np.random.default_rng(29)
    cfg = dataclasses.replace(SERVICE, sigma_reuse_dc_threshold=1e6)
    sess = SolveSession("t0", BASE, cfg)
    sess.solve()
    arrays, meta = sess.state_dict()
    # offline delta: bumps the persisted ingestor generation, meta untouched
    ing = DeltaIngestor.from_state(
        {
            k[len("ingestor."):]: v
            for k, v in arrays.items()
            if k.startswith("ingestor.")
        },
        meta["ingestor"],
    )
    ing.apply(InstanceDelta(
        update_src=BASE.src[:1], update_dst=BASE.dst[:1],
        update_coeff=np.asarray([[9.0]]),
    ))
    off_arrays, _ = ing.state_dict()
    arrays.update({f"ingestor.{k}": v for k, v in off_arrays.items()})
    back = SolveSession.from_state(cfg, arrays, meta)
    # quiet cost-only cadence: would reuse sigma if the cache were trusted
    back.ingest(_perturb_delta(BASE, rng, frac=0.02))
    _, rep = back.solve()
    assert rep["mode"] == "warm"
    assert rep["sigma_reused"] is False  # stale estimate must not be echoed
