"""Delta ingestion: in-place slab surgery == re-bucketizing the mutated edges."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MatchingObjective
from repro.instances import (
    DeltaIngestor,
    InstanceDelta,
    MatchingInstanceSpec,
    apply_delta_to_edge_list,
    bucketize,
    generate_matching_instance,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep; the property test self-skips
    HAVE_HYPOTHESIS = False


def _instance(seed=5, I=150, J=12, m=2):
    spec = MatchingInstanceSpec(
        num_sources=I, num_destinations=J, avg_degree=4.0,
        num_families=m, seed=seed,
    )
    return generate_matching_instance(spec)


def _random_delta(ref, rng, n_upd=15, n_del=6, n_ins=6, rhs=True):
    m, J, I = ref.spec.num_families, ref.spec.num_destinations, ref.spec.num_sources
    perm = rng.permutation(ref.nnz)
    upd, dele = perm[:n_upd], perm[n_upd : n_upd + n_del]
    existing = set((ref.src * J + ref.dst).tolist())
    ins_s, ins_d = [], []
    while len(ins_s) < n_ins:
        s, d = int(rng.integers(I)), int(rng.integers(J))
        if s * J + d not in existing:
            existing.add(s * J + d)
            ins_s.append(s)
            ins_d.append(d)
    return InstanceDelta(
        insert_src=ins_s, insert_dst=ins_d,
        insert_values=rng.uniform(0.1, 5.0, n_ins),
        insert_coeff=rng.uniform(0.1, 2.0, (m, n_ins)),
        delete_src=ref.src[dele], delete_dst=ref.dst[dele],
        update_src=ref.src[upd], update_dst=ref.dst[upd],
        update_values=rng.uniform(0.1, 5.0, n_upd),
        update_coeff=rng.uniform(0.1, 2.0, (m, n_upd)),
        rhs=np.asarray(ref.rhs) * rng.uniform(0.9, 1.1, ref.rhs.size)
        if rhs
        else None,
    )


def test_delta_equivalence_over_days():
    """Ingested slabs == bucketize(edge list with the same deltas), objective-wise."""
    rng = np.random.default_rng(0)
    base = _instance()
    ing = DeltaIngestor(base, row_headroom=4)
    ref = base
    lam = jnp.asarray(
        rng.random(base.spec.num_families * base.spec.num_destinations).astype(
            np.float32
        )
    )
    saw_in_place = saw_fallback = False
    for day in range(5):
        delta = _random_delta(ref, rng)
        rep = ing.apply(delta)
        saw_in_place |= rep.in_place
        saw_fallback |= rep.rebucketized
        ref = apply_delta_to_edge_list(ref, delta)
        # exact edge-list equality
        cur = ing.to_edge_list()
        np.testing.assert_array_equal(cur.src, ref.src)
        np.testing.assert_array_equal(cur.dst, ref.dst)
        np.testing.assert_allclose(cur.values, ref.values, rtol=1e-6)
        np.testing.assert_allclose(cur.coeff, ref.coeff, rtol=1e-6)
        np.testing.assert_allclose(cur.rhs, ref.rhs)
        # objective equivalence vs a fresh pack of the mutated edge list
        ev_a = MatchingObjective(ing.instance()).calculate(lam, 0.1)
        ev_b = MatchingObjective(bucketize(ref)).calculate(lam, 0.1)
        np.testing.assert_allclose(float(ev_a.g), float(ev_b.g), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(ev_a.grad), np.asarray(ev_b.grad), atol=1e-4
        )
    assert saw_in_place  # the headroom actually absorbed some days


def test_in_place_preserves_shapes():
    rng = np.random.default_rng(1)
    base = _instance(seed=7)
    ing = DeltaIngestor(base, row_headroom=8)
    shapes0 = [(b.rows, b.length) for b in ing.instance().buckets]
    rep = ing.apply(_random_delta(base, rng, n_ins=2, n_del=2))
    if rep.in_place:
        assert [(b.rows, b.length) for b in ing.instance().buckets] == shapes0
        assert not rep.shapes_changed


def test_overflow_falls_back_to_rebucketize():
    base = _instance(seed=9, m=1)
    ing = DeltaIngestor(base)  # no headroom
    J = base.spec.num_destinations
    # give source 0 an edge to every destination: exceeds any current slab
    have = set(base.dst[base.src == 0].tolist())
    new_d = [d for d in range(J) if d not in have]
    rep = ing.apply(
        InstanceDelta(
            insert_src=[0] * len(new_d), insert_dst=new_d,
            insert_values=np.ones(len(new_d)),
            insert_coeff=np.ones((1, len(new_d))),
        )
    )
    assert rep.rebucketized and not rep.in_place
    assert rep.fallback_reason
    cur = ing.to_edge_list()
    assert np.sum(cur.src == 0) == J  # all edges present after the fallback


def test_delete_all_then_reinsert_same_source():
    """Transient degree-0 must not lose the source's row mid-delta."""
    base = _instance(seed=11, m=1)
    s = int(base.src[0])
    mask = base.src == s
    dsts = base.dst[mask]
    ing = DeltaIngestor(base, row_headroom=2)
    rep = ing.apply(
        InstanceDelta(
            delete_src=[s] * dsts.size, delete_dst=dsts,
            insert_src=[s], insert_dst=[int(dsts[0])],
            insert_values=[2.5], insert_coeff=[[1.5]],
        )
    )
    assert rep.in_place
    cur = ing.to_edge_list()
    sel = cur.src == s
    assert np.sum(sel) == 1
    assert cur.dst[sel][0] == dsts[0]
    np.testing.assert_allclose(cur.values[sel], [2.5], rtol=1e-6)


def test_source_removed_entirely_and_new_source_added():
    base = _instance(seed=13, m=1)
    s = int(base.src[0])
    dsts = base.dst[base.src == s]
    # a brand-new source: one with no edges
    present = np.unique(base.src)
    absent = np.setdiff1d(np.arange(base.spec.num_sources), present)
    if absent.size == 0:
        pytest.skip("generator left no empty sources at this seed")
    t = int(absent[0])
    ing = DeltaIngestor(base, row_headroom=2)
    rep = ing.apply(
        InstanceDelta(
            delete_src=[s] * dsts.size, delete_dst=dsts,
            insert_src=[t], insert_dst=[int(dsts[0])],
            insert_values=[1.0], insert_coeff=[[1.0]],
        )
    )
    cur = ing.to_edge_list()
    assert np.sum(cur.src == s) == 0
    assert np.sum(cur.src == t) == 1
    assert rep.in_place  # freed row re-used for the new source


def test_strictness_errors():
    base = _instance(seed=15, m=1)
    ing = DeltaIngestor(base, row_headroom=2)
    s, d = int(base.src[0]), int(base.dst[0])
    with pytest.raises(KeyError):
        ing.apply(
            InstanceDelta(
                insert_src=[s], insert_dst=[d],
                insert_values=[1.0], insert_coeff=[[1.0]],
            )
        )
    J = base.spec.num_destinations
    have = set(base.dst[base.src == s].tolist())
    missing_d = next(x for x in range(J) if x not in have)
    with pytest.raises(KeyError):
        ing.apply(InstanceDelta(delete_src=[s], delete_dst=[missing_d]))
    with pytest.raises(KeyError):
        ing.apply(
            InstanceDelta(
                update_src=[s], update_dst=[missing_d], update_values=[1.0]
            )
        )


def test_apply_is_atomic_on_invalid_delta():
    """A rejected delta must leave slabs, maps and drift accounting untouched."""
    base = _instance(seed=23, m=1)
    ing = DeltaIngestor(base, row_headroom=2)
    s1, d1 = int(base.src[0]), int(base.dst[0])
    J = base.spec.num_destinations
    have = set(base.dst[base.src == s1].tolist())
    missing_d = next(x for x in range(J) if x not in have)
    before = ing.to_edge_list()
    with pytest.raises(KeyError):
        # first delete is valid, second targets a missing edge
        ing.apply(
            InstanceDelta(
                delete_src=[s1, s1], delete_dst=[d1, missing_d]
            )
        )
    after = ing.to_edge_list()
    np.testing.assert_array_equal(after.src, before.src)
    np.testing.assert_array_equal(after.dst, before.dst)
    np.testing.assert_allclose(after.values, before.values)
    assert ing.drain_cost_drift() == 0.0
    # the corrected delta now applies cleanly
    rep = ing.apply(InstanceDelta(delete_src=[s1], delete_dst=[d1]))
    assert rep.in_place
    assert ing.nnz == before.nnz - 1


def test_cost_drift_accounting():
    base = _instance(seed=17, m=1)
    ing = DeltaIngestor(base, row_headroom=2)
    new_vals = base.values[:4] + np.array([1.0, -2.0, 0.5, 3.0])
    ing.apply(
        InstanceDelta(
            update_src=base.src[:4], update_dst=base.dst[:4],
            update_values=new_vals,
        )
    )
    expect = float(np.linalg.norm(new_vals - base.values[:4]))
    got = ing.drain_cost_drift()
    np.testing.assert_allclose(got, expect, rtol=1e-5)
    assert ing.drain_cost_drift() == 0.0  # drained


def _snapshot(packed):
    return [
        dict(
            idx=np.asarray(b.idx).copy(),
            coeff=np.asarray(b.coeff).copy(),
            cost=np.asarray(b.cost).copy(),
            mask=np.asarray(b.mask).copy(),
        )
        for b in packed.buckets
    ], np.asarray(packed.rhs).copy()


def test_scatter_plan_replays_bit_for_bit_on_device():
    """Device .at[].set replay of the plan == mutated host slabs, exactly."""
    from repro.service import apply_scatter_plan, device_put_instance

    rng = np.random.default_rng(29)
    ing = DeltaIngestor(_instance(seed=29), row_headroom=6)
    dev = device_put_instance(ing.instance())
    ref = ing.to_edge_list()
    for day in range(4):
        delta = _random_delta(ref, rng, n_upd=8, n_del=3, n_ins=3)
        rep = ing.apply(delta)
        ref = apply_delta_to_edge_list(ref, delta)
        if rep.plan is None:  # fallback: consumers must re-upload
            assert rep.rebucketized
            dev = device_put_instance(ing.instance())
            continue
        assert rep.plan.generation == ing.generation
        dev = apply_scatter_plan(dev, rep.plan)
        host = ing.instance()
        for db, hb in zip(dev.buckets, host.buckets):
            np.testing.assert_array_equal(np.asarray(db.idx), hb.idx)
            np.testing.assert_array_equal(np.asarray(db.cost), hb.cost)
            np.testing.assert_array_equal(np.asarray(db.mask), hb.mask)
            np.testing.assert_array_equal(np.asarray(db.coeff), hb.coeff)
        np.testing.assert_array_equal(np.asarray(dev.rhs), np.asarray(host.rhs))


def test_scatter_plan_matches_host_apply_on_numpy_copy():
    """Replaying the plan on a pre-delta numpy snapshot == host apply, bitwise."""
    rng = np.random.default_rng(31)
    base = _instance(seed=31)
    ing = DeltaIngestor(base, row_headroom=6)
    pre, pre_rhs = _snapshot(ing.instance())
    rep = ing.apply(_random_delta(base, rng))
    assert rep.in_place and rep.plan is not None
    assert rep.plan.num_cells > 0
    for op in rep.plan.ops:
        p = pre[op.bucket]
        p["idx"][op.rows, op.slots] = op.idx
        p["cost"][op.rows, op.slots] = op.cost
        p["mask"][op.rows, op.slots] = op.mask
        p["coeff"][:, op.rows, op.slots] = op.coeff
    if rep.plan.rhs is not None:
        pre_rhs = rep.plan.rhs
    for t, b in enumerate(ing.instance().buckets):
        for k in ("idx", "coeff", "cost", "mask"):
            np.testing.assert_array_equal(pre[t][k], getattr(b, k))
    np.testing.assert_array_equal(pre_rhs, np.asarray(ing.instance().rhs))


def test_scatter_plan_run_compaction():
    """Contiguous slot spans compress to runs; expansion reproduces the cells.

    A row move rewrites ``[0, d)`` of the old and new rows — exactly the
    high-degree case run-length encoding is for: the plan's index overhead
    must be O(runs), far below O(cells), while the expanded `rows`/`slots`
    views stay unique, row-major sorted, and bit-for-bit replayable.
    """
    rng = np.random.default_rng(53)
    base = _instance(seed=53, I=60, J=40, m=1)
    ing = DeltaIngestor(base, row_headroom=8)
    # grow a low-degree source past its bucket width (but within the widest
    # bucket): the move rewrites its whole [0, d) span in two buckets
    widest = max(b.length for b in ing.instance().buckets)
    deg = ing.deg
    candidates = np.flatnonzero((deg >= 3) & (deg <= widest // 2))
    assert candidates.size, "seed produced no movable source"
    s = int(candidates[np.argmax(deg[candidates])])
    have = set(base.dst[base.src == s].tolist())
    grow = int(2 ** np.ceil(np.log2(deg[s])) + 1 - deg[s])  # past next pow2
    new_d = [d for d in range(40) if d not in have][:grow]
    rep = ing.apply(
        InstanceDelta(
            insert_src=[s] * len(new_d), insert_dst=new_d,
            insert_values=np.ones(len(new_d)),
            insert_coeff=np.ones((1, len(new_d))),
        )
    )
    assert rep.in_place and rep.moved_rows >= 1
    plan = rep.plan
    assert plan.num_runs < plan.num_cells
    for op in plan.ops:
        rows, slots = op.rows, op.slots
        assert rows.size == op.num_cells == op.idx.size
        # unique, row-major sorted cells (the .at[].set determinism invariant)
        order = np.lexsort((slots, rows))
        np.testing.assert_array_equal(order, np.arange(rows.size))
        cells = set(zip(rows.tolist(), slots.tolist()))
        assert len(cells) == rows.size
        # each run covers consecutive slots of one row
        np.testing.assert_array_equal(
            np.repeat(op.run_rows, op.run_lengths), rows
        )
    # the run-encoded index payload beats per-cell (rows + slots) encoding
    per_cell_index_bytes = 2 * 4 * plan.num_cells
    run_index_bytes = 3 * 4 * plan.num_runs
    assert run_index_bytes < per_cell_index_bytes


def test_generation_counter_and_plan_bytes():
    rng = np.random.default_rng(37)
    base = _instance(seed=37, m=1)
    ing = DeltaIngestor(base, row_headroom=4)
    assert ing.generation == 0
    rep1 = ing.apply(_random_delta(base, rng, n_upd=3, n_del=0, n_ins=0, rhs=False))
    assert (rep1.generation, ing.generation) == (1, 1)
    assert rep1.plan.generation == 1
    # an O(delta) plan must be far smaller than the O(nnz) slabs
    slab_bytes = sum(
        b.idx.nbytes + b.coeff.nbytes + b.cost.nbytes + b.mask.nbytes
        for b in ing.instance().buckets
    )
    assert rep1.plan.nbytes < slab_bytes / 10
    # rejected deltas bump nothing
    s = int(base.src[0])
    have = set(base.dst[base.src == s].tolist())
    missing_d = next(
        x for x in range(base.spec.num_destinations) if x not in have
    )
    with pytest.raises(KeyError):
        ing.apply(InstanceDelta(delete_src=[s], delete_dst=[missing_d]))
    assert ing.generation == 1


def test_ingestor_state_roundtrip_bit_for_bit():
    """from_state(state_dict()) reproduces slabs, maps, headroom and plans."""
    rng = np.random.default_rng(41)
    base = _instance(seed=41)
    ing = DeltaIngestor(base, row_headroom=4)
    ing.apply(_random_delta(base, rng))
    arrays, meta = ing.state_dict()
    back = DeltaIngestor.from_state(arrays, meta)
    assert back.generation == ing.generation
    assert back.headroom() == ing.headroom()
    assert back._free_rows == ing._free_rows
    for a, b in zip(ing.instance().buckets, back.instance().buckets):
        for k in ("idx", "coeff", "cost", "mask"):
            np.testing.assert_array_equal(getattr(a, k), getattr(b, k))
    # identical future behaviour: same delta -> identical scatter plan
    nxt = _random_delta(ing.to_edge_list(), rng, n_upd=5, n_del=2, n_ins=2)
    ra, rb = ing.apply(nxt), back.apply(nxt)
    assert ra.in_place == rb.in_place
    if ra.plan is not None:
        assert rb.plan is not None
        for oa, ob in zip(ra.plan.ops, rb.plan.ops):
            assert oa.bucket == ob.bucket
            np.testing.assert_array_equal(oa.rows, ob.rows)
            np.testing.assert_array_equal(oa.slots, ob.slots)
            np.testing.assert_array_equal(oa.cost, ob.cost)


def _check_device_scatter_matches_rebucketize(
    seed: int, steps: list[tuple[int, int, int, bool]], headroom: int
) -> None:
    """Property body: a random insert/delete/update sequence replayed on
    device through `ScatterPlan`s equals a from-scratch re-bucketize of the
    mutated edge list.

    Three links, checked every step:
      1. device slabs after plan replay == host ingested slabs, bit-for-bit
         (on a re-bucketize fallback the device copy is re-uploaded, which is
         the documented consumer contract);
      2. the ingested edge list == the reference edge list with the same
         deltas applied functionally;
      3. the objective evaluated on the device instance == the objective on
         `bucketize(reference)` — the from-scratch repack — at a fixed dual.
    """
    from repro.service import apply_scatter_plan, device_put_instance

    rng = np.random.default_rng(seed)
    base = _instance(seed=seed % 97, I=60, J=8, m=1)
    ing = DeltaIngestor(base, row_headroom=headroom)
    dev = device_put_instance(ing.instance())
    ref = base
    lam = jnp.asarray(
        rng.random(base.spec.num_families * base.spec.num_destinations)
        .astype(np.float32)
    )
    for n_upd, n_del, n_ins, with_rhs in steps:
        n_upd = min(n_upd, ref.nnz)
        n_del = min(n_del, ref.nnz - n_upd)
        delta = _random_delta(
            ref, rng, n_upd=n_upd, n_del=n_del, n_ins=n_ins, rhs=with_rhs
        )
        rep = ing.apply(delta)
        ref = apply_delta_to_edge_list(ref, delta)
        if rep.plan is None:
            assert rep.rebucketized
            dev = device_put_instance(ing.instance())
        else:
            dev = apply_scatter_plan(dev, rep.plan)
        host = ing.instance()
        for db, hb in zip(dev.buckets, host.buckets):
            np.testing.assert_array_equal(np.asarray(db.idx), hb.idx)
            np.testing.assert_array_equal(np.asarray(db.coeff), hb.coeff)
            np.testing.assert_array_equal(np.asarray(db.cost), hb.cost)
            np.testing.assert_array_equal(np.asarray(db.mask), hb.mask)
        np.testing.assert_array_equal(np.asarray(dev.rhs), np.asarray(host.rhs))
        cur = ing.to_edge_list()
        np.testing.assert_array_equal(cur.src, ref.src)
        np.testing.assert_array_equal(cur.dst, ref.dst)
        np.testing.assert_allclose(cur.values, ref.values, rtol=1e-6)
        np.testing.assert_allclose(cur.rhs, ref.rhs)
    ev_dev = MatchingObjective(dev).calculate(lam, 0.1)
    ev_ref = MatchingObjective(bucketize(ref)).calculate(lam, 0.1)
    np.testing.assert_allclose(float(ev_dev.g), float(ev_ref.g), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ev_dev.grad), np.asarray(ev_ref.grad), atol=1e-4
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        steps=st.lists(
            st.tuples(
                st.integers(0, 12),  # updates
                st.integers(0, 6),  # deletes
                st.integers(0, 6),  # inserts
                st.booleans(),  # perturb rhs
            ),
            min_size=1,
            max_size=3,
        ),
        headroom=st.sampled_from([0, 4]),
    )
    def test_scatter_plan_device_equals_rebucketize_property(
        seed, steps, headroom
    ):
        _check_device_scatter_matches_rebucketize(seed, steps, headroom)

else:

    @pytest.mark.parametrize(
        "seed,steps,headroom",
        [
            (7, [(12, 4, 4, True), (3, 0, 6, False)], 4),
            (43, [(0, 6, 0, False), (8, 2, 2, True), (1, 1, 1, True)], 0),
            (2**30 + 11, [(5, 5, 5, True)], 4),
        ],
    )
    def test_scatter_plan_device_equals_rebucketize_property(
        seed, steps, headroom
    ):
        # hypothesis unavailable: run a fixed sample of the property instead
        _check_device_scatter_matches_rebucketize(seed, steps, headroom)


def test_unpack_primal_edge_keys():
    base = _instance(seed=19, m=1)
    ing = DeltaIngestor(base, row_headroom=2)
    # unpack a primal of all-ones masks: every edge must appear exactly once
    ones = [np.asarray(b.mask) for b in ing.instance().buckets]
    keys, x = ing.unpack_primal(ones)
    J = base.spec.num_destinations
    np.testing.assert_array_equal(
        np.sort(keys), np.sort(base.src * J + base.dst)
    )
    np.testing.assert_allclose(x, 1.0)
