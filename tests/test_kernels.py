"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.simplex_proj import bitonic_sort_desc, inclusive_scan

LENGTHS = [1, 2, 4, 8, 32, 128, 512, 2048]
ROWS = [1, 5, 16, 37]


@pytest.mark.parametrize("L", [2, 8, 64, 256, 1024])
def test_bitonic_sort_exact(L):
    x = jax.random.normal(jax.random.key(L), (7, L))
    got = bitonic_sort_desc(x)
    want = jnp.sort(x, axis=-1)[:, ::-1]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("L", [2, 16, 128, 1024])
def test_inclusive_scan(L):
    x = jax.random.normal(jax.random.key(L), (4, L))
    np.testing.assert_allclose(
        inclusive_scan(x), jnp.cumsum(x, axis=-1), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("L", LENGTHS)
@pytest.mark.parametrize("n", ROWS)
@pytest.mark.parametrize("inequality", [True, False])
@pytest.mark.slow
def test_simplex_kernel_sweep(L, n, inequality):
    rng = np.random.default_rng(L * 1000 + n)
    v = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32) * 2)
    mask = jnp.asarray((rng.random((n, L)) < 0.7).astype(np.float32))
    got = kops.fused_project_simplex(
        v, mask, inequality=inequality, interpret=True
    )
    want = kref.simplex_ref(v, mask, inequality=inequality)
    np.testing.assert_allclose(got, want, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_simplex_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(16, 64)), dtype)
    mask = jnp.ones((16, 64), dtype)
    got = kops.fused_project_simplex(v, mask, interpret=True)
    want = kref.simplex_ref(v.astype(jnp.float32), mask.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=2e-2
    )
    assert got.dtype == dtype


def test_simplex_kernel_radius():
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.normal(size=(9, 32)).astype(np.float32) * 4)
    mask = jnp.ones((9, 32), jnp.float32)
    got = kops.fused_project_simplex(v, mask, radius=2.5, interpret=True)
    want = kref.simplex_ref(v, mask, radius=2.5)
    np.testing.assert_allclose(got, want, atol=3e-5)


def test_fallback_beyond_max_length():
    """Widths > 8192 take the multi-launch reference path (paper §4.3)."""
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.normal(size=(2, 16384)).astype(np.float32))
    mask = jnp.ones_like(v)
    got = kops.fused_project_simplex(v, mask, interpret=True)
    want = kref.simplex_ref(v, mask)
    np.testing.assert_allclose(got, want, atol=3e-5)


@pytest.mark.parametrize("L", [4, 64, 512])
@pytest.mark.parametrize("m", [1, 3])
@pytest.mark.slow
def test_dual_primal_kernel_sweep(L, m):
    J = 64
    n = 29
    rng = np.random.default_rng(L + m)
    idx = jnp.asarray(rng.integers(0, J, size=(n, L)), jnp.int32)
    coeff = jnp.asarray(rng.random((m, n, L)).astype(np.float32))
    cost = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32))
    mask = jnp.asarray((rng.random((n, L)) < 0.8).astype(np.float32))
    lam = jnp.asarray(rng.random(m * J).astype(np.float32))
    for gamma in [0.01, 1.0, 100.0]:
        got = kops.fused_dual_primal(
            idx, coeff, cost, mask, lam, jnp.float32(gamma),
            num_destinations=J, interpret=True,
        )
        want = kref.dual_primal_ref(idx, coeff, cost, mask, lam, gamma, J)
        np.testing.assert_allclose(got, want, atol=3e-5, err_msg=f"gamma={gamma}")


def test_dual_primal_in_objective():
    """MatchingObjective(fused_kernel=True) matches the reference objective."""
    from repro.core.objective import MatchingObjective
    from repro.instances import (
        MatchingInstanceSpec, bucketize, generate_matching_instance,
    )

    spec = MatchingInstanceSpec(num_sources=60, num_destinations=12, avg_degree=4.0, seed=7)
    packed = bucketize(generate_matching_instance(spec))
    lam = jnp.asarray(np.random.default_rng(0).random(12).astype(np.float32))
    ref_ev = MatchingObjective(packed).calculate(lam, 0.5)
    k_ev = MatchingObjective(
        packed, fused_kernel=True, kernel_interpret=True
    ).calculate(lam, 0.5)
    np.testing.assert_allclose(float(ref_ev.g), float(k_ev.g), rtol=1e-5)
    np.testing.assert_allclose(ref_ev.grad, k_ev.grad, atol=3e-5)
