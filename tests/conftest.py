import os
import subprocess
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests shell out via `run_with_devices`.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(script: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
