"""Distributed column-sharded execution (paper §4.4, B.1 parity) — subprocess
tests with 8 forced host devices.

All mesh construction goes through `repro.compat` (make_mesh/set_mesh shims),
so this suite runs on the pinned jax even though it predates
`jax.sharding.AxisType` / `jax.set_mesh`.
"""
import json

import pytest

from conftest import run_with_devices

pytestmark = pytest.mark.slow

PARITY = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.compat import make_mesh
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import (MatchingObjective, normalize_rows, Maximizer, MaximizerConfig,
                        DistributedMaximizer, DistConfig)

spec = MatchingInstanceSpec(num_sources=200, num_destinations=16, avg_degree=4.0,
                            num_families=2, seed=3)
packed = bucketize(generate_matching_instance(spec), shard_multiple=8)
scaled, _ = normalize_rows(packed)
cfg = MaximizerConfig(iters_per_stage=80)
ref = Maximizer(MatchingObjective(scaled), cfg).solve()
mesh = make_mesh((8,), ("data",))
out = {}
for mode, compress in [("psum", "none"), ("rank0", "none"), ("psum", "bf16_ef")]:
    dm = DistributedMaximizer(scaled, mesh, cfg,
                              DistConfig(axes="data", comm_mode=mode, compress=compress))
    dm.place()
    res = dm.solve()
    tr_ref = np.asarray(ref.stats[-1].g)
    tr = np.asarray(res.stats[-1].g)
    out[f"{mode}-{compress}"] = float(np.max(np.abs(tr - tr_ref) / (np.abs(tr_ref) + 1e-9)))
print("RESULT:" + json.dumps(out))
"""


def test_sharded_parity_modes():
    """B.1: distributed trajectories match the single-device solver."""
    out = run_with_devices(PARITY, 8)
    res = json.loads(out.split("RESULT:")[1])
    # exact-arithmetic modes track to fp32 reduction noise
    assert res["psum-none"] < 1e-3
    assert res["rank0-none"] < 1e-3
    # compressed reduce drifts but stays in the same basin
    assert res["psum-bf16_ef"] < 0.1


EARLY_STOP_PARITY = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.compat import make_mesh
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import (MatchingObjective, normalize_rows, Maximizer, MaximizerConfig,
                        DistributedMaximizer, DistConfig)

spec = MatchingInstanceSpec(num_sources=200, num_destinations=16, avg_degree=4.0,
                            num_families=2, seed=3)
packed = bucketize(generate_matching_instance(spec), shard_multiple=8)
scaled, _ = normalize_rows(packed)
# tol_viol drives the stop (the raw ||grad|| plateaus on inactive duals);
# adaptive restart is off so the trajectory has no fp-noise-triggered
# momentum-reset branches — the stop decision must then be identical on
# every mesh, which is exactly what the psum'd predicate guarantees.
cfg = MaximizerConfig(gammas=(10.0, 1.0), iters_per_stage=600,
                      adaptive_restart=False,
                      tol_viol=1e-5, check_every=50)
ref = Maximizer(MatchingObjective(scaled), cfg).solve()
lref = np.asarray(ref.lam)
out = {"budget": cfg.total_iter_budget,
       "single": {"iters": list(ref.iters_used), "total": ref.total_iters_used}}
for n in (1, 2, 8):
    mesh = make_mesh((n,), ("data",), devices=jax.devices()[:n])
    dm = DistributedMaximizer(scaled, mesh, cfg, DistConfig(axes="data"))
    dm.place()
    res = dm.solve()
    ld = np.asarray(res.lam)
    out[str(n)] = {
        "iters": list(res.iters_used),
        "total": res.total_iters_used,
        "lam_rel_l2": float(np.linalg.norm(ld - lref) / np.linalg.norm(lref)),
    }
print("RESULT:" + json.dumps(out))
"""


def test_early_stop_parity_across_meshes():
    """Tentpole: early-stopped DistributedMaximizer matches the single-device
    Maximizer, and the psum'd stop decision is shard-count independent."""
    out = run_with_devices(EARLY_STOP_PARITY, 8)
    res = json.loads(out.split("RESULT:")[1])
    # the collective predicate actually fired: fewer iters than the budget
    assert res["single"]["total"] < res["budget"], res
    for n in ("1", "2", "8"):
        # No shard-dependent stop decisions: per-stage counts identical.
        # Within one mesh this is structural (the psum'd vote); across mesh
        # sizes it additionally relies on the test instance's decisive
        # threshold crossings — viol drops ~a decade per chunk here, while
        # cross-mesh reduction-order noise is ~1e-7 relative, so a
        # checkpoint can't land close enough to tol_viol to flip a chunk.
        assert res[n]["iters"] == res["single"]["iters"], res
        assert res[n]["total"] == res["single"]["total"], res
        # duals match the single-device solution within 1e-6 (relative L2;
        # measured 1e-7–5e-7, i.e. fp32 reduction noise under contraction)
        assert res[n]["lam_rel_l2"] < 1e-6, res


FUSED_ORACLE_PARITY = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.compat import make_mesh
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import (MatchingObjective, normalize_rows, Maximizer, MaximizerConfig,
                        DistributedMaximizer, DistConfig)

spec = MatchingInstanceSpec(num_sources=200, num_destinations=16, avg_degree=4.0,
                            num_families=2, seed=3)
packed = bucketize(generate_matching_instance(spec), shard_multiple=8)
scaled, _ = normalize_rows(packed)
# adaptive restart off: the momentum-reset branch compares g values that the
# fused/unfused oracles (and different shard counts) reduce in different fp32
# orders, so with it on, bitwise trajectory parity is not a sound assertion
cfg = MaximizerConfig(iters_per_stage=80, adaptive_restart=False)
ref = Maximizer(MatchingObjective(scaled), cfg).solve()
lref = np.asarray(ref.lam)
out = {}
for n in (1, 2, 8):
    mesh = make_mesh((n,), ("data",), devices=jax.devices()[:n])
    dm = DistributedMaximizer(scaled, mesh, cfg,
                              DistConfig(axes="data", fused_oracle=True))
    dm.place()
    res = dm.solve()
    ld = np.asarray(res.lam)
    tr_ref = np.asarray(ref.stats[-1].g)
    tr = np.asarray(res.stats[-1].g)
    out[str(n)] = {
        "lam_rel_l2": float(np.linalg.norm(ld - lref) / np.linalg.norm(lref)),
        "g_rel_dev": float(np.max(np.abs(tr - tr_ref) / (np.abs(tr_ref) + 1e-9))),
    }
print("RESULT:" + json.dumps(out))
"""


def test_fused_oracle_sharded_parity():
    """The one-pass fused dual oracle under shard_map: each shard's local
    calculate emits its pre-psum (ax, c'x, ||x||^2) from the fused launch;
    1/2/8-shard solves must match the single-device unfused solver."""
    out = run_with_devices(FUSED_ORACLE_PARITY, 8)
    res = json.loads(out.split("RESULT:")[1])
    for n in ("1", "2", "8"):
        assert res[n]["lam_rel_l2"] < 1e-6, res
        assert res[n]["g_rel_dev"] < 1e-3, res


SHARD_COUNTS = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.compat import make_mesh
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import (MatchingObjective, normalize_rows, Maximizer, MaximizerConfig,
                        DistributedMaximizer, DistConfig)

spec = MatchingInstanceSpec(num_sources=240, num_destinations=10, avg_degree=3.0, seed=9)
packed = bucketize(generate_matching_instance(spec), shard_multiple=8)
scaled, _ = normalize_rows(packed)
cfg = MaximizerConfig(iters_per_stage=60)
gs = {}
for n in (1, 2, 4, 8):
    mesh = make_mesh((n,), ("data",), devices=jax.devices()[:n])
    dm = DistributedMaximizer(scaled, mesh, cfg, DistConfig(axes="data"))
    dm.place()
    gs[n] = float(dm.solve().g)
print("RESULT:" + json.dumps(gs))
"""


def test_invariance_to_shard_count():
    """Final dual objective independent of the column-shard count."""
    out = run_with_devices(SHARD_COUNTS, 8)
    gs = json.loads(out.split("RESULT:")[1])
    vals = list(gs.values())
    for v in vals[1:]:
        assert abs(v - vals[0]) / abs(vals[0]) < 1e-3, gs


DRYRUN_SMALL = r"""
import jax, jax.numpy as jnp, json
from repro.compat import make_mesh
from repro.core import DistributedMaximizer, DistConfig, MaximizerConfig
from repro.instances.specs import solver_input_specs
from repro.analysis.hlo_stats import collective_stats

mesh = make_mesh((2, 4), ("data", "model"))
inst = solver_input_specs(100_000, 1_000, shard_multiple=8)
dm = DistributedMaximizer(inst, mesh, MaximizerConfig(iters_per_stage=10),
                          DistConfig(axes=("data", "model")))
lowered = dm.lower_stage()
compiled = lowered.compile()
st = collective_stats(compiled.as_text())
print("RESULT:" + json.dumps({"ar": st["counts"].get("all-reduce", 0),
                              "bytes": st["total_bytes"]}))
"""


def test_solver_dryrun_small_mesh():
    """lower+compile of a sharded stage on an abstract instance; the
    all-reduce payload exists and is bounded by iters * |lam| * 4B * ~2."""
    out = run_with_devices(DRYRUN_SMALL, 8)
    res = json.loads(out.split("RESULT:")[1])
    assert res["ar"] >= 1
    assert 0 < res["bytes"] <= 10 * (1_000 + 2) * 4 * 2 * 12


DRYRUN_EARLY_STOP = r"""
import jax, jax.numpy as jnp, json
from repro.compat import make_mesh
from repro.core import DistributedMaximizer, DistConfig, MaximizerConfig
from repro.instances.specs import solver_input_specs
from repro.analysis.hlo_stats import collective_stats

mesh = make_mesh((8,), ("data",))
inst = solver_input_specs(100_000, 1_000, shard_multiple=8)
dm = DistributedMaximizer(
    inst, mesh,
    MaximizerConfig(iters_per_stage=100, tol_grad=1e-4, tol_viol=1e-4,
                    check_every=25),
    DistConfig(axes="data"))
compiled = dm.lower_stage().compile()
st = collective_stats(compiled.as_text())
print("RESULT:" + json.dumps({"counts": st["counts"]}))
"""


def test_early_stop_stage_lowers_with_predicate_collective():
    """The early-stop stage variant compiles under shard_map; the psum'd stop
    predicate contributes its own (tiny) all-reduce besides the gradient one."""
    out = run_with_devices(DRYRUN_EARLY_STOP, 8)
    res = json.loads(out.split("RESULT:")[1])
    # at least the gradient all-reduce and the predicate all-reduce
    assert res["counts"].get("all-reduce", 0) >= 2, res


COMM_VOLUME = r"""
import jax, jax.numpy as jnp, json
from repro.compat import make_mesh
from repro.core import DistributedMaximizer, DistConfig, MaximizerConfig
from repro.instances.specs import solver_input_specs
from repro.analysis.hlo_stats import collective_stats

mesh = make_mesh((8,), ("data",))
out = {}
for I in (50_000, 200_000):
    inst = solver_input_specs(I, 1_000, shard_multiple=8)
    dm = DistributedMaximizer(inst, mesh, MaximizerConfig(iters_per_stage=5),
                              DistConfig(axes="data"))
    st = collective_stats(dm.lower_stage().compile().as_text())
    out[str(I)] = st["total_bytes"]
print("RESULT:" + json.dumps(out))
"""


def test_comm_volume_independent_of_sources():
    """The paper's central property: per-iteration communication depends only
    on the dual dimension, not on the number of sources."""
    out = run_with_devices(COMM_VOLUME, 8)
    res = json.loads(out.split("RESULT:")[1])
    assert res["50000"] == res["200000"], res
