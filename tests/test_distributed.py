"""Distributed column-sharded execution (paper §4.4, B.1 parity) — subprocess
tests with 8 forced host devices."""
import json

import pytest

from conftest import run_with_devices

pytestmark = pytest.mark.slow

PARITY = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import (MatchingObjective, normalize_rows, Maximizer, MaximizerConfig,
                        DistributedMaximizer, DistConfig)

spec = MatchingInstanceSpec(num_sources=200, num_destinations=16, avg_degree=4.0,
                            num_families=2, seed=3)
packed = bucketize(generate_matching_instance(spec), shard_multiple=8)
scaled, _ = normalize_rows(packed)
cfg = MaximizerConfig(iters_per_stage=80)
ref = Maximizer(MatchingObjective(scaled), cfg).solve()
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
out = {}
for mode, compress in [("psum", "none"), ("rank0", "none"), ("psum", "bf16_ef")]:
    dm = DistributedMaximizer(scaled, mesh, cfg,
                              DistConfig(axes="data", comm_mode=mode, compress=compress))
    dm.place()
    res = dm.solve()
    tr_ref = np.asarray(ref.stats[-1].g)
    tr = np.asarray(res.stats[-1].g)
    out[f"{mode}-{compress}"] = float(np.max(np.abs(tr - tr_ref) / (np.abs(tr_ref) + 1e-9)))
print("RESULT:" + json.dumps(out))
"""


def test_sharded_parity_modes():
    """B.1: distributed trajectories match the single-device solver."""
    out = run_with_devices(PARITY, 8)
    res = json.loads(out.split("RESULT:")[1])
    # exact-arithmetic modes track to fp32 reduction noise
    assert res["psum-none"] < 1e-3
    assert res["rank0-none"] < 1e-3
    # compressed reduce drifts but stays in the same basin
    assert res["psum-bf16_ef"] < 0.1


SHARD_COUNTS = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.instances import MatchingInstanceSpec, generate_matching_instance, bucketize
from repro.core import (MatchingObjective, normalize_rows, Maximizer, MaximizerConfig,
                        DistributedMaximizer, DistConfig)

spec = MatchingInstanceSpec(num_sources=240, num_destinations=10, avg_degree=3.0, seed=9)
packed = bucketize(generate_matching_instance(spec), shard_multiple=8)
scaled, _ = normalize_rows(packed)
cfg = MaximizerConfig(iters_per_stage=60)
gs = {}
for n in (1, 2, 4, 8):
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,),
                         devices=jax.devices()[:n])
    dm = DistributedMaximizer(scaled, mesh, cfg, DistConfig(axes="data"))
    dm.place()
    gs[n] = float(dm.solve().g)
print("RESULT:" + json.dumps(gs))
"""


def test_invariance_to_shard_count():
    """Final dual objective independent of the column-shard count."""
    out = run_with_devices(SHARD_COUNTS, 8)
    gs = json.loads(out.split("RESULT:")[1])
    vals = list(gs.values())
    for v in vals[1:]:
        assert abs(v - vals[0]) / abs(vals[0]) < 1e-3, gs


DRYRUN_SMALL = r"""
import jax, jax.numpy as jnp, json
from repro.core import DistributedMaximizer, DistConfig, MaximizerConfig
from repro.instances.specs import solver_input_specs
from repro.analysis.hlo_stats import collective_stats

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
inst = solver_input_specs(100_000, 1_000, shard_multiple=8)
dm = DistributedMaximizer(inst, mesh, MaximizerConfig(iters_per_stage=10),
                          DistConfig(axes=("data", "model")))
lowered = dm.lower_stage()
compiled = lowered.compile()
st = collective_stats(compiled.as_text())
print("RESULT:" + json.dumps({"ar": st["counts"].get("all-reduce", 0),
                              "bytes": st["total_bytes"]}))
"""


def test_solver_dryrun_small_mesh():
    """lower+compile of a sharded stage on an abstract instance; the
    all-reduce payload exists and is bounded by iters * |lam| * 4B * ~2."""
    out = run_with_devices(DRYRUN_SMALL, 8)
    res = json.loads(out.split("RESULT:")[1])
    assert res["ar"] >= 1
    assert 0 < res["bytes"] <= 10 * (1_000 + 2) * 4 * 2 * 12


COMM_VOLUME = r"""
import jax, jax.numpy as jnp, json
from repro.core import DistributedMaximizer, DistConfig, MaximizerConfig
from repro.instances.specs import solver_input_specs
from repro.analysis.hlo_stats import collective_stats

mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
out = {}
for I in (50_000, 200_000):
    inst = solver_input_specs(I, 1_000, shard_multiple=8)
    dm = DistributedMaximizer(inst, mesh, MaximizerConfig(iters_per_stage=5),
                              DistConfig(axes="data"))
    st = collective_stats(dm.lower_stage().compile().as_text())
    out[str(I)] = st["total_bytes"]
print("RESULT:" + json.dumps(out))
"""


def test_comm_volume_independent_of_sources():
    """The paper's central property: per-iteration communication depends only
    on the dual dimension, not on the number of sources."""
    out = run_with_devices(COMM_VOLUME, 8)
    res = json.loads(out.split("RESULT:")[1])
    assert res["50000"] == res["200000"], res
