"""LP fidelity: the regularized solve tracks the true LP optimum.

ROADMAP design caveat: the gamma-floor smoothing leaves a bias at the
paper's production floor (1e-2), so LP-fidelity tests must extend the
continuation schedule (to ~1e-3) and compare *objectives* against an exact
small-instance reference (scipy linprog) — not assert tiny absolute
constraint violations, which the smoothed solution never achieves.

Covers the legacy matching formulation and the capacity-cap formulation
(the LP reference simply tightens the variable bounds to (0, cap)).
"""
import numpy as np
import pytest

from repro.core import Maximizer, MaximizerConfig, MatchingObjective, normalize_rows
from repro.formulation import capacity_cap_formulation
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    unpack_primal,
)

scipy_opt = pytest.importorskip("scipy.optimize")

pytestmark = pytest.mark.slow

# paper schedule extended past the production gamma floor to ~1e-3
EXTENDED_GAMMAS = (1e3, 1e2, 10.0, 1.0, 1e-1, 1e-2, 3e-3, 1e-3)


def _instance(seed=2, I=60, J=8, m=1):
    spec = MatchingInstanceSpec(
        num_sources=I, num_destinations=J, avg_degree=3.0,
        num_families=m, seed=seed,
    )
    inst = generate_matching_instance(spec)
    packed = bucketize(inst)
    scaled, _ = normalize_rows(packed)
    return inst, packed, scaled


def _lp_reference(inst, cap=None):
    """Exact LP optimum over the edge variables via scipy linprog.

    min c'x  s.t.  A x <= b,  per-source sum_j x_ij <= 1,  0 <= x <= cap.
    """
    J = inst.spec.num_destinations
    A, b, c = inst.to_dense()
    cols = inst.src * J + inst.dst
    A_e = A[:, cols]
    # per-source simplex rows over the edge set
    sources = np.unique(inst.src)
    S = np.zeros((sources.size, cols.size))
    for r, i in enumerate(sources):
        S[r, np.flatnonzero(inst.src == i)] = 1.0
    res = scipy_opt.linprog(
        c[cols],
        A_ub=np.vstack([A_e, S]),
        b_ub=np.concatenate([b, np.ones(sources.size)]),
        bounds=(0, cap),
        method="highs",
    )
    assert res.status == 0, res.message
    return res


def _primal_value(inst, packed, res):
    x = unpack_primal(packed, [np.asarray(s) for s in res.x_slabs])
    return float(np.dot(inst.cost, x)), x


def test_matching_tracks_lp_optimum():
    inst, packed, scaled = _instance()
    ref = _lp_reference(inst)
    cfg = MaximizerConfig(gammas=EXTENDED_GAMMAS, iters_per_stage=300)
    res = Maximizer(MatchingObjective(scaled), cfg).solve()
    val, x = _primal_value(inst, packed, res)
    scale = max(abs(ref.fun), 1.0)
    gap = (val - ref.fun) / scale
    # smoothed objective upper-bounds the LP optimum and must be close;
    # no absolute-violation assertion (see module docstring)
    assert gap >= -1e-4, f"beat the LP optimum? gap={gap}"
    assert gap <= 2e-2, f"objective gap vs linprog too large: {gap}"
    # the dual objective brackets from below at the final gamma
    assert float(res.g) <= ref.fun + 1e-2 * scale


def test_gamma_floor_bias_shrinks_with_continuation():
    """Extending the schedule below the production floor must tighten the
    gap — the quantitative form of the ROADMAP caveat."""
    inst, packed, scaled = _instance(seed=4)
    ref = _lp_reference(inst)
    scale = max(abs(ref.fun), 1.0)

    def gap(gammas):
        cfg = MaximizerConfig(gammas=gammas, iters_per_stage=300)
        res = Maximizer(MatchingObjective(scaled), cfg).solve()
        val, _ = _primal_value(inst, packed, res)
        return (val - ref.fun) / scale

    g_floor = gap(EXTENDED_GAMMAS[:6])  # production floor 1e-2
    g_ext = gap(EXTENDED_GAMMAS)  # extended to 1e-3
    assert g_ext <= g_floor + 1e-5
    assert g_ext <= 2e-2


def test_capacity_cap_tracks_lp_optimum():
    """Capacity-cap formulation vs linprog with tightened bounds (0, cap)."""
    inst, packed, scaled = _instance(seed=3)
    cap = 0.4
    ref = _lp_reference(inst, cap=cap)
    ref_uncapped = _lp_reference(inst)
    # the cap must actually bind on this instance, else the test is vacuous
    assert ref.fun > ref_uncapped.fun + 1e-6

    comp = capacity_cap_formulation(cap=cap).compile(scaled)
    cfg = MaximizerConfig(gammas=EXTENDED_GAMMAS, iters_per_stage=300)
    res = comp.solve(cfg)
    val, x = _primal_value(inst, packed, res)
    assert x.max() <= cap + 1e-5
    scale = max(abs(ref.fun), 1.0)
    gap = (val - ref.fun) / scale
    assert gap >= -1e-4, f"beat the capped LP optimum? gap={gap}"
    assert gap <= 2e-2, f"capacity-cap objective gap vs linprog: {gap}"
