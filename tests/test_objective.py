"""MatchingObjective vs dense-matrix formulas (eq. 2-4) on small instances.

Assertions are written against the public `DualEval` contract — every field
(`g`, `grad`, `x_slabs`, `primal_linear`, `primal_ridge`, `ax`) is pinned to
its dense definition, plus the two internal identities that tie them
together (`grad == ax - b`, `g == primal_linear + primal_ridge + lam'grad`).
The formulation layer's shim and the service engine both consume exactly
this contract, so these pins are what "zero solver edits" rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MatchingObjective, project_simplex
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    unpack_primal,
)


@pytest.fixture(scope="module")
def small():
    spec = MatchingInstanceSpec(
        num_sources=25, num_destinations=7, avg_degree=3.0, num_families=2, seed=21
    )
    inst = generate_matching_instance(spec)
    return inst, bucketize(inst)


def _dense_x_star(inst, lam, gamma):
    """Blockwise closed form (eq. 3) computed densely per source."""
    spec = inst.spec
    J, m = spec.num_destinations, spec.num_families
    A, b, c = inst.to_dense()
    cols = inst.src * J + inst.dst
    z = -(A[:, cols].T @ lam + c[cols]) / gamma
    # per-source simplex projection
    x = np.zeros_like(z)
    for i in np.unique(inst.src):
        rows = np.flatnonzero(inst.src == i)
        zi = z[rows][None, :].astype(np.float32)
        xi = project_simplex(jnp.asarray(zi), jnp.ones_like(jnp.asarray(zi)))
        x[rows] = np.asarray(xi)[0]
    return x, A[:, cols], b, c[cols]


@pytest.mark.parametrize("gamma", [0.05, 1.0, 50.0])
def test_calculate_matches_dense(small, gamma):
    """Every public DualEval field against its dense definition."""
    inst, packed = small
    m, J = inst.spec.num_families, inst.spec.num_destinations
    lam = np.random.default_rng(0).random(m * J).astype(np.float32)
    ev = MatchingObjective(packed).calculate(jnp.asarray(lam), gamma)
    x_dense, A, b, c = _dense_x_star(inst, lam, gamma)

    # x_slabs: the eq.-3 primal candidate
    x_ours = unpack_primal(packed, ev.x_slabs)
    np.testing.assert_allclose(x_ours, x_dense, atol=2e-5)
    # ax: the raw matrix-vector product A x* (pre-rhs)
    np.testing.assert_allclose(np.asarray(ev.ax), A @ x_dense, atol=1e-4)
    # primal decomposition: c'x and (gamma/2)||x||^2
    np.testing.assert_allclose(
        float(ev.primal_linear), c @ x_dense, rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        float(ev.primal_ridge), gamma / 2 * (x_dense ** 2).sum(),
        rtol=1e-4, atol=1e-6,
    )
    # grad: exactly ax - b (the contract distributed reductions rely on)
    grad_dense = A @ x_dense - b
    np.testing.assert_allclose(np.asarray(ev.grad), grad_dense, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(ev.grad), np.asarray(ev.ax) - b, atol=1e-6
    )
    # g: the eq.-2 dual objective, and its internal decomposition identity
    g_dense = c @ x_dense + gamma / 2 * (x_dense ** 2).sum() + lam @ grad_dense
    np.testing.assert_allclose(float(ev.g), g_dense, rtol=1e-5)
    np.testing.assert_allclose(
        float(ev.g),
        float(ev.primal_linear) + float(ev.primal_ridge)
        + float(lam @ np.asarray(ev.grad)),
        rtol=1e-5,
    )


@pytest.mark.parametrize("gamma", [0.05, 1.0])
def test_primal_objective_matches_decomposition(small, gamma):
    """primal_objective(x, gamma) == primal_linear + primal_ridge at x*."""
    inst, packed = small
    obj = MatchingObjective(packed)
    lam = jnp.asarray(
        np.random.default_rng(2).random(obj.dual_dim).astype(np.float32)
    )
    ev = obj.calculate(lam, gamma)
    np.testing.assert_allclose(
        float(obj.primal_objective(ev.x_slabs, gamma)),
        float(ev.primal_linear) + float(ev.primal_ridge),
        rtol=1e-5,
    )


def test_apply_A_and_AT_adjoint(small):
    """<A x, y> == <x, A^T y> over random x, y."""
    inst, packed = small
    obj = MatchingObjective(packed)
    rng = np.random.default_rng(1)
    x_slabs = tuple(
        jnp.asarray(rng.normal(size=b.cost.shape).astype(np.float32)) * b.mask
        for b in packed.buckets
    )
    y = jnp.asarray(rng.normal(size=obj.dual_dim).astype(np.float32))
    lhs = float(jnp.vdot(obj.apply_A(x_slabs), y))
    aty = obj.apply_AT(y)
    rhs = float(sum(jnp.vdot(a, x) for a, x in zip(aty, x_slabs)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_power_iteration_matches_dense_sigma(small):
    inst, packed = small
    A, _, _ = inst.to_dense()
    cols = inst.src * inst.spec.num_destinations + inst.dst
    sig2_dense = np.linalg.svd(A[:, cols], compute_uv=False)[0] ** 2
    sig2 = float(MatchingObjective(packed).power_iteration(jax.random.key(0), 100))
    np.testing.assert_allclose(sig2, sig2_dense, rtol=1e-2)


def test_max_violation(small):
    """max_violation == max(0, Ax - b) computed from the DualEval fields."""
    inst, packed = small
    obj = MatchingObjective(packed)
    ev = obj.calculate(jnp.zeros(obj.dual_dim), 1.0)
    viol = float(obj.max_violation(ev.x_slabs))
    assert viol >= 0.0
    _, b, _ = inst.to_dense()
    np.testing.assert_allclose(
        viol,
        max(0.0, float((np.asarray(ev.ax) - b).max())),
        atol=1e-6,
    )
