"""MatchingObjective vs dense-matrix formulas (eq. 2-4) on small instances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MatchingObjective, project_simplex
from repro.instances import (
    MatchingInstanceSpec,
    bucketize,
    generate_matching_instance,
    unpack_primal,
)


@pytest.fixture(scope="module")
def small():
    spec = MatchingInstanceSpec(
        num_sources=25, num_destinations=7, avg_degree=3.0, num_families=2, seed=21
    )
    inst = generate_matching_instance(spec)
    return inst, bucketize(inst)


def _dense_x_star(inst, lam, gamma):
    """Blockwise closed form (eq. 3) computed densely per source."""
    spec = inst.spec
    J, m = spec.num_destinations, spec.num_families
    A, b, c = inst.to_dense()
    cols = inst.src * J + inst.dst
    z = -(A[:, cols].T @ lam + c[cols]) / gamma
    # per-source simplex projection
    x = np.zeros_like(z)
    for i in np.unique(inst.src):
        rows = np.flatnonzero(inst.src == i)
        zi = z[rows][None, :].astype(np.float32)
        xi = project_simplex(jnp.asarray(zi), jnp.ones_like(jnp.asarray(zi)))
        x[rows] = np.asarray(xi)[0]
    return x, A[:, cols], b, c[cols]


@pytest.mark.parametrize("gamma", [0.05, 1.0, 50.0])
def test_calculate_matches_dense(small, gamma):
    inst, packed = small
    m, J = inst.spec.num_families, inst.spec.num_destinations
    lam = np.random.default_rng(0).random(m * J).astype(np.float32)
    ev = MatchingObjective(packed).calculate(jnp.asarray(lam), gamma)
    x_dense, A, b, c = _dense_x_star(inst, lam, gamma)
    x_ours = unpack_primal(packed, ev.x_slabs)
    np.testing.assert_allclose(x_ours, x_dense, atol=2e-5)
    grad_dense = A @ x_dense - b
    np.testing.assert_allclose(np.asarray(ev.grad), grad_dense, atol=1e-4)
    g_dense = c @ x_dense + gamma / 2 * (x_dense ** 2).sum() + lam @ grad_dense
    np.testing.assert_allclose(float(ev.g), g_dense, rtol=1e-5)


def test_apply_A_and_AT_adjoint(small):
    """<A x, y> == <x, A^T y> over random x, y."""
    inst, packed = small
    obj = MatchingObjective(packed)
    rng = np.random.default_rng(1)
    x_slabs = tuple(
        jnp.asarray(rng.normal(size=b.cost.shape).astype(np.float32)) * b.mask
        for b in packed.buckets
    )
    y = jnp.asarray(rng.normal(size=obj.dual_dim).astype(np.float32))
    lhs = float(jnp.vdot(obj.apply_A(x_slabs), y))
    aty = obj.apply_AT(y)
    rhs = float(sum(jnp.vdot(a, x) for a, x in zip(aty, x_slabs)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_power_iteration_matches_dense_sigma(small):
    inst, packed = small
    A, _, _ = inst.to_dense()
    cols = inst.src * inst.spec.num_destinations + inst.dst
    sig2_dense = np.linalg.svd(A[:, cols], compute_uv=False)[0] ** 2
    sig2 = float(MatchingObjective(packed).power_iteration(jax.random.key(0), 100))
    np.testing.assert_allclose(sig2, sig2_dense, rtol=1e-2)


def test_max_violation(small):
    inst, packed = small
    obj = MatchingObjective(packed)
    ev = obj.calculate(jnp.zeros(obj.dual_dim), 1.0)
    assert float(obj.max_violation(ev.x_slabs)) >= 0.0
