#!/usr/bin/env python
"""Telemetry JSONL validator — CI gate on the metrics export schema.

Validates every record of one or more telemetry JSONL files against
`repro.telemetry.SCHEMA` (each line must be a JSON object with numeric
``ts``, a known ``kind`` and all of that kind's required payload keys).

Usage:
    python tools/check_metrics.py m.jsonl [more.jsonl ...]
    python tools/check_metrics.py --require-kinds ingest,counters m.jsonl

``--require-kinds`` additionally fails unless every listed kind appears at
least once across the validated files — CI uses it to assert the service
dry-run actually exported something, not just an empty-but-valid file.

``--require-bench-dtypes`` fails unless every ``bench`` record carries a
``slab_dtypes`` list of known slab storage dtypes (the mixed-precision
sweep axis benchmarks/run.py stamps into the history record) — CI's
bench-smoke step uses it so the perf-trajectory artifact always says
which dtypes each run swept.
Exits non-zero listing every schema error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# runnable from a repo checkout without an installed package
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.telemetry import SCHEMA, validate_record  # noqa: E402


def _check_bench_dtypes(obj: dict) -> list[str]:
    """Validate a bench record's ``slab_dtypes`` payload field."""
    from repro.instances import SLAB_DTYPES

    dtypes = obj.get("payload", {}).get("slab_dtypes")
    if not isinstance(dtypes, list) or not dtypes:
        return ["bench record missing non-empty 'slab_dtypes' list"]
    unknown = [d for d in dtypes if d not in SLAB_DTYPES]
    if unknown:
        return [f"bench record has unknown slab dtypes {unknown!r} "
                f"(known: {list(SLAB_DTYPES)})"]
    if "float32" not in dtypes:
        return ["bench record 'slab_dtypes' lacks the float32 baseline"]
    return []


def check(
    paths: list[str],
    require_kinds: set[str],
    require_bench_dtypes: bool = False,
) -> list[str]:
    errors: list[str] = []
    seen_kinds: set[str] = set()
    total = 0
    for name in paths:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        n = 0
        for lineno, line in enumerate(p.read_text().splitlines(), start=1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{name}:{lineno}: invalid JSON ({e})")
                continue
            errors.extend(
                f"{name}:{lineno}: {e}" for e in validate_record(obj)
            )
            if isinstance(obj, dict) and obj.get("kind") in SCHEMA:
                seen_kinds.add(obj["kind"])
                if require_bench_dtypes and obj["kind"] == "bench":
                    errors.extend(
                        f"{name}:{lineno}: {e}"
                        for e in _check_bench_dtypes(obj)
                    )
        if n == 0:
            errors.append(f"{name}: no records")
        total += n
    for kind in sorted(require_kinds - seen_kinds):
        errors.append(f"required kind {kind!r} never appeared")
    if not errors:
        print(
            f"check_metrics: {total} record(s) across {len(paths)} file(s), "
            f"kinds: {sorted(seen_kinds)} — OK"
        )
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+", help="telemetry JSONL file(s)")
    ap.add_argument(
        "--require-kinds",
        default="",
        help="comma-separated record kinds that must each appear at least once",
    )
    ap.add_argument(
        "--require-bench-dtypes",
        action="store_true",
        help="every 'bench' record must carry a valid 'slab_dtypes' list "
             "(known dtypes, float32 baseline included)",
    )
    args = ap.parse_args()
    require = {k.strip() for k in args.require_kinds.split(",") if k.strip()}
    unknown = require - set(SCHEMA)
    if unknown:
        print(f"unknown kinds in --require-kinds: {sorted(unknown)}")
        return 2
    errors = check(args.paths, require, args.require_bench_dtypes)
    for e in errors:
        print(e)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
