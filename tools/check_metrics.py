#!/usr/bin/env python
"""Telemetry JSONL validator — CI gate on the metrics export schema.

Validates every record of one or more telemetry JSONL files against
`repro.telemetry.SCHEMA` (each line must be a JSON object with numeric
``ts``, a known ``kind`` and all of that kind's required payload keys).

Usage:
    python tools/check_metrics.py m.jsonl [more.jsonl ...]
    python tools/check_metrics.py --require-kinds ingest,counters m.jsonl

``--require-kinds`` additionally fails unless every listed kind appears at
least once across the validated files — CI uses it to assert the service
dry-run actually exported something, not just an empty-but-valid file.
Exits non-zero listing every schema error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# runnable from a repo checkout without an installed package
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.telemetry import SCHEMA, validate_record  # noqa: E402


def check(paths: list[str], require_kinds: set[str]) -> list[str]:
    errors: list[str] = []
    seen_kinds: set[str] = set()
    total = 0
    for name in paths:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        n = 0
        for lineno, line in enumerate(p.read_text().splitlines(), start=1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{name}:{lineno}: invalid JSON ({e})")
                continue
            errors.extend(
                f"{name}:{lineno}: {e}" for e in validate_record(obj)
            )
            if isinstance(obj, dict) and obj.get("kind") in SCHEMA:
                seen_kinds.add(obj["kind"])
        if n == 0:
            errors.append(f"{name}: no records")
        total += n
    for kind in sorted(require_kinds - seen_kinds):
        errors.append(f"required kind {kind!r} never appeared")
    if not errors:
        print(
            f"check_metrics: {total} record(s) across {len(paths)} file(s), "
            f"kinds: {sorted(seen_kinds)} — OK"
        )
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+", help="telemetry JSONL file(s)")
    ap.add_argument(
        "--require-kinds",
        default="",
        help="comma-separated record kinds that must each appear at least once",
    )
    args = ap.parse_args()
    require = {k.strip() for k in args.require_kinds.split(",") if k.strip()}
    unknown = require - set(SCHEMA)
    if unknown:
        print(f"unknown kinds in --require-kinds: {sorted(unknown)}")
        return 2
    errors = check(args.paths, require)
    for e in errors:
        print(e)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
