#!/usr/bin/env python
"""Dead-link checker for the docs site and README.

Scans markdown files for relative links (`[text](target)`) and verifies each
target exists in the repo.  Anchors (`#section`) are checked against the
target file's headings (GitHub slug rules, simplified).  External links
(http/https/mailto) are ignored — CI must not depend on the network.

Usage: python tools/check_links.py README.md docs/*.md
Exits non-zero listing every dead link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (lowercase, spaces->dashes, drop punctuation)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    return {slugify(h) for h in HEADING.findall(path.read_text())}


def check(files: list[str]) -> list[str]:
    errors = []
    for name in files:
        src = Path(name)
        text = src.read_text()
        for target in LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = (
                src if not path_part else (src.parent / path_part).resolve()
            )
            if not dest.exists():
                errors.append(f"{name}: dead link -> {target}")
                continue
            if anchor and dest.suffix == ".md" and slugify(anchor) not in anchors_of(dest):
                errors.append(f"{name}: dead anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = argv or ["README.md"]
    errors = check(files)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): {len(errors)} dead link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
